"""Vectorized cohort engine vs the sequential reference oracle.

The contract: for any federation, participant mix, and client-size skew,
one vectorized round produces aggregated params matching the sequential
engine within 1e-5 (identical batch shuffles, identical dropout keys,
identical FedAvg weighting — the dummy padding steps are exact no-ops).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.recruitment import BALANCED
from repro.data.pipeline import (
    ArrayDataset,
    ClientDataset,
    build_client_datasets,
    build_cohort_schedule,
    cohort_steps_per_epoch,
)
from repro.data.synth_eicu import CohortConfig, generate_cohort
from repro.federated.cohort import CohortTrainer
from repro.federated.fedavg import aggregate, aggregate_stacked, tree_allclose
from repro.federated.server import FederatedConfig, FederatedServer
from repro.launch.mesh import make_host_mesh
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

SEQ_LEN, FEAT = 6, 38  # short stays keep the GRU scan cheap


def make_client(client_id: int, n: int, rng: np.random.Generator) -> ClientDataset:
    x = rng.normal(size=(n, SEQ_LEN, FEAT)).astype(np.float32)
    y = rng.uniform(0.5, 20.0, size=n).astype(np.float32)
    ds = ArrayDataset(x, y)
    return ClientDataset(client_id=client_id, train=ds, val=ds)


@pytest.fixture(scope="module")
def model():
    cfg = GRUConfig(input_dim=FEAT, hidden_dim=8, num_layers=2)
    return cfg, make_loss_fn(cfg), init_gru(jax.random.key(1), cfg)


def run_engines(clients, params0, loss_fn, **cfg_kwargs):
    opt = AdamW(learning_rate=5e-3, weight_decay=5e-3)
    outs = {}
    for engine in ("sequential", "vectorized"):
        fed = FederatedConfig(engine=engine, **cfg_kwargs)
        outs[engine] = FederatedServer(fed, clients, loss_fn, opt).run(params0)
    return outs["sequential"], outs["vectorized"]


def assert_params_close(a, b, atol=1e-5):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol, rtol=0)


# --------------------------------------------------------------------------
# parity against the sequential oracle
# --------------------------------------------------------------------------

def test_round_parity_16_clients_uneven_sizes(model):
    """The acceptance bar: 16 clients with heavy size skew (so the padded
    schedule is full of masked dummy batches) agree within 1e-5."""
    _, loss_fn, params0 = model
    rng = np.random.default_rng(0)
    sizes = [3, 5, 8, 13, 16, 21, 30, 33, 40, 47, 55, 64, 65, 90, 120, 130]
    clients = [make_client(i, n, rng) for i, n in enumerate(sizes)]
    seq, vec = run_engines(
        clients, params0, loss_fn, rounds=1, local_epochs=2, batch_size=32, seed=0
    )
    assert_params_close(seq.params, vec.params)
    assert seq.total_local_steps == vec.total_local_steps
    np.testing.assert_allclose(
        [r.mean_local_loss for r in seq.history],
        [r.mean_local_loss for r in vec.history],
        atol=1e-5,
    )


def test_multiround_parity_with_participation(model):
    """Across rounds with random 50% participation the engines consume the
    numpy RNG identically, so they select the same participants and stay
    in lockstep."""
    _, loss_fn, params0 = model
    rng = np.random.default_rng(1)
    clients = [make_client(i, int(n), rng) for i, n in enumerate(rng.integers(4, 70, 12))]
    seq, vec = run_engines(
        clients, params0, loss_fn,
        rounds=3, local_epochs=1, batch_size=16, participation_fraction=0.5, seed=7,
    )
    for rs, rv in zip(seq.history, vec.history):
        assert rs.participant_ids == rv.participant_ids
    assert_params_close(seq.params, vec.params)


def test_recruitment_composition(model):
    """Recruitment runs before the engine choice: both engines build the same
    recruited federation and agree on the trained params."""
    _, loss_fn, params0 = model
    cohort = generate_cohort(CohortConfig().scaled(0.02), seed=0)
    clients = build_client_datasets(cohort)
    cfg = GRUConfig()  # the real cohort's 38-feature, 24h shape
    seq, vec = run_engines(
        clients, init_gru(jax.random.key(0), cfg), make_loss_fn(cfg),
        rounds=1, local_epochs=1, recruitment=BALANCED, seed=0,
    )
    assert vec.recruitment is not None
    assert seq.federation_ids.tolist() == vec.federation_ids.tolist()
    assert 0 < len(vec.federation_ids) < len(clients)
    assert_params_close(seq.params, vec.params)


def test_chunked_cohort_matches_unchunked(model):
    """cohort_chunk only bounds memory; the aggregate is unchanged."""
    _, loss_fn, params0 = model
    rng = np.random.default_rng(2)
    clients = [make_client(i, int(n), rng) for i, n in enumerate(rng.integers(4, 50, 10))]
    opt = AdamW(learning_rate=5e-3, weight_decay=5e-3)
    results = []
    for chunk in (None, 3):
        fed = FederatedConfig(
            rounds=1, local_epochs=2, batch_size=16, engine="vectorized",
            cohort_chunk=chunk, seed=0,
        )
        results.append(FederatedServer(fed, clients, loss_fn, opt).run(params0).params)
    assert_params_close(results[0], results[1], atol=1e-6)


def test_shard_map_path_on_host_mesh(model):
    """The shard_map multi-device path degenerates correctly on a 1-device
    data mesh and still matches the plain vmap result."""
    _, loss_fn, params0 = model
    rng = np.random.default_rng(3)
    clients = [make_client(i, 20, rng) for i in range(4)]
    opt = AdamW(learning_rate=5e-3, weight_decay=5e-3)
    outs = []
    for mesh in (None, make_host_mesh()):
        fed = FederatedConfig(
            rounds=1, local_epochs=1, batch_size=16, engine="vectorized", mesh=mesh, seed=0
        )
        outs.append(FederatedServer(fed, clients, loss_fn, opt).run(params0).params)
    assert_params_close(outs[0], outs[1], atol=1e-6)


# --------------------------------------------------------------------------
# schedule + aggregation building blocks
# --------------------------------------------------------------------------

def test_cohort_schedule_shapes_and_masking():
    rng = np.random.default_rng(0)
    data = [
        ArrayDataset(rng.normal(size=(n, 3, 4)).astype(np.float32), np.ones(n, np.float32))
        for n in (5, 16, 33)
    ]
    batch, epochs = 16, 2
    assert cohort_steps_per_epoch([5, 16, 33], batch) == 3
    sched = build_cohort_schedule(data, batch, epochs, rng)
    assert sched.x.shape == (3, 6, 16, 3, 4)
    assert sched.y.shape == (3, 6, 16) and sched.mask.shape == (3, 6, 16)
    # real steps per client = ceil(n/B) per epoch
    np.testing.assert_array_equal(sched.step_valid.sum(axis=1), [2, 2, 6])
    assert sched.real_steps == 10
    # dummy steps carry an all-zero example mask; real steps cover n examples
    np.testing.assert_allclose(sched.mask.sum(axis=(1, 2)), [2 * 5, 2 * 16, 2 * 33])
    assert sched.mask[~sched.step_valid].sum() == 0
    np.testing.assert_array_equal(sched.weights, [5, 16, 33])


def test_schedule_consumes_rng_like_sequential():
    """Client-major permutation order: a schedule built from the same seed
    yields the same batches the per-client iterator would."""
    n = 20
    x = np.arange(n * 2, dtype=np.float32).reshape(n, 2)
    y = np.arange(n, dtype=np.float32)
    ds = ArrayDataset(x, y)
    sched = build_cohort_schedule([ds, ds], 8, 1, np.random.default_rng(5))
    rng = np.random.default_rng(5)
    for c in range(2):
        for t, (xb, yb, mb) in enumerate(ds.padded_batches(8, rng)):
            np.testing.assert_array_equal(sched.x[c, t], xb)
            np.testing.assert_array_equal(sched.y[c, t], yb)
            np.testing.assert_array_equal(sched.mask[c, t], mb)


def test_aggregate_stacked_matches_listwise():
    rng = np.random.default_rng(4)
    trees = [
        {"w": rng.normal(size=(3, 2)).astype(np.float32), "b": rng.normal(size=4).astype(np.float32)}
        for _ in range(5)
    ]
    weights = rng.uniform(1, 100, 5)
    stacked = jax.tree.map(lambda *ls: np.stack(ls), *trees)
    assert tree_allclose(
        aggregate_stacked(stacked, weights), aggregate(trees, weights), atol=1e-6
    )


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        FederatedConfig(engine="warp-drive")


def test_cohort_trainer_key_count_mismatch(model):
    _, loss_fn, params0 = model
    rng = np.random.default_rng(6)
    trainer = CohortTrainer(
        loss_fn, AdamW(), batch_size=16, local_epochs=1
    )
    clients = [make_client(0, 8, rng)]
    with pytest.raises(ValueError):
        trainer.train_cohort(params0, clients, rng, [])


def test_cohort_chunk_zero_rejected(model):
    """chunk=0 must raise, not silently disable chunking (falsy-0 trap)."""
    _, loss_fn, params0 = model
    rng = np.random.default_rng(6)
    trainer = CohortTrainer(loss_fn, AdamW(), batch_size=16, local_epochs=1, cohort_chunk=0)
    clients = [make_client(0, 8, rng)]
    with pytest.raises(ValueError, match="cohort_chunk"):
        trainer.train_cohort(params0, clients, rng, [jax.random.key(0)])


def test_single_compilation_across_rounds(model):
    """The server pins steps_per_epoch to the federation-wide max, so rounds
    with different (randomly sampled) participant mixes reuse one compiled
    round function instead of retracing on every new cohort shape."""
    _, loss_fn, params0 = model
    rng = np.random.default_rng(8)
    # heavy size skew: per-cohort max steps would differ round to round
    sizes = [4, 6, 9, 30, 60, 90, 110, 140]
    clients = [make_client(i, n, rng) for i, n in enumerate(sizes)]
    opt = AdamW(learning_rate=5e-3, weight_decay=5e-3)
    fed = FederatedConfig(
        rounds=4, local_epochs=1, batch_size=32, engine="vectorized",
        participation_fraction=0.5, seed=3,
    )
    server = FederatedServer(fed, clients, loss_fn, opt)
    out = server.run(params0)
    mixes = {tuple(sorted(r.participant_ids)) for r in out.history}
    assert len(mixes) > 1  # the rounds really did sample different cohorts
    assert server.cohort_trainer._round._cache_size() == 1


def test_engine_default_is_vectorized():
    assert FederatedConfig().engine == "vectorized"
    assert dataclasses.replace(FederatedConfig(), engine="sequential").engine == "sequential"
