"""Decode path == full forward, per architecture family.

The strongest correctness property of the serving stack: stepping the
decode cache token by token reproduces the full-sequence forward logits.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ArchType
from repro.models.zoo import Model

B, S = 2, 12
RNG = np.random.default_rng(1)


def decode_all(model, params, toks, cache, start_pos=0):
    outs = []
    pos = start_pos
    for t in range(toks.shape[1]):
        lg, cache = model.decode_step(params, toks[:, t : t + 1], cache, jnp.int32(pos))
        outs.append(lg)
        pos += 1
    return jnp.stack(outs, axis=1), cache


@pytest.mark.parametrize(
    "arch", ["qwen3-1.7b", "smollm-135m", "yi-9b", "nemotron-4-15b", "mamba2-130m", "zamba2-7b"]
)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full = model.forward_logits(params, {"tokens": toks, "labels": toks})
    dec, _ = decode_all(model, params, toks, model.init_cache(B, S))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "llama4-scout-17b-a16e"])
def test_moe_decode_matches_forward_high_capacity(arch):
    """With generous capacity (no token drops) MoE decode == forward; at
    tight capacity they may differ only through dropped tokens."""
    cfg0 = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg0, moe=dataclasses.replace(cfg0.moe, capacity_factor=8.0))
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full = model.forward_logits(params, {"tokens": toks, "labels": toks})
    dec, _ = decode_all(model, params, toks, model.init_cache(B, S))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4, rtol=1e-4)


def test_encdec_decode_matches_forward():
    cfg = get_config("seamless-m4t-large-v2").reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    src = jnp.asarray(RNG.normal(size=(B, 8, cfg.d_model)), jnp.float32)
    full = model.forward_logits(params, {"tokens": toks, "labels": toks, "src_embeds": src})
    cache = model.init_cache(B, S)
    cache = model.encode_for_decode(params, src, cache)
    dec, _ = decode_all(model, params, toks, cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4, rtol=1e-4)


def test_vlm_decode_with_patch_prefill():
    cfg = get_config("internvl2-26b").reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    F = cfg.num_frontend_tokens
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    patches = jnp.asarray(RNG.normal(size=(B, F, cfg.d_model)), jnp.float32)
    full = model.forward_logits(params, {"tokens": toks, "labels": toks, "patch_embeds": patches})
    cache = model.init_cache(B, F + S)
    pos = 0
    for i in range(F):
        _, cache = model.decode_step(
            params, None, cache, jnp.int32(pos), token_embeds=patches[:, i : i + 1]
        )
        pos += 1
    dec, _ = decode_all(model, params, toks, cache, start_pos=pos)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4, rtol=1e-4)


def test_sliding_window_decode_forgets_far_context():
    """Long-context variant: with window W, tokens farther than W behind the
    query must not influence the logits (the cache is a ring buffer)."""
    base = get_config("qwen3-1.7b").reduced()
    cfg = dataclasses.replace(base, sliding_window=4)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    n = 10
    toks_a = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, n)), jnp.int32)
    toks_b = toks_a.at[:, 0].set((toks_a[0, 0] + 7) % cfg.vocab_size)  # differ at pos 0 only

    def last_logits(toks):
        cache = model.init_cache(1, n)
        out = None
        for t in range(n):
            out, cache = model.decode_step(params, toks[:, t : t + 1], cache, jnp.int32(t))
        return out

    la, lb = last_logits(toks_a), last_logits(toks_b)
    # position 0 is far outside the window of the final step
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)


def test_sliding_window_prefill_matches_decode():
    base = get_config("smollm-135m").reduced()
    cfg = dataclasses.replace(base, sliding_window=4)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full = model.forward_logits(params, {"tokens": toks, "labels": toks})
    dec, _ = decode_all(model, params, toks, model.init_cache(B, S))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4, rtol=1e-4)
