"""Shared test plumbing.

If real ``hypothesis`` is installed (the ``test`` extra in pyproject.toml;
CI always has it) the property tests use it unchanged.  In minimal
environments the deterministic fallback in ``_hypothesis_fallback`` is
registered under the ``hypothesis`` module names before test collection so
``from hypothesis import given, ...`` keeps working.
"""

from __future__ import annotations

import sys
import types

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_fallback as _fallback  # tests/ is on sys.path via pytest rootdir insertion

    module = types.ModuleType("hypothesis")
    module.given = _fallback.given
    module.settings = _fallback.settings
    module.strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists", "tuples"):
        setattr(module.strategies, name, getattr(_fallback, name))
    sys.modules["hypothesis"] = module
    sys.modules["hypothesis.strategies"] = module.strategies
