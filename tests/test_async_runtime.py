"""The async federation runtime: scheduler, latency/staleness, parity, replay.

The acceptance bar: ``"fedbuff:K"`` with K = all participants and a
zero-spread latency model reproduces synchronous flat FedAvg to 1e-5
across both engines and both staging modes (the parity gate), and seeded
runs replay bit-identically.  Around it: virtual-clock event ordering,
latency/dropout registry round-trips with did-you-mean suggestions,
property tests for the polynomial staleness weights, straggler/dropout
semantics, and the new RoundRecord timing fields.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import ArrayDataset, ClientDataset
from repro.federated import (
    AsyncFederation,
    AsyncFederationConfig,
    Federation,
    FederationConfig,
    available_runtime_models,
    chain_split_keys,
    polynomial_staleness_weight,
    resolve_aggregator,
    resolve_dropout,
    resolve_latency,
    resolve_recruitment,
    staleness_weights,
)
from repro.federated.runtime import (
    AsyncAggregator,
    BernoulliDropout,
    FedBuffAggregator,
    HierarchicalAsyncAggregator,
    LognormalLatency,
    VirtualScheduler,
)
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

SEQ_LEN, FEAT = 3, 5


def make_clients(count, rng, lo=2, hi=18):
    clients = []
    for i, n in enumerate(rng.integers(lo, hi, count)):
        x = rng.normal(size=(int(n), SEQ_LEN, FEAT)).astype(np.float32)
        y = rng.uniform(0.5, 20.0, size=int(n)).astype(np.float32)
        ds = ArrayDataset(x, y)
        clients.append(ClientDataset(client_id=i, train=ds, val=ds))
    return clients


@pytest.fixture(scope="module")
def setup():
    cfg = GRUConfig(input_dim=FEAT, hidden_dim=2, num_layers=1)
    clients = make_clients(10, np.random.default_rng(0))
    return clients, make_loss_fn(cfg), init_gru(jax.random.key(1), cfg)


def opt():
    return AdamW(learning_rate=5e-3, weight_decay=5e-3)


def assert_params_close(a, b, atol=1e-5):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol, rtol=0)


# --------------------------------------------------------------------------
# virtual-clock scheduler
# --------------------------------------------------------------------------

def test_scheduler_orders_by_time_then_seq():
    sched = VirtualScheduler(seed=0)
    sched.schedule(2.0, "b")
    sched.schedule(1.0, "a")
    sched.schedule(2.0, "c")       # same time as "b", scheduled later
    sched.schedule(1.0, "a2")
    order = [sched.pop().kind for _ in range(4)]
    assert order == ["a", "a2", "b", "c"]  # time first, insertion seq on ties
    assert sched.now == 2.0
    assert sched.processed == 4
    assert sched.empty


def test_scheduler_clock_never_runs_backwards():
    sched = VirtualScheduler(seed=0)
    sched.schedule(5.0, "x")
    sched.pop()
    with pytest.raises(ValueError, match="past"):
        sched.schedule(4.0, "late")
    with pytest.raises(ValueError, match="delay"):
        sched.after(-1.0, "neg")
    with pytest.raises(ValueError, match="finite"):
        sched.schedule(float("nan"), "nan")
    with pytest.raises(IndexError):
        sched.pop()
    # scheduling exactly at "now" is allowed (flush-at-event-boundary)
    ev = sched.schedule(5.0, "now")
    assert ev.time == 5.0 and sched.pop().kind == "now"


def test_scheduler_replays_identically():
    def drive(seed):
        sched = VirtualScheduler(seed=seed)
        trace = []
        for i in range(5):
            sched.after(float(sched.rng.exponential()), f"e{i}")
        while not sched.empty:
            ev = sched.pop()
            trace.append((ev.time, ev.seq, ev.kind))
        return trace

    assert drive(7) == drive(7)
    assert drive(7) != drive(8)  # and the seed actually matters


# --------------------------------------------------------------------------
# latency / dropout registries
# --------------------------------------------------------------------------

def test_latency_registry_round_trips():
    assert resolve_latency("constant").seconds == 1.0
    assert resolve_latency("constant:2.5").seconds == 2.5
    assert resolve_latency("lognormal:0.7").sigma == 0.7
    assert resolve_latency("lognormal:0.7,2.0").median == 2.0
    assert resolve_latency("pareto:1.1").alpha == 1.1
    assert resolve_latency("trace:0.02,0.5").per_sample == 0.02
    model = LognormalLatency(sigma=0.3)
    assert resolve_latency(model) is model
    names = available_runtime_models()
    assert set(names["latency"]) >= {"constant", "lognormal", "pareto", "trace"}
    assert set(names["dropout"]) >= {"never", "bernoulli"}


def test_latency_model_validation():
    with pytest.raises(ValueError, match="seconds"):
        resolve_latency("constant:0")
    with pytest.raises(ValueError, match="sigma"):
        resolve_latency("lognormal:-1")
    with pytest.raises(ValueError, match="alpha"):
        resolve_latency("pareto:0")
    with pytest.raises(ValueError, match="per_sample"):
        resolve_latency("trace:-0.1")
    with pytest.raises(ValueError, match="probability"):
        resolve_dropout("bernoulli:1.5")


def test_latency_semantics():
    rng = np.random.default_rng(0)
    const = resolve_latency("constant:3.0")
    assert const.zero_spread
    assert const.sample(0, 100, rng) == const.sample(1, 5, rng) == 3.0
    # trace: deterministic, proportional to the client's local sample count
    trace = resolve_latency("trace:0.1,1.0")
    assert trace.sample(0, 10, rng) == pytest.approx(2.0)
    assert trace.sample(0, 40, rng) == pytest.approx(5.0)
    # persistent rates: a client's speed is stable across dispatches
    slowfast = resolve_latency("pareto:1.5")
    first = [slowfast.sample(c, 10, rng) for c in range(20)]
    again = [slowfast.sample(c, 10, rng) for c in range(20)]
    assert first == again
    assert len(set(first)) > 1  # and there is real spread across clients
    # lognormal:0 degenerates to the constant model
    assert resolve_latency("lognormal:0.0").zero_spread


def test_dropout_models():
    rng = np.random.default_rng(0)
    assert not resolve_dropout("never").drops(0, rng)
    always = resolve_dropout("bernoulli:1.0")
    assert all(always.drops(c, rng) for c in range(10))
    # bare float shorthand
    half = resolve_dropout(0.5)
    assert isinstance(half, BernoulliDropout) and half.p == 0.5
    hits = sum(half.drops(0, rng) for _ in range(400))
    assert 120 < hits < 280


def test_unknown_spec_gets_did_you_mean_suggestion():
    """Satellite: registry errors suggest the nearest known spec name."""
    with pytest.raises(ValueError, match="did you mean 'nu-greedy'"):
        resolve_recruitment("nugreedy")
    with pytest.raises(ValueError, match="did you mean 'lognormal'"):
        resolve_latency("lognormel:0.5")
    with pytest.raises(ValueError, match="did you mean 'fedbuff'"):
        resolve_aggregator("fedbuf:8")
    # no near-miss: no suggestion, but the known names still print
    with pytest.raises(ValueError, match=r"unknown latency policy 'xyzzy'; choose"):
        resolve_latency("xyzzy")


# --------------------------------------------------------------------------
# staleness weights (property tests)
# --------------------------------------------------------------------------

@given(
    s=st.floats(min_value=0.0, max_value=50.0),
    a=st.floats(min_value=0.0, max_value=4.0),
)
@settings(max_examples=25, deadline=None)
def test_polynomial_weight_properties(s, a):
    w = polynomial_staleness_weight(s, a)
    assert 0.0 < w <= 1.0
    assert polynomial_staleness_weight(0.0, a) == 1.0
    # monotone non-increasing in staleness
    assert polynomial_staleness_weight(s + 1.0, a) <= w
    # exponent 0 disables the discount entirely
    assert polynomial_staleness_weight(s, 0.0) == 1.0


@given(
    sizes=st.lists(st.integers(min_value=1, max_value=500), min_size=1, max_size=12),
    a=st.floats(min_value=0.0, max_value=3.0),
)
@settings(max_examples=25, deadline=None)
def test_staleness_weights_normalize(sizes, a):
    stale = [i % 5 for i in range(len(sizes))]
    w = staleness_weights(sizes, stale, a)
    assert w.shape == (len(sizes),)
    assert np.all(w > 0)
    assert np.isclose(w.sum(), 1.0)
    # zero staleness everywhere reduces to plain sample weighting
    flat = staleness_weights(sizes, np.zeros(len(sizes)), a)
    np.testing.assert_allclose(flat, np.asarray(sizes) / np.sum(sizes))


def test_staleness_validation():
    with pytest.raises(ValueError, match="exponent"):
        polynomial_staleness_weight(1.0, -0.5)
    with pytest.raises(ValueError, match="staleness"):
        polynomial_staleness_weight(-1.0, 0.5)
    with pytest.raises(ValueError, match="sample sizes"):
        staleness_weights([0, 0], [0, 0], 0.5)


# --------------------------------------------------------------------------
# the parity gate: fedbuff at full buffer + zero spread == sync FedAvg
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "engine,staging",
    [
        ("vectorized", "resident"),
        ("vectorized", "rebuild"),
        ("sequential", "resident"),
        ("sequential", "rebuild"),
    ],
)
def test_fedbuff_full_buffer_matches_sync_fedavg(setup, engine, staging):
    """K = all participants + zero latency spread: every update has
    staleness 0 and anchors at the current params, so each flush *is* a
    flat FedAvg round — 1e-5 against the synchronous facade, both engines,
    both staging modes."""
    clients, loss_fn, params0 = setup
    base = dict(rounds=2, local_epochs=1, batch_size=4, seed=0, engine=engine, staging=staging)
    sync = Federation(
        FederationConfig(**base, recruitment="all", selection="uniform", aggregator="fedavg"),
        clients, loss_fn, opt(),
    ).run(params0)
    asyn = AsyncFederation(
        AsyncFederationConfig(
            **base, recruitment="all", aggregator=f"fedbuff:{len(clients)}",
            latency="constant",
        ),
        clients, loss_fn, opt(),
    ).run(params0)
    assert sync.federation_ids.tolist() == asyn.federation_ids.tolist()
    for rs, ra in zip(sync.history, asyn.history):
        assert rs.participant_ids == ra.participant_ids
        assert ra.staleness == 0.0
    assert_params_close(sync.params, asyn.params)
    np.testing.assert_allclose(
        [r.mean_local_loss for r in sync.history],
        [r.mean_local_loss for r in asyn.history],
        atol=1e-5,
    )


def test_fedbuff_parity_under_auto_mesh(setup):
    """The parity gate through the shard_map client axis: under CI's
    4-host-device leg every singleton task pads to the mesh width and
    reduces through the cross-shard psum; on one device 'auto' degenerates
    to plain vmap — same numbers either way."""
    clients, loss_fn, params0 = setup
    base = dict(rounds=2, local_epochs=1, batch_size=4, seed=0, engine="vectorized")
    sync = Federation(
        FederationConfig(**base, aggregator="fedavg", mesh="auto"),
        clients, loss_fn, opt(),
    ).run(params0)
    asyn = AsyncFederation(
        AsyncFederationConfig(
            **base, aggregator=f"fedbuff:{len(clients)}", latency="constant",
            mesh="auto",
        ),
        clients, loss_fn, opt(),
    ).run(params0)
    assert_params_close(sync.params, asyn.params)


def test_chain_split_singletons_match_batched_chain():
    """The key-stream argument under the parity gate: n chained 1-splits
    are bitwise the one n-split chain the sync vectorized round draws."""
    key = jax.random.key(0)
    _, batched = chain_split_keys(key, 6)
    singles, k = [], key
    for _ in range(6):
        k, sub = chain_split_keys(k, 1)
        singles.append(np.asarray(sub[0]))
    np.testing.assert_array_equal(np.stack(singles), np.asarray(batched))


@pytest.mark.parametrize("engine", ["vectorized", "sequential"])
def test_hierarchical_async_single_region_matches_sync(setup, engine):
    """R = 1: the whole federation is one region, each combine lands a
    full-weight, zero-staleness regional FedAvg — synchronous flat FedAvg
    on the event loop."""
    clients, loss_fn, params0 = setup
    base = dict(rounds=2, local_epochs=1, batch_size=4, seed=0, engine=engine)
    sync = Federation(
        FederationConfig(**base, aggregator="fedavg"), clients, loss_fn, opt()
    ).run(params0)
    asyn = AsyncFederation(
        AsyncFederationConfig(**base, aggregator="hierarchical-async:1", latency="constant"),
        clients, loss_fn, opt(),
    ).run(params0)
    assert_params_close(sync.params, asyn.params)
    np.testing.assert_allclose(
        [r.mean_local_loss for r in sync.history],
        [r.mean_local_loss for r in asyn.history],
        atol=1e-5,
    )


# --------------------------------------------------------------------------
# seeded replay determinism
# --------------------------------------------------------------------------

def test_seeded_replay_is_bit_identical(setup):
    """Same seed -> same timeline, same flushes, same parameters, bitwise —
    the property that makes the simulator a controlled instrument."""
    clients, loss_fn, params0 = setup

    def run():
        fed = AsyncFederation(
            AsyncFederationConfig(
                rounds=4, local_epochs=1, batch_size=4, seed=3,
                aggregator="fedbuff:3,0.5", latency="pareto:1.2", dropout=0.2,
            ),
            clients, loss_fn, opt(),
        )
        out = fed.run(params0)
        return fed, out

    fed1, out1 = run()
    fed2, out2 = run()
    assert [
        (r.virtual_time, r.participant_ids, r.staleness, r.mean_local_loss)
        for r in out1.history
    ] == [
        (r.virtual_time, r.participant_ids, r.staleness, r.mean_local_loss)
        for r in out2.history
    ]
    for a, b in zip(jax.tree.leaves(out1.params), jax.tree.leaves(out2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s1, s2 = fed1.last_run_stats, fed2.last_run_stats
    assert s1 == s2
    assert s1["dropped"] > 0  # the scenario actually exercised dropout
    # a different seed produces a genuinely different timeline
    fed3 = AsyncFederation(
        AsyncFederationConfig(
            rounds=4, local_epochs=1, batch_size=4, seed=4,
            aggregator="fedbuff:3,0.5", latency="pareto:1.2", dropout=0.2,
        ),
        clients, loss_fn, opt(),
    )
    out3 = fed3.run(params0)
    assert [r.virtual_time for r in out3.history] != [
        r.virtual_time for r in out1.history
    ]


# --------------------------------------------------------------------------
# async semantics: staleness, stragglers, dropout, degenerate buffers
# --------------------------------------------------------------------------

def test_partial_buffer_accrues_staleness(setup):
    """fedbuff with a small buffer under latency spread: in-flight tasks
    anchor at old versions, so later flushes carry staleness > 0 and the
    virtual clock advances monotonically."""
    clients, loss_fn, params0 = setup
    out = AsyncFederation(
        AsyncFederationConfig(
            rounds=5, local_epochs=1, batch_size=4, seed=0,
            aggregator="fedbuff:3", latency="lognormal:0.8",
        ),
        clients, loss_fn, opt(),
    ).run(params0)
    assert len(out.history) == 5
    times = [r.virtual_time for r in out.history]
    assert times == sorted(times) and times[0] > 0
    assert all(r.staleness >= 0 for r in out.history)
    assert max(r.staleness for r in out.history) > 0
    assert all(np.isfinite(r.mean_local_loss) for r in out.history)
    summary = out.summary()
    assert summary["virtual_time"] == times[-1]
    assert summary["mean_staleness"] > 0


def test_trace_latency_flushes_small_clients_first(setup):
    """Under size-proportional latency with a one-update buffer, the first
    flush must contain exactly the smallest client — the straggler effect
    the recruitment trade-off is about."""
    clients, loss_fn, params0 = setup
    out = AsyncFederation(
        AsyncFederationConfig(
            rounds=3, local_epochs=1, batch_size=4, seed=0,
            aggregator="fedbuff:1", latency="trace:1.0,0.0",
        ),
        clients, loss_fn, opt(),
    ).run(params0)
    # A flush lands at the next event boundary, so every client tied at the
    # minimum size completes into the first flush together.
    min_n = min(c.n_train for c in clients)
    smallest = sorted(c.client_id for c in clients if c.n_train == min_n)
    assert out.history[0].participant_ids == smallest
    assert out.history[0].virtual_time == pytest.approx(min_n)


def test_total_dropout_terminates_at_time_ceiling(setup):
    """dropout=1: no update ever reaches the server; the virtual-time
    ceiling stops the retry loop, and the params come back untouched."""
    clients, loss_fn, params0 = setup
    fed = AsyncFederation(
        AsyncFederationConfig(
            rounds=3, local_epochs=1, batch_size=4, seed=0,
            aggregator="fedbuff:2", latency="constant", dropout=1.0,
            max_virtual_time=25.0,
        ),
        clients, loss_fn, opt(),
    )
    out = fed.run(params0)
    assert out.history == []
    assert fed.last_run_stats["flushes"] == 0
    assert fed.last_run_stats["dropped"] > 0
    assert fed.last_run_stats["virtual_time"] <= 25.0
    for a, b in zip(jax.tree.leaves(out.params), jax.tree.leaves(params0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_total_dropout_without_ceiling_raises(setup):
    """dropout=1 and no virtual-time ceiling: the runtime must refuse to
    spin forever — a sustained drought of dropped tasks is a loud error."""
    clients, loss_fn, params0 = setup
    fed = AsyncFederation(
        AsyncFederationConfig(
            rounds=3, local_epochs=1, batch_size=4, seed=0,
            aggregator="fedbuff:2", latency="constant", dropout=1.0,
        ),
        clients, loss_fn, opt(),
    )
    with pytest.raises(RuntimeError, match="dropped"):
        fed.run(params0)


def test_fractional_fedbuff_buffer_resolves_against_federation(setup):
    """'fedbuff:0.25' sizes the buffer as a fraction of the federation's
    tasks once recruitment has run — same int-count/float-fraction grammar
    as the selection specs."""
    clients, loss_fn, params0 = setup
    agg = resolve_aggregator("fedbuff:0.5")
    assert agg.buffer_fraction == 0.5
    agg.prepare(10)
    assert agg.buffer_size == 5
    resolve_aggregator("fedbuff:1.0").prepare(7)  # 1.0 = whole federation
    assert resolve_aggregator("fedbuff:8").buffer_fraction is None
    with pytest.raises(ValueError, match="fractional"):
        resolve_aggregator("fedbuff:1.5")
    # "fedbuff:1.0" + zero spread is the parity configuration by spec alone
    sync = Federation(
        FederationConfig(rounds=1, local_epochs=1, batch_size=4, aggregator="fedavg"),
        clients, loss_fn, opt(),
    ).run(params0)
    asyn = AsyncFederation(
        AsyncFederationConfig(
            rounds=1, local_epochs=1, batch_size=4,
            aggregator="fedbuff:1.0", latency="constant",
        ),
        clients, loss_fn, opt(),
    ).run(params0)
    assert_params_close(sync.params, asyn.params)


def test_oversized_buffer_force_flushes(setup):
    """fedbuff:K with K > federation size cannot fill its buffer; the
    runtime force-flushes once every task has reported instead of
    deadlocking — the semi-synchronous degenerate case."""
    clients, loss_fn, params0 = setup
    fed = AsyncFederation(
        AsyncFederationConfig(
            rounds=2, local_epochs=1, batch_size=4, seed=0,
            aggregator="fedbuff:99", latency="lognormal:0.5",
        ),
        clients, loss_fn, opt(),
    )
    out = fed.run(params0)
    assert len(out.history) == 2
    assert fed.last_run_stats["forced_flushes"] == 2
    # every member reported into each forced flush
    assert out.history[0].participant_ids == sorted(c.client_id for c in clients)


def test_concurrency_cap_refills_without_starvation(setup):
    """M_max semantics: a completion funds the next not-yet-trained task,
    so a cap below the federation size still cycles through every client
    and can fill a buffer larger than the cap without forced flushes."""
    clients, loss_fn, params0 = setup
    fed = AsyncFederation(
        AsyncFederationConfig(
            rounds=3, local_epochs=1, batch_size=4, seed=0,
            aggregator="fedbuff:4", latency="lognormal:0.5", concurrency=3,
        ),
        clients, loss_fn, opt(),
    )
    out = fed.run(params0)
    assert len(out.history) == 3
    # the buffer (4) exceeds the cap (3): only slot refill on completion
    # can fill it, so no flush may fall back to the forced path
    assert fed.last_run_stats["forced_flushes"] == 0
    assert all(len(r.participant_ids) >= 4 for r in out.history)
    # and the cap must not starve the tail of the task list: more distinct
    # clients train than could ever fit in 3 concurrent slots
    seen = {c for r in out.history for c in r.participant_ids}
    assert len(seen) > 3


def test_hierarchical_async_regions(setup):
    clients, loss_fn, params0 = setup
    agg = HierarchicalAsyncAggregator(num_regions=3)
    groups = agg.task_groups(np.arange(10))
    assert len(groups) == 3
    np.testing.assert_array_equal(np.concatenate(groups), np.arange(10))
    out = AsyncFederation(
        AsyncFederationConfig(
            rounds=4, local_epochs=1, batch_size=4, seed=0,
            aggregator="hierarchical-async:3", latency="lognormal:0.8",
        ),
        clients, loss_fn, opt(),
    ).run(params0)
    assert len(out.history) == 4
    # each flush is one region's completion: a strict subset of the federation
    assert all(
        0 < len(r.participant_ids) < len(clients) for r in out.history
    )
    assert max(r.staleness for r in out.history) > 0


# --------------------------------------------------------------------------
# facade wiring and validation
# --------------------------------------------------------------------------

def test_sync_federation_rejects_buffered_aggregators(setup):
    clients, loss_fn, _ = setup
    with pytest.raises(ValueError, match="AsyncFederation"):
        Federation(
            FederationConfig(aggregator="fedbuff:4"), clients, loss_fn, opt()
        )


def test_async_federation_rejects_sync_aggregators(setup):
    clients, loss_fn, _ = setup
    with pytest.raises(ValueError, match="buffered aggregator"):
        AsyncFederation(
            AsyncFederationConfig(aggregator="fedavg"), clients, loss_fn, opt()
        )
    with pytest.raises(TypeError, match="AsyncFederationConfig"):
        AsyncFederation(FederationConfig(), clients, loss_fn, opt())


def test_async_config_validation():
    with pytest.raises(ValueError, match="rounds"):
        AsyncFederationConfig(rounds=0)
    with pytest.raises(ValueError, match="concurrency"):
        AsyncFederationConfig(concurrency=0)
    with pytest.raises(ValueError, match="max_virtual_time"):
        AsyncFederationConfig(max_virtual_time=-1.0)
    with pytest.raises(ValueError, match="buffer_size"):
        FedBuffAggregator(buffer_size=0)
    with pytest.raises(ValueError, match="region"):
        HierarchicalAsyncAggregator(num_regions=0)


def test_bad_task_groups_rejected(setup):
    clients, loss_fn, params0 = setup

    class Lossy(FedBuffAggregator):
        def task_groups(self, federation_ids):
            return [np.asarray(federation_ids)[:-1]]  # drops one member

    fed = AsyncFederation(
        AsyncFederationConfig(rounds=1, local_epochs=1, batch_size=4, aggregator=Lossy(2)),
        clients, loss_fn, opt(),
    )
    with pytest.raises(ValueError, match="partition"):
        fed.run(params0)


def test_custom_async_aggregator_instance(setup):
    """A user-defined buffered aggregator passed as an instance: flush on
    every completion, plain unweighted delta averaging."""
    clients, loss_fn, params0 = setup

    class EveryCompletion(AsyncAggregator):
        def ready(self, buffered):
            return buffered >= 1

        def combine(self, params, updates, version, total_weight):
            coeff = 1.0 / max(len(updates), 1)
            new = params
            for u in updates:
                new = jax.tree.map(
                    lambda p, a, b: p + coeff * (a - b), new, u.params, u.anchor
                )
            return new

    out = AsyncFederation(
        AsyncFederationConfig(
            rounds=3, local_epochs=1, batch_size=4, aggregator=EveryCompletion(),
            latency="lognormal:0.4",
        ),
        clients, loss_fn, opt(),
    ).run(params0)
    assert len(out.history) == 3
    assert all(len(r.participant_ids) == 1 for r in out.history)


def test_round_record_timing_fields(setup):
    """Satellite: round_time_s everywhere; virtual_time/staleness are
    async-only; summary() totals all three."""
    clients, loss_fn, params0 = setup
    sync = Federation(
        FederationConfig(rounds=2, local_epochs=1, batch_size=4), clients, loss_fn, opt()
    ).run(params0)
    for r in sync.history:
        assert r.round_time_s == r.wall_time_s >= 0
        assert r.virtual_time is None and r.staleness is None
    s = sync.summary()
    assert s["total_round_time_s"] == pytest.approx(
        sum(r.wall_time_s for r in sync.history)
    )
    assert s["virtual_time"] is None and s["mean_staleness"] is None

    asyn = AsyncFederation(
        AsyncFederationConfig(
            rounds=2, local_epochs=1, batch_size=4, aggregator="fedbuff:4",
            latency="lognormal:0.5",
        ),
        clients, loss_fn, opt(),
    ).run(params0)
    a = asyn.summary()
    assert a["virtual_time"] == asyn.history[-1].virtual_time > 0
    assert a["mean_staleness"] is not None
    assert a["total_round_time_s"] >= 0
    n_tensors = len(jax.tree.leaves(params0))
    for r in asyn.history:
        assert r.params_down == r.params_up == len(r.participant_ids) * n_tensors


def test_recruitment_composes_with_async_runtime(setup):
    """nu-greedy recruitment runs before the event loop, identically to the
    sync facade: only recruited clients ever appear in any flush."""
    clients, loss_fn, params0 = setup
    sync_ids, _ = Federation(
        FederationConfig(recruitment="nu-greedy"), clients, loss_fn, opt()
    ).build_federation()
    asyn = AsyncFederation(
        AsyncFederationConfig(
            rounds=3, local_epochs=1, batch_size=4, recruitment="nu-greedy",
            aggregator="fedbuff:2", latency="pareto:1.5",
        ),
        clients, loss_fn, opt(),
    )
    out = asyn.run(params0)
    assert out.federation_ids.tolist() == sync_ids.tolist()
    fed = set(sync_ids.tolist())
    for r in out.history:
        assert set(r.participant_ids) <= fed
