"""Synthetic eICU cohort generator tests (the simulated data gate)."""

import numpy as np

from repro.core.histogram import l1_divergence, target_histogram
from repro.data.pipeline import build_client_datasets, global_dataset
from repro.data.synth_eicu import Cohort, CohortConfig, generate_cohort

SMALL = CohortConfig().scaled(0.05)


def test_cohort_shapes_and_splits():
    c = generate_cohort(SMALL, seed=0)
    n = SMALL.total_stays
    assert c.x_temporal.shape == (n, 24, 20)
    assert c.x_static.shape == (n, 18)
    assert c.y.shape == (n,)
    # split fractions match the paper's 62,375 / 13,376 / 13,376
    fr_train = (c.split == Cohort.TRAIN).mean()
    assert abs(fr_train - 0.6998) < 0.01
    assert (c.split == Cohort.VAL).sum() > 0 and (c.split == Cohort.TEST).sum() > 0


def test_los_statistics_match_paper():
    c = generate_cohort(CohortConfig().scaled(0.3), seed=1)
    # paper: mean 3.69, median 2.27 (global); tolerate sampling noise
    assert abs(float(np.mean(c.y)) - 3.69) < 0.45
    assert abs(float(np.median(c.y)) - 2.27) < 0.3
    assert np.all(c.y > 0)


def test_hospitals_are_non_iid():
    c = generate_cohort(SMALL, seed=2)
    global_hist = target_histogram(c.y)
    divs = []
    for h in range(c.num_hospitals):
        y_h = c.y[c.hospital_id == h]
        if len(y_h) < 20:
            continue
        divs.append(l1_divergence(global_hist, target_histogram(y_h)))
    divs = np.array(divs)
    # non-IID: typical hospital diverges noticeably; heterogeneity across sites
    assert divs.mean() > 0.05
    assert divs.std() > 0.01


def test_determinism():
    a = generate_cohort(SMALL, seed=3)
    b = generate_cohort(SMALL, seed=3)
    assert np.array_equal(a.y, b.y)
    assert np.array_equal(a.x_temporal, b.x_temporal)
    c = generate_cohort(SMALL, seed=4)
    assert not np.array_equal(a.y, c.y)


def test_client_datasets_partition_train_split():
    c = generate_cohort(SMALL, seed=5)
    clients = build_client_datasets(c)
    assert len(clients) > 150  # most of the 189 survive the size cut
    total = sum(cl.n_train for cl in clients)
    # every train sample belongs to exactly one surviving client (minus
    # samples of dropped degenerate hospitals)
    assert total <= (c.split == Cohort.TRAIN).sum()
    assert total >= 0.98 * (c.split == Cohort.TRAIN).sum()
    ids = [cl.client_id for cl in clients]
    assert len(set(ids)) == len(ids)


def test_features_carry_signal():
    """Severity-driven features: correlation between a feature summary and
    log-LoS must be clearly nonzero, else the prediction task is vacuous."""
    c = generate_cohort(SMALL, seed=6)
    feat = c.x_temporal.mean(axis=(1, 2)) + c.x_static.mean(axis=1)
    r = np.corrcoef(feat, np.log(c.y))[0, 1]
    assert abs(r) > 0.2


def test_fused_features_layout():
    c = generate_cohort(SMALL, seed=7)
    fused = c.fused_features()
    assert fused.shape == (SMALL.total_stays, 24, 38)
    # static block is constant across time
    assert np.allclose(fused[:, 0, 20:], fused[:, 12, 20:])


def test_client_stats_disclosure_only():
    c = generate_cohort(SMALL, seed=8)
    clients = build_client_datasets(c)
    s = clients[0].stats()
    assert s.counts.shape == (10,)
    assert s.n == clients[0].n_train
    assert s.counts.sum() == s.n
