"""Backward parity for the training-grade kernel tier.

The residual backward (single reverse scan over stashed hidden / chunk
states) and the hand-written Pallas backward kernels must reproduce the
jnp-oracle gradients everywhere the federated hot path composes them:
plain calls, odd sequence lengths, bf16, under ``vmap`` over clients ×
``lax.scan`` over steps, with the ``REPRO_PALLAS_INTERPRET`` override
forcing the backward kernels, and through a full federated round on the
``mesh="auto"`` leg with buffer donation on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ArrayDataset, ClientDataset
from repro.federated.server import FederatedConfig, FederatedServer
from repro.kernels import backend
from repro.kernels.analysis import recompute_elimination_report
from repro.kernels.gru_scan.kernel import gru_scan_bwd
from repro.kernels.gru_scan.ops import gru_scan_op, gru_scan_oracle
from repro.kernels.gru_scan.ref import gru_scan_bwd_ref, gru_scan_ref
from repro.kernels.ssd.kernel import ssd_chunk_scan_bwd
from repro.kernels.ssd.ops import ssd_full
from repro.kernels.ssd.ref import (
    ssd_chunk_scan_bwd_ref,
    ssd_chunk_scan_ref,
    ssd_chunk_states_ref,
    ssd_ref,
)
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

RNG = np.random.default_rng(7)

F32_TOL = 1e-5
BF16_TOL = 1e-2


def assert_grads_close(got, ref, tol: float) -> None:
    for g, r in zip(got, ref):
        g32 = np.asarray(g, np.float32)
        r32 = np.asarray(r, np.float32)
        assert np.all(np.isfinite(g32))
        scale = max(1.0, float(np.max(np.abs(r32))))
        np.testing.assert_array_less(np.max(np.abs(g32 - r32)), tol * scale)


def gru_inputs(b, t, n, dtype=jnp.float32):
    xg = jnp.asarray(RNG.normal(size=(b, t, 3 * n)), dtype)
    whh = jnp.asarray(RNG.normal(size=(n, 3 * n)) * 0.3, dtype)
    bhh = jnp.asarray(RNG.normal(size=(3 * n,)) * 0.1, dtype)
    return xg, whh, bhh


# --------------------------------------------------------------------------
# direct backward parity: residual + Pallas kernels vs the jnp oracle
# --------------------------------------------------------------------------

GRU_ODD_SHAPES = [(3, 7, 16), (2, 13, 32), (5, 31, 8), (1, 1, 8)]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,n", GRU_ODD_SHAPES)
def test_gru_residual_backward_matches_oracle(dtype, b, t, n):
    """The op's new backward (residual reverse scan) vs full oracle VJP."""
    xg, whh, bhh = gru_inputs(b, t, n, dtype)

    def loss(fn):
        return lambda *a: jnp.sum(fn(*a).astype(jnp.float32) ** 2)

    g = jax.grad(loss(gru_scan_op), argnums=(0, 1, 2))(xg, whh, bhh)
    g_ref = jax.grad(loss(gru_scan_ref), argnums=(0, 1, 2))(xg, whh, bhh)
    assert_grads_close(g, g_ref, tol=F32_TOL if dtype == jnp.float32 else BF16_TOL)


@pytest.mark.parametrize("b,t,n", GRU_ODD_SHAPES)
def test_gru_pallas_backward_kernel_matches_oracle(b, t, n):
    """The hand-written backward kernel (interpret mode) against the oracle
    VJP cotangents directly — not just through the custom_vjp plumbing."""
    xg, whh, bhh = gru_inputs(b, t, n)
    dy = jnp.asarray(RNG.normal(size=(b, t, n)), jnp.float32)
    h_seq = gru_scan_ref(xg, whh, bhh)
    _, vjp = jax.vjp(gru_scan_ref, xg, whh, bhh)
    got = gru_scan_bwd(xg, whh, bhh, h_seq, dy, interpret=True)
    assert_grads_close(got, vjp(dy), tol=F32_TOL)


def test_gru_pallas_backward_ragged_batch_tile():
    """Batch 130 rags against b_tile=128: the zero-padded rows must not
    leak into the shared dW/db accumulators."""
    xg, whh, bhh = gru_inputs(130, 24, 32)
    dy = jnp.asarray(RNG.normal(size=(130, 24, 32)), jnp.float32)
    h_seq = gru_scan_ref(xg, whh, bhh)
    _, vjp = jax.vjp(gru_scan_ref, xg, whh, bhh)
    got = gru_scan_bwd(xg, whh, bhh, h_seq, dy, interpret=True)
    assert_grads_close(got, vjp(dy), tol=F32_TOL)


SSD_ODD_CASES = [(23, 8), (37, 16), (7, 4)]


@pytest.mark.parametrize("s,chunk", SSD_ODD_CASES)
def test_ssd_residual_backward_matches_oracle(s, chunk):
    """Odd lengths rag against the chunking; the residual backward through
    the full unchunked wrapper must match the per-step oracle."""
    b, h, p, n = 1, 2, 8, 8
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(RNG.normal(size=(b, s, h)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.normal(size=(h,)) * 0.3, jnp.float32))
    bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)

    def loss(fn):
        return lambda xx, dd, bb, cc: jnp.sum(fn(xx, dd, a, bb, cc) ** 2)

    kernel = lambda xx, dd, aa, bb, cc: ssd_full(xx, dd, aa, bb, cc, chunk=chunk)
    g = jax.grad(loss(kernel), argnums=(0, 1, 2, 3))(x, dt, bm, cm)
    g_ref = jax.grad(loss(ssd_ref), argnums=(0, 1, 2, 3))(x, dt, bm, cm)
    assert_grads_close(g, g_ref, tol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nc,length", [(3, 8), (5, 7)])
def test_ssd_chunked_backward_matches_chunk_oracle(dtype, nc, length):
    """Against the chunk-layout oracle (the old backward's reference) the
    new residual backward must hold 1e-5 f32 / 1e-2 bf16 — same layout,
    so only the backward implementation differs."""
    from repro.kernels.ssd.ops import ssd_chunk_scan

    b, h, p, n = 2, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    xc = jax.random.normal(ks[0], (b, nc, length, h, p)).astype(dtype)
    dtc = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, length, h))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    cum = jnp.cumsum(dtc.astype(jnp.float32) * a, axis=2).astype(dtype)
    bc = (jax.random.normal(ks[3], (b, nc, length, n)) * 0.5).astype(dtype)
    cc = (jax.random.normal(ks[4], (b, nc, length, n)) * 0.5).astype(dtype)

    def loss(fn):
        return lambda *args: jnp.sum(fn(*args).astype(jnp.float32) ** 2)

    g = jax.grad(loss(ssd_chunk_scan), argnums=(0, 1, 2, 3, 4))(xc, dtc, cum, bc, cc)
    g_ref = jax.grad(loss(ssd_chunk_scan_ref), argnums=(0, 1, 2, 3, 4))(
        xc, dtc, cum, bc, cc
    )
    assert_grads_close(g, g_ref, tol=F32_TOL if dtype == jnp.float32 else BF16_TOL)


def test_ssd_pallas_backward_kernel_matches_oracle():
    b, nc, length, h, p, n = 2, 3, 8, 4, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    xc = jax.random.normal(ks[0], (b, nc, length, h, p), jnp.float32)
    dtc = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, length, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    cum = jnp.cumsum(dtc * a[None, None, None, :], axis=2)
    bc = jax.random.normal(ks[3], (b, nc, length, n)) * 0.5
    cc = jax.random.normal(ks[4], (b, nc, length, n)) * 0.5
    dy = jax.random.normal(ks[5], (b, nc, length, h, p))
    states = ssd_chunk_states_ref(xc, dtc, cum, bc, cc)
    _, vjp = jax.vjp(ssd_chunk_scan_ref, xc, dtc, cum, bc, cc)
    got = ssd_chunk_scan_bwd(xc, dtc, cum, bc, cc, states, dy, interpret=True)
    assert_grads_close(got, vjp(dy), tol=F32_TOL)
    resid = ssd_chunk_scan_bwd_ref(xc, dtc, cum, bc, cc, states, dy)
    assert_grads_close(resid, vjp(dy), tol=F32_TOL)


# --------------------------------------------------------------------------
# composition: vmap over clients × lax.scan over steps
# --------------------------------------------------------------------------


def test_gru_backward_under_vmap_and_scan():
    """The cohort engine's composition: grads under jit(vmap(...)) driven by
    a lax.scan over steps must match the oracle composed identically."""
    clients, b, t, n, steps = 4, 3, 13, 16, 3
    xg = jnp.asarray(RNG.normal(size=(clients, b, t, 3 * n)), jnp.float32)
    whh = jnp.asarray(RNG.normal(size=(clients, n, 3 * n)) * 0.3, jnp.float32)
    bhh = jnp.asarray(RNG.normal(size=(clients, 3 * n)) * 0.1, jnp.float32)

    def train(op):
        grad_one = jax.grad(lambda w, x, bb: jnp.sum(op(x, w, bb) ** 2))

        def step(w, _):
            g = jax.vmap(grad_one)(w, xg, bhh)
            return w - 1e-3 * g, jnp.sum(g ** 2)

        return jax.jit(lambda w: jax.lax.scan(step, w, None, length=steps))

    (w_op, gs_op) = train(gru_scan_op)(whh)
    (w_ref, gs_ref) = train(gru_scan_ref)(whh)
    assert_grads_close([w_op], [w_ref], tol=F32_TOL)
    np.testing.assert_allclose(np.asarray(gs_op), np.asarray(gs_ref), rtol=1e-5)


def test_ssd_backward_under_vmap_and_scan():
    clients, b, s, h, p, n = 3, 1, 16, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (clients, b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (clients, b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (clients, b, s, n)) * 0.5
    cm = jax.random.normal(ks[4], (clients, b, s, n)) * 0.5

    def train(fn):
        grad_one = jax.grad(
            lambda xx, dd, bb, cc: jnp.sum(fn(xx, dd, a, bb, cc) ** 2)
        )

        def step(carry, _):
            g = jax.vmap(grad_one)(carry, dt, bm, cm)
            return carry - 1e-3 * g, jnp.sum(g ** 2)

        return jax.jit(lambda xx: jax.lax.scan(step, xx, None, length=2))

    kernel = lambda xx, dd, aa, bb, cc: ssd_full(xx, dd, aa, bb, cc, chunk=8)
    (x_op, gs_op) = train(kernel)(x)
    (x_ref, gs_ref) = train(ssd_ref)(x)
    assert_grads_close([x_op], [x_ref], tol=1e-4)
    np.testing.assert_allclose(np.asarray(gs_op), np.asarray(gs_ref), rtol=1e-4)


# --------------------------------------------------------------------------
# backend selection + env override
# --------------------------------------------------------------------------


def test_backend_interpret_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    on_tpu = backend.on_tpu()
    assert backend.interpret() == (not on_tpu)
    assert backend.pallas_backward() == on_tpu
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert backend.interpret() is True
    assert backend.pallas_backward() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "off")
    assert backend.pallas_backward() == on_tpu


def test_forced_interpret_routes_backward_through_pallas(monkeypatch):
    """With REPRO_PALLAS_INTERPRET=1 the custom_vjp backward runs the
    hand-written Pallas kernels (interpret mode) — and still matches."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert backend.pallas_backward()
    xg, whh, bhh = gru_inputs(3, 9, 16)
    loss = lambda fn: (lambda *a: jnp.sum(fn(*a) ** 2))
    g = jax.grad(loss(gru_scan_op), argnums=(0, 1, 2))(xg, whh, bhh)
    g_ref = jax.grad(loss(gru_scan_ref), argnums=(0, 1, 2))(xg, whh, bhh)
    assert_grads_close(g, g_ref, tol=F32_TOL)


def test_recompute_elimination_structural():
    """The jaxpr check the benchmark report asserts on: the residual
    backward has strictly fewer scan sites than the oracle pairing."""
    xg, whh, bhh = gru_inputs(4, 12, 16)
    rep = recompute_elimination_report(gru_scan_op, gru_scan_oracle, xg, whh, bhh)
    assert rep["recompute_eliminated"]
    assert rep["residual_bwd"]["scans"] == 1
    assert rep["oracle_bwd"]["scans"] >= 2


# --------------------------------------------------------------------------
# full federated round: use_pallas=True vs jnp path, engines × staging × mesh
# --------------------------------------------------------------------------

NUM_CLIENTS, SEQ_LEN, FEAT = 8, 6, 5


@pytest.fixture(scope="module")
def fed_clients():
    rng = np.random.default_rng(11)
    clients = []
    for i, stays in enumerate(rng.integers(4, 9, NUM_CLIENTS)):
        x = rng.normal(size=(int(stays), SEQ_LEN, FEAT)).astype(np.float32)
        y = rng.uniform(0.5, 20.0, size=int(stays)).astype(np.float32)
        ds = ArrayDataset(x, y)
        clients.append(ClientDataset(client_id=i, train=ds, val=ds))
    return clients


def run_round(clients, *, use_pallas: bool, **cfg_kwargs):
    cfg = GRUConfig(input_dim=FEAT, hidden_dim=4, num_layers=2, dropout=0.0,
                    use_pallas=use_pallas)
    params0 = init_gru(jax.random.key(2), cfg)
    fed = FederatedConfig(rounds=2, local_epochs=1, batch_size=4, seed=0,
                          donate_buffers=True, **cfg_kwargs)
    opt = AdamW(learning_rate=5e-3, weight_decay=5e-3)
    return FederatedServer(fed, clients, make_loss_fn(cfg), opt).run(params0)


def assert_params_close(a, b, atol=F32_TOL):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol, rtol=0)


@pytest.mark.parametrize("engine", ["vectorized", "sequential"])
@pytest.mark.parametrize("staging", ["rebuild", "resident"])
def test_federated_round_use_pallas_parity(fed_clients, engine, staging):
    """Acceptance bar: a full federated round with use_pallas=True matches
    the jnp path to 1e-5 under both engines × both staging modes."""
    ref = run_round(fed_clients, use_pallas=False, engine=engine, staging=staging)
    pal = run_round(fed_clients, use_pallas=True, engine=engine, staging=staging)
    assert_params_close(ref.params, pal.params)
    np.testing.assert_allclose(
        [r.mean_local_loss for r in ref.history],
        [r.mean_local_loss for r in pal.history],
        atol=F32_TOL,
    )


def test_federated_round_use_pallas_parity_mesh(fed_clients):
    """The mesh='auto' leg (shard_map over the data mesh on CI's 4-device
    matrix entry, plain vmap on 1 device) with donation on."""
    ref = run_round(fed_clients, use_pallas=False, engine="vectorized", mesh="auto")
    pal = run_round(fed_clients, use_pallas=True, engine="vectorized", mesh="auto")
    assert_params_close(ref.params, pal.params)
