"""MoE expert-sharding modes: global 'ep' vs shard-local 'ep_local' vs 'tp'.

The §Perf-winning ep_local dispatch must be numerically identical to the
global formulation (same routing, same capacity semantics modulo per-shard
vs global drop boundaries — eliminated here with generous capacity).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.zoo import Model

RNG = np.random.default_rng(0)
B, S = 2, 16


def _model(arch: str, sharding: str, capacity: float = 8.0) -> Model:
    cfg0 = get_config(arch).reduced()
    moe = dataclasses.replace(cfg0.moe, capacity_factor=capacity, expert_sharding=sharding)
    return Model(dataclasses.replace(cfg0, moe=moe), remat=False)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "llama4-scout-17b-a16e"])
def test_local_matches_global(arch):
    mg = _model(arch, "ep")
    ml = _model(arch, "ep_local")
    params = mg.init(jax.random.key(0))
    toks = jnp.asarray(RNG.integers(0, mg.cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    lg = mg.forward_logits(params, batch)
    ll = ml.forward_logits(params, batch)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ll), atol=2e-5, rtol=1e-5)


def test_local_mode_trains(arch="llama4-scout-17b-a16e"):
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamW

    model = _model(arch, "ep_local", capacity=1.5)
    optimizer = AdamW(learning_rate=1e-3)
    params = model.init(jax.random.key(1))
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(model, optimizer))
    toks = jnp.asarray(RNG.integers(0, model.cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for _ in range(3):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses[-1]) and losses[-1] < losses[0]


def test_local_mode_capacity_drops_gracefully():
    """At capacity 0-ish every token is dropped: output = shared-expert only,
    still finite (no NaN from the drop slot)."""
    model = _model("deepseek-v3-671b", "ep_local", capacity=0.01)
    params = model.init(jax.random.key(2))
    toks = jnp.asarray(RNG.integers(0, model.cfg.vocab_size, (B, S)), jnp.int32)
    logits = model.forward_logits(params, {"tokens": toks, "labels": toks})
    assert bool(jnp.all(jnp.isfinite(logits)))
