"""In-jit DP-SGD: config validation, engine parity, accounting, structure.

The structural tests pin the acceptance criterion that DP noise rides the
*jitted cohort step*: the traced round jaxpr must not grow with the number
of clients (vmap, not a Python loop), and Gaussian sampling (``erf_inv``)
must appear inside the round program itself.
"""

from __future__ import annotations

import functools
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import CohortConfig, build_client_datasets, generate_cohort
from repro.federated import Federation, FederationConfig
from repro.federated.cohort import CohortTrainer
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim import AdamW
from repro.privacy.accountant import (
    RdpAccountant,
    epsilon_after,
    rdp_subsampled_gaussian,
)
from repro.privacy.dp import (
    DPConfig,
    add_gaussian_noise,
    per_example_clip_factors,
    resolve_dp,
)


@functools.lru_cache(maxsize=1)
def _fixture():
    cohort = generate_cohort(CohortConfig().scaled(0.02), seed=0)
    clients = build_client_datasets(cohort)[:8]
    mcfg = GRUConfig(dropout=0.0, hidden_dim=8, num_layers=1)
    loss_fn = make_loss_fn(mcfg)
    params0 = init_gru(jax.random.key(0), mcfg)
    return clients, loss_fn, params0


def _run(privacy, engine="vectorized", rounds=2, seed=0):
    clients, loss_fn, params0 = _fixture()
    config = FederationConfig(
        rounds=rounds, local_epochs=1, batch_size=16, seed=seed,
        engine=engine, privacy=privacy,
    )
    fed = Federation(config, clients, loss_fn, AdamW(learning_rate=1e-2))
    return fed.run(params0)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


def _max_abs_diff(a, b):
    return max(
        float(np.max(np.abs(x - y))) for x, y in zip(_leaves(a), _leaves(b))
    )


# ---------------------------------------------------------------------------
# DPConfig / resolve_dp validation


def test_dp_config_rejects_json_strings_and_bools():
    with pytest.raises(TypeError, match="number"):
        DPConfig(clip_norm="0.1")
    with pytest.raises(TypeError, match="number"):
        DPConfig(noise_multiplier="1.0")
    with pytest.raises(TypeError, match="number"):
        DPConfig(noise_multiplier=True)
    with pytest.raises(TypeError, match="number"):
        DPConfig(delta="1e-5")


def test_dp_config_rejects_bad_ranges():
    with pytest.raises(ValueError):
        DPConfig(clip_norm=-1.0)
    with pytest.raises(ValueError):
        DPConfig(clip_norm=0.0)
    with pytest.raises(ValueError):
        DPConfig(noise_multiplier=-0.5)
    with pytest.raises(ValueError):
        DPConfig(delta=0.0)
    with pytest.raises(ValueError):
        DPConfig(delta=1.0)
    # Noise without a finite clip norm has unbounded sensitivity.
    with pytest.raises(ValueError, match="clip_norm"):
        DPConfig(clip_norm=None, noise_multiplier=1.0)


def test_resolve_dp_forms():
    assert resolve_dp(None) is None
    cfg = DPConfig(clip_norm=2.0, noise_multiplier=0.5)
    assert resolve_dp(cfg) is cfg
    from_dict = resolve_dp({"clip_norm": 2.0, "noise_multiplier": 0.5})
    assert from_dict == cfg
    with pytest.raises(ValueError, match="unknown"):
        resolve_dp({"clipnorm": 2.0})
    with pytest.raises(TypeError):
        resolve_dp({"clip_norm": "2.0"})


def test_noise_sigma_and_effective_clip():
    assert DPConfig(clip_norm=2.0, noise_multiplier=1.5).noise_sigma == 3.0
    assert DPConfig(clip_norm=None, noise_multiplier=0.0).effective_clip == float("inf")
    assert DPConfig(clip_norm=None, noise_multiplier=0.0).noise_sigma == 0.0


# ---------------------------------------------------------------------------
# Clip / noise primitives


def test_per_example_clip_factors():
    grads = {"w": jnp.array([[3.0, 4.0], [0.3, 0.4]])}  # norms 5.0 and 0.5
    f = per_example_clip_factors(grads, 1.0)
    np.testing.assert_allclose(np.asarray(f), [0.2, 1.0], rtol=1e-5)


def test_add_gaussian_noise_zero_sigma_is_identity():
    tree = {"a": jnp.arange(4.0), "b": jnp.ones((2, 2))}
    out = add_gaussian_noise(tree, jax.random.key(3), 0.0)
    assert _max_abs_diff(tree, out) == 0.0
    noised = add_gaussian_noise(tree, jax.random.key(3), 1.0)
    assert _max_abs_diff(tree, noised) > 0.0


# ---------------------------------------------------------------------------
# Engine parity and determinism


def test_degenerate_dp_matches_unprotected():
    """noise_multiplier=0, clip_norm=None is the unprotected objective."""
    degenerate = DPConfig(clip_norm=None, noise_multiplier=0.0)
    for engine in ("vectorized", "sequential"):
        base = _run(None, engine=engine)
        dp = _run(degenerate, engine=engine)
        assert _max_abs_diff(base.params, dp.params) < 2e-5, engine


def test_dp_cross_engine_parity():
    dp = DPConfig(clip_norm=1.0, noise_multiplier=1.1)
    vec = _run(dp, engine="vectorized")
    seq = _run(dp, engine="sequential")
    assert _max_abs_diff(vec.params, seq.params) < 2e-5


def test_seeded_dp_run_replays_bitwise():
    dp = DPConfig(clip_norm=1.0, noise_multiplier=1.1)
    a = _run(dp, seed=3)
    b = _run(dp, seed=3)
    assert _max_abs_diff(a.params, b.params) == 0.0
    assert [r.epsilon for r in a.history] == [r.epsilon for r in b.history]


# ---------------------------------------------------------------------------
# Accounting on round records


def test_dp_run_reports_monotone_epsilon():
    dp = DPConfig(clip_norm=1.0, noise_multiplier=1.1)
    result = _run(dp, rounds=3)
    eps = [r.epsilon for r in result.history]
    assert all(e is not None and math.isfinite(e) and e > 0 for e in eps)
    assert eps == sorted(eps) and eps[0] < eps[-1]
    assert result.summary()["epsilon"] == eps[-1]


def test_unprotected_run_reports_no_epsilon():
    result = _run(None)
    assert all(r.epsilon is None for r in result.history)
    assert result.summary()["epsilon"] is None


def test_accountant_basics():
    acc = RdpAccountant(noise_multiplier=1.0, delta=1e-5)
    assert acc.epsilon() == 0.0
    acc.step(0.5)
    e1 = acc.epsilon()
    acc.step(0.5)
    e2 = acc.epsilon()
    assert 0 < e1 < e2
    # More noise, same schedule: strictly tighter epsilon.
    quiet = RdpAccountant(noise_multiplier=2.0, delta=1e-5)
    quiet.step(0.5)
    quiet.step(0.5)
    assert quiet.epsilon() < e2
    # sigma = 0 provides no privacy: honest infinity, not a small number.
    assert RdpAccountant(noise_multiplier=0.0).epsilon() == 0.0
    none = RdpAccountant(noise_multiplier=0.0)
    none.step(0.5)
    assert none.epsilon() == float("inf")


def test_rdp_full_batch_closed_form():
    # q = 1 (no subsampling): RDP of the Gaussian mechanism is alpha/(2 sigma^2).
    sigma, alpha = 1.3, 7
    assert rdp_subsampled_gaussian(1.0, sigma, alpha) == pytest.approx(
        alpha / (2 * sigma**2)
    )
    assert rdp_subsampled_gaussian(0.0, sigma, alpha) == 0.0


def test_epsilon_after_matches_stepped_accountant():
    acc = RdpAccountant(noise_multiplier=1.1, delta=1e-5)
    acc.step(0.25, steps=10)
    assert epsilon_after(
        rounds=10, sampling_rate=0.25, noise_multiplier=1.1, delta=1e-5
    ) == pytest.approx(acc.epsilon())


# ---------------------------------------------------------------------------
# Structural: noise rides the jitted cohort round


def _round_args(params, num_clients, steps=2, batch=4, seq=6, feat=38):
    acc = jax.tree.map(jnp.zeros_like, params)
    shape = (num_clients, steps)
    x = jnp.zeros(shape + (batch, seq, feat), jnp.float32)
    y = jnp.zeros(shape + (batch,), jnp.float32)
    m = jnp.ones(shape + (batch,), jnp.float32)
    valid = jnp.ones(shape, bool)
    kd = jnp.stack(
        [jax.random.key_data(jax.random.key(i)) for i in range(num_clients)]
    )
    w = jnp.ones((num_clients,), jnp.float32)
    return (params, acc, x, y, m, valid, kd, w)


def _trainer(dp):
    _, loss_fn, _ = _fixture()
    return CohortTrainer(
        loss_fn=loss_fn, optimizer=AdamW(learning_rate=1e-2),
        batch_size=4, local_epochs=1, dp=dp, donate=False,
    )


def test_dp_round_jaxpr_does_not_grow_with_clients():
    """vmap over the stacked client axis — no per-client Python loop."""
    _, _, params0 = _fixture()
    trainer = _trainer(DPConfig(clip_norm=1.0, noise_multiplier=1.1))
    small = str(trainer._round.trace(*_round_args(params0, 4)).jaxpr)
    large = str(trainer._round.trace(*_round_args(params0, 8)).jaxpr)
    assert small.count(" = ") == large.count(" = ")


def test_gaussian_sampling_is_inside_the_round_program():
    _, _, params0 = _fixture()
    dp_jaxpr = str(
        _trainer(DPConfig(clip_norm=1.0, noise_multiplier=1.1))
        ._round.trace(*_round_args(params0, 4)).jaxpr
    )
    plain_jaxpr = str(_trainer(None)._round.trace(*_round_args(params0, 4)).jaxpr)
    # Gaussian sampling lowers through erf_inv; the unprotected round
    # never samples a normal.
    assert "erf_inv" in dp_jaxpr
    assert "erf_inv" not in plain_jaxpr
