"""Per-architecture smoke tests (harness deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
of the same family (2 layers, d_model<=512, <=4 experts) and run one
forward + one train step on CPU, asserting output shapes and no NaNs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ArchType
from repro.launch.steps import make_train_step
from repro.models.zoo import Model, count_params_config
from repro.optim.adamw import AdamW

B, S = 2, 16
RNG = np.random.default_rng(0)


def make_batch(cfg):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.arch_type == ArchType.VLM:
        batch["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.num_frontend_tokens, cfg.d_model)), jnp.float32
        )
    if cfg.arch_type == ArchType.ENCDEC:
        batch["src_embeds"] = jnp.asarray(RNG.normal(size=(B, 8, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    logits = model.forward_logits(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, remat=False)
    optimizer = AdamW(learning_rate=1e-3)
    params = model.init(jax.random.key(1))
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(model, optimizer))
    batch = make_batch(cfg)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved and stayed finite
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(new_params))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_decreases_over_steps(arch):
    """Three steps on a FIXED batch must reduce the loss (learnability)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg, remat=False)
    optimizer = AdamW(learning_rate=3e-3)
    params = model.init(jax.random.key(2))
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(model, optimizer))
    batch = make_batch(cfg)
    losses = []
    for _ in range(3):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_full_config_param_counts_sane():
    """Analytic parameter counts must be within sanity range of the
    published model sizes (the stubs exclude modality towers)."""
    expected = {
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "mamba2-130m": (0.1e9, 0.16e9),
        "seamless-m4t-large-v2": (1.4e9, 2.4e9),
        "deepseek-v3-671b": (6.3e11, 7.1e11),
        "smollm-135m": (0.12e9, 0.15e9),
        "yi-9b": (8.0e9, 9.5e9),
        "internvl2-26b": (1.8e10, 2.1e10),   # minus the stubbed 6B ViT
        "nemotron-4-15b": (1.4e10, 1.7e10),
        "llama4-scout-17b-a16e": (0.95e11, 1.15e11),
        "zamba2-7b": (5.0e9, 8.0e9),
    }
    for arch, (lo, hi) in expected.items():
        n = count_params_config(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]B"


def test_moe_active_params_smaller():
    for arch in ("deepseek-v3-671b", "llama4-scout-17b-a16e"):
        cfg = get_config(arch)
        assert count_params_config(cfg, active_only=True) < 0.3 * count_params_config(cfg)
