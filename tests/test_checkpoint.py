"""Checkpoint save/restore roundtrip tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import checkpoint_metadata, load_pytree, save_pytree
from repro.models.gru import GRUConfig, init_gru


def test_roundtrip_nested_pytree(tmp_path):
    tree = {
        "layers": [{"w": jnp.arange(6.0).reshape(2, 3)}, {"w": jnp.ones((3,))}],
        "head": {"b": jnp.asarray([1.5])},
    }
    save_pytree(str(tmp_path), tree, metadata={"round": 7})
    out = load_pytree(str(tmp_path), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint_metadata(str(tmp_path))["round"] == 7


def test_roundtrip_model_params(tmp_path):
    params = init_gru(jax.random.key(0), GRUConfig())
    save_pytree(str(tmp_path), params)
    out = load_pytree(str(tmp_path), params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_structure_mismatch_raises(tmp_path):
    save_pytree(str(tmp_path), {"a": jnp.zeros(2)})
    with pytest.raises(ValueError, match="structure mismatch"):
        load_pytree(str(tmp_path), {"b": jnp.zeros(2)})


def test_shape_mismatch_raises(tmp_path):
    save_pytree(str(tmp_path), {"a": jnp.zeros(2)})
    with pytest.raises(ValueError, match="shape mismatch"):
        load_pytree(str(tmp_path), {"a": jnp.zeros(3)})
