"""AdamW unit tests (reference math, decoupled decay, clipping, schedule)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamW, apply_updates, cosine_schedule, global_norm


def test_first_step_matches_reference_math():
    opt = AdamW(learning_rate=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    state = opt.init(p)
    updates, state = opt.update(g, state, p)
    # bias-corrected first step: m_hat = g, v_hat = g^2 -> step = lr * sign-ish
    expected = -0.1 * np.asarray([0.5, -0.5]) / (np.abs([0.5, -0.5]) + 1e-8)
    np.testing.assert_allclose(np.asarray(updates["w"]), expected, rtol=1e-5)


def test_weight_decay_is_decoupled():
    opt = AdamW(learning_rate=0.1, weight_decay=0.5)
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.0])}  # zero grad: update is pure decay
    state = opt.init(p)
    updates, _ = opt.update(g, state, p)
    np.testing.assert_allclose(np.asarray(updates["w"]), [-0.1 * 0.5 * 2.0], rtol=1e-6)


def test_clipping_bounds_update():
    opt = AdamW(learning_rate=1.0, clip_norm=1e-3)
    p = {"w": jnp.ones(4)}
    g = {"w": 1e6 * jnp.ones(4)}
    state = opt.init(p)
    updates, _ = opt.update(g, state, p)
    assert bool(jnp.all(jnp.isfinite(updates["w"])))


def test_convergence_on_quadratic():
    opt = AdamW(learning_rate=0.05, weight_decay=0.0)
    p = jnp.asarray([5.0, -3.0])
    state = opt.init(p)
    loss = lambda w: jnp.sum((w - jnp.asarray([1.0, 2.0])) ** 2)
    for _ in range(400):
        g = jax.grad(loss)(p)
        updates, state = opt.update(g, state, p)
        p = apply_updates(p, updates)
    np.testing.assert_allclose(np.asarray(p), [1.0, 2.0], atol=1e-2)


def test_cosine_schedule_shape():
    sched = cosine_schedule(warmup_steps=10, total_steps=100, min_ratio=0.1)
    vals = [float(sched(jnp.int32(s))) for s in [0, 5, 10, 50, 100, 200]]
    assert vals[0] == 0.0
    assert abs(vals[2] - 1.0) < 1e-6
    assert vals[3] < 1.0
    assert abs(vals[4] - 0.1) < 1e-6
    assert vals[5] == vals[4]  # clipped past the end


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == 5.0
