"""Integration tests of the federated runtime against the paper's protocol."""

import jax
import numpy as np
import pytest

from repro.core.recruitment import BALANCED, RecruitmentConfig
from repro.data.pipeline import build_client_datasets, global_dataset
from repro.data.synth_eicu import Cohort, CohortConfig, generate_cohort
from repro.federated.central import CentralConfig, train_central
from repro.federated.selection import select_clients
from repro.federated.server import FederatedConfig, FederatedServer
from repro.metrics.regression import evaluate_predictions
from repro.models.gru import GRUConfig, gru_apply, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

TINY = CohortConfig().scaled(0.02)  # ~1.8k stays, fast


@pytest.fixture(scope="module")
def setup():
    cohort = generate_cohort(TINY, seed=0)
    clients = build_client_datasets(cohort)
    cfg = GRUConfig()
    return cohort, clients, cfg, make_loss_fn(cfg), AdamW(learning_rate=5e-3, weight_decay=5e-3)


def test_selection_semantics():
    rng = np.random.default_rng(0)
    ids = np.arange(30)
    assert len(select_clients(rng, ids)) == 30
    sub = select_clients(rng, ids, fraction=0.1)
    assert len(sub) == 3 and len(set(sub.tolist())) == 3
    # participants come back in sorted-id order — the cohort stacking order
    # (an unsorted rng.choice draw would leak the draw order into records)
    assert sub.tolist() == sorted(sub.tolist())
    seven = select_clients(rng, ids, count=7)
    assert len(seven) == 7 and seven.tolist() == sorted(seven.tolist())
    assert len(select_clients(rng, ids, fraction=0.001)) == 1  # at least one
    with pytest.raises(ValueError):
        select_clients(rng, ids, fraction=0.5, count=3)


def test_recruitment_prunes_federation(setup):
    _, clients, cfg, loss_fn, opt = setup
    fed = FederatedConfig(rounds=1, local_epochs=1, recruitment=BALANCED, seed=0)
    server = FederatedServer(fed, clients, loss_fn, opt)
    ids, rec = server.build_federation()
    assert rec is not None
    assert 0 < len(ids) < len(clients)
    assert set(ids.tolist()) <= {c.client_id for c in clients}


def test_no_recruitment_keeps_everyone(setup):
    _, clients, cfg, loss_fn, opt = setup
    fed = FederatedConfig(rounds=1, local_epochs=1, recruitment=None, seed=0)
    server = FederatedServer(fed, clients, loss_fn, opt)
    ids, rec = server.build_federation()
    assert rec is None and len(ids) == len(clients)


def test_federated_round_improves_over_init(setup):
    cohort, clients, cfg, loss_fn, opt = setup
    params0 = init_gru(jax.random.key(0), cfg)
    fed = FederatedConfig(
        rounds=3, local_epochs=1, participation_fraction=0.2,
        recruitment=RecruitmentConfig(gamma_th=0.3), seed=0,
    )
    server = FederatedServer(fed, clients, loss_fn, opt)
    result = server.run(params0)
    test = global_dataset(cohort, Cohort.TEST)
    m0 = evaluate_predictions(test.y, np.asarray(gru_apply(params0, cfg, test.x)))
    m1 = evaluate_predictions(test.y, np.asarray(gru_apply(result.params, cfg, test.x)))
    assert m1["msle"] < m0["msle"]
    # history integrity
    assert len(result.history) == 3
    for r in result.history:
        assert set(r.participant_ids) <= set(result.federation_ids.tolist())
        assert r.local_steps > 0
    assert result.total_local_steps == sum(r.local_steps for r in result.history)


def test_recruited_federation_fewer_steps(setup):
    """The paper's training-time claim in its simulated form: recruitment
    cuts the per-round local-step budget."""
    _, clients, cfg, loss_fn, opt = setup
    base = FederatedConfig(rounds=1, local_epochs=1, seed=0)
    rec = FederatedConfig(rounds=1, local_epochs=1, recruitment=BALANCED, seed=0)
    params = init_gru(jax.random.key(0), cfg)
    out_base = FederatedServer(base, clients, loss_fn, opt).run(params)
    out_rec = FederatedServer(rec, clients, loss_fn, opt).run(params)
    assert out_rec.total_local_steps < out_base.total_local_steps


def test_central_baseline_trains(setup):
    cohort, _, cfg, loss_fn, opt = setup
    params0 = init_gru(jax.random.key(0), cfg)
    result = train_central(
        CentralConfig(epochs=2, batch_size=128, seed=0),
        global_dataset(cohort, Cohort.TRAIN),
        params0, loss_fn, opt,
    )
    assert result.epoch_losses[-1] < result.epoch_losses[0]
    assert result.total_steps > 0


def test_aggregation_weighted_by_sample_size(setup):
    """FedAvg weighting: a client with more data pulls the average harder.
    Verified indirectly: with one participant the global params equal that
    client's locally trained params."""
    cohort, clients, cfg, loss_fn, opt = setup
    params0 = init_gru(jax.random.key(0), cfg)
    one = [clients[0]]
    fed = FederatedConfig(rounds=1, local_epochs=1, seed=0)
    out = FederatedServer(fed, one, loss_fn, opt).run(params0)
    from repro.federated.client import LocalTrainer

    trainer = LocalTrainer(loss_fn, opt, batch_size=128, local_epochs=1)
    # replicate the server's rng path: one jax split before the client call
    _, sub = jax.random.split(jax.random.key(0))
    expected, _, _ = trainer.train_client(
        params0, clients[0], np.random.default_rng(0), sub
    )
    # same rng path -> identical params
    for a, b in zip(jax.tree.leaves(out.params), jax.tree.leaves(expected)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
