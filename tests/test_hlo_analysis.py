"""Trip-count-aware HLO analyzer: validated against known-FLOPs programs.

These tests pin the calibration facts the roofline methodology rests on:
raw ``cost_analysis`` counts scan bodies once and reports per-device numbers,
while the analyzer recovers exact looped totals (including fused dots).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (
    COLLECTIVE_KINDS,
    HloAnalyzer,
    RooflineTerms,
    analyze_hlo,
)

TRIP = 5
N = 64


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


@pytest.fixture(scope="module")
def scanned_matmul():
    def body(x, w):
        return jnp.tanh(x @ w), jnp.float32(0)

    def f(x, ws):
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x)

    xs = jax.ShapeDtypeStruct((8, N), jnp.float32)
    ws = jax.ShapeDtypeStruct((TRIP, N, N), jnp.float32)
    return _compile(f, xs, ws)


def test_scan_flops_exact(scanned_matmul):
    out = analyze_hlo(scanned_matmul.as_text())
    true = TRIP * 2 * 8 * N * N
    assert out["flops"] == pytest.approx(true, rel=0.01)


def test_fused_dot_flops():
    def body(x, w):
        return jax.nn.gelu(x @ w + 1.0), jnp.float32(0)

    def f(x, ws):
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x)

    xs = jax.ShapeDtypeStruct((8, N), jnp.float32)
    ws = jax.ShapeDtypeStruct((TRIP, N, N), jnp.float32)
    out = analyze_hlo(_compile(f, xs, ws).as_text())
    assert out["flops"] == pytest.approx(TRIP * 2 * 8 * N * N, rel=0.01)


def test_grad_flops_ratio(scanned_matmul):
    def body(x, w):
        return jnp.tanh(x @ w), jnp.float32(0)

    def f(x, ws):
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x)

    xs = jax.ShapeDtypeStruct((8, N), jnp.float32)
    ws = jax.ShapeDtypeStruct((TRIP, N, N), jnp.float32)
    g = lambda x, ws: jnp.sum(jax.grad(lambda xx: f(xx, ws))(x))
    fwd = analyze_hlo(scanned_matmul.as_text())["flops"]
    bwd = analyze_hlo(_compile(g, xs, ws).as_text())["flops"]
    # grad wrt x: one dot fwd + one dot bwd per layer
    assert bwd == pytest.approx(2 * fwd, rel=0.02)


def test_bytes_scale_with_trip_count():
    def make(trip):
        def body(x, w):
            return jnp.tanh(x @ w), jnp.float32(0)

        def f(x, ws):
            x, _ = jax.lax.scan(body, x, ws)
            return jnp.sum(x)

        xs = jax.ShapeDtypeStruct((8, N), jnp.float32)
        ws = jax.ShapeDtypeStruct((trip, N, N), jnp.float32)
        return analyze_hlo(_compile(f, xs, ws).as_text())["bytes"]

    b2, b8 = make(2), make(8)
    assert b8 > 3.0 * b2  # roughly linear in trip count


def test_computation_parsing(scanned_matmul):
    an = HloAnalyzer(scanned_matmul.as_text())
    assert an.entry is not None
    assert len(an.comps) >= 3  # entry + while body + cond at least
    out = an.analyze()
    for k in COLLECTIVE_KINDS:
        assert k in out


def test_roofline_terms_math():
    t = RooflineTerms(hlo_flops=197e12 * 2, hlo_bytes=819e9, coll_bytes=50e9 * 4, chips=2, model_flops=197e12)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.collective_s == pytest.approx(2.0)
    assert t.dominant == "collective"
    assert t.useful_flops_ratio == pytest.approx(0.5)
    d = t.as_dict()
    assert d["dominant"] == "collective"
