"""The federation control plane: specs, streaming, kill-and-resume.

The acceptance bar: a job preempted mid-run and resumed from its snapshot
matches the uninterrupted run — final params to 1e-5, scheduler state
(virtual-clock times, event order, participant sets) exactly — for both a
synchronous FedAvg job and an async ``fedbuff:K`` job under straggler
latency and dropout.  Around it: spec validation with did-you-mean
suggestions, spec-hash identity, JSONL record round-trips, rejection of
resume under a mismatched spec, the CLI surface, and the generated
registry table staying in sync with docs/API_SPEC.md.
"""

import copy
import json
import os
from pathlib import Path

import numpy as np
import pytest

from repro.federated.api import RoundRecord
from repro.launch.federation_service import (
    EX_TEMPFAIL,
    JobPreempted,
    RecordStream,
    check_registry_table,
    diff_runs,
    job_spec_hash,
    main,
    read_records,
    registry_table,
    resume_job,
    status_job,
    submit_job,
    validate_job_spec,
)

REPO_ROOT = Path(__file__).resolve().parents[1]

# Tiny but real: 8 hospitals, a 2-unit GRU, a handful of rounds — each
# submitted job runs the full engine path in a couple of seconds on CPU.
SYNC_SPEC = {
    "name": "t-sync",
    "mode": "sync",
    "rounds": 3,
    "local_epochs": 1,
    "batch_size": 8,
    "seed": 3,
    "recruitment": "all",
    "selection": "loss-weighted:2",
    "data": {"scale": 0.002, "num_hospitals": 8, "split_mode": "stratified"},
    "model": {"hidden_dim": 2, "num_layers": 1},
}
ASYNC_SPEC = {
    "name": "t-async",
    "mode": "async",
    "rounds": 4,
    "local_epochs": 1,
    "batch_size": 8,
    "seed": 3,
    "recruitment": "all",
    "aggregator": "fedbuff:3",
    "latency": "lognormal:0.6",
    "dropout": "bernoulli:0.1",
    "concurrency": 4,
    "data": {"scale": 0.002, "num_hospitals": 8, "split_mode": "stratified"},
    "model": {"hidden_dim": 2, "num_layers": 1},
}


# --------------------------------------------------------------------------
# spec validation + hashing
# --------------------------------------------------------------------------

def test_validate_fills_defaults_and_normalizes():
    out = validate_job_spec({"mode": "sync"})
    assert out["rounds"] == 15
    assert out["selection"] == "uniform"
    assert out["aggregator"] == "fedavg"
    assert out["optimizer"]["learning_rate"] == 5e-3
    assert out["data"]["scale"] == 1.0
    out_async = validate_job_spec({"mode": "async"})
    assert out_async["aggregator"] == "fedbuff"
    assert out_async["latency"] == "constant"


def test_validate_rejects_unknown_keys_with_suggestion():
    with pytest.raises(ValueError, match="did you mean 'recruitment'"):
        validate_job_spec({"mode": "sync", "recrutment": "all"})
    with pytest.raises(ValueError, match="did you mean 'hidden_dim'"):
        validate_job_spec({"mode": "sync", "model": {"hiden_dim": 4}})
    with pytest.raises(ValueError, match="did you mean 'async'"):
        validate_job_spec({"mode": "asink"})
    with pytest.raises(ValueError, match="did you mean 'nu-greedy'"):
        validate_job_spec({"mode": "sync", "recruitment": "nu-greedee"})
    with pytest.raises(ValueError, match="did you mean 'lognormal'"):
        validate_job_spec({"mode": "async", "latency": "lognormel:0.5"})


def test_validate_cross_checks_mode_and_policies():
    with pytest.raises(ValueError, match="mode='async'"):
        validate_job_spec({"mode": "sync", "aggregator": "fedbuff:4"})
    with pytest.raises(ValueError, match="buffered aggregator"):
        validate_job_spec({"mode": "async", "aggregator": "fedavg"})
    with pytest.raises(ValueError, match="only valid for mode 'sync'"):
        validate_job_spec({"mode": "async", "selection": "uniform"})
    with pytest.raises(ValueError, match="only valid for mode 'async'"):
        validate_job_spec({"mode": "sync", "latency": "constant"})
    with pytest.raises(ValueError, match="checkpoint_every"):
        validate_job_spec({"mode": "sync", "checkpoint_every": 0})
    with pytest.raises(ValueError, match="mesh"):
        validate_job_spec({"mode": "sync", "mesh": "ring"})
    with pytest.raises(ValueError, match="must be a JSON object"):
        validate_job_spec(["not", "a", "dict"])


def test_spec_hash_is_canonical_and_sensitive():
    a = validate_job_spec(copy.deepcopy(SYNC_SPEC))
    # Key order and default-filling do not change identity.
    reordered = validate_job_spec(dict(reversed(list(SYNC_SPEC.items()))))
    assert job_spec_hash(a) == job_spec_hash(reordered)
    explicit = copy.deepcopy(SYNC_SPEC)
    explicit["engine"] = "vectorized"  # already the default
    assert job_spec_hash(validate_job_spec(explicit)) == job_spec_hash(a)
    # Any semantic change does.
    changed = copy.deepcopy(SYNC_SPEC)
    changed["seed"] = 4
    assert job_spec_hash(validate_job_spec(changed)) != job_spec_hash(a)


def test_model_use_pallas_round_trips_through_spec_hash():
    out = validate_job_spec(copy.deepcopy(SYNC_SPEC))
    assert out["model"]["use_pallas"] is False  # default off

    flagged = copy.deepcopy(SYNC_SPEC)
    flagged["model"]["use_pallas"] = True
    a = validate_job_spec(flagged)
    assert a["model"]["use_pallas"] is True
    # Kernel path is part of job identity, and re-validating the
    # normalized spec is a fixed point of the hash.
    assert job_spec_hash(a) != job_spec_hash(out)
    assert job_spec_hash(validate_job_spec(copy.deepcopy(a))) == job_spec_hash(a)
    # Explicit default hashes the same as omitted.
    explicit = copy.deepcopy(SYNC_SPEC)
    explicit["model"]["use_pallas"] = False
    assert job_spec_hash(validate_job_spec(explicit)) == job_spec_hash(out)

    with pytest.raises(ValueError, match="use_pallas must be a JSON boolean"):
        validate_job_spec({"mode": "sync", "model": {"use_pallas": "false"}})
    with pytest.raises(ValueError, match="did you mean 'use_pallas'"):
        validate_job_spec({"mode": "sync", "model": {"use_palas": True}})


def test_paper_settings_render_as_valid_job_specs():
    from repro.experiments.paper import ExperimentConfig, job_spec_for

    exp = ExperimentConfig(cohort_scale=0.01, rounds=2, local_epochs=1, batch_size=8)
    for setting in ("federated-ac", "federated-sc", "federated-arc", "federated-src"):
        spec = validate_job_spec(job_spec_for(setting, exp, seed=1))
        assert spec["mode"] == "sync"
        assert spec["data"]["scale"] == 0.01
    src = validate_job_spec(job_spec_for("federated-src", exp))
    assert src["recruitment"].startswith("nu-greedy:")
    assert src["selection"] == "uniform:0.1"
    with pytest.raises(ValueError, match="pooled training"):
        job_spec_for("central", exp)


# --------------------------------------------------------------------------
# record streaming
# --------------------------------------------------------------------------

def _record(i: int, virtual: bool) -> RoundRecord:
    return RoundRecord(
        round_index=i,
        participant_ids=[1, 4, 7],
        mean_local_loss=1.0 / (i + 1),
        local_steps=5 * (i + 1),
        params_down=12,
        params_up=12,
        bytes_transferred=4096,
        wall_time_s=0.25,
        virtual_time=float(i) if virtual else None,
        staleness=0.5 if virtual else None,
    )


@pytest.mark.parametrize("virtual", [False, True])
def test_record_stream_jsonl_round_trip(tmp_path, virtual):
    path = str(tmp_path / "records.jsonl")
    seen = []
    stream = RecordStream(path, subscribers=[seen.append])
    records = [_record(i, virtual) for i in range(3)]
    for r in records:
        stream.emit(r)
    assert seen == records and stream.count == 3
    assert read_records(path) == records
    # append=False truncates: a fresh run owns the stream.
    RecordStream(path)
    assert read_records(path) == []


# --------------------------------------------------------------------------
# kill-and-resume parity (the tentpole gate)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def async_runs(tmp_path_factory):
    """One uninterrupted async run + one preempted-at-flush-2 run dir."""
    root = tmp_path_factory.mktemp("svc_async")
    full = str(root / "full")
    cut = str(root / "cut")
    result = submit_job(copy.deepcopy(ASYNC_SPEC), full)
    with pytest.raises(JobPreempted):
        submit_job(copy.deepcopy(ASYNC_SPEC), cut, preempt_after=2)
    return full, cut, result


def test_async_preempted_run_dir_state(async_runs):
    full, cut, _ = async_runs
    status = status_job(cut)
    assert status["status"] == "preempted"
    assert status["checkpoint_round"] == 2
    assert status["rounds_recorded"] == 2
    # The record stream prefix already matches the uninterrupted run
    # (host round_time_s excepted — real clocks are not replayed).
    def states(path):
        out = []
        for r in read_records(os.path.join(path, "records.jsonl")):
            state = r.to_state()
            state.pop("round_time_s")
            out.append(state)
        return out

    assert states(cut) == states(full)[:2]


def test_async_kill_and_resume_parity(async_runs):
    full, cut, full_result = async_runs
    resumed = resume_job(cut)
    assert resumed["status"] == "completed"
    assert resumed["resumed_from"] == 2
    # Virtual clock exact, params to 1e-5 — diff_runs checks both.
    assert diff_runs(cut, full) == []
    full_recs = read_records(os.path.join(full, "records.jsonl"))
    cut_recs = read_records(os.path.join(cut, "records.jsonl"))
    assert [r.virtual_time for r in cut_recs] == [r.virtual_time for r in full_recs]
    assert [r.staleness for r in cut_recs] == [r.staleness for r in full_recs]
    assert resumed["summary"]["virtual_time"] == full_result["summary"]["virtual_time"]
    assert status_job(cut)["status"] == "completed"


def test_resume_rejects_mismatched_spec(async_runs, tmp_path):
    _, cut, _ = async_runs
    other = copy.deepcopy(ASYNC_SPEC)
    other["seed"] = 99
    with pytest.raises(ValueError, match="must run the exact spec"):
        resume_job(cut, spec=other)
    # A tampered job.json is caught against the snapshot's embedded hash.
    tampered = tmp_path / "tampered"
    tampered.mkdir()
    for name in ("job.json", "records.jsonl"):
        (tampered / name).write_bytes((Path(cut) / name).read_bytes())
    import shutil

    shutil.copytree(Path(cut) / "checkpoint", tampered / "checkpoint")
    job = json.loads((tampered / "job.json").read_text())
    job["spec"]["seed"] = 99
    job["spec_hash"] = job_spec_hash(job["spec"])
    (tampered / "job.json").write_text(json.dumps(job))
    with pytest.raises(ValueError, match="refusing to resume"):
        resume_job(str(tampered))


def test_resume_requires_a_snapshot(tmp_path):
    run_dir = tmp_path / "no_snap"
    run_dir.mkdir()
    spec = validate_job_spec(copy.deepcopy(SYNC_SPEC))
    (run_dir / "job.json").write_text(
        json.dumps({"spec": spec, "spec_hash": job_spec_hash(spec)})
    )
    with pytest.raises(FileNotFoundError, match="nothing to resume"):
        resume_job(str(run_dir))


# --------------------------------------------------------------------------
# CLI (sync job end to end: submit, preempt, status, resume, diff)
# --------------------------------------------------------------------------

def test_cli_sync_kill_resume_flow(tmp_path, capsys):
    spec_path = tmp_path / "job.json"
    spec_path.write_text(json.dumps(SYNC_SPEC))
    full = str(tmp_path / "full")
    cut = str(tmp_path / "cut")

    assert main(["submit", "--spec", str(spec_path), "--run-dir", full, "--quiet"]) == 0
    assert (
        main(
            [
                "submit", "--spec", str(spec_path), "--run-dir", cut,
                "--preempt-after", "1", "--quiet",
            ]
        )
        == EX_TEMPFAIL
    )
    capsys.readouterr()
    assert main(["status", "--run-dir", cut]) == 0
    assert json.loads(capsys.readouterr().out)["status"] == "preempted"
    assert (
        main(
            ["resume", "--run-dir", cut, "--spec", str(spec_path), "--quiet"]
        )
        == 0
    )
    assert main(["diff", cut, full]) == 0
    # Different seeds genuinely diff (exercises the mismatch exit path).
    other_spec = dict(SYNC_SPEC, seed=11)
    other_path = tmp_path / "other.json"
    other_path.write_text(json.dumps(other_spec))
    other = str(tmp_path / "other")
    assert main(["submit", "--spec", str(other_path), "--run-dir", other, "--quiet"]) == 0
    capsys.readouterr()
    assert main(["diff", other, full]) == 1


def test_cli_sync_resume_matches_uninterrupted_params(tmp_path):
    # Belt-and-braces on top of the CLI flow: the Python API asserts the
    # same 1e-5 params bar the async leg gets, on the sync path.
    full = str(tmp_path / "full")
    cut = str(tmp_path / "cut")
    submit_job(copy.deepcopy(SYNC_SPEC), full)
    with pytest.raises(JobPreempted):
        submit_job(copy.deepcopy(SYNC_SPEC), cut, preempt_after=2)
    resume_job(cut)
    assert diff_runs(cut, full) == []
    with np.load(os.path.join(full, "final", "arrays.npz")) as za, np.load(
        os.path.join(cut, "final", "arrays.npz")
    ) as zb:
        for key in za.files:
            np.testing.assert_allclose(za[key], zb[key], atol=1e-5, rtol=0)


# --------------------------------------------------------------------------
# registry table drift
# --------------------------------------------------------------------------

def test_registry_table_lists_every_registered_spec():
    table = registry_table()
    for name in ("nu-greedy", "fedbuff", "hierarchical-async", "lognormal",
                 "bernoulli", "loss-weighted"):
        assert f"`{name}`" in table


def test_api_spec_registry_table_is_current():
    assert check_registry_table(str(REPO_ROOT / "docs" / "API_SPEC.md")) == []


def test_registry_drift_detected(tmp_path):
    stale = tmp_path / "doc.md"
    stale.write_text(
        "<!-- registry-table:begin -->\n| old |\n<!-- registry-table:end -->\n"
    )
    assert any("stale" in p for p in check_registry_table(str(stale)))
    no_markers = tmp_path / "plain.md"
    no_markers.write_text("nothing here\n")
    assert any("no" in p for p in check_registry_table(str(no_markers)))
    assert main(["registries", "--check", str(stale)]) == 1
    # --write regenerates in place, after which the check passes.
    assert main(["registries", "--write", str(stale)]) == 0
    assert check_registry_table(str(stale)) == []
