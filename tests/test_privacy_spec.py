"""The job spec's ``privacy`` section: strict validation + hash round-trip."""

from __future__ import annotations

import pytest

from repro.launch.federation_service import (
    federation_config_from_spec,
    job_spec_hash,
    validate_job_spec,
)
from repro.privacy.dp import DPConfig


def test_privacy_defaults_to_null_and_merges_section():
    out = validate_job_spec({"mode": "sync"})
    assert out["privacy"] is None
    out = validate_job_spec({"mode": "sync", "privacy": {}})
    assert out["privacy"] == {
        "clip_norm": 1.0,
        "noise_multiplier": 1.0,
        "delta": 1e-5,
    }
    out = validate_job_spec(
        {"mode": "sync", "privacy": {"noise_multiplier": 0.5}}
    )
    assert out["privacy"]["noise_multiplier"] == 0.5
    assert out["privacy"]["clip_norm"] == 1.0


def test_privacy_rejects_json_strings_and_bad_numbers():
    with pytest.raises(TypeError, match="never coerced"):
        validate_job_spec({"mode": "sync", "privacy": {"clip_norm": "0.1"}})
    with pytest.raises(TypeError, match="never coerced"):
        validate_job_spec(
            {"mode": "sync", "privacy": {"noise_multiplier": "1.0"}}
        )
    with pytest.raises(TypeError, match="never coerced"):
        validate_job_spec({"mode": "sync", "privacy": {"noise_multiplier": True}})
    with pytest.raises(ValueError):
        validate_job_spec({"mode": "sync", "privacy": {"clip_norm": -1.0}})
    with pytest.raises(ValueError):
        validate_job_spec(
            {"mode": "sync", "privacy": {"noise_multiplier": -0.5}}
        )
    with pytest.raises(ValueError, match="did you mean"):
        validate_job_spec({"mode": "sync", "privacy": {"clipnorm": 1.0}})
    with pytest.raises(ValueError, match="must be an object"):
        validate_job_spec({"mode": "sync", "privacy": "dp"})


def test_privacy_spec_hash_round_trip():
    spec = {"mode": "sync", "privacy": {"noise_multiplier": 1.3}}
    normalized = validate_job_spec(spec)
    digest = job_spec_hash(normalized)
    # Re-validating the normalized form is a fixed point: same hash.
    assert job_spec_hash(validate_job_spec(normalized)) == digest
    # The DP job is a different experiment from the unprotected one...
    assert digest != job_spec_hash(validate_job_spec({"mode": "sync"}))
    # ...and from a differently-calibrated DP job.
    other = validate_job_spec(
        {"mode": "sync", "privacy": {"noise_multiplier": 0.7}}
    )
    assert digest != job_spec_hash(other)


def test_privacy_flows_into_facade_configs():
    sync = validate_job_spec({"mode": "sync", "privacy": {"clip_norm": 2.0}})
    config = federation_config_from_spec(sync)
    assert config.privacy == {
        "clip_norm": 2.0,
        "noise_multiplier": 1.0,
        "delta": 1e-5,
    }
    async_spec = validate_job_spec(
        {"mode": "async", "privacy": {"noise_multiplier": 0.0, "clip_norm": None}}
    )
    async_config = federation_config_from_spec(async_spec)
    assert async_config.privacy["noise_multiplier"] == 0.0
    # Old snapshots have no "privacy" key: they resume unprotected.
    legacy = dict(validate_job_spec({"mode": "sync"}))
    legacy.pop("privacy")
    assert federation_config_from_spec(legacy).privacy is None


def test_privacy_clip_only_and_noiseless_forms_validate():
    out = validate_job_spec(
        {
            "mode": "sync",
            "privacy": {"clip_norm": None, "noise_multiplier": 0.0},
        }
    )
    assert out["privacy"]["clip_norm"] is None
    # Noise without a clip norm is unbounded sensitivity — rejected.
    with pytest.raises(ValueError, match="clip_norm"):
        validate_job_spec(
            {
                "mode": "sync",
                "privacy": {"clip_norm": None, "noise_multiplier": 1.0},
            }
        )


def test_dp_config_state_round_trips_through_spec():
    cfg = DPConfig(clip_norm=2.0, noise_multiplier=0.5, delta=1e-6)
    out = validate_job_spec({"mode": "sync", "privacy": cfg.to_state()})
    assert out["privacy"] == cfg.to_state()
