"""Paper-scale smoke: the full 189-client federation on CI hardware.

The paper's headline experiments run at 189 hospital clients (section 6);
PR 1's engine was only ever exercised at 8-128 synthetic clients.  These
tests pin the missing scale step: a whole 189-participant round through the
vectorized + donated + (on multi-device runs) shard_map path must match the
sequential per-client oracle within 1e-5 and finish inside a hard wall-time
budget.  Model dims are tiny — the client axis is the scale under test.

Under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (CI's second
matrix leg) the data mesh has 4 shards and 189 clients force the padding
path (189 -> 192 with three weight-0 dummy clients).
"""

import jax
import numpy as np
import pytest

from repro.data.pipeline import (
    ArrayDataset,
    ClientDataset,
    build_cohort_schedule,
    pad_cohort_schedule,
)
from repro.federated.cohort import chain_split_keys
from repro.federated.server import FederatedConfig, FederatedServer
from repro.launch.mesh import make_data_mesh
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

NUM_CLIENTS = 189
SEQ_LEN, FEAT = 4, 6          # tiny stays: scale lives on the client axis
ROUND_BUDGET_S = 60.0         # hard per-round budget, compiled steady state


def make_clients(rng: np.random.Generator) -> list[ClientDataset]:
    clients = []
    for i, n in enumerate(rng.integers(2, 9, NUM_CLIENTS)):
        x = rng.normal(size=(int(n), SEQ_LEN, FEAT)).astype(np.float32)
        y = rng.uniform(0.5, 20.0, size=int(n)).astype(np.float32)
        ds = ArrayDataset(x, y)
        clients.append(ClientDataset(client_id=i, train=ds, val=ds))
    return clients


@pytest.fixture(scope="module")
def model():
    cfg = GRUConfig(input_dim=FEAT, hidden_dim=4, num_layers=1)
    return make_loss_fn(cfg), init_gru(jax.random.key(1), cfg)


@pytest.fixture(scope="module")
def clients():
    return make_clients(np.random.default_rng(0))


def run_engine(clients, params0, loss_fn, **cfg_kwargs):
    fed = FederatedConfig(rounds=2, local_epochs=1, batch_size=8, seed=0, **cfg_kwargs)
    opt = AdamW(learning_rate=5e-3, weight_decay=5e-3)
    return FederatedServer(fed, clients, loss_fn, opt).run(params0)


def assert_params_close(a, b, atol=1e-5):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol, rtol=0)


def test_189_clients_vectorized_matches_sequential_oracle(model, clients):
    """The acceptance bar: a full-federation round (every one of the 189
    clients participates) agrees with the sequential oracle within 1e-5 on
    params and reported metrics, and the compiled round beats the budget."""
    loss_fn, params0 = model
    seq = run_engine(clients, params0, loss_fn, engine="sequential")
    vec = run_engine(clients, params0, loss_fn, engine="vectorized")
    assert_params_close(seq.params, vec.params)
    assert seq.total_local_steps == vec.total_local_steps
    np.testing.assert_allclose(
        [r.mean_local_loss for r in seq.history],
        [r.mean_local_loss for r in vec.history],
        atol=1e-5,
    )
    # round 0 pays compilation; the steady-state round must be fast
    assert vec.history[1].wall_time_s < ROUND_BUDGET_S


def test_189_clients_shard_map_parity(model, clients):
    """The multi-device path (auto data mesh over every visible device,
    189 padded up to the axis size) matches the single-device vmap result.
    On CI's 4-device leg this exercises real sharding + the psum."""
    loss_fn, params0 = model
    plain = run_engine(clients, params0, loss_fn, engine="vectorized")
    sharded = run_engine(
        clients, params0, loss_fn, engine="vectorized", mesh=make_data_mesh()
    )
    assert_params_close(plain.params, sharded.params)
    np.testing.assert_allclose(
        [r.mean_local_loss for r in plain.history],
        [r.mean_local_loss for r in sharded.history],
        atol=1e-5,
    )


def test_189_clients_chunked_and_donated(model, clients):
    """cohort_chunk + donation at full federation scale change nothing
    numerically (the donated accumulator is an exact in-place FedAvg)."""
    loss_fn, params0 = model
    base = run_engine(clients, params0, loss_fn, engine="vectorized")
    chunked = run_engine(
        clients, params0, loss_fn, engine="vectorized", cohort_chunk=64
    )
    plain_buf = run_engine(
        clients, params0, loss_fn, engine="vectorized", donate_buffers=False
    )
    assert_params_close(base.params, chunked.params, atol=1e-6)
    assert_params_close(base.params, plain_buf.params, atol=0.0)


def test_auto_mesh_resolves_on_any_device_count(model, clients):
    """mesh='auto' must work whatever XLA_FLAGS forced: a >1-device data
    mesh when available, plain vmap otherwise — same numbers either way."""
    loss_fn, params0 = model
    auto = run_engine(clients, params0, loss_fn, engine="vectorized", mesh="auto")
    plain = run_engine(clients, params0, loss_fn, engine="vectorized")
    assert_params_close(auto.params, plain.params)


def test_pad_cohort_schedule_roundtrip():
    rng = np.random.default_rng(3)
    data = [
        ArrayDataset(rng.normal(size=(n, 2, 3)).astype(np.float32), np.ones(n, np.float32))
        for n in (5, 9, 12)
    ]
    sched = build_cohort_schedule(data, 4, 1, rng)
    padded = pad_cohort_schedule(sched, 4)
    assert padded.num_clients == 4
    assert pad_cohort_schedule(sched, 1) is sched
    assert pad_cohort_schedule(sched, 3) is sched  # already divides
    # dummy client: zero weight, no valid steps, zero masks
    assert padded.weights[-1] == 0.0
    assert not padded.step_valid[-1].any()
    assert padded.mask[-1].sum() == 0.0
    # real clients untouched
    np.testing.assert_array_equal(padded.x[:3], sched.x)
    np.testing.assert_array_equal(padded.weights[:3], sched.weights)


def test_chain_split_keys_matches_python_chain():
    """The one-dispatch key chain is bit-identical to the sequential
    engine's per-client split loop — the parity contract's key half."""
    key = jax.random.key(7)
    k, subs = key, []
    for _ in range(17):
        k, s = jax.random.split(k)
        subs.append(s)
    new_key, key_data = chain_split_keys(jax.random.key(7), 17)
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(new_key)), np.asarray(jax.random.key_data(k))
    )
    for i, s in enumerate(subs):
        np.testing.assert_array_equal(np.asarray(jax.random.key_data(s)), key_data[i])
