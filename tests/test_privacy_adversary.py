"""Adversarial-client regression suite: attacks that break plain FedAvg.

The recipe (12 clients, seeded scenario) is chosen so the separation is
decisive, not marginal:

* Client 9 holds ~68% of the training samples; scenario seed 5 places it
  among the label-flip attackers, so the poisoned *sample mass* is ~71%
  while the poisoned *client count* stays at the allowed 30%.  FedAvg's
  n_c weighting is exactly the vulnerability — a few large poisoned
  clients dominate the weighted average — while trimmed-mean and Krum
  are unweighted per-client rules and survive.
* Scaled-update at scale 50 is the classic norm-amplification attack:
  three attackers multiply their delta 50x and swamp the average.

Robustness criterion is "does not degrade" (attacked <= clean + tol),
not "close to clean": trimming changes which honest clients survive, so
an attacked robust run can legitimately land *below* its clean run.
"""

from __future__ import annotations

import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import CohortConfig, build_client_datasets, generate_cohort
from repro.data.pipeline import ArrayDataset
from repro.federated import Federation, FederationConfig
from repro.federated.api import resolve_aggregator
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim import AdamW
from repro.privacy.adversary import (
    KrumAggregator,
    ScenarioConfig,
    apply_scenario,
    attacker_ids,
    flip_labels,
)

N_CLIENTS = 12
ROUNDS = 6
# Attacker placement: seed 5 puts the dominant client (~68% of samples)
# in the label-flip set; seed 1 picks small clients for scaled-update,
# where sample mass is irrelevant because the attack amplifies norms.
LABEL_FLIP = ScenarioConfig(attack="label-flip", fraction=0.3, seed=5)
SCALED_UPDATE = ScenarioConfig(
    attack="scaled-update", fraction=0.25, scale=50.0, seed=1
)


@functools.lru_cache(maxsize=1)
def _fixture():
    cohort = generate_cohort(CohortConfig().scaled(0.02), seed=0)
    clients = build_client_datasets(cohort)[:N_CLIENTS]
    mcfg = GRUConfig(dropout=0.0, hidden_dim=8, num_layers=1)
    loss_fn = make_loss_fn(mcfg)
    params0 = init_gru(jax.random.key(0), mcfg)
    vx = jnp.asarray(np.concatenate([np.asarray(c.val.x) for c in clients]))
    vy = jnp.asarray(np.concatenate([np.asarray(c.val.y) for c in clients]))
    vm = jnp.ones(vy.shape[0], jnp.float32)
    return clients, loss_fn, params0, (vx, vy, vm)


@functools.lru_cache(maxsize=32)
def _final_val_loss(aggregator, attack, engine, staging):
    """Clean-validation loss after a federated run under the scenario.

    The per-round mean_local_loss is contaminated by attacker-reported
    losses (label-flip attackers report loss on poisoned data), so the
    suite always re-evaluates the final parameters on the clean val
    split with the real loss_fn.
    """
    clients, loss_fn, params0, val_batch = _fixture()
    config = FederationConfig(
        rounds=ROUNDS,
        local_epochs=3,
        batch_size=16,
        aggregator=aggregator,
        seed=0,
        engine=engine,
        staging=staging,
    )
    fed = Federation(clients=clients, loss_fn=loss_fn, config=config,
                     optimizer=AdamW(learning_rate=5e-2))
    if attack == "label-flip":
        apply_scenario(fed, LABEL_FLIP)
    elif attack == "scaled-update":
        apply_scenario(fed, SCALED_UPDATE)
    result = fed.run(params0)
    return float(loss_fn(result.params, val_batch, jax.random.key(9)))


# ---------------------------------------------------------------------------
# Attacks break plain FedAvg


@pytest.mark.parametrize("attack", ["label-flip", "scaled-update"])
def test_attacks_break_plain_fedavg(attack):
    clean = _final_val_loss("fedavg", None, "sequential", "rebuild")
    attacked = _final_val_loss("fedavg", attack, "sequential", "rebuild")
    # Empirically ~5.9x (label-flip) and ~3.5x (scaled-update); 2x is a
    # comfortable margin that still fails if the attack stops biting.
    assert attacked > 2.0 * clean, (
        f"{attack} no longer degrades plain fedavg: "
        f"clean {clean:.4f} vs attacked {attacked:.4f}"
    )


def test_label_flip_breaks_fedavg_on_vectorized_resident():
    clean = _final_val_loss("fedavg", None, "vectorized", "resident")
    attacked = _final_val_loss("fedavg", "label-flip", "vectorized", "resident")
    assert attacked > 2.0 * clean


# ---------------------------------------------------------------------------
# Robust aggregators survive the same attacks

ROBUST_TOL = 0.1  # absolute slack over the aggregator's own clean run


@pytest.mark.parametrize("aggregator", ["trimmed-mean:0.35", "krum:4"])
@pytest.mark.parametrize("attack", ["label-flip", "scaled-update"])
def test_robust_aggregators_do_not_degrade(aggregator, attack):
    clean = _final_val_loss(aggregator, None, "sequential", "rebuild")
    attacked = _final_val_loss(aggregator, attack, "sequential", "rebuild")
    assert attacked <= clean + ROBUST_TOL, (
        f"{aggregator} degraded under {attack}: "
        f"clean {clean:.4f} vs attacked {attacked:.4f}"
    )


def test_trimmed_mean_survives_label_flip_on_vectorized_rebuild():
    clean = _final_val_loss("trimmed-mean:0.35", None, "sequential", "rebuild")
    attacked = _final_val_loss(
        "trimmed-mean:0.35", "label-flip", "vectorized", "rebuild"
    )
    assert attacked <= clean + ROBUST_TOL


def test_robust_aggregators_beat_attacked_fedavg():
    broken = _final_val_loss("fedavg", "label-flip", "sequential", "rebuild")
    trimmed = _final_val_loss(
        "trimmed-mean:0.35", "label-flip", "sequential", "rebuild"
    )
    assert trimmed < broken


# ---------------------------------------------------------------------------
# Scenario mechanics (cheap unit tests)


def test_attacker_ids_seeded_and_bounded():
    ids = list(range(10))
    a = attacker_ids(ids, ScenarioConfig(attack="label-flip", fraction=0.3, seed=7))
    b = attacker_ids(ids, ScenarioConfig(attack="label-flip", fraction=0.3, seed=7))
    np.testing.assert_array_equal(a, b)
    assert a.size == 3
    assert set(a.tolist()) <= set(ids)
    none = attacker_ids(ids, ScenarioConfig(attack="label-flip", fraction=0.0))
    assert none.size == 0
    # fraction > 0 always drafts at least one attacker.
    one = attacker_ids(ids, ScenarioConfig(attack="label-flip", fraction=0.01))
    assert one.size == 1


def test_scenario_config_validation():
    with pytest.raises(ValueError, match="did you mean 'label-flip'"):
        ScenarioConfig(attack="labelflip")
    with pytest.raises(ValueError, match=r"fraction must be in \[0, 1\]"):
        ScenarioConfig(attack="label-flip", fraction=1.5)
    with pytest.raises(ValueError, match="scale must be finite"):
        ScenarioConfig(attack="scaled-update", scale=float("inf"))


def test_flip_labels_mirrors_targets():
    y = np.array([1.0, 2.0, 10.0], dtype=np.float32)
    ds = ArrayDataset(x=np.zeros((3, 4), np.float32), y=y)
    flipped = flip_labels(ds)
    np.testing.assert_allclose(np.asarray(flipped.y), [10.0, 9.0, 1.0])
    assert flipped.x is ds.x


def test_model_poisoning_rejects_grouped_aggregators():
    clients, loss_fn, params0, _ = _fixture()
    config = FederationConfig(
        rounds=1, local_epochs=1, batch_size=16,
        aggregator="hierarchical:2", seed=0, engine="sequential",
    )
    fed = Federation(clients=clients, loss_fn=loss_fn, config=config,
                     optimizer=AdamW(learning_rate=5e-2))
    with pytest.raises(ValueError, match="grouped"):
        apply_scenario(fed, SCALED_UPDATE)


def test_krum_spec_forms_and_validation():
    agg = resolve_aggregator("krum:2,3")
    assert isinstance(agg, KrumAggregator)
    assert (agg.f, agg.m) == (2, 3)
    with pytest.raises(ValueError, match="f >= 0"):
        KrumAggregator(f=-1)
    with pytest.raises(ValueError, match="m >= 1"):
        KrumAggregator(m=0)
    # Too few clients for the Byzantine guarantee: C < 2f + 3.
    stacked = {"w": jnp.ones((4, 3))}
    with pytest.raises(ValueError, match="2f\\+3"):
        KrumAggregator(f=1).aggregate(stacked, jnp.ones(4))


def test_krum_discards_the_obvious_outlier():
    rng = np.random.default_rng(0)
    honest = rng.normal(size=(6, 5)).astype(np.float32) * 0.01
    outlier = np.full((1, 5), 100.0, dtype=np.float32)
    stacked = {"w": jnp.asarray(np.concatenate([honest, outlier]))}
    out = KrumAggregator(f=1).aggregate(stacked, jnp.ones(7))
    assert float(jnp.max(jnp.abs(out["w"]))) < 1.0
