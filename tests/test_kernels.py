"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gru_scan.kernel import gru_scan
from repro.kernels.gru_scan.ops import gru_sequence
from repro.kernels.gru_scan.ref import gru_scan_ref
from repro.kernels.ssd.ops import ssd_full
from repro.kernels.ssd.ref import ssd_ref

RNG = np.random.default_rng(42)


# --------------------------------------------------------------------------
# GRU scan
# --------------------------------------------------------------------------

GRU_SHAPES = [
    (1, 1, 8),
    (3, 24, 32),     # the paper's shape (N=32, T=24h)
    (128, 24, 32),
    (130, 24, 32),   # ragged batch vs b_tile
    (16, 7, 16),
    (5, 50, 64),
]


@pytest.mark.parametrize("b,t,n", GRU_SHAPES)
def test_gru_scan_matches_ref(b, t, n):
    xg = jnp.asarray(RNG.normal(size=(b, t, 3 * n)), jnp.float32)
    whh = jnp.asarray(RNG.normal(size=(n, 3 * n)) * 0.3, jnp.float32)
    bhh = jnp.asarray(RNG.normal(size=(3 * n,)) * 0.1, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(gru_scan(xg, whh, bhh)),
        np.asarray(gru_scan_ref(xg, whh, bhh)),
        atol=1e-5, rtol=1e-5,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gru_scan_dtypes(dtype):
    b, t, n = 4, 12, 16
    xg = jnp.asarray(RNG.normal(size=(b, t, 3 * n)), dtype)
    whh = jnp.asarray(RNG.normal(size=(n, 3 * n)) * 0.3, dtype)
    bhh = jnp.asarray(RNG.normal(size=(3 * n,)) * 0.1, dtype)
    out = gru_scan(xg, whh, bhh)
    ref = gru_scan_ref(xg, whh, bhh)
    assert out.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_gru_sequence_full_layer():
    """ops.py wrapper: hoisted input projection + kernel == direct math."""
    b, t, f, n = 6, 24, 38, 32
    x = jnp.asarray(RNG.normal(size=(b, t, f)), jnp.float32)
    w_ih = jnp.asarray(RNG.normal(size=(f, 3 * n)) * 0.2, jnp.float32)
    w_hh = jnp.asarray(RNG.normal(size=(n, 3 * n)) * 0.2, jnp.float32)
    b_ih = jnp.zeros(3 * n)
    b_hh = jnp.zeros(3 * n)
    out = gru_sequence(x, w_ih, w_hh, b_ih, b_hh)
    ref = gru_scan_ref(x @ w_ih + b_ih, w_hh, b_hh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# --------------------------------------------------------------------------
# gradients: Pallas ops vs oracle under jax.grad, across dtypes + odd lengths
# --------------------------------------------------------------------------

GRAD_DTYPES = [jnp.float32, jnp.bfloat16]


def assert_grads_close(got, ref, tol: float) -> None:
    """Scale-aware gradient comparison: max |got - ref| within ``tol`` of the
    reference's own magnitude.  Elementwise rtol is meaningless for bf16
    gradients whose cotangents span orders of magnitude."""
    for g, r in zip(got, ref):
        assert g.dtype == r.dtype
        g32 = np.asarray(g, np.float32)
        r32 = np.asarray(r, np.float32)
        assert np.all(np.isfinite(g32))
        scale = max(1.0, float(np.max(np.abs(r32))))
        np.testing.assert_array_less(np.max(np.abs(g32 - r32)), tol * scale)


GRU_GRAD_SHAPES = [
    (3, 7, 16),      # odd T, not a multiple of any tile
    (2, 13, 32),     # odd T at the paper's hidden size
]


@pytest.mark.parametrize("dtype", GRAD_DTYPES)
@pytest.mark.parametrize("b,t,n", GRU_GRAD_SHAPES)
def test_gru_scan_grad_matches_ref(dtype, b, t, n):
    """d(loss)/d(inputs, weights, bias) through the Pallas op equals the
    oracle's gradients — the custom_vjp must not just "flow", it must be
    *correct* for every argument, dtype, and ragged sequence length."""
    from repro.kernels.gru_scan.ops import gru_scan_op

    xg = jnp.asarray(RNG.normal(size=(b, t, 3 * n)), dtype)
    whh = jnp.asarray(RNG.normal(size=(n, 3 * n)) * 0.3, dtype)
    bhh = jnp.asarray(RNG.normal(size=(3 * n,)) * 0.1, dtype)

    def loss(fn):
        return lambda x, w, bb: jnp.sum(fn(x, w, bb).astype(jnp.float32) ** 2)

    g = jax.grad(loss(gru_scan_op), argnums=(0, 1, 2))(xg, whh, bhh)
    g_ref = jax.grad(loss(gru_scan_ref), argnums=(0, 1, 2))(xg, whh, bhh)
    assert_grads_close(g, g_ref, tol=1e-5 if dtype == jnp.float32 else 3e-2)


SSD_GRAD_CASES = [
    # (s, chunk): odd lengths rag against the chunking
    (23, 8),
    (37, 16),
]


@pytest.mark.parametrize("dtype", GRAD_DTYPES)
@pytest.mark.parametrize("s,chunk", SSD_GRAD_CASES)
def test_ssd_grad_matches_ref(dtype, s, chunk):
    """SSD kernel gradients wrt activations AND dt/B/C match the oracle
    across dtypes and sequence lengths that do not divide the chunk."""
    b, h, p, n = 1, 2, 8, 8
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), dtype)
    dt = jax.nn.softplus(jnp.asarray(RNG.normal(size=(b, s, h)), dtype))
    a = -jnp.exp(jnp.asarray(RNG.normal(size=(h,)) * 0.3, jnp.float32))
    bm = jnp.asarray(RNG.normal(size=(b, s, n)), dtype)
    cm = jnp.asarray(RNG.normal(size=(b, s, n)), dtype)

    def loss(fn):
        return lambda xx, dd, bb, cc: jnp.sum(
            fn(xx, dd, a.astype(dtype), bb, cc).astype(jnp.float32) ** 2
        )

    kernel = lambda xx, dd, aa, bb, cc: ssd_full(xx, dd, aa, bb, cc, chunk=chunk)
    g = jax.grad(loss(kernel), argnums=(0, 1, 2, 3))(x, dt, bm, cm)
    g_ref = jax.grad(loss(ssd_ref), argnums=(0, 1, 2, 3))(x, dt, bm, cm)
    assert_grads_close(g, g_ref, tol=1e-4 if dtype == jnp.float32 else 5e-2)


def test_gru_scan_grads_flow():
    """The op must be differentiable (custom_vjp through the oracle) and the
    gradient must equal the oracle's gradient."""
    from repro.kernels.gru_scan.ops import gru_scan_op

    b, t, n = 3, 8, 16
    xg = jnp.asarray(RNG.normal(size=(b, t, 3 * n)), jnp.float32)
    whh = jnp.asarray(RNG.normal(size=(n, 3 * n)) * 0.3, jnp.float32)
    bhh = jnp.zeros(3 * n)
    g = jax.grad(lambda w: jnp.sum(gru_scan_op(xg, w, bhh) ** 2))(whh)
    g_ref = jax.grad(lambda w: jnp.sum(gru_scan_ref(xg, w, bhh) ** 2))(whh)
    assert bool(jnp.all(jnp.isfinite(g)))
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-4, rtol=1e-4)


def test_ssd_op_grads_flow():
    from repro.kernels.ssd.ops import ssd_full

    b, s, h, p, n = 1, 24, 2, 8, 8
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(RNG.normal(size=(b, s, h)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.normal(size=(h,)) * 0.3, jnp.float32))
    bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    g = jax.grad(lambda xx: jnp.sum(ssd_full(xx, dt, a, bm, cm, chunk=8) ** 2))(x)
    g_ref = jax.grad(lambda xx: jnp.sum(ssd_ref(xx, dt, a, bm, cm) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=5e-3, rtol=5e-3)


# --------------------------------------------------------------------------
# SSD chunk scan
# --------------------------------------------------------------------------

SSD_SHAPES = [
    # (b, s, h, p, n, chunk)
    (1, 16, 1, 8, 8, 8),
    (2, 64, 4, 16, 32, 16),
    (1, 37, 2, 8, 16, 16),    # ragged seq vs chunk
    (3, 128, 8, 32, 64, 32),
    (2, 96, 3, 16, 16, 32),   # h not divisible by 4 -> h_tile fallback
]


@pytest.mark.parametrize("b,s,h,p,n,chunk", SSD_SHAPES)
def test_ssd_matches_naive_recurrence(b, s, h, p, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(RNG.normal(size=(b, s, h)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.normal(size=(h,)) * 0.5, jnp.float32))
    bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    out = ssd_full(x, dt, a, bm, cm, chunk=chunk)
    ref = ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4, rtol=1e-4)


def test_ssd_strong_decay_localizes():
    """With very fast decay the SSD output reduces to the diagonal term
    dt * C.B * x — a physics sanity check on the state recurrence."""
    b, s, h, p, n = 1, 12, 2, 4, 8
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.full((b, s, h), 1.0)
    a = jnp.full((h,), -50.0)  # state dies between steps
    bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    out = ssd_full(x, dt, a, bm, cm, chunk=4)
    diag = jnp.einsum("bsn,bsn->bs", cm, bm)[:, :, None, None] * x * 1.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(diag), atol=1e-3, rtol=1e-3)
