"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gru_scan.kernel import gru_scan
from repro.kernels.gru_scan.ops import gru_sequence
from repro.kernels.gru_scan.ref import gru_scan_ref
from repro.kernels.ssd.ops import ssd_full
from repro.kernels.ssd.ref import ssd_ref

RNG = np.random.default_rng(42)


# --------------------------------------------------------------------------
# GRU scan
# --------------------------------------------------------------------------

GRU_SHAPES = [
    (1, 1, 8),
    (3, 24, 32),     # the paper's shape (N=32, T=24h)
    (128, 24, 32),
    (130, 24, 32),   # ragged batch vs b_tile
    (16, 7, 16),
    (5, 50, 64),
]


@pytest.mark.parametrize("b,t,n", GRU_SHAPES)
def test_gru_scan_matches_ref(b, t, n):
    xg = jnp.asarray(RNG.normal(size=(b, t, 3 * n)), jnp.float32)
    whh = jnp.asarray(RNG.normal(size=(n, 3 * n)) * 0.3, jnp.float32)
    bhh = jnp.asarray(RNG.normal(size=(3 * n,)) * 0.1, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(gru_scan(xg, whh, bhh)),
        np.asarray(gru_scan_ref(xg, whh, bhh)),
        atol=1e-5, rtol=1e-5,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gru_scan_dtypes(dtype):
    b, t, n = 4, 12, 16
    xg = jnp.asarray(RNG.normal(size=(b, t, 3 * n)), dtype)
    whh = jnp.asarray(RNG.normal(size=(n, 3 * n)) * 0.3, dtype)
    bhh = jnp.asarray(RNG.normal(size=(3 * n,)) * 0.1, dtype)
    out = gru_scan(xg, whh, bhh)
    ref = gru_scan_ref(xg, whh, bhh)
    assert out.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_gru_sequence_full_layer():
    """ops.py wrapper: hoisted input projection + kernel == direct math."""
    b, t, f, n = 6, 24, 38, 32
    x = jnp.asarray(RNG.normal(size=(b, t, f)), jnp.float32)
    w_ih = jnp.asarray(RNG.normal(size=(f, 3 * n)) * 0.2, jnp.float32)
    w_hh = jnp.asarray(RNG.normal(size=(n, 3 * n)) * 0.2, jnp.float32)
    b_ih = jnp.zeros(3 * n)
    b_hh = jnp.zeros(3 * n)
    out = gru_sequence(x, w_ih, w_hh, b_ih, b_hh)
    ref = gru_scan_ref(x @ w_ih + b_ih, w_hh, b_hh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_gru_scan_grads_flow():
    """The op must be differentiable (custom_vjp through the oracle) and the
    gradient must equal the oracle's gradient."""
    from repro.kernels.gru_scan.ops import gru_scan_op

    b, t, n = 3, 8, 16
    xg = jnp.asarray(RNG.normal(size=(b, t, 3 * n)), jnp.float32)
    whh = jnp.asarray(RNG.normal(size=(n, 3 * n)) * 0.3, jnp.float32)
    bhh = jnp.zeros(3 * n)
    g = jax.grad(lambda w: jnp.sum(gru_scan_op(xg, w, bhh) ** 2))(whh)
    g_ref = jax.grad(lambda w: jnp.sum(gru_scan_ref(xg, w, bhh) ** 2))(whh)
    assert bool(jnp.all(jnp.isfinite(g)))
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-4, rtol=1e-4)


def test_ssd_op_grads_flow():
    from repro.kernels.ssd.ops import ssd_full

    b, s, h, p, n = 1, 24, 2, 8, 8
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(RNG.normal(size=(b, s, h)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.normal(size=(h,)) * 0.3, jnp.float32))
    bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    g = jax.grad(lambda xx: jnp.sum(ssd_full(xx, dt, a, bm, cm, chunk=8) ** 2))(x)
    g_ref = jax.grad(lambda xx: jnp.sum(ssd_ref(xx, dt, a, bm, cm) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=5e-3, rtol=5e-3)


# --------------------------------------------------------------------------
# SSD chunk scan
# --------------------------------------------------------------------------

SSD_SHAPES = [
    # (b, s, h, p, n, chunk)
    (1, 16, 1, 8, 8, 8),
    (2, 64, 4, 16, 32, 16),
    (1, 37, 2, 8, 16, 16),    # ragged seq vs chunk
    (3, 128, 8, 32, 64, 32),
    (2, 96, 3, 16, 16, 32),   # h not divisible by 4 -> h_tile fallback
]


@pytest.mark.parametrize("b,s,h,p,n,chunk", SSD_SHAPES)
def test_ssd_matches_naive_recurrence(b, s, h, p, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(RNG.normal(size=(b, s, h)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.normal(size=(h,)) * 0.5, jnp.float32))
    bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    out = ssd_full(x, dt, a, bm, cm, chunk=chunk)
    ref = ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4, rtol=1e-4)


def test_ssd_strong_decay_localizes():
    """With very fast decay the SSD output reduces to the diagonal term
    dt * C.B * x — a physics sanity check on the state recurrence."""
    b, s, h, p, n = 1, 12, 2, 4, 8
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.full((b, s, h), 1.0)
    a = jnp.full((h,), -50.0)  # state dies between steps
    bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    out = ssd_full(x, dt, a, bm, cm, chunk=4)
    diag = jnp.einsum("bsn,bsn->bs", cm, bm)[:, :, None, None] * x * 1.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(diag), atol=1e-3, rtol=1e-3)
