"""The composable federation API: facade parity, registries, policies, shims.

The acceptance bar: the ``Federation`` facade, driven purely by policy
specs, reproduces the legacy ``FederatedServer`` results to 1e-5 across all
five section-6 settings x both engines x both staging modes.  Around it:
registry round-trips, unknown-policy errors, deprecation-shim warnings, the
new policies' semantics (random-k / top-n / round-robin / loss-weighted /
trimmed-mean / hierarchical), sorted participant order, and the real
communication accounting that replaced ``comm_params``.
"""

import jax
import numpy as np
import pytest

from repro.core.recruitment import BALANCED, QUALITY_GREEDY, RecruitmentConfig
from repro.data.pipeline import ArrayDataset, ClientDataset
from repro.federated import (
    Federation,
    FederationConfig,
    FederatedConfig,
    FederatedServer,
    HierarchicalFedAvg,
    LossWeightedSelection,
    RecruitmentDecision,
    RecruitmentPolicy,
    RoundRobinSelection,
    TrimmedMeanAggregator,
    UniformSelection,
    available_policies,
    params_nbytes,
    resolve_aggregator,
    resolve_recruitment,
    resolve_selection,
    round_robin_clients,
    select_clients,
    trimmed_mean_stacked,
)
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

SEQ_LEN, FEAT = 3, 5


def make_clients(count, rng, lo=2, hi=18):
    clients = []
    for i, n in enumerate(rng.integers(lo, hi, count)):
        x = rng.normal(size=(int(n), SEQ_LEN, FEAT)).astype(np.float32)
        y = rng.uniform(0.5, 20.0, size=int(n)).astype(np.float32)
        ds = ArrayDataset(x, y)
        clients.append(ClientDataset(client_id=i, train=ds, val=ds))
    return clients


@pytest.fixture(scope="module")
def setup():
    cfg = GRUConfig(input_dim=FEAT, hidden_dim=2, num_layers=1)
    clients = make_clients(10, np.random.default_rng(0))
    return clients, make_loss_fn(cfg), init_gru(jax.random.key(1), cfg)


def opt():
    return AdamW(learning_rate=5e-3, weight_decay=5e-3)


def assert_params_close(a, b, atol=1e-5):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol, rtol=0)


# --------------------------------------------------------------------------
# golden parity: policy combinations == legacy server, all settings/engines
# --------------------------------------------------------------------------

# Each section-6 setting as its (legacy kwargs, policy specs) pair.  The
# recruitment gammas match experiments.paper.policies_for at gamma_th=0.1.
SETTINGS = {
    "ac": (
        dict(participation_fraction=None, recruitment=None),
        dict(recruitment="all", selection="uniform"),
    ),
    "sc": (
        dict(participation_fraction=0.5, recruitment=None),
        dict(recruitment="all", selection="uniform:0.5"),
    ),
    "arc": (
        dict(participation_fraction=None, recruitment=BALANCED),
        dict(recruitment="nu-greedy", selection="uniform"),
    ),
    "src": (
        dict(participation_fraction=0.5, recruitment=BALANCED),
        dict(recruitment="nu-greedy:0.5,0.5,0.1", selection="uniform:0.5"),
    ),
    "src-qg": (
        dict(participation_fraction=0.5, recruitment=QUALITY_GREEDY),
        dict(recruitment="nu-greedy:quality-greedy", selection="uniform:0.5"),
    ),
}


@pytest.mark.parametrize("setting", sorted(SETTINGS))
@pytest.mark.parametrize(
    "engine,staging",
    [
        ("vectorized", "resident"),
        ("vectorized", "rebuild"),
        ("sequential", "resident"),
        ("sequential", "rebuild"),
    ],
)
def test_golden_parity_with_legacy_server(setup, setting, engine, staging):
    clients, loss_fn, params0 = setup
    legacy_kwargs, specs = SETTINGS[setting]
    base = dict(rounds=2, local_epochs=1, batch_size=4, seed=0, engine=engine, staging=staging)
    with pytest.warns(DeprecationWarning):
        server = FederatedServer(
            FederatedConfig(**base, **legacy_kwargs), clients, loss_fn, opt()
        )
    legacy = server.run(params0)
    new = Federation(
        FederationConfig(**base, **specs, aggregator="fedavg"), clients, loss_fn, opt()
    ).run(params0)
    assert legacy.federation_ids.tolist() == new.federation_ids.tolist()
    for rl, rn in zip(legacy.history, new.history):
        assert rl.participant_ids == rn.participant_ids
    assert_params_close(legacy.params, new.params)
    np.testing.assert_allclose(
        [r.mean_local_loss for r in legacy.history],
        [r.mean_local_loss for r in new.history],
        atol=1e-5,
    )


def test_sorted_selection_engine_parity(setup):
    """Satellite regression: participant ids are sorted (the cohort stacking
    order) and vectorized/sequential stay in 1e-5 lockstep under sampling."""
    clients, loss_fn, params0 = setup
    outs = {}
    for engine in ("sequential", "vectorized"):
        outs[engine] = Federation(
            FederationConfig(
                rounds=3, local_epochs=1, batch_size=4, selection="uniform:0.5",
                seed=11, engine=engine,
            ),
            clients, loss_fn, opt(),
        ).run(params0)
    for rs, rv in zip(outs["sequential"].history, outs["vectorized"].history):
        assert rs.participant_ids == rv.participant_ids
        assert rs.participant_ids == sorted(rs.participant_ids)
        assert 1 < len(rs.participant_ids) < len(clients)  # sorting had work to do
    assert_params_close(outs["sequential"].params, outs["vectorized"].params)


def test_select_clients_returns_sorted_ids():
    rng = np.random.default_rng(0)
    ids = np.arange(40, 0, -1)  # descending input
    full = select_clients(rng, ids)
    assert full.tolist() == sorted(ids.tolist())
    for _ in range(5):
        sub = select_clients(rng, ids, fraction=0.3)
        assert sub.tolist() == sorted(sub.tolist())


# --------------------------------------------------------------------------
# registries
# --------------------------------------------------------------------------

def test_registry_round_trips():
    assert resolve_recruitment("nu-greedy").config == BALANCED
    assert resolve_recruitment("nu-greedy:quality-greedy").config == QUALITY_GREEDY
    assert resolve_recruitment("nu-greedy:1.0,0.01,0.2").config == RecruitmentConfig(
        1.0, 0.01, 0.2
    )
    assert resolve_recruitment("random-k:7").k == 7
    assert resolve_recruitment("top-n-samples:5").n == 5
    assert resolve_selection("uniform:0.25").fraction == 0.25
    assert resolve_selection("uniform:6").count == 6
    assert resolve_selection("round-robin:3").count == 3
    assert resolve_selection("loss-weighted:0.5").fraction == 0.5
    assert resolve_aggregator("trimmed-mean:0.2").trim == 0.2
    assert resolve_aggregator("hierarchical:4").num_regions == 4
    # instances pass through untouched
    sel = UniformSelection(fraction=0.1)
    assert resolve_selection(sel) is sel
    names = available_policies()
    assert "nu-greedy" in names["recruitment"]
    assert "round-robin" in names["selection"]
    assert "hierarchical" in names["aggregator"]


def test_selection_spec_validated_at_construction():
    """Bad participation specs fail when the policy is built, not mid-run."""
    with pytest.raises(ValueError, match="fraction"):
        resolve_selection("loss-weighted:1.5")
    with pytest.raises(ValueError, match="count"):
        resolve_selection("round-robin:0")
    with pytest.raises(ValueError, match="fraction"):
        UniformSelection(fraction=0.0)
    with pytest.raises(ValueError, match="not both"):
        UniformSelection(fraction=0.5, count=3)


def test_unknown_policy_error_messages():
    with pytest.raises(ValueError, match="unknown recruitment policy 'warp'"):
        resolve_recruitment("warp")
    with pytest.raises(ValueError, match="unknown selection.*uniform"):
        resolve_selection("bogus")
    with pytest.raises(ValueError, match="unknown aggregator.*fedavg"):
        resolve_aggregator("median")
    with pytest.raises(TypeError, match="aggregator"):
        resolve_aggregator(42)


def test_deprecation_shim_warns_and_maps(setup):
    clients, loss_fn, _ = setup
    cfg = FederatedConfig(rounds=1, participation_fraction=0.1, recruitment=BALANCED)
    with pytest.warns(DeprecationWarning, match="Federation"):
        server = FederatedServer(cfg, clients, loss_fn, opt())
    fed_cfg = cfg.to_federation()
    assert fed_cfg.recruitment.config == BALANCED
    assert fed_cfg.selection.fraction == 0.1
    assert fed_cfg.aggregator == "fedavg"
    # legacy surface still reachable through the shim
    ids, rec = server.build_federation()
    assert rec is not None and 0 < len(ids) <= len(clients)
    assert server.cohort_trainer is server.federation.cohort_trainer


# --------------------------------------------------------------------------
# recruitment policies
# --------------------------------------------------------------------------

def test_recruitment_baselines(setup):
    clients, loss_fn, _ = setup
    stats = [c.stats() for c in clients]
    rng = np.random.default_rng(0)
    all_ids = sorted(c.client_id for c in clients)
    assert resolve_recruitment("all").recruit(stats, rng).federation_ids.tolist() == all_ids
    picked = resolve_recruitment("random-k:4").recruit(stats, rng).federation_ids
    assert len(picked) == 4 and picked.tolist() == sorted(set(picked.tolist()))
    top = resolve_recruitment("top-n-samples:3").recruit(stats, rng).federation_ids
    sizes = {c.client_id: c.n_train for c in clients}
    cut = sorted(sizes.values(), reverse=True)[2]
    assert all(sizes[int(i)] >= cut for i in top) and len(top) == 3
    # k larger than the cohort degrades to everyone
    assert len(resolve_recruitment("random-k:99").recruit(stats, rng).federation_ids) == len(
        clients
    )


def test_custom_recruitment_policy_instance(setup):
    """A user-defined policy passed as an instance, no registration needed."""
    clients, loss_fn, params0 = setup

    class EvenIdsOnly(RecruitmentPolicy):
        def recruit(self, stats, rng):
            ids = np.array(sorted(s.client_id for s in stats if s.client_id % 2 == 0))
            return RecruitmentDecision(federation_ids=ids)

    out = Federation(
        FederationConfig(rounds=1, local_epochs=1, batch_size=4, recruitment=EvenIdsOnly()),
        clients, loss_fn, opt(),
    ).run(params0)
    assert all(int(i) % 2 == 0 for i in out.federation_ids)


def test_recruitment_validation(setup):
    clients, loss_fn, _ = setup

    class Liar(RecruitmentPolicy):
        def recruit(self, stats, rng):
            return RecruitmentDecision(federation_ids=np.array([999]))

    fed = Federation(
        FederationConfig(recruitment=Liar()), clients, loss_fn, opt()
    )
    with pytest.raises(ValueError, match="unknown client ids"):
        fed.build_federation()


# --------------------------------------------------------------------------
# selection policies
# --------------------------------------------------------------------------

def test_round_robin_covers_everyone_deterministically():
    ids = np.arange(10, 0, -1)  # unsorted on purpose
    rng = np.random.default_rng(0)
    state_before = rng.bit_generator.state
    seen = []
    sel = RoundRobinSelection(count=3)
    for rnd in range(4):
        picked = sel.select(rnd, ids, rng)
        assert picked.tolist() == sorted(picked.tolist()) and len(picked) == 3
        seen.extend(picked.tolist())
    assert set(seen) == set(ids.tolist())        # full coverage in ceil(10/3) rounds
    assert rng.bit_generator.state == state_before  # consumed no RNG at all
    # pure-function form agrees
    np.testing.assert_array_equal(
        round_robin_clients(1, ids, 3), sel.select(1, ids, np.random.default_rng(9))
    )


def test_loss_weighted_prefers_lossy_clients():
    ids = np.arange(6)
    sel = LossWeightedSelection(count=2)
    rng = np.random.default_rng(0)
    # before any observation: uniform — every client reachable
    first = sel.select(0, ids, rng)
    assert len(first) == 2
    sel.observe(ids, np.array([0.01, 0.01, 0.01, 0.01, 0.01, 50.0]))
    hits = sum(5 in sel.select(r, ids, rng).tolist() for r in range(40))
    assert hits >= 35  # ~uniform would give ~13/40
    # NaN losses (clients that ran no steps) must not poison the weights
    sel.observe(ids[:1], np.array([np.nan]))
    assert len(sel.select(0, ids, rng)) == 2


def test_selection_must_stay_inside_federation(setup):
    clients, loss_fn, params0 = setup

    class Rogue(UniformSelection):
        def select(self, round_index, federation_ids, rng):
            return np.array([0, 999])

    fed = Federation(
        FederationConfig(rounds=1, selection=Rogue()), clients, loss_fn, opt()
    )
    with pytest.raises(ValueError, match="sorted subset"):
        fed.run(params0)


# --------------------------------------------------------------------------
# aggregators
# --------------------------------------------------------------------------

def test_trimmed_mean_stacked_semantics():
    rng = np.random.default_rng(0)
    stacked = {"w": rng.normal(size=(10, 4, 3)).astype(np.float32)}
    # trim=0 == plain coordinate mean
    np.testing.assert_allclose(
        np.asarray(trimmed_mean_stacked(stacked, 0.0)["w"]),
        stacked["w"].mean(axis=0),
        atol=1e-6,
    )
    # a hijacked client cannot move the trimmed mean far
    poisoned = {"w": stacked["w"].copy()}
    poisoned["w"][3] = 1e6
    clean_mean = np.delete(stacked["w"], 3, axis=0).mean(axis=0)
    robust = np.asarray(trimmed_mean_stacked(poisoned, 0.2)["w"])
    assert float(np.max(np.abs(robust - clean_mean))) < 1.0
    plain = np.asarray(trimmed_mean_stacked(poisoned, 0.0)["w"])
    assert float(np.max(np.abs(plain))) > 1e4  # untrimmed it blows up
    with pytest.raises(ValueError, match="trim"):
        trimmed_mean_stacked(stacked, 0.5)


def test_trimmed_mean_federation_runs(setup):
    clients, loss_fn, params0 = setup
    out = Federation(
        FederationConfig(
            rounds=2, local_epochs=1, batch_size=4, aggregator=TrimmedMeanAggregator(0.2),
            selection="uniform", seed=0,
        ),
        clients, loss_fn, opt(),
    ).run(params0)
    assert len(out.history) == 2
    assert all(np.isfinite(r.mean_local_loss) for r in out.history)


@pytest.mark.parametrize("engine", ["vectorized", "sequential"])
def test_hierarchical_matches_flat_fedavg(setup, engine):
    """Two-level FedAvg telescopes to flat FedAvg: contiguous regional
    groups consume the RNG stream in the same client-major order, so the
    only difference is the (associativity of the) weighted mean — 1e-5."""
    clients, loss_fn, params0 = setup
    base = dict(rounds=2, local_epochs=1, batch_size=4, seed=0, engine=engine)
    flat = Federation(
        FederationConfig(**base, aggregator="fedavg"), clients, loss_fn, opt()
    ).run(params0)
    hier = Federation(
        FederationConfig(**base, aggregator="hierarchical:3"), clients, loss_fn, opt()
    ).run(params0)
    assert_params_close(flat.params, hier.params)
    np.testing.assert_allclose(
        [r.mean_local_loss for r in flat.history],
        [r.mean_local_loss for r in hier.history],
        atol=1e-5,
    )


def test_hierarchical_groups_partition():
    agg = HierarchicalFedAvg(num_regions=3)
    ids = np.arange(10)
    groups = agg.groups(ids)
    assert len(groups) == 3
    np.testing.assert_array_equal(np.concatenate(groups), ids)
    # more regions than participants degrades to singleton groups
    assert len(HierarchicalFedAvg(num_regions=8).groups(np.arange(3))) == 3


# --------------------------------------------------------------------------
# communication accounting
# --------------------------------------------------------------------------

def test_round_record_comm_accounting(setup):
    clients, loss_fn, params0 = setup
    n_tensors = len(jax.tree.leaves(params0))
    nbytes = params_nbytes(params0)
    out = Federation(
        FederationConfig(rounds=2, local_epochs=1, batch_size=4, selection="uniform:0.5"),
        clients, loss_fn, opt(),
    ).run(params0)
    for r in out.history:
        k = len(r.participant_ids)
        assert r.params_down == k * n_tensors
        assert r.params_up == k * n_tensors
        assert r.bytes_transferred == 2 * k * nbytes
    summary = out.summary()
    assert summary["params_down"] == sum(r.params_down for r in out.history)
    assert summary["params_up"] == sum(r.params_up for r in out.history)
    assert summary["bytes_transferred"] == sum(r.bytes_transferred for r in out.history)
    # fewer participants -> fewer bytes: the recruitment claim in comm terms
    small = Federation(
        FederationConfig(rounds=2, local_epochs=1, batch_size=4, selection="uniform:2"),
        clients, loss_fn, opt(),
    ).run(params0)
    assert small.summary()["bytes_transferred"] < summary["bytes_transferred"]
