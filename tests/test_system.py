"""End-to-end behaviour tests: the paper's pipeline from cohort to metrics.

Mirrors the claims of paper section 6 at test scale:
  * recruitment selects a minority of clients,
  * Federated-SRC trains fewer local steps than Federated-SC,
  * all settings produce finite, sane metrics on the hold-out test set.
"""

import numpy as np
import pytest

from repro.experiments.paper import (
    MODEL_SETTINGS,
    ExperimentConfig,
    build_cohort,
    run_setting,
)

EXP = ExperimentConfig(cohort_scale=0.02, rounds=2, local_epochs=1, central_epochs=2)


@pytest.fixture(scope="module")
def cohort():
    return build_cohort(EXP, seed=0)


def test_all_settings_exist():
    assert set(MODEL_SETTINGS) == {
        "central", "federated-ac", "federated-sc", "federated-arc",
        "federated-src", "federated-src-qg", "federated-src-dg",
    }


@pytest.mark.parametrize("setting", ["central", "federated-sc", "federated-src"])
def test_setting_runs_and_reports(setting, cohort):
    out = run_setting(setting, EXP, cohort, seed=0)
    m = out["metrics"]
    for k in ("mae", "mape", "mse", "msle"):
        assert np.isfinite(m[k]) and m[k] >= 0
    assert out["tau_s"] > 0
    assert out["local_steps"] > 0
    if setting == "federated-src":
        assert out["recruited"] is not None
        assert out["federation_size"] == out["recruited"]
    if setting == "federated-sc":
        assert out["recruited"] is None


def test_src_cheaper_than_sc(cohort):
    sc = run_setting("federated-sc", EXP, cohort, seed=0)
    src = run_setting("federated-src", EXP, cohort, seed=0)
    # recruitment shrinks the federation -> fewer clients available per round
    assert src["federation_size"] < sc["federation_size"]


def test_greedy_ablations_recruit_differently(cohort):
    qg = run_setting("federated-src-qg", EXP, cohort, seed=0)
    dg = run_setting("federated-src-dg", EXP, cohort, seed=0)
    balanced = run_setting("federated-src", EXP, cohort, seed=0)
    sizes = {qg["recruited"], dg["recruited"], balanced["recruited"]}
    assert len(sizes) >= 2  # the strategies pick different federations


def test_predictions_in_positive_domain(cohort):
    out = run_setting("central", EXP, cohort, seed=1)
    # MSLE finite implies predictions were valid for log1p (>= 0)
    assert np.isfinite(out["metrics"]["msle"])
