"""Masked-sum secure aggregation: bitwise exactness and dropout recovery.

The design contract under test: masking lives in the wrapping uint64
ring, so the masked sum is *bitwise* equal to the sum of the quantized
inputs — with all survivors the pair masks cancel algebraically, and
under dropout the recovery path regenerates exactly the orphaned masks.
The only tolerance anywhere is the fixed-point quantization itself.
"""

from __future__ import annotations

import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import CohortConfig, build_client_datasets, generate_cohort
from repro.federated import Federation, FederationConfig
from repro.federated.api import resolve_aggregator
from repro.federated.runtime.latency import BernoulliDropout, NeverDropout
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim import AdamW
from repro.privacy.secagg import (
    SecAggFedAvg,
    dequantize_total,
    masked_client_tensors,
    masked_sum,
    pair_masks,
    quantize_leaf,
    ring_offsets,
)


def _quantized(c=7, size=33, seed=0):
    rng = np.random.default_rng(seed)
    values = rng.normal(size=(c, size)).astype(np.float64)
    return quantize_leaf(values, 24)


# ---------------------------------------------------------------------------
# Mask algebra


def test_masked_sum_bitwise_equal_when_all_survive():
    q = _quantized()
    offsets = ring_offsets(7, 3)
    masked = masked_client_tensors(q, seed=5, round_index=2, offsets=offsets)
    # Masking really changed every client's tensor...
    assert not np.array_equal(masked, q)
    total = masked_sum(masked, np.ones(7, bool), 5, 2, offsets)
    # ...yet the sum is bitwise identical to the unmasked quantized sum.
    np.testing.assert_array_equal(total, q.sum(axis=0, dtype=np.uint64))


def test_masked_sum_recovers_exactly_under_dropout():
    q = _quantized()
    offsets = ring_offsets(7, 3)
    masked = masked_client_tensors(q, seed=5, round_index=0, offsets=offsets)
    survivors = np.array([True, False, True, True, False, True, True])
    total = masked_sum(masked, survivors, 5, 0, offsets)
    np.testing.assert_array_equal(
        total, q[survivors].sum(axis=0, dtype=np.uint64)
    )


def test_masked_sum_rejects_total_dropout():
    q = _quantized(c=4)
    offsets = ring_offsets(4, 2)
    masked = masked_client_tensors(q, 0, 0, offsets)
    with pytest.raises(RuntimeError, match="unrecoverable"):
        masked_sum(masked, np.zeros(4, bool), 0, 0, offsets)
    with pytest.raises(ValueError, match="shape"):
        masked_sum(masked, np.ones(3, bool), 0, 0, offsets)


def test_pair_masks_deterministic_per_round_and_offset():
    a = pair_masks(1, 0, 1, 5, 8)
    np.testing.assert_array_equal(a, pair_masks(1, 0, 1, 5, 8))
    assert not np.array_equal(a, pair_masks(1, 1, 1, 5, 8))
    assert not np.array_equal(a, pair_masks(1, 0, 2, 5, 8))


def test_quantization_roundtrip():
    rng = np.random.default_rng(3)
    values = rng.normal(size=(4, 10))
    q = quantize_leaf(values, 24)
    back = dequantize_total(q, 24)
    np.testing.assert_allclose(back, values, atol=2.0**-24)
    # Negative values survive the int64 -> uint64 two's-complement view.
    assert (values < 0).any()


def test_ring_offsets_clamp_to_cohort_size():
    assert ring_offsets(10, 3) == [1, 2, 3]
    assert ring_offsets(4, 8) == [1, 2, 3]  # at most C - 1 distinct pairs
    assert ring_offsets(2, 8) == [1]


# ---------------------------------------------------------------------------
# Aggregator behavior


def test_secagg_aggregate_matches_fedavg_within_quantization():
    rng = np.random.default_rng(0)
    c = 9
    stacked = {
        "w": jnp.asarray(rng.normal(size=(c, 5, 3)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(c, 4)).astype(np.float32)),
    }
    weights = jnp.asarray(rng.uniform(1.0, 5.0, size=c).astype(np.float32))
    agg = SecAggFedAvg()
    out = agg.aggregate(stacked, weights)
    ref = agg.reference_aggregate(stacked, weights)
    for leaf_out, leaf_ref in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(
            np.asarray(leaf_out), np.asarray(leaf_ref), atol=1e-5
        )


def test_secagg_dropout_aggregates_survivors_only():
    rng = np.random.default_rng(1)
    c = 8
    stacked = {"w": jnp.asarray(rng.normal(size=(c, 6)).astype(np.float32))}
    weights = jnp.ones(c, jnp.float32)
    agg = SecAggFedAvg(dropout=0.4, seed=7)
    out = agg.aggregate(stacked, weights)
    survivors = agg.last_survivors
    assert survivors is not None and not survivors.all() and survivors.any()
    ref = np.asarray(stacked["w"])[survivors].mean(axis=0)
    np.testing.assert_allclose(np.asarray(out["w"]), ref, atol=1e-5)


def test_secagg_round_counter_advances_and_resets():
    rng = np.random.default_rng(2)
    stacked = {"w": jnp.asarray(rng.normal(size=(5, 4)).astype(np.float32))}
    weights = jnp.ones(5, jnp.float32)
    agg = SecAggFedAvg(dropout=0.3, seed=1)
    first = np.asarray(agg.aggregate(stacked, weights)["w"]).copy()
    surv_first = agg.last_survivors.copy()
    agg.aggregate(stacked, weights)
    agg.reset_round(0)
    replay = np.asarray(agg.aggregate(stacked, weights)["w"])
    np.testing.assert_array_equal(surv_first, agg.last_survivors)
    np.testing.assert_array_equal(first, replay)


def test_secagg_spec_forms():
    plain = resolve_aggregator("secagg-fedavg")
    assert isinstance(plain, SecAggFedAvg)
    assert isinstance(plain.dropout_model, NeverDropout)
    prob = resolve_aggregator("secagg-fedavg:0.2")
    assert isinstance(prob.dropout_model, BernoulliDropout)
    named = resolve_aggregator("secagg-fedavg:bernoulli:0.1")
    assert isinstance(named.dropout_model, BernoulliDropout)
    with pytest.raises(ValueError, match="neighbor"):
        SecAggFedAvg(neighbors=0)
    with pytest.raises(ValueError, match="fraction_bits"):
        SecAggFedAvg(fraction_bits=64)


# ---------------------------------------------------------------------------
# Full federated run


@functools.lru_cache(maxsize=1)
def _run_pair():
    cohort = generate_cohort(CohortConfig().scaled(0.02), seed=0)
    clients = build_client_datasets(cohort)[:8]
    mcfg = GRUConfig(dropout=0.0, hidden_dim=8, num_layers=1)
    loss_fn = make_loss_fn(mcfg)
    params0 = init_gru(jax.random.key(0), mcfg)

    def run(aggregator):
        config = FederationConfig(
            rounds=2, local_epochs=1, batch_size=16, seed=0,
            aggregator=aggregator, engine="sequential",
        )
        fed = Federation(config, clients, loss_fn, AdamW(learning_rate=1e-2))
        return fed.run(params0)

    return run("fedavg"), run("secagg-fedavg")


def test_secagg_run_matches_sequential_fedavg():
    """End to end, the only deviation from fedavg is quantization.

    Both runs use the sequential engine (secagg's stacked mode forces it)
    so the comparison isolates the masked reduction from engine-level
    float association.
    """
    base, secagg = _run_pair()
    diffs = [
        float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
        for a, b in zip(
            jax.tree.leaves(base.params), jax.tree.leaves(secagg.params)
        )
    ]
    assert max(diffs) < 1e-5
