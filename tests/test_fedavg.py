"""FedAvg aggregation unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.federated.fedavg import aggregate, apply_delta, delta, params_nbytes, tree_allclose


def tree(vals):
    return {"a": jnp.asarray(vals[0]), "b": {"c": jnp.asarray(vals[1])}}


def test_uniform_average():
    t1 = tree([np.ones((2, 2)), np.zeros(3)])
    t2 = tree([3 * np.ones((2, 2)), 2 * np.ones(3)])
    out = aggregate([t1, t2])
    assert np.allclose(out["a"], 2.0)
    assert np.allclose(out["b"]["c"], 1.0)


def test_weighted_by_sample_size():
    t1 = tree([np.zeros((2,)), np.zeros(1)])
    t2 = tree([np.ones((2,)), np.ones(1)])
    out = aggregate([t1, t2], weights=[1, 3])
    assert np.allclose(out["a"], 0.75)


def test_single_client_identity():
    t = tree([np.arange(4.0), np.ones(2)])
    assert tree_allclose(aggregate([t], weights=[17]), t)


def test_invalid_weights_raise():
    t = tree([np.zeros(1), np.zeros(1)])
    with pytest.raises(ValueError):
        aggregate([t, t], weights=[-1, 2])
    with pytest.raises(ValueError):
        aggregate([t, t], weights=[0, 0])
    with pytest.raises(ValueError):
        aggregate([])


@settings(max_examples=25, deadline=None)
@given(
    n_clients=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_convexity_and_idempotence(n_clients, seed):
    """Aggregate lies inside the convex hull per coordinate, and aggregating
    identical replicas is the identity."""
    rng = np.random.default_rng(seed)
    trees = [tree([rng.normal(size=(3, 2)), rng.normal(size=5)]) for _ in range(n_clients)]
    weights = rng.uniform(0.1, 10.0, n_clients)
    out = aggregate(trees, weights)
    for key_fn in (lambda t: t["a"], lambda t: t["b"]["c"]):
        stack = np.stack([np.asarray(key_fn(t)) for t in trees])
        lo, hi = stack.min(axis=0), stack.max(axis=0)
        v = np.asarray(key_fn(out))
        assert np.all(v >= lo - 1e-5) and np.all(v <= hi + 1e-5)
    # idempotence
    same = aggregate([trees[0]] * n_clients, weights)
    assert tree_allclose(same, trees[0], atol=1e-5)


def test_delta_roundtrip():
    rng = np.random.default_rng(0)
    a = tree([rng.normal(size=(2, 2)), rng.normal(size=3)])
    b = tree([rng.normal(size=(2, 2)), rng.normal(size=3)])
    d = delta(b, a)
    assert tree_allclose(apply_delta(a, d), b, atol=1e-6)


def test_params_nbytes():
    t = {"x": jnp.zeros((4, 4), jnp.float32), "y": jnp.zeros(8, jnp.float32)}
    assert params_nbytes(t) == (16 + 8) * 4


def test_trimmed_mean_rejects_half_or_more_with_hint():
    from repro.federated.api import resolve_aggregator
    from repro.federated.fedavg import trimmed_mean_stacked

    stacked = {"w": jnp.zeros((4, 3))}
    # A trim of 0.5+ removes everything; the error suggests the per-tail
    # fraction the caller probably meant.
    with pytest.raises(ValueError, match="did you mean trim=0.25"):
        trimmed_mean_stacked(stacked, 0.5)
    with pytest.raises(ValueError, match="did you mean trim=0.3"):
        trimmed_mean_stacked(stacked, 0.6)
    # A client *count* gets redirected to the fraction form.
    with pytest.raises(ValueError, match="pass the fraction 2/C"):
        trimmed_mean_stacked(stacked, 2.0)
    # Construction-time check: the registry spec fails before any round.
    with pytest.raises(ValueError, match="did you mean trim=0.25"):
        resolve_aggregator("trimmed-mean:0.5")
    # Valid edge: trim just below one half.
    out = trimmed_mean_stacked({"w": jnp.arange(4.0)[:, None]}, 0.49)
    np.testing.assert_allclose(np.asarray(out["w"]), [1.5])
