"""Regression metrics + Welch t-test (pure-numpy scipy replacement)."""

import math

import numpy as np
import pytest

from repro.metrics.regression import evaluate_predictions, mae, mape, mse, msle
from repro.metrics.stats import significance_stars, t_sf, welch_t_test


def test_metric_formulas():
    y = np.array([1.0, 2.0, 4.0])
    yh = np.array([1.0, 3.0, 2.0])
    assert mae(y, yh) == pytest.approx(1.0)
    assert mse(y, yh) == pytest.approx((0 + 1 + 4) / 3)
    assert mape(y, yh) == pytest.approx((0 + 0.5 + 0.5) / 3)
    expected_msle = np.mean((np.log1p(y) - np.log1p(yh)) ** 2)
    assert msle(y, yh) == pytest.approx(expected_msle)
    out = evaluate_predictions(y, yh)
    assert set(out) == {"mae", "mape", "mse", "msle"}


def test_perfect_predictions_zero():
    y = np.linspace(0.5, 10, 20)
    out = evaluate_predictions(y, y)
    assert all(v == 0.0 for v in out.values())


def test_t_sf_reference_values():
    # classic table values: two-sided p for t with df
    assert t_sf(0.0, 10) == pytest.approx(1.0, abs=1e-9)
    assert t_sf(2.228, 10) == pytest.approx(0.05, abs=2e-3)   # t_{0.025, 10}
    assert t_sf(1.96, 1e6) == pytest.approx(0.05, abs=1e-3)   # -> normal
    assert t_sf(3.169, 10) == pytest.approx(0.01, abs=2e-3)


def test_welch_detects_difference():
    rng = np.random.default_rng(0)
    a = rng.normal(0.0, 1.0, 60)
    b = rng.normal(1.0, 1.0, 60)
    t, p = welch_t_test(a, b)
    assert p < 0.001
    t2, p2 = welch_t_test(a, rng.normal(0.0, 1.0, 60))
    assert p2 > 0.01


def test_welch_symmetry():
    rng = np.random.default_rng(1)
    a, b = rng.normal(size=30), rng.normal(size=30) + 0.3
    t_ab, p_ab = welch_t_test(a, b)
    t_ba, p_ba = welch_t_test(b, a)
    assert t_ab == pytest.approx(-t_ba)
    assert p_ab == pytest.approx(p_ba)


def test_significance_stars():
    assert significance_stars(0.005) == "**"
    assert significance_stars(0.03) == "*"
    assert significance_stars(0.2) == ""
    assert significance_stars(float("nan")) == ""


def test_welch_degenerate_inputs():
    t, p = welch_t_test(np.array([1.0]), np.array([1.0, 2.0]))
    assert math.isnan(t) and math.isnan(p)
    t, p = welch_t_test(np.array([2.0, 2.0]), np.array([2.0, 2.0]))
    assert p == 1.0
