"""Device-resident staging vs the rebuild path vs the sequential oracle.

The contract of ``staging="resident"``: client train arrays are uploaded
once per federation, every round stages only a ``(C, T, B)`` int32 index
plan drawn from the *same* numpy RNG stream as ``build_cohort_schedule``,
and the on-device batch gather reproduces the rebuilt schedule's batches
**bitwise** — so aggregated params match the PR-2 rebuild path and the
sequential oracle within the same 1e-5 the engine parity suite uses,
across chunking, donation, and the shard_map mesh path.  Prefetch (the
double-buffered background staging thread) must be a pure overlap: params
bit-identical on and off.  And the point of it all: per-round
host->device ``bytes_staged`` collapses (>=10x; in practice ~100-900x) at
the paper's 189-client federation.
"""

import threading
import time

import jax
import numpy as np
import pytest

from repro.data.device_cohort import (
    build_cohort_plan,
    build_device_cohort,
    pad_cohort_plan,
)
from repro.data.pipeline import (
    ArrayDataset,
    ClientDataset,
    build_cohort_schedule,
)
from repro.federated.cohort import CohortTrainer, chain_split_keys
from repro.federated.server import FederatedConfig, FederatedServer
from repro.federated.staging import StagingPipeline
from repro.launch.mesh import make_data_mesh
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

SEQ_LEN, FEAT = 4, 6


def make_clients(count: int, rng: np.random.Generator, lo: int = 2, hi: int = 9):
    clients = []
    for i, n in enumerate(rng.integers(lo, hi, count)):
        x = rng.normal(size=(int(n), SEQ_LEN, FEAT)).astype(np.float32)
        y = rng.uniform(0.5, 20.0, size=int(n)).astype(np.float32)
        ds = ArrayDataset(x, y)
        clients.append(ClientDataset(client_id=i, train=ds, val=ds))
    return clients


@pytest.fixture(scope="module")
def model():
    cfg = GRUConfig(input_dim=FEAT, hidden_dim=4, num_layers=1)
    return make_loss_fn(cfg), init_gru(jax.random.key(1), cfg)


def run_server(clients, params0, loss_fn, **cfg_kwargs):
    defaults = dict(rounds=2, local_epochs=2, batch_size=4, seed=0)
    defaults.update(cfg_kwargs)
    fed = FederatedConfig(**defaults)
    opt = AdamW(learning_rate=5e-3, weight_decay=5e-3)
    return FederatedServer(fed, clients, loss_fn, opt).run(params0)


def assert_params_close(a, b, atol=1e-5):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol, rtol=0)


# --------------------------------------------------------------------------
# the index plan is the schedule, bit for bit
# --------------------------------------------------------------------------

def test_plan_gathers_schedule_bitwise():
    """Gathering the resident arrays through the plan reproduces the
    rebuilt schedule's x/y/mask arrays exactly — the parity foundation."""
    rng = np.random.default_rng(3)
    sizes = (5, 9, 12)
    data = [
        ArrayDataset(
            rng.normal(size=(n, 2, 3)).astype(np.float32),
            rng.uniform(1, 9, size=n).astype(np.float32),
        )
        for n in sizes
    ]
    batch, epochs = 4, 2
    sched = build_cohort_schedule(data, batch, epochs, np.random.default_rng(7))
    plan = build_cohort_plan(sizes, batch, epochs, np.random.default_rng(7))
    assert plan.pad_index == max(sizes)
    np.testing.assert_array_equal(plan.step_valid, sched.step_valid)
    np.testing.assert_array_equal(plan.weights, sched.weights)
    # emulate the on-device gather on host: pad each client to pad_index+1
    for c, d in enumerate(data):
        xp = np.zeros((plan.pad_index + 1, 2, 3), np.float32)
        yp = np.zeros(plan.pad_index + 1, np.float32)
        xp[: sizes[c]], yp[: sizes[c]] = d.x, d.y
        np.testing.assert_array_equal(xp[plan.sample_idx[c]], sched.x[c])
        np.testing.assert_array_equal(yp[plan.sample_idx[c]], sched.y[c])
        mask = (plan.sample_idx[c] < sizes[c]).astype(np.float32)
        np.testing.assert_array_equal(mask, sched.mask[c])


def test_plan_consumes_rng_like_schedule():
    """Both builders draw the identical RNG stream — after building either,
    the generator state is the same, so rebuild and resident federations
    stay in lockstep round after round (participation sampling included)."""
    rng = np.random.default_rng(11)
    sizes = [int(n) for n in rng.integers(2, 40, 10)]
    data = [
        ArrayDataset(
            np.zeros((n, 2, 2), np.float32), np.zeros(n, np.float32)
        )
        for n in sizes
    ]
    r_sched, r_plan = np.random.default_rng(5), np.random.default_rng(5)
    build_cohort_schedule(data, 8, 3, r_sched)
    build_cohort_plan(sizes, 8, 3, r_plan)
    assert r_sched.bit_generator.state == r_plan.bit_generator.state


def test_pad_cohort_plan():
    plan = build_cohort_plan([5, 9, 12], 4, 1, np.random.default_rng(0))
    padded = pad_cohort_plan(plan, 4)
    assert padded.num_clients == 4
    assert pad_cohort_plan(plan, 1) is plan
    assert pad_cohort_plan(plan, 3) is plan  # already divides
    # dummy client: zero weight, no valid steps, every slot on the pad row
    assert padded.weights[-1] == 0.0
    assert not padded.step_valid[-1].any()
    assert (padded.sample_idx[-1] == plan.pad_index).all()
    # real clients untouched
    np.testing.assert_array_equal(padded.sample_idx[:3], plan.sample_idx)
    np.testing.assert_array_equal(padded.client_rows[:3], plan.client_rows)


def test_plan_rejects_small_pad_index():
    with pytest.raises(ValueError, match="pad_index"):
        build_cohort_plan([5, 9], 4, 1, np.random.default_rng(0), pad_index=7)


def test_device_cohort_layout():
    rng = np.random.default_rng(1)
    clients = make_clients(3, rng, lo=3, hi=8)
    dc = build_device_cohort(clients)
    max_n = max(c.n_train for c in clients)
    assert dc.x.shape == (3, max_n + 1, SEQ_LEN, FEAT)
    assert dc.y.shape == (3, max_n + 1)
    assert dc.pad_index == max_n
    assert dc.nbytes == dc.x.nbytes + dc.y.nbytes
    for c in clients:
        r = dc.row_of(c)
        assert dc.owns(c)
        np.testing.assert_array_equal(np.asarray(dc.x)[r, : c.n_train], c.train.x)
        np.testing.assert_array_equal(np.asarray(dc.y)[r, : c.n_train], c.train.y)
        # rows past n_train (the pad row included) are zero
        assert np.asarray(dc.x)[r, c.n_train :].sum() == 0.0
    stranger = make_clients(1, rng)[0]
    assert not dc.owns(stranger)
    with pytest.raises(KeyError):
        dc.row_of(ClientDataset(client_id=99, train=stranger.train, val=stranger.val))


# --------------------------------------------------------------------------
# engine parity: resident == rebuild == sequential oracle
# --------------------------------------------------------------------------

def test_resident_parity_with_rebuild_and_oracle(model):
    """The acceptance bar: across multiple rounds with uneven client sizes,
    resident staging matches both the rebuild path and the sequential
    per-client oracle within 1e-5 on params and reported losses."""
    loss_fn, params0 = model
    clients = make_clients(12, np.random.default_rng(0), lo=2, hi=30)
    seq = run_server(clients, params0, loss_fn, engine="sequential")
    reb = run_server(clients, params0, loss_fn, engine="vectorized", staging="rebuild")
    res = run_server(clients, params0, loss_fn, engine="vectorized", staging="resident")
    assert_params_close(seq.params, res.params)
    assert_params_close(reb.params, res.params)
    assert seq.total_local_steps == res.total_local_steps
    np.testing.assert_allclose(
        [r.mean_local_loss for r in seq.history],
        [r.mean_local_loss for r in res.history],
        atol=1e-5,
    )


def test_resident_parity_chunked_donated_shard_map(model):
    """Chunking, donation off, and the mesh path change nothing: every
    resident variant agrees with the unchunked resident round to 1e-5
    (and chunk/donation variants to 1e-6, same bars as the engine suite)."""
    loss_fn, params0 = model
    clients = make_clients(11, np.random.default_rng(2), lo=2, hi=20)
    base = run_server(clients, params0, loss_fn, engine="vectorized", staging="resident")
    chunked = run_server(
        clients, params0, loss_fn, engine="vectorized", staging="resident", cohort_chunk=4
    )
    undonated = run_server(
        clients, params0, loss_fn, engine="vectorized", staging="resident",
        donate_buffers=False,
    )
    sharded = run_server(
        clients, params0, loss_fn, engine="vectorized", staging="resident",
        mesh=make_data_mesh(),
    )
    assert_params_close(base.params, chunked.params, atol=1e-6)
    assert_params_close(base.params, undonated.params, atol=0.0)
    assert_params_close(base.params, sharded.params)


def test_resident_parity_with_participation_sampling(model):
    """Random 50% participation: the resident plan builder consumes the
    numpy RNG exactly like the schedule builder, so rebuild and resident
    federations sample identical cohorts and agree on the params."""
    loss_fn, params0 = model
    clients = make_clients(10, np.random.default_rng(4), lo=2, hi=25)
    reb = run_server(
        clients, params0, loss_fn, rounds=3, engine="vectorized", staging="rebuild",
        participation_fraction=0.5, seed=9,
    )
    res = run_server(
        clients, params0, loss_fn, rounds=3, engine="vectorized", staging="resident",
        participation_fraction=0.5, seed=9,
    )
    for rr, rv in zip(reb.history, res.history):
        assert rr.participant_ids == rv.participant_ids
    assert_params_close(reb.params, res.params)


def test_prefetch_on_off_bit_identical(model):
    """The background staging thread is pure overlap: params and losses are
    bit-identical with prefetch on and off, and the prefetching run really
    did stage chunks ahead of the consumer."""
    loss_fn, params0 = model
    clients = make_clients(12, np.random.default_rng(5), lo=2, hi=20)
    results = {}
    stats = {}
    for prefetch in (True, False):
        fed = FederatedConfig(
            rounds=2, local_epochs=1, batch_size=4, seed=0, engine="vectorized",
            staging="resident", cohort_chunk=4, prefetch=prefetch,
        )
        server = FederatedServer(
            fed, clients, loss_fn, AdamW(learning_rate=5e-3, weight_decay=5e-3)
        )
        results[prefetch] = server.run(params0)
        stats[prefetch] = server.cohort_trainer.last_round_stats
    for a, b in zip(
        jax.tree.leaves(results[True].params), jax.tree.leaves(results[False].params)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        [r.mean_local_loss for r in results[True].history],
        [r.mean_local_loss for r in results[False].history],
    )
    # The overlap counter itself is thread-timing-dependent (a loaded CI
    # box can schedule the producer late), so the deterministic >=1 check
    # lives in test_staging_pipeline_really_runs_ahead; here we assert the
    # mechanism engaged and the accounting stays consistent.
    assert stats[True]["prefetch"] and stats[True]["plans_prefetched"] >= 0
    assert not stats[False]["prefetch"] and stats[False]["plans_prefetched"] == 0


# --------------------------------------------------------------------------
# the point: per-round host->device traffic collapses at 189 clients
# --------------------------------------------------------------------------

def test_bytes_staged_collapse_at_189_clients(model):
    """Resident staging moves >=10x fewer host bytes per round than the
    rebuild path at the paper's full 189-client federation (~35x even at
    this smoke scale's tiny 4x6 stays; ~900x at the real 24x38 shape)."""
    loss_fn, params0 = model
    clients = make_clients(189, np.random.default_rng(6))
    staged = {}
    for staging in ("rebuild", "resident"):
        fed = FederatedConfig(
            rounds=1, local_epochs=1, batch_size=8, seed=0,
            engine="vectorized", staging=staging,
        )
        server = FederatedServer(
            fed, clients, loss_fn, AdamW(learning_rate=5e-3, weight_decay=5e-3)
        )
        server.run(params0)
        stats = server.cohort_trainer.last_round_stats
        assert stats["staging"] == staging
        staged[staging] = stats["bytes_staged"]
        if staging == "resident":
            assert stats["bytes_resident"] > 0  # the one-time upload
    assert staged["rebuild"] >= 10 * staged["resident"]


def test_staging_comparison_smoke():
    """The bench harness behind --mode pipeline, at smoke scale: both
    headline numbers are recorded, the byte collapse holds (>=10x), and
    the cross-variant parity guard stays inside the engine tolerance."""
    from repro.experiments.paper import run_staging_comparison

    report = run_staging_comparison(
        rounds=2,
        total_stays=189 * 8,
        batch_size=8,
        cohort_chunk=64,
        variants=("rebuild", "resident"),
        repeats=1,
        verbose=False,
    )
    assert report["num_clients"] == 189
    assert report["bytes_ratio"] >= 10.0
    assert report["speedup"] > 0.0  # recorded; the >=1.5x bar is the bench's
    assert report["max_param_diff"] <= 1e-4
    res = report["variants"]["resident"]
    assert res["bytes_staged_per_round"] < report["variants"]["rebuild"]["bytes_staged_per_round"]


# --------------------------------------------------------------------------
# plumbing: pipeline ordering/errors, resident reuse, device-side keys
# --------------------------------------------------------------------------

def test_staging_pipeline_orders_and_overlaps():
    produced = []

    def stage(k):
        produced.append(k)
        return k * k

    pipe = StagingPipeline(stage, range(6))
    out = list(pipe)
    assert out == [k * k for k in range(6)]
    assert produced == list(range(6))  # strict order: the RNG contract


def test_staging_pipeline_propagates_errors():
    def stage(k):
        if k == 2:
            raise RuntimeError("boom at chunk 2")
        return k

    pipe = StagingPipeline(stage, range(5))
    got = []
    with pytest.raises(RuntimeError, match="boom at chunk 2"):
        for item in pipe:
            got.append(item)
    assert got == [0, 1]


def test_staging_pipeline_close_unblocks_producer():
    release = threading.Event()

    def stage(k):
        if k > 0:
            release.wait(timeout=5.0)
        return k

    pipe = StagingPipeline(stage, range(4))
    it = iter(pipe)
    assert next(it) == 0
    release.set()
    pipe.close()  # must not hang even with items unconsumed
    assert not pipe._thread.is_alive()


def test_staging_pipeline_really_runs_ahead():
    """With a slow consumer, the producer finishes staging the next chunk
    before it is requested (the double-buffer overlap)."""
    times = {}

    def stage(k):
        times[k] = time.perf_counter()
        return k

    pipe = StagingPipeline(stage, range(3))
    it = iter(pipe)
    first = next(it)
    time.sleep(0.15)  # "train" on chunk 0 while chunk 1 stages
    t_request = time.perf_counter()
    second = next(it)
    assert (first, second) == (0, 1)
    assert times[1] < t_request
    assert pipe.prefetched >= 1
    pipe.close()


def test_device_cohort_reused_across_rounds(model):
    """The federation's resident arrays are uploaded once and reused: the
    server's rounds all hit the same DeviceCohort object."""
    loss_fn, params0 = model
    clients = make_clients(6, np.random.default_rng(8), lo=2, hi=12)
    fed = FederatedConfig(
        rounds=3, local_epochs=1, batch_size=4, seed=0,
        engine="vectorized", staging="resident",
    )
    server = FederatedServer(
        fed, clients, loss_fn, AdamW(learning_rate=5e-3, weight_decay=5e-3)
    )
    server.run(params0)
    dc = server.cohort_trainer._device_cohort
    assert dc is not None and all(dc.owns(c) for c in clients)
    # a later round over a subset reuses the attached arrays
    trainer = server.cohort_trainer
    keys = list(jax.random.split(jax.random.key(3), 3))
    trainer.train_cohort(params0, clients[:3], np.random.default_rng(1), keys)
    assert trainer._device_cohort is dc


def test_caller_key_array_survives_donation(model):
    """Regression: a full-range key slice is an identity in jax, so the
    round's eager delete of staged buffers must never reach the caller's
    array — reusing the same device key data across trainers is the
    documented parity workflow."""
    loss_fn, params0 = model
    clients = make_clients(4, np.random.default_rng(10), lo=2, hi=8)
    _, key_data = chain_split_keys(jax.random.key(0), len(clients))
    results = {}
    for staging in ("resident", "rebuild"):
        trainer = CohortTrainer(
            loss_fn, AdamW(learning_rate=5e-3, weight_decay=5e-3),
            batch_size=4, local_epochs=1, staging=staging,
        )
        new_params, _, _ = trainer.train_cohort(
            params0, clients, np.random.default_rng(0), key_data
        )
        jax.block_until_ready(new_params)
        results[staging] = new_params
        assert not key_data.is_deleted()
    assert_params_close(results["resident"], results["rebuild"])


def test_staging_pipeline_runs_at_most_depth_ahead():
    """Regression: the producer takes a slot before staging, so with
    depth=1 it never builds chunk k+2 while chunk k is still in hand."""
    staged = []

    def stage(k):
        staged.append(k)
        return k

    pipe = StagingPipeline(stage, range(4))
    it = iter(pipe)
    assert next(it) == 0  # chunk 0 in hand; producer may stage only chunk 1
    time.sleep(0.3)
    assert staged == [0, 1], f"producer ran ahead: {staged}"
    assert next(it) == 1
    pipe.close()


def test_chain_split_keys_stays_on_device():
    """The vectorized engine consumes the key chain on device; returning
    numpy here would cost a sync + re-upload per round."""
    new_key, key_data = chain_split_keys(jax.random.key(0), 7)
    assert isinstance(key_data, jax.Array)
    assert not isinstance(key_data, np.ndarray)
    assert key_data.shape[0] == 7


def test_unknown_staging_rejected(model):
    loss_fn, _ = model
    with pytest.raises(ValueError, match="staging"):
        FederatedConfig(staging="teleport")
    with pytest.raises(ValueError, match="staging"):
        CohortTrainer(loss_fn, AdamW(), batch_size=4, local_epochs=1, staging="teleport")


def test_round_stats_report_staging(model):
    loss_fn, params0 = model
    clients = make_clients(5, np.random.default_rng(9), lo=2, hi=10)
    trainer = CohortTrainer(
        loss_fn, AdamW(learning_rate=5e-3, weight_decay=5e-3),
        batch_size=4, local_epochs=1, staging="resident",
    )
    keys = list(jax.random.split(jax.random.key(0), len(clients)))
    new_params, losses, steps = trainer.train_cohort(
        params0, clients, np.random.default_rng(0), keys
    )
    jax.block_until_ready(new_params)
    stats = trainer.last_round_stats
    assert stats["staging"] == "resident"
    assert stats["bytes_staged"] > 0
    assert stats["bytes_resident"] == trainer._device_cohort.nbytes
    assert np.isfinite(losses).all()
