"""Unit + property tests for the paper's client recruitment (core contribution)."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.histogram import LOS_BIN_EDGES, l1_divergence, normalize, target_histogram
from repro.core.recruitment import (
    BALANCED,
    ClientStats,
    RecruitmentConfig,
    StreamingRecruiter,
    StreamingRecruitmentConfig,
    recruit,
    recruit_streaming,
    recruitment_curve,
    representativeness,
)

NUM_BINS = len(LOS_BIN_EDGES) - 1


def make_stats(counts_list):
    return [
        ClientStats(client_id=i, counts=np.asarray(c, dtype=np.int64), n=int(np.sum(c)))
        for i, c in enumerate(counts_list)
    ]


# --------------------------------------------------------------------------
# histogram
# --------------------------------------------------------------------------

def test_los_bins_match_paper():
    # paper: [0,1), [1,2), ..., [7,8), [8,14), [14, inf) — ten bins
    assert NUM_BINS == 10
    y = np.array([0.5, 1.5, 7.9, 8.0, 13.99, 14.0, 99.0])
    h = target_histogram(y)
    assert h[0] == 1 and h[1] == 1 and h[7] == 1
    assert h[8] == 2          # [8, 14)
    assert h[9] == 2          # [14, inf)
    assert h.sum() == len(y)


def test_normalize_zero_safe():
    assert normalize(np.zeros(10)).sum() == 0.0


def test_l1_divergence_bounds():
    a = np.array([10, 0, 0]); b = np.array([0, 0, 10])
    assert l1_divergence(a, a) == 0.0
    assert l1_divergence(a, b) == pytest.approx(2.0)  # disjoint supports


# --------------------------------------------------------------------------
# representativeness (eq. 4)
# --------------------------------------------------------------------------

def test_identical_distributions_rank_by_size():
    # same shape, different n: nu differs only through gamma_sa * n^-1/2
    base = np.array([5, 3, 2, 0, 0, 0, 0, 0, 0, 0])
    stats = make_stats([base * 2, base * 8, base * 32])
    nu = representativeness(stats, RecruitmentConfig(gamma_dv=0.5, gamma_sa=0.5))
    assert nu[0] > nu[1] > nu[2]  # bigger client = more representative (lower nu)


def test_divergent_client_penalized():
    typical = np.array([50, 30, 10, 5, 2, 1, 1, 1, 0, 0])
    outlier = np.array([0, 0, 0, 0, 0, 0, 0, 0, 30, 70])  # long-stay-only hospital
    stats = make_stats([typical, typical, typical, outlier])
    nu = representativeness(stats, RecruitmentConfig(gamma_dv=1.0, gamma_sa=0.0))
    assert nu[3] > nu[:3].max()


def test_gamma_weights_move_nu():
    a = np.array([50, 30, 20, 0, 0, 0, 0, 0, 0, 0])
    b = np.array([1, 1, 1, 1, 1, 1, 1, 1, 1, 1])
    stats = make_stats([a, b])
    qg = representativeness(stats, RecruitmentConfig(gamma_dv=1.0, gamma_sa=0.01))
    dg = representativeness(stats, RecruitmentConfig(gamma_dv=0.01, gamma_sa=1.0))
    # quality-greedy cares about shape, data-greedy about size
    assert not np.allclose(qg, dg)


# --------------------------------------------------------------------------
# recruitment (threshold crossing)
# --------------------------------------------------------------------------

def test_gamma_th_one_recruits_everyone():
    rng = np.random.default_rng(0)
    stats = make_stats([rng.integers(1, 100, NUM_BINS) for _ in range(23)])
    res = recruit(stats, RecruitmentConfig(gamma_th=1.0))
    assert res.num_recruited == 23
    assert sorted(res.recruited_ids.tolist()) == list(range(23))


def test_recruited_are_lowest_nu():
    rng = np.random.default_rng(1)
    stats = make_stats([rng.integers(1, 100, NUM_BINS) for _ in range(40)])
    res = recruit(stats, BALANCED)
    nu = res.nu
    recruited_nu = nu[np.isin(res.client_ids, res.recruited_ids)]
    excluded_nu = nu[~np.isin(res.client_ids, res.recruited_ids)]
    assert res.num_recruited >= 1
    assert recruited_nu.max() <= excluded_nu.min() + 1e-12


# Shared strategies for the recruitment property tests.  A population is a
# list of (histogram, sample-size) pairs — sizes drawn independently of the
# histogram mass so the n^-1/2 term is exercised on its own.  Everything
# here works under both real hypothesis and tests/_hypothesis_fallback.
HISTOGRAMS = st.lists(st.integers(0, 50), min_size=NUM_BINS, max_size=NUM_BINS).filter(
    lambda c: sum(c) > 0
)
POPULATIONS = st.lists(
    st.tuples(HISTOGRAMS, st.integers(1, 5000)), min_size=2, max_size=20
)
GAMMA_PAIRS = st.tuples(
    st.floats(0.01, 2.0, allow_nan=False),
    st.floats(0.0, 2.0, allow_nan=False),
)


def make_stats_sized(population):
    """ClientStats with independently drawn histogram and sample size.

    ``n`` is clamped up to the histogram mass (a client can have unlabeled
    stays — mass < n — but never more counts than stays), so the n^-1/2 term
    is still exercised independently of the histogram shape."""
    return [
        ClientStats(
            client_id=i,
            counts=np.asarray(c, dtype=np.int64),
            n=max(int(n), int(np.sum(c))),
        )
        for i, (c, n) in enumerate(population)
    ]


@settings(max_examples=30, deadline=None)
@given(
    data=st.lists(
        st.lists(st.integers(0, 50), min_size=NUM_BINS, max_size=NUM_BINS).filter(
            lambda c: sum(c) > 0
        ),
        min_size=2,
        max_size=25,
    ),
    gammas=st.tuples(
        st.floats(0.01, 2.0, allow_nan=False),
        st.floats(0.0, 2.0, allow_nan=False),
    ),
)
def test_property_recruitment_invariants(data, gammas):
    """For any population and weights: recruited set is a non-empty subset,
    nu is finite-positive, and num_recruited is monotone in gamma_th."""
    stats = make_stats(data)
    gdv, gsa = gammas
    counts = []
    for gth in (0.05, 0.25, 0.5, 0.75, 1.0):
        cfg = RecruitmentConfig(gamma_dv=gdv, gamma_sa=gsa, gamma_th=gth)
        res = recruit(stats, cfg)
        assert 1 <= res.num_recruited <= len(stats)
        assert np.all(np.isfinite(res.nu)) and np.all(res.nu >= 0)
        assert len(set(res.recruited_ids.tolist())) == res.num_recruited
        counts.append(res.num_recruited)
    assert counts == sorted(counts)          # monotone in gamma_th
    assert counts[-1] == len(stats)          # gamma_th = 1 -> everyone


@settings(max_examples=20, deadline=None)
@given(perm_seed=st.integers(0, 2**31 - 1))
def test_property_order_invariance(perm_seed):
    """Recruitment outcome is invariant to client presentation order."""
    rng = np.random.default_rng(7)
    data = [rng.integers(1, 60, NUM_BINS) for _ in range(17)]
    stats = make_stats(data)
    res_a = recruit(stats, BALANCED)
    perm = np.random.default_rng(perm_seed).permutation(len(stats))
    res_b = recruit([stats[i] for i in perm], BALANCED)
    assert sorted(res_a.recruited_ids.tolist()) == sorted(res_b.recruited_ids.tolist())


@settings(max_examples=25, deadline=None)
@given(population=POPULATIONS, gammas=GAMMA_PAIRS)
def test_property_greedy_threshold_crossing(population, gammas):
    """Eq. 5, exactly: recruitment is the shortest ascending-nu prefix whose
    cumulative representativeness reaches iota — plus the crossing client."""
    stats = make_stats_sized(population)
    gdv, gsa = gammas
    cfg = RecruitmentConfig(gamma_dv=gdv, gamma_sa=gsa, gamma_th=0.35)
    res = recruit(stats, cfg)
    order = np.argsort(res.nu, kind="stable")
    k = res.num_recruited
    # the recruited ids ARE the ascending-nu greedy prefix, in nu order
    np.testing.assert_array_equal(res.recruited_ids, res.client_ids[order][:k])
    cumulative = np.cumsum(res.nu[order])
    assert res.iota == pytest.approx(cfg.gamma_th * res.nu_g)
    if k < len(stats):
        # sum through the recruited prefix crossed the threshold ...
        assert cumulative[k - 1] >= res.iota - 1e-9
    if k >= 2:
        # ... and no shorter prefix did (the one before the crosser is below)
        assert cumulative[k - 2] < res.iota + 1e-9


@settings(max_examples=25, deadline=None)
@given(population=POPULATIONS, gammas=GAMMA_PAIRS)
def test_property_iota_monotone_and_nested(population, gammas):
    """gamma_th up => iota up and the recruited set only ever grows (the
    greedy order is fixed by nu, so recruitment sets are nested prefixes),
    reaching the full population at gamma_th = 1.0."""
    stats = make_stats_sized(population)
    gdv, gsa = gammas
    prev_iota, prev_ids = -np.inf, set()
    for gth in (0.05, 0.2, 0.5, 0.8, 1.0):
        res = recruit(stats, RecruitmentConfig(gamma_dv=gdv, gamma_sa=gsa, gamma_th=gth))
        assert res.iota >= prev_iota - 1e-12
        ids = set(res.recruited_ids.tolist())
        assert prev_ids <= ids
        prev_iota, prev_ids = res.iota, ids
    assert len(prev_ids) == len(stats)  # gamma_th = 1.0 recruits everyone


@settings(max_examples=20, deadline=None)
@given(population=POPULATIONS, perm_seed=st.integers(0, 2**31 - 1))
def test_property_permutation_invariance_random_populations(population, perm_seed):
    """For arbitrary drawn populations, recruitment does not depend on the
    order clients are presented in: nu values travel with their client and
    the recruited nu multiset is unchanged.  (Ties in nu may legitimately
    swap *which* tied client crosses the threshold, so id-set equality is
    only asserted when all nu are distinct.)"""
    stats = make_stats_sized(population)
    perm = np.random.default_rng(perm_seed).permutation(len(stats))
    res_a = recruit(stats, BALANCED)
    res_b = recruit([stats[int(i)] for i in perm], BALANCED)
    np.testing.assert_allclose(res_a.nu[perm], res_b.nu, rtol=0, atol=0)
    assert res_a.num_recruited == res_b.num_recruited
    assert res_a.nu_g == pytest.approx(res_b.nu_g)
    nu_by_id = {int(i): float(v) for i, v in zip(res_a.client_ids, res_a.nu)}
    recruited_nu_a = sorted(nu_by_id[int(i)] for i in res_a.recruited_ids)
    recruited_nu_b = sorted(nu_by_id[int(i)] for i in res_b.recruited_ids)
    np.testing.assert_allclose(recruited_nu_a, recruited_nu_b, rtol=0, atol=0)
    if len(set(res_a.nu.tolist())) == len(stats):
        assert sorted(res_a.recruited_ids.tolist()) == sorted(res_b.recruited_ids.tolist())


def test_recruitment_curve_matches_paper_shape():
    """Fig. 2: num recruited grows with gamma_th, hits all clients at 1.0."""
    rng = np.random.default_rng(3)
    stats = make_stats([rng.integers(1, 100, NUM_BINS) * rng.integers(1, 50) for _ in range(189)])
    curve = recruitment_curve(stats, BALANCED, [0.05, 0.1, 0.3, 0.6, 1.0])
    ns = [n for _, n in curve]
    assert ns == sorted(ns)
    assert ns[-1] == 189
    assert ns[0] < 189 // 2  # low threshold recruits a minority


def test_invalid_configs_raise():
    with pytest.raises(ValueError):
        RecruitmentConfig(gamma_th=0.0)
    with pytest.raises(ValueError):
        RecruitmentConfig(gamma_th=1.5)
    with pytest.raises(ValueError):
        RecruitmentConfig(gamma_dv=-1.0)
    with pytest.raises(ValueError):
        ClientStats(client_id=0, counts=np.ones(10), n=0)


# --------------------------------------------------------------------------
# disclosure validation + mass-normalized divergence (bugfix regressions)
# --------------------------------------------------------------------------

def test_counts_exceeding_n_rejected():
    # a histogram can never count more stays than the client reports having
    with pytest.raises(ValueError, match="exceeds reported n"):
        ClientStats(client_id=3, counts=np.full(10, 2), n=4)
    # fewer is fine: stays may lack an LoS label
    ClientStats(client_id=3, counts=np.full(10, 2), n=40)


def test_divergence_normalized_by_histogram_mass():
    """Two clients with the *same* LoS distribution must get the same
    divergence term even if one has unlabeled stays (mass < n).  The old
    code divided by n, under-scaling the partially-labeled client's p_local
    so it no longer summed to 1 and its divergence was biased upward."""
    shape = np.array([30, 10, 5, 3, 2, 0, 0, 0, 0, 0])
    fully = ClientStats(client_id=0, counts=shape, n=int(shape.sum()))
    partial = ClientStats(client_id=1, counts=shape, n=int(shape.sum()) * 2)
    nu = representativeness([fully, partial], RecruitmentConfig(gamma_dv=1.0, gamma_sa=0.0))
    assert nu[0] == pytest.approx(nu[1], abs=1e-12)


# --------------------------------------------------------------------------
# threshold-crossing edges (bugfix regressions)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n", [3, 4, 5, 7])
def test_exact_tie_recruits_through_crossing_only(n):
    """iota landing exactly on a cumulative boundary recruits up to and
    including the crossing client — never one past it.  With gamma_dv=0 and
    identical sizes every nu equals n^-1/2, so gamma_th=0.4 over 10 clients
    makes the 4th prefix an exact mathematical tie with iota; irrational
    nu values (n=3,5,7) exercise the float-rounding side of the tie."""
    shape = np.array([5, 3, 2, 0, 0, 0, 0, 0, 0, 0])
    stats = [ClientStats(client_id=i, counts=shape * n, n=int(shape.sum()) * n) for i in range(10)]
    cfg = RecruitmentConfig(gamma_dv=0.0, gamma_sa=1.0, gamma_th=0.4)
    res = recruit(stats, cfg)
    assert res.num_recruited == 4


def test_full_threshold_with_zero_nu_population():
    """All-identical distributions with gamma_sa=0 give nu == 0 everywhere;
    gamma_th=1.0 must still recruit the whole population (the old crossing
    logic found iota=0 at the first client and recruited exactly one)."""
    shape = np.array([4, 3, 2, 1, 0, 0, 0, 0, 0, 0])
    stats = [ClientStats(client_id=i, counts=shape, n=int(shape.sum())) for i in range(25)]
    res = recruit(stats, RecruitmentConfig(gamma_dv=1.0, gamma_sa=0.0, gamma_th=1.0))
    assert res.num_recruited == 25
    assert res.nu_g == 0.0


def test_is_recruited_matches_isin():
    rng = np.random.default_rng(11)
    stats = make_stats([rng.integers(1, 100, NUM_BINS) for _ in range(60)])
    res = recruit(stats, BALANCED)
    for cid in res.client_ids:
        assert res.is_recruited(int(cid)) == bool(np.isin(cid, res.recruited_ids))
    assert not res.is_recruited(10_000)


# --------------------------------------------------------------------------
# streaming recruitment (population scale)
# --------------------------------------------------------------------------

def random_population(num, seed=0, lo=1, hi=400):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(num):
        n = int(rng.integers(lo, hi))
        counts = rng.multinomial(n, rng.dirichlet(np.full(NUM_BINS, 0.7)))
        out.append(ClientStats(client_id=i, counts=counts, n=n))
    return out


def test_streaming_exact_parity_at_paper_scale():
    """Populations within the exact buffer (default 1024 >= 10^3) delegate
    to the exact oracle: identical participant sets, nu_g, and iota."""
    stats = random_population(1000, seed=5)
    exact = recruit(stats, BALANCED)
    streamed = recruit_streaming(iter(stats), BALANCED)
    assert streamed.mode == "exact"
    assert sorted(streamed.recruited_ids.tolist()) == sorted(exact.recruited_ids.tolist())
    assert streamed.nu_g == pytest.approx(exact.nu_g, rel=0, abs=0)
    assert streamed.iota == pytest.approx(exact.iota, rel=0, abs=0)
    assert streamed.clients_seen == 1000


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_streaming_sketch_tolerance(seed):
    """Above the exact buffer the sketch path carries a tolerance contract:
    num_recruited within a few percent of the exact oracle and the
    participant sets nearly identical (pool candidates are re-scored
    exactly; only the iota estimate moves the cutoff)."""
    stats = random_population(3000, seed=seed)
    exact = recruit(stats, BALANCED)
    streamed = recruit_streaming(
        iter(stats),
        BALANCED,
        stream=StreamingRecruitmentConfig(exact_buffer=200, pool_size=3000),
    )
    assert streamed.mode == "sketch"
    assert not streamed.pool_exhausted
    rel = abs(streamed.num_recruited - exact.num_recruited) / exact.num_recruited
    assert rel <= 0.05
    overlap = len(set(streamed.recruited_ids) & set(exact.recruited_ids))
    assert overlap / exact.num_recruited >= 0.9
    # the sketch's independent count estimate lands in the same ballpark
    assert abs(streamed.estimated_num_recruited - exact.num_recruited) <= 0.15 * exact.num_recruited


def test_streaming_order_robust():
    """The sketch decision may move the cutoff by a few clients across
    presentation orders, but stays within the tolerance contract."""
    stats = random_population(2500, seed=9)
    base = recruit_streaming(
        iter(stats), BALANCED,
        stream=StreamingRecruitmentConfig(exact_buffer=128, pool_size=2500),
    )
    perm = np.random.default_rng(0).permutation(len(stats))
    shuffled = recruit_streaming(
        (stats[int(i)] for i in perm), BALANCED,
        stream=StreamingRecruitmentConfig(exact_buffer=128, pool_size=2500),
    )
    rel = abs(base.num_recruited - shuffled.num_recruited) / base.num_recruited
    assert rel <= 0.05


def test_streaming_gamma_th_one_recruits_everyone():
    stats = random_population(600, seed=3)
    cfg = RecruitmentConfig(gamma_dv=0.5, gamma_sa=0.5, gamma_th=1.0)
    streamed = recruit_streaming(
        iter(stats), cfg, stream=StreamingRecruitmentConfig(exact_buffer=64, pool_size=32)
    )
    assert streamed.mode == "sketch"
    assert sorted(streamed.recruited_ids.tolist()) == list(range(600))


def test_streaming_pool_exhaustion_flagged():
    """A pool too small to hold the iota crossing truncates num_recruited —
    that must be flagged and warned about, never silent."""
    stats = random_population(800, seed=4)
    with pytest.warns(UserWarning, match="pool"):
        streamed = recruit_streaming(
            iter(stats), BALANCED,
            stream=StreamingRecruitmentConfig(exact_buffer=32, pool_size=24),
        )
    assert streamed.pool_exhausted
    assert streamed.num_recruited == 24


def test_streaming_recruiter_lifecycle():
    stats = random_population(50, seed=6)
    rec = StreamingRecruiter(BALANCED)
    rec.extend(stats)
    first = rec.finalize()
    assert rec.finalize() is first          # idempotent
    with pytest.raises(RuntimeError):
        rec.observe(stats[0])               # sealed after finalize
    with pytest.raises(ValueError):
        StreamingRecruiter(BALANCED).finalize()  # empty stream
    assert first.is_recruited(int(first.recruited_ids[0]))
    excluded = set(range(50)) - set(first.recruited_ids.tolist())
    if excluded:
        assert not first.is_recruited(next(iter(excluded)))
