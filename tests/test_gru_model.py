"""The paper's GRU model: shapes, positivity, loss, dropout, pallas parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gru import GRUConfig, count_params, gru_apply, init_gru, make_loss_fn, msle_loss

RNG = np.random.default_rng(0)
CFG = GRUConfig()  # paper Table 1: 2 layers, N=32, dropout 0.05, input 38


def test_output_shape_and_positivity():
    params = init_gru(jax.random.key(0), CFG)
    x = jnp.asarray(RNG.normal(size=(9, 24, 38)), jnp.float32)
    y = gru_apply(params, CFG, x)
    assert y.shape == (9,)
    assert bool(jnp.all(y >= 0))  # eq. (2): ReLU head, LoS cannot be negative


def test_param_count_matches_architecture():
    params = init_gru(jax.random.key(0), CFG)
    n, f, h = 32, 38, 32
    expected = (f * 3 * n + h * 3 * n + 6 * n) + (h * 3 * h + h * 3 * h + 6 * h) + (h + 1)
    assert count_params(params) == expected


def test_msle_loss_properties():
    y = jnp.asarray([1.0, 2.0, 3.0])
    assert float(msle_loss(y, y)) == 0.0
    assert float(msle_loss(y, y + 1)) > 0
    # masked entries do not contribute
    m = jnp.asarray([1.0, 1.0, 0.0])
    full = msle_loss(y[:2], (y + 5)[:2])
    masked = msle_loss(y, y.at[2].set(99.0) + 5 * 0 + jnp.asarray([5.0, 5.0, 0.0]), m)
    assert float(masked) == pytest.approx(float(full), rel=1e-5)


def test_dropout_train_vs_eval():
    params = init_gru(jax.random.key(0), CFG)
    x = jnp.asarray(RNG.normal(size=(4, 24, 38)), jnp.float32)
    y_eval = gru_apply(params, CFG, x)
    y_tr1 = gru_apply(params, CFG, x, train=True, rng=jax.random.key(1))
    y_tr2 = gru_apply(params, CFG, x, train=True, rng=jax.random.key(2))
    assert not np.allclose(np.asarray(y_tr1), np.asarray(y_tr2))
    assert np.allclose(np.asarray(y_eval), np.asarray(gru_apply(params, CFG, x)))


def test_loss_fn_and_grads():
    params = init_gru(jax.random.key(0), CFG)
    loss_fn = make_loss_fn(CFG)
    x = jnp.asarray(RNG.normal(size=(8, 24, 38)), jnp.float32)
    y = jnp.asarray(RNG.uniform(0.5, 10, 8), jnp.float32)
    mask = jnp.ones(8)
    loss, grads = jax.value_and_grad(loss_fn)(params, (x, y, mask), jax.random.key(0))
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))


def test_pallas_path_matches_scan():
    params = init_gru(jax.random.key(0), CFG)
    x = jnp.asarray(RNG.normal(size=(5, 24, 38)), jnp.float32)
    y0 = gru_apply(params, CFG, x)
    y1 = gru_apply(params, GRUConfig(use_pallas=True), x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)
