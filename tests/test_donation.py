"""Donated round buffers: invalidation semantics + real peak-memory wins.

The vectorized engine jits its round step with ``donate_argnums`` on the
cross-chunk accumulator (aliased in place by XLA) and eagerly releases each
chunk's device-resident schedule once the step consuming it returns.  Two
properties are load-bearing for the 189-client paper federation:

* donated buffers are genuinely *gone* — jax raises on any reuse (the
  accumulator from chunk k cannot silently alias stale memory in chunk k+1);
* the round's peak live-buffer footprint is strictly lower than the
  non-donated path's (which holds the previous chunk's schedule while
  staging the next one).
"""

import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ArrayDataset, ClientDataset, build_cohort_schedule
from repro.federated.cohort import CohortTrainer
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

SEQ_LEN, FEAT = 4, 6


def make_clients(count: int, n: int, rng: np.random.Generator) -> list[ClientDataset]:
    clients = []
    for i in range(count):
        x = rng.normal(size=(n, SEQ_LEN, FEAT)).astype(np.float32)
        y = rng.uniform(0.5, 20.0, size=n).astype(np.float32)
        ds = ArrayDataset(x, y)
        clients.append(ClientDataset(client_id=i, train=ds, val=ds))
    return clients


@pytest.fixture(scope="module")
def model():
    cfg = GRUConfig(input_dim=FEAT, hidden_dim=4, num_layers=1)
    return make_loss_fn(cfg), init_gru(jax.random.key(1), cfg)


def make_trainer(loss_fn, donate: bool, chunk: int | None = None) -> CohortTrainer:
    return CohortTrainer(
        loss_fn=loss_fn,
        optimizer=AdamW(learning_rate=5e-3, weight_decay=5e-3),
        batch_size=4,
        local_epochs=1,
        cohort_chunk=chunk,
        donate=donate,
    )


def run_round(trainer, params, clients, seed=0):
    keys = list(jax.random.split(jax.random.key(seed), len(clients)))
    new_params, losses, steps = trainer.train_cohort(
        params, clients, np.random.default_rng(seed), keys
    )
    jax.block_until_ready(new_params)
    return new_params


def test_donated_accumulator_is_invalidated(model):
    """After the round step runs, the donated accumulator input is deleted
    and any reuse raises — XLA really did alias it into the output."""
    loss_fn, params = model
    trainer = make_trainer(loss_fn, donate=True)
    clients = make_clients(4, 8, np.random.default_rng(0))
    sched = build_cohort_schedule([c.train for c in clients], 4, 1, np.random.default_rng(1))
    key_data = jnp.stack(
        [jax.random.key_data(k) for k in jax.random.split(jax.random.key(0), 4)]
    )
    acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    acc_leaves = jax.tree.leaves(acc)
    out_acc, _ = trainer._round(
        params,
        acc,
        jnp.asarray(sched.x),
        jnp.asarray(sched.y),
        jnp.asarray(sched.mask),
        jnp.asarray(sched.step_valid),
        key_data,
        jnp.asarray(sched.weights),
    )
    jax.block_until_ready(out_acc)
    assert all(leaf.is_deleted() for leaf in acc_leaves)
    with pytest.raises(RuntimeError, match="deleted"):
        _ = acc_leaves[0] + 1.0
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(acc_leaves[-1])
    # the round's *output* accumulator is alive and well
    assert not any(leaf.is_deleted() for leaf in jax.tree.leaves(out_acc))


def test_undonated_buffers_survive(model):
    loss_fn, params = model
    trainer = make_trainer(loss_fn, donate=False)
    clients = make_clients(3, 8, np.random.default_rng(2))
    sched = build_cohort_schedule([c.train for c in clients], 4, 1, np.random.default_rng(1))
    key_data = jnp.stack(
        [jax.random.key_data(k) for k in jax.random.split(jax.random.key(0), 3)]
    )
    acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    out_acc, _ = trainer._round(
        params,
        acc,
        jnp.asarray(sched.x),
        jnp.asarray(sched.y),
        jnp.asarray(sched.mask),
        jnp.asarray(sched.step_valid),
        key_data,
        jnp.asarray(sched.weights),
    )
    jax.block_until_ready(out_acc)
    assert not any(leaf.is_deleted() for leaf in jax.tree.leaves(acc))


def test_peak_live_buffers_strictly_lower_with_donation(model):
    """Across a chunked round, the donated path's peak live-buffer count and
    bytes are strictly below the plain path's (which keeps each consumed
    chunk's schedule alive until the next one is already staged)."""
    loss_fn, params = model
    clients = make_clients(12, 12, np.random.default_rng(3))
    stats = {}
    results = {}
    for donate in (False, True):
        gc.collect()
        trainer = make_trainer(loss_fn, donate=donate, chunk=4)
        results[donate] = run_round(trainer, params, clients)
        stats[donate] = trainer.last_round_stats
    assert stats[False]["chunks"] == stats[True]["chunks"] == 3
    assert stats[True]["peak_live_buffers"] < stats[False]["peak_live_buffers"]
    assert stats[True]["peak_live_bytes"] < stats[False]["peak_live_bytes"]
    # donation is a memory optimization only: results are bit-identical
    for a, b in zip(jax.tree.leaves(results[False]), jax.tree.leaves(results[True])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_round_stats_populated(model):
    loss_fn, params = model
    trainer = make_trainer(loss_fn, donate=True)
    clients = make_clients(5, 8, np.random.default_rng(4))
    run_round(trainer, params, clients)
    stats = trainer.last_round_stats
    assert stats is not None
    assert stats["donated"] is True
    assert stats["chunks"] == 1 and stats["shards"] >= 1
    assert stats["peak_live_buffers"] > 0 and stats["peak_live_bytes"] > 0
