"""Population-scale staging: LRU resident pools, the static-slice fast
path, and the staging-pipeline error contract.

The contract of ``resident_budget_bytes``: a federation whose baked cohort
exceeds the budget trains out of a bounded LRU pool of resident rows —
rows upload lazily per round via ``ensure_resident`` (run once per round,
before any plan is staged, so prefetch never races an eviction) — and the
aggregated params match the fully resident path within the engine parity
suite's 1e-5.  The slice fast path is the same kind of claim: when a
chunk's resident rows form one contiguous (shard-aligned) run, selecting
them with a static ``lax.slice`` instead of ``jnp.take`` must be a pure
routing change, bit-identical params.  And ``StagingPipeline.close`` must
never swallow a producer exception the consumer didn't collect, nor
silently abandon a stuck producer thread.
"""

import logging
import threading
import time

import jax
import numpy as np
import pytest

from repro.data.device_cohort import (
    build_cohort_plan,
    build_device_cohort,
    pad_cohort_plan,
)
from repro.data.pipeline import ArrayDataset, ClientDataset
from repro.federated.cohort import CohortTrainer, chain_split_keys
from repro.federated.staging import StagingPipeline
from repro.launch.mesh import make_data_mesh
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

SEQ_LEN, FEAT = 4, 6


def row_bytes_of(clients) -> int:
    """One padded client row in the device cohort these clients would bake:
    ``(max_n + 1)`` samples of x plus y."""
    max_n = max(c.n_train for c in clients)
    return (max_n + 1) * SEQ_LEN * FEAT * 4 + (max_n + 1) * 4


def make_clients(count: int, rng: np.random.Generator, lo: int = 2, hi: int = 9):
    clients = []
    for i, n in enumerate(rng.integers(lo, hi, count)):
        x = rng.normal(size=(int(n), SEQ_LEN, FEAT)).astype(np.float32)
        y = rng.uniform(0.5, 20.0, size=int(n)).astype(np.float32)
        ds = ArrayDataset(x, y)
        clients.append(ClientDataset(client_id=i, train=ds, val=ds))
    return clients


@pytest.fixture(scope="module")
def model():
    cfg = GRUConfig(input_dim=FEAT, hidden_dim=4, num_layers=1)
    return make_loss_fn(cfg), init_gru(jax.random.key(1), cfg)


def make_trainer(loss_fn, **kwargs):
    defaults = dict(batch_size=4, local_epochs=1, staging="resident")
    defaults.update(kwargs)
    return CohortTrainer(
        loss_fn, AdamW(learning_rate=5e-3, weight_decay=5e-3), **defaults
    )


def assert_params_close(a, b, atol=1e-5):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol, rtol=0)


def run_sampled_rounds(trainer, params, clients, rounds=4, cohort_size=8):
    """Identical sampled-subset rounds for any trainer: same plan RNG, same
    participation draws, same key chain — so two trainers differ only in
    how rows reach the device."""
    trainer.attach_device_cohort(clients)  # the full federation, not a round
    plan_rng = np.random.default_rng(0)
    pick_rng = np.random.default_rng(42)
    key = jax.random.key(7)
    for _ in range(rounds):
        ids = np.sort(pick_rng.choice(len(clients), size=cohort_size, replace=False))
        cohort = [clients[int(i)] for i in ids]
        key, subs = chain_split_keys(key, len(cohort))
        params, _, _ = trainer.train_cohort(
            params, cohort, plan_rng, subs, steps_per_epoch=2
        )
    return jax.block_until_ready(params)


# --------------------------------------------------------------------------
# the LRU pool is a pure memory bound: params match fully resident
# --------------------------------------------------------------------------

def test_pooled_rounds_match_fully_resident(model):
    """Four sampled-subset rounds through a 10-row pool (evicting between
    rounds) aggregate the same params as the same rounds against the fully
    resident cohort — residency is transport, not math."""
    loss_fn, params0 = model
    clients = make_clients(30, np.random.default_rng(5))
    rb = row_bytes_of(clients)
    full = run_sampled_rounds(make_trainer(loss_fn), params0, clients)
    pooled_trainer = make_trainer(loss_fn, resident_budget_bytes=10 * rb)
    pooled = run_sampled_rounds(pooled_trainer, params0, clients)
    dc = pooled_trainer._device_cohort
    assert dc.is_pooled and dc.pool_rows == 10
    assert dc.evictions > 0, "4 rounds of 8 from 30 clients must evict"
    assert_params_close(pooled, full)
    stats = pooled_trainer.last_round_stats
    assert stats["pool"] and stats["pool_rows"] == 10
    assert 0 <= stats["pool_uploads"] <= 8  # this round's delta, not the total
    assert dc.nbytes == 10 * rb


def test_lru_evicts_oldest_untouched_and_reuploads_correctly(model):
    _, _ = model
    clients = make_clients(6, np.random.default_rng(2), lo=3, hi=9)
    rb = row_bytes_of(clients)
    dc = build_device_cohort(clients, resident_budget_bytes=4 * rb)
    assert dc.pool_rows == 4
    assert dc.ensure_resident(clients[:4]) == 4
    assert dc.ensure_resident([clients[0], clients[1]]) == 0  # refresh recency
    assert dc.hits == 2
    assert dc.ensure_resident([clients[4]]) == 1  # c2 is now the LRU victim
    assert dc.evictions == 1
    assert 2 not in dc.rows and {0, 1, 3, 4} <= dc.rows.keys()
    # the evicted client's row was handed to c4 with its data re-staged
    c4 = clients[4]
    row = np.asarray(dc.x[dc.row_of(c4)])
    np.testing.assert_array_equal(row[: c4.n_train], c4.train.x)
    np.testing.assert_array_equal(row[c4.n_train :], 0.0)
    np.testing.assert_array_equal(
        np.asarray(dc.y[dc.row_of(c4)])[: c4.n_train], c4.train.y
    )
    # bringing c2 back is an upload again, not a hit
    assert dc.ensure_resident([clients[2]]) == 1
    assert dc.uploads == 6
    assert dc.bytes_uploaded == 6 * rb


def test_round_cohort_larger_than_pool_rejected(model):
    clients = make_clients(8, np.random.default_rng(3))
    dc = build_device_cohort(clients, resident_budget_bytes=3 * row_bytes_of(clients))
    with pytest.raises(ValueError, match="exceeds the resident pool"):
        dc.ensure_resident(clients[:4])


def test_budget_below_one_row_rejected():
    clients = make_clients(4, np.random.default_rng(4))
    with pytest.raises(ValueError, match="cannot hold even one client row"):
        build_device_cohort(clients, resident_budget_bytes=row_bytes_of(clients) - 1)


def test_foreign_client_rejected_by_pool(model):
    clients = make_clients(4, np.random.default_rng(6), lo=8)  # uniform rows
    dc = build_device_cohort(
        clients[:3], resident_budget_bytes=2 * row_bytes_of(clients)
    )
    assert dc.is_pooled
    with pytest.raises(KeyError, match="not part of the federation"):
        dc.ensure_resident([clients[3]])
    with pytest.raises(KeyError, match="not resident in the pool"):
        dc.row_of(clients[0])  # never made resident


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >1 device")
def test_pool_refuses_mesh():
    clients = make_clients(8, np.random.default_rng(7))
    with pytest.raises(ValueError, match="single-host"):
        build_device_cohort(
            clients,
            mesh=make_data_mesh(),
            resident_budget_bytes=2 * row_bytes_of(clients),
        )


# --------------------------------------------------------------------------
# the static-slice fast path is routing, not math
# --------------------------------------------------------------------------

def run_full_round(trainer, params, clients):
    _, subs = chain_split_keys(jax.random.key(5), len(clients))
    params, _, _ = trainer.train_cohort(
        params, clients, np.random.default_rng(1), subs, steps_per_epoch=2
    )
    return jax.block_until_ready(params)


def test_slice_fastpath_bitwise_vs_gather(model):
    """All-participant chunks are contiguous resident-row runs: the slice
    path must take them (3 chunks of 8) and produce bit-identical params to
    the forced gather."""
    loss_fn, params0 = model
    clients = make_clients(24, np.random.default_rng(8))
    results = {}
    for fast in (True, False):
        trainer = make_trainer(loss_fn, cohort_chunk=8, slice_fastpath=fast)
        results[fast] = run_full_round(trainer, params0, clients)
        assert trainer.last_round_stats["slice_chunks"] == (3 if fast else 0)
    for la, lb in zip(jax.tree.leaves(results[True]), jax.tree.leaves(results[False])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_noncontiguous_cohort_falls_back_to_gather(model):
    """A strided subset has no contiguous row run — the fast path must
    decline (slice_chunks == 0), not slice the wrong rows."""
    loss_fn, params0 = model
    clients = make_clients(16, np.random.default_rng(9))
    trainer = make_trainer(loss_fn, cohort_chunk=4)
    run_full_round(trainer, params0, clients)  # attach (rows = client order)
    subset = clients[::2]
    _, subs = chain_split_keys(jax.random.key(6), len(subset))
    trainer.train_cohort(
        params0, subset, np.random.default_rng(2), subs, steps_per_epoch=2
    )
    assert trainer.last_round_stats["slice_chunks"] == 0


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >1 device")
def test_slice_fastpath_bitwise_under_mesh(model):
    """Under the data mesh, shard-aligned contiguous chunks go through the
    slice path (this is what re-enabled chunking in the mesh benchmarks)
    and still match the forced gather bit for bit."""
    loss_fn, params0 = model
    mesh = make_data_mesh()
    clients = make_clients(24, np.random.default_rng(11))
    results = {}
    for fast in (True, False):
        trainer = make_trainer(
            loss_fn, cohort_chunk=12, mesh=mesh, slice_fastpath=fast
        )
        results[fast] = run_full_round(trainer, params0, clients)
        assert trainer.last_round_stats["slice_chunks"] == (2 if fast else 0)
    for la, lb in zip(jax.tree.leaves(results[True]), jax.tree.leaves(results[False])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_pad_cohort_plan_keeps_contiguity_when_rows_allow():
    """Dummy clients borrow the continuation rows (keeping the slice path
    alive) when the device cohort has them, and fall back to row 0 when it
    does not — either way every dummy slot gathers the all-zero pad row."""
    plan = build_cohort_plan(
        [3, 5, 4], 2, 1, np.random.default_rng(0), client_rows=[4, 5, 6]
    )
    padded = pad_cohort_plan(plan, 4, num_rows=8)
    np.testing.assert_array_equal(padded.client_rows, [4, 5, 6, 7])
    assert (padded.sample_idx[3] == plan.pad_index).all()
    assert not padded.step_valid[3].any() and padded.weights[3] == 0.0
    cramped = pad_cohort_plan(plan, 4, num_rows=7)  # no room after row 6
    np.testing.assert_array_equal(cramped.client_rows, [4, 5, 6, 0])


# --------------------------------------------------------------------------
# staging pipeline error contract
# --------------------------------------------------------------------------

def test_close_reraises_uncollected_stage_exception():
    """A stage_fn failure the consumer never iterated to must surface from
    close(), not vanish in the drain loop."""

    def stage(k):
        raise RuntimeError("staging blew up")

    pipe = StagingPipeline(stage, range(3))
    deadline = time.monotonic() + 5.0
    while pipe._queue.qsize() == 0 and time.monotonic() < deadline:
        time.sleep(0.01)
    with pytest.raises(RuntimeError, match="staging blew up"):
        pipe.close()
    pipe.close()  # idempotent; the pending exception is delivered once


def test_close_flags_and_logs_stuck_producer(caplog):
    """A producer stuck inside stage_fn cannot be joined: close() must warn
    and flag the leak instead of silently abandoning the daemon thread."""
    release = threading.Event()

    def stage(k):
        release.wait(10.0)
        return k

    pipe = StagingPipeline(stage, range(2), join_timeout=0.2)
    with caplog.at_level(logging.WARNING, logger="repro.federated.staging"):
        pipe.close()
    assert pipe.leaked
    assert any("failed to join" in r.message for r in caplog.records)
    release.set()
    pipe._thread.join(timeout=5.0)


def test_killed_pipeline_mid_round_surfaces_error(model):
    """End to end: a staging failure mid-round kills the round with the
    original exception (not a hang, not a swallowed error), and the trainer
    survives to run the next round cleanly."""
    loss_fn, params0 = model
    clients = make_clients(12, np.random.default_rng(12))
    trainer = make_trainer(loss_fn, cohort_chunk=4)
    run_full_round(trainer, params0, clients)  # healthy attach + round
    boom = {"armed": True}
    real_put = trainer._device_put_chunk

    def failing_put(arrays):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("device lost")
        return real_put(arrays)

    trainer._device_put_chunk = failing_put
    _, subs = chain_split_keys(jax.random.key(8), len(clients))
    with pytest.raises(RuntimeError, match="device lost"):
        trainer.train_cohort(
            params0, clients, np.random.default_rng(3), subs, steps_per_epoch=2
        )
    trainer._device_put_chunk = real_put
    run_full_round(trainer, params0, clients)  # recovered


# --------------------------------------------------------------------------
# the population experiment drives all of it end to end
# --------------------------------------------------------------------------

def test_run_population_scale_smoke():
    """Tiny two-point sweep through the real bench harness: exact-mode
    parity at the small point, pooled rounds at both, and the report's
    scaling summary (the sub-linear and O(1)-membership assertions run
    inside)."""
    from repro.experiments.population import run_population_scale

    report = run_population_scale(
        populations=(60, 180),
        rounds=2,
        round_clients=12,
        pool_rows=24,
        verbose=False,
    )
    small, large = report["entries"]
    assert small["streaming_mode"] == "exact" and small["participant_match"]
    for entry in (small, large):
        assert entry["pool_rows"] == 24
        assert entry["pool_uploads_total"] >= 12
        assert entry["round_time_s"] > 0
    assert report["population_ratio"] == 3.0
