"""Observability tier: tracer, metrics registry, control-plane streams.

The contract under test: a traced run's spans reconcile *exactly* with its
round records (the round/flush span reuses the record's own measured wall
time), the Chrome export is Perfetto-loadable JSON with both clock
processes, the typed registry absorbs the engines' ad-hoc stat dicts into
one stable ``snapshot()`` schema that streams as ``metrics.jsonl`` and
survives kill-and-resume, the staging/pool counters are exact (seeded
multi-chunk rounds, both staging modes), and ``RoundRecord`` serializes
the canonical ``round_time_s`` name while still loading legacy
``wall_time_s`` streams.
"""

import dataclasses
import json
import math
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.data.pipeline import ArrayDataset, ClientDataset
from repro.federated.api import Federation, FederationConfig, RoundRecord
from repro.federated.runtime import AsyncFederation, AsyncFederationConfig
from repro.federated.staging import StagingPipeline
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.profile import (
    CompileWatcher,
    ObservabilityConfig,
    resolve_observability,
)
from repro.obs.report import render_report
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer, resolve_tracer
from repro.optim.adamw import AdamW

SEQ_LEN, FEAT = 3, 5


def make_clients(count, rng, lo=2, hi=18):
    clients = []
    for i, n in enumerate(rng.integers(lo, hi, count)):
        x = rng.normal(size=(int(n), SEQ_LEN, FEAT)).astype(np.float32)
        y = rng.uniform(0.5, 20.0, size=int(n)).astype(np.float32)
        ds = ArrayDataset(x, y)
        clients.append(ClientDataset(client_id=i, train=ds, val=ds))
    return clients


@pytest.fixture(scope="module")
def setup():
    cfg = GRUConfig(input_dim=FEAT, hidden_dim=2, num_layers=1)
    clients = make_clients(10, np.random.default_rng(0))
    return clients, make_loss_fn(cfg), init_gru(jax.random.key(1), cfg)


# ---------------------------------------------------------------------------
# tracer unit behaviour
# ---------------------------------------------------------------------------


class TestTracer:
    def test_span_records_nested(self):
        tracer = Tracer()
        with tracer.span("outer", track="t", n=1):
            with tracer.span("inner", track="t"):
                pass
        spans = tracer.spans()
        # Inner exits first, so it lands first in the ring.
        assert [s.name for s in spans] == ["inner", "outer"]
        inner, outer = spans
        assert outer.ts <= inner.ts
        assert outer.ts + outer.dur >= inner.ts + inner.dur
        assert outer.args == {"n": 1}

    def test_ring_is_bounded_and_counts_drops(self):
        tracer = Tracer(capacity=4)
        for i in range(10):
            tracer.instant("tick", ts=float(i))
        events = tracer.events()
        assert len(events) == 4
        assert tracer.dropped == 6
        assert [e.ts for e in events] == [6.0, 7.0, 8.0, 9.0]

    def test_capacity_validation(self):
        with pytest.raises(ValueError, match="capacity"):
            Tracer(capacity=0)

    def test_wrap_decorator(self):
        tracer = Tracer()

        @tracer.wrap("work", track="w")
        def work(x):
            """doc"""
            return x + 1

        assert work(2) == 3
        assert work.__name__ == "work"
        assert work.__doc__ == "doc"
        assert [s.name for s in tracer.spans()] == ["work"]

    def test_null_tracer_is_inert(self):
        null = resolve_tracer(None)
        assert null is NULL_TRACER
        assert isinstance(null, NullTracer)
        assert not null.enabled
        with null.span("x", n=1):
            pass
        null.complete("x", start=0.0, dur=1.0)
        null.instant("x")
        null.flow_start("x", 0, ts=0.0)
        null.flow_end("x", 0, ts=0.0, track="t")
        assert null.events() == []

        @null.wrap("x")
        def fn():
            return 7

        assert fn() == 7
        tracer = Tracer()
        assert resolve_tracer(tracer) is tracer

    def test_summary_totals(self):
        tracer = Tracer()
        tracer.complete("a", start=0.0, dur=1.0)
        tracer.complete("a", start=2.0, dur=3.0)
        tracer.complete("b", start=0.0, dur=5.0, clock="virtual")
        summary = tracer.summary()
        assert summary["host"]["a"] == {"count": 2, "total_s": 4.0}
        assert summary["virtual"]["b"]["total_s"] == 5.0

    def test_thread_safety_no_loss_under_capacity(self):
        tracer = Tracer(capacity=10_000)

        def push(tag):
            for i in range(1000):
                tracer.instant(tag, ts=float(i))

        threads = [threading.Thread(target=push, args=(f"t{k}",)) for k in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(tracer.events()) == 4000
        assert tracer.dropped == 0


class TestChromeExport:
    def test_export_structure(self, tmp_path):
        tracer = Tracer()
        with tracer.span("round", round=0):
            pass
        tracer.complete(
            "task", start=1.0, dur=2.0, track="client:3", clock="virtual",
            latency=np.float64(2.0), clients=np.array([3]),
        )
        fid = tracer.new_flow_id()
        tracer.flow_start("task", fid, ts=1.0, track="server")
        tracer.flow_end("task", fid, ts=3.0, track="client:3")
        tracer.instant("flush", ts=3.0, clock="virtual")
        path = tracer.export_chrome(str(tmp_path / "trace.json"))
        doc = json.loads(open(path).read())
        events = doc["traceEvents"]
        assert doc["displayTimeUnit"] == "ms"
        # Both clock processes are named.
        procs = {e["pid"]: e["args"]["name"] for e in events if e["name"] == "process_name"}
        assert procs == {1: "host clock", 2: "virtual clock"}
        # The virtual task span sits on its per-client track, in microseconds.
        task = next(e for e in events if e["name"] == "task" and e["ph"] == "X")
        assert task["pid"] == 2
        assert task["ts"] == pytest.approx(1e6)
        assert task["dur"] == pytest.approx(2e6)
        # numpy args were coerced to JSON-safe types by the exporter.
        assert task["args"] == {"latency": 2.0, "clients": [3]}
        threads = {
            (e["pid"], e["args"]["name"]) for e in events if e["name"] == "thread_name"
        }
        assert (2, "client:3") in threads
        # Flow arrows pair by id; the end carries the enclosing binding point.
        flows = [e for e in events if e["ph"] in ("s", "f")]
        assert {e["ph"] for e in flows} == {"s", "f"}
        assert len({e["id"] for e in flows}) == 1
        assert next(e for e in flows if e["ph"] == "f")["bp"] == "e"
        # The whole document survives a strict JSON round-trip.
        json.dumps(doc)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_monotone(self):
        c = Counter("c")
        c.inc()
        c.inc(4)
        assert c.value == 5
        with pytest.raises(ValueError, match="cannot decrease"):
            c.inc(-1)

    def test_histogram_stats(self):
        h = Histogram("h")
        assert h.snapshot() == {"count": 0, "sum": 0.0, "last": 0.0}
        for v in (2.0, 8.0, 5.0):
            h.observe(v)
        snap = h.snapshot()
        assert snap["count"] == 3
        assert snap["min"] == 2.0
        assert snap["max"] == 8.0
        assert snap["mean"] == pytest.approx(5.0)
        assert snap["last"] == 5.0

    def test_registry_get_or_create_and_type_conflict(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("x")

    def test_snapshot_load_round_trip(self):
        reg = MetricsRegistry()
        reg.counter("a").inc(3)
        reg.gauge("b").set(1.5)
        reg.histogram("c").observe(2.0)
        reg.histogram("c").observe(4.0)
        snap = reg.snapshot()
        assert json.loads(json.dumps(snap)) == snap
        restored = MetricsRegistry()
        restored.load_snapshot(snap)
        assert restored.snapshot() == snap
        # The restored registry continues the series, not restarts it.
        restored.counter("a").inc()
        assert restored.snapshot()["counters"]["a"] == 4
        restored.histogram("c").observe(1.0)
        assert restored.snapshot()["histograms"]["c"]["min"] == 1.0
        # Empty/None snapshots are no-ops.
        MetricsRegistry().load_snapshot(None)


class TestObservabilitySection:
    def test_null_stays_null(self):
        assert resolve_observability(None) is None

    def test_defaults_and_strictness(self):
        cfg = resolve_observability({})
        assert cfg == ObservabilityConfig()
        assert cfg.trace and cfg.trace_capacity == 65536
        with pytest.raises(ValueError, match="unknown observability key"):
            resolve_observability({"trace_cap": 1})
        with pytest.raises(ValueError, match="must be a bool"):
            resolve_observability({"trace": "yes"})
        with pytest.raises(ValueError, match="non-negative int"):
            resolve_observability({"jax_profile_rounds": -1})
        with pytest.raises(ValueError, match="non-negative int"):
            resolve_observability({"trace_capacity": True})
        with pytest.raises(ValueError, match=">= 1"):
            resolve_observability({"trace_capacity": 0})


class TestCompileWatcher:
    def test_poll_folds_deltas(self):
        reg = MetricsRegistry()
        with CompileWatcher(reg) as watcher:
            watcher.compiles += 3
            watcher.compile_time_s += 0.5
            assert watcher.poll() == 3
            assert watcher.poll() == 0  # steady state: no new compiles
        snap = reg.snapshot()
        assert snap["counters"]["jit.compiles"] == 3
        assert snap["counters"]["jit.compile_time_s"] == pytest.approx(0.5)
        assert snap["gauges"]["jit.round_compiles"] == 0

    def test_none_registry_is_fine(self):
        with CompileWatcher(None) as watcher:
            watcher.compiles += 1
            assert watcher.poll() == 1


# ---------------------------------------------------------------------------
# RoundRecord serialization (the wall_time_s -> round_time_s rename)
# ---------------------------------------------------------------------------


class TestRoundRecordSerialization:
    RECORD = RoundRecord(
        round_index=3,
        participant_ids=[1, 4, 7],
        mean_local_loss=0.25,
        local_steps=42,
        params_down=30,
        params_up=30,
        bytes_transferred=1001,
        wall_time_s=0.125,
        virtual_time=9.5,
        staleness=1.5,
        epsilon=0.75,
    )

    def test_to_state_uses_canonical_name(self):
        state = self.RECORD.to_state()
        assert state["round_time_s"] == 0.125
        assert "wall_time_s" not in state

    def test_every_field_survives_jsonl_round_trip(self):
        line = json.dumps(self.RECORD.to_state(), sort_keys=True)
        back = RoundRecord.from_state(json.loads(line))
        for field in dataclasses.fields(RoundRecord):
            assert getattr(back, field.name) == getattr(self.RECORD, field.name), field.name
        assert back.round_time_s == self.RECORD.wall_time_s

    def test_legacy_wall_time_key_still_loads(self):
        state = dataclasses.asdict(self.RECORD)  # pre-rename stream shape
        back = RoundRecord.from_state(state)
        assert back == self.RECORD


# ---------------------------------------------------------------------------
# traced runs: span/record reconciliation, both engines
# ---------------------------------------------------------------------------


class TestTracedFederation:
    def test_sync_round_spans_reconcile_exactly(self, setup):
        clients, loss_fn, params0 = setup
        tracer = Tracer()
        fed = Federation(
            FederationConfig(rounds=3, local_epochs=1, batch_size=4, seed=0),
            clients, loss_fn, AdamW(learning_rate=5e-3),
            tracer=tracer,
        )
        out = fed.run(params0)
        rounds = tracer.spans("round")
        assert len(rounds) == len(out.history) == 3
        # The round span is emitted from the record's own measured wall
        # time, so the reconciliation is exact, not within-tolerance.
        for span, record in zip(rounds, out.history):
            assert span.dur == record.round_time_s
            assert span.args["round"] == record.round_index
        # Every phase of the round program shows up under the round total.
        # (fedavg is an in-jit "reduced" aggregator, so there is no separate
        # aggregate span here — see test_stacked_aggregate_span.)
        summary = tracer.summary()["host"]
        for phase in ("select", "train"):
            assert summary[phase]["count"] == 3
            assert summary[phase]["total_s"] <= summary["round"]["total_s"]
        # The facade's registry absorbed the records.
        snap = out.metrics
        assert snap["counters"]["rounds.completed"] == 3
        assert snap["counters"]["train.local_steps"] == out.total_local_steps
        assert snap["counters"]["comms.bytes_down"] + snap["counters"][
            "comms.bytes_up"
        ] == sum(r.bytes_transferred for r in out.history)
        assert snap["histograms"]["round.time_s"]["count"] == 3
        assert out.summary()["metrics"] == snap

    def test_stacked_aggregate_span(self, setup):
        clients, loss_fn, params0 = setup
        tracer = Tracer()
        fed = Federation(
            FederationConfig(
                rounds=2, local_epochs=1, batch_size=4, seed=0,
                aggregator="trimmed-mean:0.1",
            ),
            clients, loss_fn, AdamW(learning_rate=5e-3),
            tracer=tracer,
        )
        fed.run(params0)
        aggregates = tracer.spans("aggregate")
        assert len(aggregates) == 2
        assert all(s.args["clients"] == len(clients) for s in aggregates)

    def test_async_flush_and_task_spans(self, setup):
        clients, loss_fn, params0 = setup
        tracer = Tracer()
        fed = AsyncFederation(
            AsyncFederationConfig(
                rounds=3, local_epochs=1, batch_size=4, seed=0,
                aggregator="fedbuff:3", latency="lognormal:0.5",
                dropout="never", concurrency=4,
            ),
            clients, loss_fn, AdamW(learning_rate=5e-3),
            tracer=tracer,
        )
        out = fed.run(params0)
        flushes = tracer.spans("flush", clock="host")
        assert len(flushes) == len(out.history)
        for span, record in zip(flushes, out.history):
            assert span.dur == record.round_time_s
            assert span.args["virtual_time"] == record.virtual_time
        # Virtual task spans: dispatch time + latency, one per surviving
        # task, each on its own client/group track with a flow arrow.
        tasks = tracer.spans("task", clock="virtual")
        stats = fed.last_run_stats
        assert len(tasks) == stats["tasks"]
        final_virtual = out.history[-1].virtual_time
        for task in tasks:
            assert task.ts >= 0.0 and task.dur > 0.0
            assert task.track.startswith(("client:", "group:"))
        # Tasks folded into the last flush finished by then on the virtual
        # clock; later dispatches may still be in flight.
        assert min(t.ts + t.dur for t in tasks) <= final_virtual
        flow_phases = [e.phase for e in tracer.events() if e.flow_id is not None]
        assert flow_phases.count("s") == flow_phases.count("f") == len(tasks)
        # Virtual flush instants mark the records' flush times (the raw
        # scheduler events land on their own "scheduler" track).
        marks = [
            e for e in tracer.events()
            if e.name == "flush" and e.clock == "virtual" and e.phase == "i"
            and e.track == "server"
        ]
        assert [m.ts for m in marks] == [r.virtual_time for r in out.history]
        # And the whole ring exports as loadable Chrome JSON.
        doc = tracer.to_chrome()
        json.dumps(doc)
        assert any(e.get("ph") == "X" and e["pid"] == 2 for e in doc["traceEvents"])

    def test_async_off_run_records_nothing(self, setup):
        clients, loss_fn, params0 = setup
        fed = AsyncFederation(
            AsyncFederationConfig(
                rounds=2, local_epochs=1, batch_size=4, seed=0,
                aggregator="fedbuff:3", latency="constant", dropout="never",
            ),
            clients, loss_fn, AdamW(learning_rate=5e-3),
        )
        out = fed.run(params0)
        assert isinstance(fed.tracer, NullTracer)
        assert fed.tracer.events() == []
        # Metrics still flow — the registry is not optional.
        assert out.metrics["counters"]["async.tasks"] == fed.last_run_stats["tasks"]
        assert out.metrics["gauges"]["async.virtual_time"] == pytest.approx(
            fed.last_run_stats["virtual_time"]
        )


# ---------------------------------------------------------------------------
# staging / pool counters: exact across seeded multi-chunk rounds
# ---------------------------------------------------------------------------


class TestStagingCounters:
    def test_pipeline_prefetch_counter_all_hits(self):
        """Deterministic hit accounting: the consumer only asks for a chunk
        once the producer has it queued, so every chunk is a prefetch hit."""
        pipeline = StagingPipeline(lambda start: start * 10, [0, 1, 2, 3])
        it = iter(pipeline)
        for expected in (0, 10, 20, 30):
            deadline = time.time() + 5
            while pipeline._queue.qsize() == 0:
                assert time.time() < deadline, "staging producer stalled"
                time.sleep(0.001)
            assert next(it) == expected
        assert pipeline.prefetched == 4

    def test_pipeline_prefetch_counter_all_misses_and_wait_spans(self):
        """Deterministic miss accounting: staging only proceeds once the
        consumer is already inside the blocking ``prefetch_wait`` path (the
        tracer hook releases the producer), so no chunk counts as
        prefetched and every miss records a wait span."""
        gate = threading.Semaphore(0)

        class ReleasingTracer(Tracer):
            def span(self, name, track="server", **args):
                if name == "prefetch_wait":
                    gate.release()
                return super().span(name, track=track, **args)

        tracer = ReleasingTracer()

        def stage_fn(start):
            assert gate.acquire(timeout=5)
            return start * 10

        pipeline = StagingPipeline(stage_fn, [0, 1, 2, 3], tracer=tracer)
        assert list(pipeline) == [0, 10, 20, 30]
        assert pipeline.prefetched == 0
        waits = tracer.spans("prefetch_wait")
        assert len(waits) == 4
        assert all(w.track == "staging" for w in waits)

    @pytest.mark.parametrize("staging", ["resident", "rebuild"])
    def test_round_counters_absorbed_exactly(self, setup, staging):
        clients, loss_fn, params0 = setup
        rounds = 3
        fed = Federation(
            FederationConfig(
                rounds=rounds, local_epochs=1, batch_size=4, seed=0,
                staging=staging, cohort_chunk=4, engine="vectorized",
                prefetch=False,  # inline staging: every counter deterministic
            ),
            clients, loss_fn, AdamW(learning_rate=5e-3),
        )
        out = fed.run(params0)
        stats = fed.cohort_trainer.last_round_stats
        assert stats["chunks"] == math.ceil(len(clients) / 4)
        counters = out.metrics["counters"]
        gauges = out.metrics["gauges"]
        # Steady-state rounds stage identical plans, so the cumulative
        # counters are exactly rounds x the per-round stats.
        assert counters["staging.chunks"] == rounds * stats["chunks"]
        assert stats["bytes_staged"] > 0
        assert counters["staging.bytes_staged"] == rounds * stats["bytes_staged"]
        assert gauges["staging.bytes_resident"] == stats["bytes_resident"]
        assert counters["staging.plans_prefetched"] == 0  # no pipeline
        if staging == "resident":
            assert stats["bytes_resident"] > 0

    def test_prefetched_plans_counted(self, setup):
        clients, loss_fn, params0 = setup
        rounds = 2
        fed = Federation(
            FederationConfig(
                rounds=rounds, local_epochs=1, batch_size=4, seed=0,
                staging="resident", cohort_chunk=4, prefetch=True,
            ),
            clients, loss_fn, AdamW(learning_rate=5e-3),
        )
        out = fed.run(params0)
        stats = fed.cohort_trainer.last_round_stats
        counters = out.metrics["counters"]
        # How many chunks win the overlap race varies with machine load,
        # but the cumulative counter must stay within the per-round bound
        # and agree with the last round's own tally as a lower bound.
        chunks = stats["chunks"]
        assert 0 <= counters["staging.plans_prefetched"] <= rounds * chunks
        assert counters["staging.plans_prefetched"] >= stats["plans_prefetched"]

    def test_pool_counters_absorbed_exactly(self, setup):
        clients, loss_fn, params0 = setup
        # A pool budget below the cohort footprint forces uploads and LRU
        # evictions as the seeded per-round selections churn the residents.
        max_n = max(c.n_train for c in clients)
        row_bytes = (max_n + 1) * (SEQ_LEN * FEAT * 4 + 4)
        rounds = 4
        fed = Federation(
            FederationConfig(
                rounds=rounds, local_epochs=1, batch_size=4, seed=0,
                selection="uniform:4", resident_budget_bytes=5 * row_bytes,
                cohort_chunk=4,
            ),
            clients, loss_fn, AdamW(learning_rate=5e-3),
        )
        out = fed.run(params0)
        dcohort = fed.cohort_trainer._device_cohort
        assert dcohort.is_pooled and dcohort.pool_rows == 5
        counters = out.metrics["counters"]
        assert counters["pool.uploads"] == dcohort.uploads
        assert counters["pool.evictions"] == dcohort.evictions
        assert counters["pool.hits"] == dcohort.hits
        assert counters["pool.bytes_uploaded"] == dcohort.bytes_uploaded
        # Every participant appearance is either a pool hit or an upload —
        # the exact identity the round loop maintains.
        appearances = sum(len(r.participant_ids) for r in out.history)
        assert counters["pool.hits"] + counters["pool.uploads"] == appearances
        assert counters["pool.uploads"] >= len(set(out.history[0].participant_ids))
        # 10 clients churning through 5 rows across 4 rounds must evict.
        assert counters["pool.evictions"] > 0


# ---------------------------------------------------------------------------
# control plane: metrics.jsonl + trace.json in the run dir, resume continuity
# ---------------------------------------------------------------------------


OBS_SPEC = {
    "name": "t-obs",
    "mode": "sync",
    "rounds": 4,
    "local_epochs": 1,
    "batch_size": 8,
    "seed": 3,
    "recruitment": "all",
    "selection": "uniform",
    "data": {"scale": 0.002, "num_hospitals": 6, "split_mode": "stratified"},
    "model": {"hidden_dim": 2, "num_layers": 1},
    "observability": {"trace": True, "trace_capacity": 4096},
}


class TestServiceObservability:
    def test_spec_validation(self):
        from repro.launch.federation_service import validate_job_spec

        normalized = validate_job_spec(dict(OBS_SPEC))
        assert normalized["observability"]["trace"] is True
        assert normalized["observability"]["jax_profile_rounds"] == 0
        # Tri-state: absent stays null and hashes differently.
        bare = validate_job_spec({k: v for k, v in OBS_SPEC.items() if k != "observability"})
        assert bare["observability"] is None
        with pytest.raises(ValueError, match="unknown key"):
            validate_job_spec({**OBS_SPEC, "observability": {"capactiy": 1}})
        with pytest.raises(ValueError, match="must be a bool"):
            validate_job_spec({**OBS_SPEC, "observability": {"trace": 1}})

    def test_run_dir_artifacts_and_resume_continuity(self, tmp_path, capsys):
        from repro.launch.federation_service import (
            JobPreempted,
            read_records,
            resume_job,
            submit_job,
        )

        run_dir = str(tmp_path / "run")
        with pytest.raises(JobPreempted):
            submit_job(dict(OBS_SPEC), run_dir, preempt_after=2)
        # The cut run already has a partial trace and a metrics prefix.
        assert os.path.exists(os.path.join(run_dir, "trace.json"))
        cut_lines = [
            json.loads(line)
            for line in open(os.path.join(run_dir, "metrics.jsonl"))
        ]
        assert cut_lines and all("counters" in line for line in cut_lines)

        out = resume_job(run_dir)
        assert out["status"] == "completed"
        records = read_records(os.path.join(run_dir, "records.jsonl"))
        lines = [
            json.loads(line)
            for line in open(os.path.join(run_dir, "metrics.jsonl"))
        ]
        # One metrics line per record, in lockstep, cumulative through each.
        assert [l["round_index"] for l in lines] == [r.round_index for r in records]
        completed = [l["counters"]["rounds.completed"] for l in lines]
        assert completed == list(range(1, len(records) + 1))
        steps = [l["counters"]["train.local_steps"] for l in lines]
        assert steps == list(np.cumsum([r.local_steps for r in records]))
        # The final summary folds the same snapshot.
        assert out["summary"]["metrics"]["counters"]["rounds.completed"] == len(records)
        # The completed run's trace loads and covers the resumed rounds.
        doc = json.loads(open(os.path.join(run_dir, "trace.json")).read())
        round_spans = [
            e for e in doc["traceEvents"] if e["name"] == "round" and e["ph"] == "X"
        ]
        assert [e["args"]["round"] for e in round_spans] == [2, 3]

        # The report CLI renders every section from the run dir.
        assert render_report(run_dir) == 0
        rendered = capsys.readouterr().out
        assert "per-phase time" in rendered
        assert "round" in rendered and "metrics" in rendered

    def test_report_on_missing_dir(self, capsys):
        assert render_report("/nonexistent/run-dir") == 2
