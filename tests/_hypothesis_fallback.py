"""Deterministic stand-in for ``hypothesis`` when the package is absent.

CI installs the real hypothesis from the ``test`` extra; this fallback keeps
the property tests collectable and meaningful in minimal environments (the
baked container has no hypothesis and no network).  It implements just the
surface these tests use — ``given``/``settings`` decorators and the
``integers``/``floats``/``lists``/``tuples`` strategies with ``filter``/
``map`` — and replays a fixed number of seeded pseudo-random examples
instead of doing real property search.  Imported by ``conftest.py``, which
registers it under the ``hypothesis`` module names.
"""

from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable

_DEFAULT_EXAMPLES = 12
_MAX_EXAMPLES = 25  # cap so the stub never exceeds real-hypothesis budgets


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]) -> None:
        self._draw = draw

    def filter(self, predicate: Callable[[Any], bool]) -> "_Strategy":
        def draw(rnd: random.Random) -> Any:
            for _ in range(1000):
                value = self._draw(rnd)
                if predicate(value):
                    return value
            raise ValueError("filter predicate rejected 1000 consecutive examples")

        return _Strategy(draw)

    def map(self, fn: Callable[[Any], Any]) -> "_Strategy":
        return _Strategy(lambda rnd: fn(self._draw(rnd)))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(
    min_value: float,
    max_value: float,
    allow_nan: bool | None = None,
    allow_infinity: bool | None = None,
    **_: Any,
) -> _Strategy:
    return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))


def sampled_from(elements) -> _Strategy:
    pool = list(elements)
    return _Strategy(lambda rnd: pool[rnd.randrange(len(pool))])


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10, **_: Any) -> _Strategy:
    def draw(rnd: random.Random):
        return [elements._draw(rnd) for _ in range(rnd.randint(min_size, max_size))]

    return _Strategy(draw)


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(lambda rnd: tuple(e._draw(rnd) for e in elements))


def given(**strategies: _Strategy):
    def decorator(fn):
        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any):
            n_examples = getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES)
            for example in range(n_examples):
                rnd = random.Random(0x5EED + example)
                drawn = {name: s._draw(rnd) for name, s in strategies.items()}
                fn(*args, **kwargs, **drawn)

        wrapper._stub_max_examples = _DEFAULT_EXAMPLES
        # Hide the drawn parameters from pytest's fixture resolution: keep
        # only the arguments given() does not supply (e.g. real fixtures).
        params = [
            p
            for name, p in inspect.signature(fn).parameters.items()
            if name not in strategies
        ]
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper

    return decorator


def settings(max_examples: int | None = None, deadline: Any = None, **_: Any):
    def decorator(fn):
        if max_examples is not None and hasattr(fn, "_stub_max_examples"):
            fn._stub_max_examples = min(max_examples, _MAX_EXAMPLES)
        return fn

    return decorator
