"""Sharding-rule tests on host meshes (the dry-run itself runs the 512-dev
production meshes in a separate process; these tests run on 1 device)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distribution.compat import set_mesh
from repro.distribution.sharding import clean_spec, constrain
from repro.launch.mesh import data_axes, make_host_mesh
from repro.launch.specs import (
    INPUT_SHAPES,
    batch_shardings,
    batch_specs,
    cache_shardings,
    cache_specs,
    config_for_shape,
    long_context_variant,
    params_shardings,
    params_specs,
)
from repro.models.zoo import Model


def test_constrain_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = constrain(x, "data", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_clean_spec_drops_unknown_axes():
    mesh = make_host_mesh()
    with set_mesh(mesh):
        spec = clean_spec(("pod", "data", "bogus"))
        assert spec == P(None, "data", None)
        spec2 = clean_spec((("pod", "data"), "model"))
        assert spec2 == P(("data",), "model")


def test_constrain_under_host_mesh():
    mesh = make_host_mesh()
    with set_mesh(mesh):
        @jax.jit
        def f(x):
            return constrain(x * 2, "data", "model")
        out = f(jnp.ones((4, 4)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((4, 4)))


def test_param_shardings_cover_every_leaf():
    mesh = make_host_mesh()
    for arch in ("smollm-135m", "deepseek-v3-671b", "mamba2-130m", "zamba2-7b"):
        cfg = get_config(arch).reduced()
        model = Model(cfg)
        specs = params_specs(model)
        with set_mesh(mesh):
            sh = params_shardings(specs, cfg, mesh)
        n_leaves = len(jax.tree.leaves(specs))
        n_shardings = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
        assert n_leaves == n_shardings


def test_batch_shardings_divisibility_guard():
    mesh = make_host_mesh()
    spec = {"tokens": jax.ShapeDtypeStruct((1, 16), jnp.int32)}
    sh = batch_shardings(spec, mesh)
    # batch=1 divisible by 1 on host mesh: sharded spec exists, no crash
    assert sh["tokens"] is not None


def test_long_context_variant_rules():
    # SSM/hybrid unchanged; attention archs get the window
    assert long_context_variant(get_config("mamba2-130m")).sliding_window is None
    assert long_context_variant(get_config("zamba2-7b")).sliding_window is None
    assert long_context_variant(get_config("yi-9b")).sliding_window == 8192
    assert long_context_variant(get_config("deepseek-v3-671b")).sliding_window == 8192
    # base configs never carry the window
    assert get_config("yi-9b").sliding_window is None


def test_input_shape_matrix():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    s = INPUT_SHAPES["train_4k"]
    assert (s.seq_len, s.global_batch, s.kind) == (4096, 256, "train")
    s = INPUT_SHAPES["long_500k"]
    assert (s.seq_len, s.global_batch, s.kind) == (524288, 1, "decode")


def test_batch_specs_per_modality():
    shape = INPUT_SHAPES["train_4k"]
    vlm = get_config("internvl2-26b")
    specs = batch_specs(vlm, shape)
    assert specs["tokens"].shape == (256, 4096 - vlm.num_frontend_tokens)
    assert specs["patch_embeds"].shape == (256, vlm.num_frontend_tokens, vlm.d_model)
    enc = get_config("seamless-m4t-large-v2")
    specs = batch_specs(enc, shape)
    assert specs["src_embeds"].shape == (256, 1024, enc.d_model)


def test_cache_specs_sub_quadratic_sizes():
    """long_500k: the SSM cache is O(1) in seq len; the windowed dense cache
    is O(window); a full cache would be O(500k)."""
    shape = INPUT_SHAPES["long_500k"]
    ssm_cfg = config_for_shape(get_config("mamba2-130m"), shape)
    dense_cfg = config_for_shape(get_config("qwen3-1.7b"), shape)
    m_ssm = Model(ssm_cfg)
    m_dense = Model(dense_cfg)
    c_ssm = cache_specs(m_ssm, shape)
    c_dense = cache_specs(m_dense, shape)
    ssm_bytes = sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(c_ssm))
    dense_bytes = sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(c_dense))
    full_estimate = dense_cfg.num_layers * 2 * shape.seq_len * dense_cfg.num_kv_heads * dense_cfg.resolved_head_dim * 2
    assert dense_bytes < 0.05 * full_estimate     # window 8192 << 524288
    assert ssm_bytes < 64 * 1024 * 1024           # state cache is tiny


def test_cache_shardings_build(tmp_path):
    mesh = make_host_mesh()
    shape = INPUT_SHAPES["decode_32k"]
    for arch in ("yi-9b", "deepseek-v3-671b", "zamba2-7b", "seamless-m4t-large-v2"):
        cfg = config_for_shape(get_config(arch), shape)
        model = Model(cfg)
        cs = cache_specs(model, shape)
        with set_mesh(mesh):
            sh = cache_shardings(cs, cfg, mesh)
        assert len(jax.tree.leaves(cs)) == len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))


def test_data_axes():
    mesh = make_host_mesh()
    assert data_axes(mesh) == ("data",)
