"""Aggregate the dry-run sweep into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"

ARCH_ORDER = [
    "qwen3-1.7b", "mamba2-130m", "seamless-m4t-large-v2", "deepseek-v3-671b",
    "smollm-135m", "yi-9b", "internvl2-26b", "nemotron-4-15b",
    "llama4-scout-17b-a16e", "zamba2-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.1f}ns"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1.0:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def fmt_bytes(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(mesh: str, variant: str = "baseline") -> dict[tuple[str, str], dict]:
    out = {}
    for f in RESULTS.glob(f"*__{mesh}__{variant}.json"):
        rec = json.loads(f.read_text())
        if "roofline" in rec:
            out[(rec["arch"], rec["shape"])] = rec
    return out


def table(mesh: str, variant: str = "baseline") -> str:
    recs = load(mesh, variant)
    rows = [
        "| arch | shape | compute | memory | collective | dominant | useful FLOPs | bytes/dev | coll bytes |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = recs.get((arch, shape))
            if rec is None:
                rows.append(f"| {arch} | {shape} | - | - | - | MISSING | - | - | - |")
                continue
            r = rec["roofline"]
            mem = rec["memory"]
            per_dev = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0))
            useful = r["useful_flops_ratio"]
            rows.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
                f"| {useful:.3f} | {fmt_bytes(per_dev)} | {fmt_bytes(r['coll_bytes'])} |"
                if useful is not None else
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** | - "
                f"| {fmt_bytes(per_dev)} | {fmt_bytes(r['coll_bytes'])} |"
            )
    return "\n".join(rows)


def summary(mesh: str) -> str:
    recs = load(mesh)
    dom = {}
    for rec in recs.values():
        dom[rec["roofline"]["dominant"]] = dom.get(rec["roofline"]["dominant"], 0) + 1
    lines = [f"mesh={mesh}: {len(recs)} pairs compiled; dominance: {dom}"]
    # worst useful-flops ratio and most collective-bound
    ranked = sorted(
        (r for r in recs.values() if r["roofline"]["useful_flops_ratio"]),
        key=lambda r: r["roofline"]["useful_flops_ratio"],
    )
    if ranked:
        w = ranked[0]
        lines.append(
            f"worst useful-FLOPs: {w['arch']} x {w['shape']} "
            f"({w['roofline']['useful_flops_ratio']:.3f})"
        )
    coll = max(recs.values(), key=lambda r: r["roofline"]["collective_s"])
    lines.append(f"most collective-bound: {coll['arch']} x {coll['shape']} "
                 f"({fmt_s(coll['roofline']['collective_s'])}/step)")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    print(table(args.mesh, args.variant))
    print()
    print(summary(args.mesh))


if __name__ == "__main__":
    main()
