"""Aggregate the dry-run sweep into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]

Also renders the training-kernel section from ``BENCH_kernels.json``
(``benchmarks/run.py --mode kernels``), where the paper's GRU-eICU shape is
a first-class row next to the LM shape — and asserts the structural claim
that the residual backward contains no forward-recompute scan.

Missing results directories, incomplete records, and arch names outside the
known order are skipped with a warning instead of raising.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"

ARCH_ORDER = [
    "qwen3-1.7b", "mamba2-130m", "seamless-m4t-large-v2", "deepseek-v3-671b",
    "smollm-135m", "yi-9b", "internvl2-26b", "nemotron-4-15b",
    "llama4-scout-17b-a16e", "zamba2-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# Kernel-tier rows (BENCH_kernels.json keys), GRU-eICU first-class.
KERNEL_ROW_ORDER = ["gru-eicu", "mamba2-lm"]


def warn(msg: str) -> None:
    print(f"[roofline_report] warning: {msg}", file=sys.stderr, flush=True)


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.1f}ns"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1.0:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def fmt_bytes(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


_ROOFLINE_KEYS = ("compute_s", "memory_s", "collective_s", "dominant", "coll_bytes")


def load(mesh: str, variant: str = "baseline") -> dict[tuple[str, str], dict]:
    out: dict[tuple[str, str], dict] = {}
    if not RESULTS.exists():
        warn(f"results dir {RESULTS} missing — run repro.launch.dryrun first")
        return out
    for f in RESULTS.glob(f"*__{mesh}__{variant}.json"):
        try:
            rec = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError) as exc:
            warn(f"skipping unreadable record {f.name}: {exc}")
            continue
        roofline = rec.get("roofline")
        if not isinstance(roofline, dict):
            continue
        missing = [k for k in _ROOFLINE_KEYS if k not in roofline]
        if missing or "arch" not in rec or "shape" not in rec:
            warn(f"skipping incomplete record {f.name} (missing {missing or 'arch/shape'})")
            continue
        out[(rec["arch"], rec["shape"])] = rec
    return out


def _row(arch: str, shape: str, rec: dict) -> str:
    r = rec["roofline"]
    mem = rec.get("memory", {})
    per_dev = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
    useful = r.get("useful_flops_ratio")
    useful_s = f"{useful:.3f}" if useful is not None else "-"
    return (
        f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
        f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** | {useful_s} "
        f"| {fmt_bytes(per_dev)} | {fmt_bytes(r['coll_bytes'])} |"
    )


def table(mesh: str, variant: str = "baseline") -> str:
    recs = load(mesh, variant)
    rows = [
        "| arch | shape | compute | memory | collective | dominant | useful FLOPs | bytes/dev | coll bytes |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    known = set()
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            known.add((arch, shape))
            rec = recs.get((arch, shape))
            if rec is None:
                rows.append(f"| {arch} | {shape} | - | - | - | MISSING | - | - | - |")
                continue
            rows.append(_row(arch, shape, rec))
    # Records outside the known grid render at the bottom instead of
    # silently disappearing (previously dropped; unknown keys KeyError'd).
    for key in sorted(recs.keys() - known):
        warn(f"arch/shape {key} not in the known order — appending")
        rows.append(_row(*key, recs[key]))
    return "\n".join(rows)


def summary(mesh: str) -> str:
    recs = load(mesh)
    if not recs:
        return f"mesh={mesh}: no dry-run records found"
    dom: dict[str, int] = {}
    for rec in recs.values():
        dom[rec["roofline"]["dominant"]] = dom.get(rec["roofline"]["dominant"], 0) + 1
    lines = [f"mesh={mesh}: {len(recs)} pairs compiled; dominance: {dom}"]
    # worst useful-flops ratio and most collective-bound
    ranked = sorted(
        (r for r in recs.values() if r["roofline"].get("useful_flops_ratio")),
        key=lambda r: r["roofline"]["useful_flops_ratio"],
    )
    if ranked:
        w = ranked[0]
        lines.append(
            f"worst useful-FLOPs: {w['arch']} x {w['shape']} "
            f"({w['roofline']['useful_flops_ratio']:.3f})"
        )
    coll = max(recs.values(), key=lambda r: r["roofline"]["collective_s"])
    lines.append(f"most collective-bound: {coll['arch']} x {coll['shape']} "
                 f"({fmt_s(coll['roofline']['collective_s'])}/step)")
    return "\n".join(lines)


def kernels_table(bench_path: Path) -> str:
    """Training-kernel tier from BENCH_kernels.json: fwd / bwd / local-step
    timings per backward pairing, plus the recompute-elimination check."""
    if not bench_path.exists():
        warn(f"{bench_path} missing — run benchmarks/run.py --mode kernels")
        return "(no kernel benchmark data)"
    try:
        bench = json.loads(bench_path.read_text())
    except (OSError, json.JSONDecodeError) as exc:
        warn(f"unreadable {bench_path}: {exc}")
        return "(no kernel benchmark data)"

    rows = [
        "| shape | pass | oracle-vjp | residual | pallas | speedup (resid/oracle) |",
        "|---|---|---|---|---|---|",
    ]
    us = lambda v: f"{v/1e3:.2f}ms" if v >= 1e3 else f"{v:.0f}us"
    eliminated = []
    for name in KERNEL_ROW_ORDER:
        fam = bench.get(name)
        if not isinstance(fam, dict):
            warn(f"kernel family {name!r} missing from {bench_path.name}")
            continue
        bwd = fam.get("bwd_us", {})
        step = fam.get("local_step_us", {})
        fwd = fam.get("fwd_us", {})
        if bwd.get("oracle_vjp") and bwd.get("residual_jnp"):
            speedup = f"{bwd['oracle_vjp'] / bwd['residual_jnp']:.2f}x"
        else:
            speedup = "-"
        rows.append(
            f"| {name} | fwd | - | {us(fwd.get('jnp_ref', 0))} (jnp) "
            f"| {us(fwd.get('pallas_interpret', 0))} | |"
        )
        rows.append(
            f"| {name} | bwd | {us(bwd.get('oracle_vjp', 0))} "
            f"| {us(bwd.get('residual_jnp', 0))} "
            f"| {us(bwd.get('pallas_interpret', 0))} | {speedup} |"
        )
        rows.append(
            f"| {name} | local step | {us(step.get('oracle_vjp', 0))} "
            f"| {us(step.get('residual', 0))} | - | |"
        )
        rec = fam.get("recompute", {})
        eliminated.append(bool(rec.get("recompute_eliminated")))
        res_scans = rec.get("residual_bwd", {}).get("scans")
        orc_scans = rec.get("oracle_bwd", {}).get("scans")
        rows.append(
            f"| {name} | bwd scan sites | {orc_scans} | {res_scans} | 0 (in-kernel loop) | |"
        )

    # The structural claim this tier exists for: no second forward scan.
    assert eliminated and all(eliminated), (
        "residual backward still contains a forward-recompute scan — "
        f"see 'recompute' sections of {bench_path}"
    )
    rows.append("")
    rows.append("recompute check: residual backward has no forward-recompute scan ✓")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument(
        "--kernels", default="BENCH_kernels.json",
        help="path to the kernels benchmark output (skipped with a warning "
        "when absent)",
    )
    args = ap.parse_args()
    print(table(args.mesh, args.variant))
    print()
    print(summary(args.mesh))
    print()
    print(kernels_table(Path(args.kernels)))


if __name__ == "__main__":
    main()
