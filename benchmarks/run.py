"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  table4_*   — paper Table 4 (central + 4 federated settings) at benchmark
               scale; us_per_call = wall time per local training step,
               derived = test MSLE.
  table5_*   — paper Table 5 (QG / DG recruitment ablations).
  fig2_*     — paper Fig. 2 (gamma_th sweep); derived = clients recruited.
  kernel_*   — Pallas kernels vs jnp oracle (interpret mode on CPU);
               derived = max |err| vs the oracle.
  roofline_* — per (arch x shape) dry-run roofline terms from
               benchmarks/results/dryrun; us_per_call = dominant-term
               seconds * 1e6, derived = dominant term name.

Full-scale paper numbers (the ones recorded in EXPERIMENTS.md) come from
``python -m repro.experiments.run_full``; this harness keeps the default
run CPU-budget friendly (~ a few minutes).
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived) -> None:
    ROWS.append((name, us_per_call, str(derived)))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


# --------------------------------------------------------------------------
# paper tables (benchmark scale)
# --------------------------------------------------------------------------

def bench_paper_tables(scale: float, seeds: list[int]) -> None:
    from repro.experiments.paper import ExperimentConfig, build_cohort, run_setting

    exp = ExperimentConfig(cohort_scale=scale, rounds=5, local_epochs=2, central_epochs=5)
    cohort = build_cohort(exp, seed=0)
    table4 = ["central", "federated-ac", "federated-sc", "federated-arc", "federated-src"]
    table5 = ["federated-src-qg", "federated-src-dg"]
    for setting in table4 + table5:
        msles, taus, steps = [], [], []
        for seed in seeds:
            out = run_setting(setting, exp, cohort, seed=seed)
            msles.append(out["metrics"]["msle"])
            taus.append(out["tau_s"])
            steps.append(out["local_steps"])
        us_per_step = 1e6 * (sum(taus) / len(taus)) / max(sum(steps) / len(steps), 1)
        prefix = "table5" if setting in table5 else "table4"
        emit(f"{prefix}_{setting}", us_per_step, f"msle={sum(msles)/len(msles):.4f}")


def bench_fig2(scale: float) -> None:
    import dataclasses

    from repro.experiments.paper import ExperimentConfig, build_cohort, run_setting

    exp = ExperimentConfig(cohort_scale=scale, rounds=3, local_epochs=1)
    cohort = build_cohort(exp, seed=0)
    for gth in (0.05, 0.1, 0.3, 0.6, 1.0):
        e = dataclasses.replace(exp, gamma_th=gth)
        out = run_setting("federated-src", e, cohort, seed=0)
        us = 1e6 * out["tau_s"] / max(out["local_steps"], 1)
        emit(f"fig2_gamma{gth}", us, f"recruited={out['recruited']}")


# --------------------------------------------------------------------------
# cohort engine: sequential vs vectorized federated rounds
# --------------------------------------------------------------------------

def bench_cohort(
    client_counts: tuple[int, ...] = (8, 32, 128),
    samples_per_client: int = 16,
    batch_size: int = 4,
    local_epochs: int = 1,
    reps: int = 3,
    out_path: str = "BENCH_cohort.json",
) -> None:
    """Per-round wall clock of the two federated engines on a synthetic
    federation, at growing cohort sizes.  Writes ``BENCH_cohort.json`` with
    the sequential/vectorized seconds and the speedup per cohort size.

    Defaults target the dispatch-bound regime the engine exists to remove
    (many small hospitals, a handful of tiny local steps each, as in the
    eICU tail): the sequential loop pays a Python dispatch + device sync
    per client-step, the vectorized engine one jitted call per round.  With
    bigger per-client compute a few-core CPU saturates on raw FLOPs and
    both engines converge to the same floor; on parallel hardware the
    vectorized gain grows with cohort size instead."""
    import jax
    import numpy as np

    from repro.data.pipeline import ArrayDataset, ClientDataset
    from repro.federated.client import LocalTrainer
    from repro.federated.cohort import CohortTrainer
    from repro.federated.fedavg import aggregate
    from repro.models.gru import GRUConfig, init_gru, make_loss_fn
    from repro.optim.adamw import AdamW

    cfg = GRUConfig()  # the paper's LoS model: 38 features, N=32, L=2
    loss_fn = make_loss_fn(cfg)
    opt = AdamW(learning_rate=5e-3, weight_decay=5e-3)
    params = init_gru(jax.random.key(0), cfg)
    data_rng = np.random.default_rng(0)

    def synth_clients(count: int) -> list[ClientDataset]:
        clients = []
        for i in range(count):
            # mild size skew so the padded schedule is exercised
            n = samples_per_client + (i % 4) * (batch_size // 4)
            x = data_rng.normal(size=(n, 24, cfg.input_dim)).astype(np.float32)
            y = data_rng.uniform(0.5, 20.0, size=n).astype(np.float32)
            ds = ArrayDataset(x, y)
            clients.append(ClientDataset(client_id=i, train=ds, val=ds))
        return clients

    # One trainer per engine for the whole sweep — exactly like a multi-round
    # FederatedServer run, compilation is paid once, not per round.
    seq_trainer = LocalTrainer(loss_fn, opt, batch_size=batch_size, local_epochs=local_epochs)
    vec_trainer = CohortTrainer(loss_fn, opt, batch_size=batch_size, local_epochs=local_epochs)

    def run_sequential(clients) -> None:
        rng, key = np.random.default_rng(1), jax.random.key(1)
        outs, weights = [], []
        for c in clients:
            key, sub = jax.random.split(key)
            p, _, n = seq_trainer.train_client(params, c, rng, sub)
            outs.append(p)
            weights.append(n)
        jax.block_until_ready(aggregate(outs, weights))

    def run_vectorized(clients) -> None:
        rng, key = np.random.default_rng(1), jax.random.key(1)
        keys = list(jax.random.split(key, len(clients)))
        p, _, _ = vec_trainer.train_cohort(params, clients, rng, keys)
        jax.block_until_ready(p)

    report = {}
    for count in client_counts:
        clients = synth_clients(count)
        row = {}
        for name, fn in (("sequential", run_sequential), ("vectorized", run_vectorized)):
            fn(clients)  # warmup: compile + caches
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(clients)
            row[name] = (time.perf_counter() - t0) / reps
        row["speedup"] = row["sequential"] / row["vectorized"]
        report[str(count)] = row
        emit(f"cohort_seq_{count}c", 1e6 * row["sequential"], "per-round wall")
        emit(f"cohort_vec_{count}c", 1e6 * row["vectorized"], f"speedup={row['speedup']:.2f}x")

    payload = {
        "bench": "cohort_engine_round",
        "model": "gru_eicu",
        "batch_size": batch_size,
        "samples_per_client": samples_per_client,
        "local_epochs": local_epochs,
        "reps": reps,
        "results": report,
    }
    Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out_path}", flush=True)


# --------------------------------------------------------------------------
# paper-scale federation: all five settings at 189 clients, both engines
# --------------------------------------------------------------------------

def bench_paper189(
    rounds: int = 3,
    total_stays: int = 4096,
    mesh_auto: bool = False,
    out_path: str = "BENCH_paper189.json",
) -> None:
    """The paper's full 189-client experiment grid (section 6) end to end.

    Every model setting (central / federated ac, sc, arc, src) runs at the
    full 189-hospital federation under both engines; per-setting rows report
    steady-state microseconds per round and the vectorized-over-sequential
    speedup, plus a donated-vs-plain buffer memory probe.  Per-hospital data
    is CI-scaled (the client axis is the paper-scale dimension); pass
    ``--mesh-auto`` under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    to run the client axis through the shard_map path.
    """
    from repro.experiments.paper import run_paper_scale

    report = run_paper_scale(
        rounds=rounds,
        total_stays=total_stays,
        mesh="auto" if mesh_auto else None,
    )
    for setting, row in report["settings"].items():
        for engine, entry in row.items():
            if engine == "speedup":
                continue
            derived = f"msle={entry['metrics']['msle']:.4f}"
            if engine == "vectorized" and "speedup" in row:
                derived += f";speedup={row['speedup']:.2f}x"
            if entry.get("time_unit", "round") != "round":
                derived += f";per_{entry['time_unit']}"
            emit(f"paper189_{setting}_{engine}", 1e6 * entry["round_time_s"], derived)
    mem = report["memory"]
    emit(
        "paper189_memory_donated",
        float(mem["donated"]["peak_live_bytes"]),
        f"peak_bufs={mem['donated']['peak_live_buffers']}",
    )
    emit(
        "paper189_memory_plain",
        float(mem["plain"]["peak_live_bytes"]),
        f"donated_lower={mem['donated_peak_lower']}",
    )
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path}", flush=True)


# --------------------------------------------------------------------------
# staging pipeline: rebuild-per-round vs device-resident + prefetch
# --------------------------------------------------------------------------

def bench_pipeline(
    rounds: int = 4,
    total_stays: int = 189 * 64,
    cohort_chunk: int = 48,
    mesh_auto: bool = False,
    out_path: str = "BENCH_pipeline.json",
) -> None:
    """Per-round staging cost at 189 clients: PR 2's rebuild-per-round path
    (full schedule re-materialized in numpy and re-uploaded every round)
    against the device-resident path (data uploaded once, rounds stage only
    int32 index plans, batches gathered on device, plans double-buffered on
    a background thread).  Reports per-variant steady-state round seconds,
    per-round host->device bytes, and the rebuild/resident speedup and byte
    ratio; with more than one visible device (or ``--mesh-auto``) the same
    grid additionally runs through the shard_map client-axis path.  A
    facade-overhead probe rides along: the policy-API ``Federation`` round
    program vs the bare PR-3 ``chain_split_keys`` + ``train_cohort`` loop
    (budget: <= 2% per-round overhead).  Writes ``BENCH_pipeline.json``.
    """
    import jax

    from repro.experiments.paper import run_facade_overhead, run_staging_comparison

    report = {
        "bench": "staging_pipeline",
        "single_device": run_staging_comparison(
            rounds=rounds, total_stays=total_stays, cohort_chunk=cohort_chunk
        ),
        "facade_overhead": run_facade_overhead(),
    }
    if mesh_auto and jax.device_count() > 1:
        # The mesh leg honours cohort_chunk: all-participant chunks are
        # contiguous resident-row runs, so the static-slice fast path keeps
        # each shard's rows local instead of the cross-shard gather that
        # used to force the unchunked fallback here.
        report["shard_map"] = run_staging_comparison(
            rounds=rounds, total_stays=total_stays, cohort_chunk=cohort_chunk,
            mesh="auto", variants=("rebuild", "rebuild-chunked", "resident"),
        )
    elif mesh_auto:
        emit("pipeline_shard_map_skipped", 0.0, "only one device visible")
    for leg, rep in report.items():
        if not isinstance(rep, dict) or "variants" not in rep:
            continue
        for variant, entry in rep["variants"].items():
            emit(
                f"pipeline_{leg}_{variant}",
                1e6 * entry["round_time_s"],
                f"staged={entry['bytes_staged_per_round']}B"
                f";prefetched={entry['plans_prefetched']}",
            )
        emit(
            f"pipeline_{leg}_speedup",
            1e6 * rep["variants"]["resident"]["round_time_s"],
            f"speedup={rep['speedup']:.2f}x;bytes_ratio={rep['bytes_ratio']:.1f}x"
            f";max_param_diff={rep['max_param_diff']:.2e}",
        )
    facade = report["facade_overhead"]
    emit(
        "pipeline_facade_overhead",
        1e6 * facade["facade_round_s"],
        f"overhead={100 * facade['overhead_frac']:+.2f}%"
        f";within_budget={facade['within_budget']}",
    )
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path}", flush=True)


# --------------------------------------------------------------------------
# control plane: submitted-job overhead vs direct Federation.run
# --------------------------------------------------------------------------

def bench_service(
    rounds: int = 6,
    scale: float = 0.02,
    out_path: str = "BENCH_pipeline.json",
) -> None:
    """The federation-service envelope vs a direct ``Federation.run``.

    Times the same workload end to end through both paths: bare
    ``build_workload`` + facade run, and a job submitted through
    ``repro.launch.federation_service`` (spec validation + hashing,
    job.json, the per-round JSONL record stream, snapshots, final-params
    save).  Budget: <= 2% total overhead.  Merges a ``service_overhead``
    section into ``BENCH_pipeline.json`` next to the facade-overhead probe
    (the two taxes stack on the same hot loop, so they belong in one
    report).
    """
    from repro.experiments.paper import run_service_overhead

    section = run_service_overhead(rounds=rounds, scale=scale)
    path = Path(out_path)
    report = json.loads(path.read_text()) if path.exists() else {
        "bench": "staging_pipeline"
    }
    report["service_overhead"] = section
    emit(
        "pipeline_service_overhead",
        1e6 * section["service_total_s"],
        f"overhead={100 * section['overhead_frac']:+.2f}%"
        f";within_budget={section['within_budget']}",
    )
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path}", flush=True)


# --------------------------------------------------------------------------
# observability: tracer-off / tracer-on overhead, sync + async engines
# --------------------------------------------------------------------------

def bench_obs(
    rounds: int = 10,
    flushes: int = 10,
    repeats: int = 3,
    out_path: str = "BENCH_obs.json",
    trace_path: str = "BENCH_obs_trace.json",
) -> None:
    """The observability tax at the paper's 189 clients, both engines.

    Three sync variants (bare hot loop, ``Federation`` with the null
    tracer, ``Federation`` with a live tracer) plus an async off/on pair
    (fedbuff, constant latency, so each flush is the same unit of work).
    Budgets: instrumented-off <= 1% over bare, tracer-on <= 5% over off.
    Writes ``BENCH_obs.json`` and exports the async on-run's ring as a
    Perfetto-loadable ``BENCH_obs_trace.json`` sample.
    """
    from repro.experiments.paper import run_obs_overhead

    report = run_obs_overhead(
        rounds=rounds, flushes=flushes, repeats=repeats, trace_path=trace_path
    )
    sync, async_ = report["sync"], report["async"]
    emit(
        "obs_sync_off",
        1e6 * sync["off_round_s"],
        f"overhead={100 * sync['overhead_off_frac']:+.2f}%;budget=1%",
    )
    emit(
        "obs_sync_on",
        1e6 * sync["on_round_s"],
        f"overhead={100 * sync['overhead_on_frac']:+.2f}%;budget=5%",
    )
    emit(
        "obs_async_on",
        1e6 * async_["on_flush_s"],
        f"overhead={100 * async_['overhead_on_frac']:+.2f}%;budget=5%"
        f";events={report['trace']['async_events']}",
    )
    emit("obs_within_budget", 0.0, report["within_budget"])
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path}", flush=True)
    print(f"# wrote {trace_path}", flush=True)


# --------------------------------------------------------------------------
# async runtime: simulated time-to-target under straggler distributions
# --------------------------------------------------------------------------

def bench_async(
    flushes: int = 8,
    cohort_scale: float = 0.05,
    dropout: float = 0.05,
    out_path: str = "BENCH_async.json",
) -> None:
    """Recruited vs all-clients async federations on the virtual clock.

    Runs the ``repro.federated.runtime`` event-driven federation (fedbuff
    buffered aggregation, per-client straggler latencies, dropout) for both
    the ``"all"`` and nu-greedy federations under each latency model, and
    reports the paper's claim on the axis the sync engines cannot measure:
    simulated time-to-target-loss.  Rows quote virtual (simulated) seconds
    scaled to us; ``derived`` carries the recruited-over-all speedup and
    the mean update staleness.  Writes ``BENCH_async.json``.
    """
    from repro.experiments.paper import ASYNC_FEDERATIONS, run_async_comparison

    report = run_async_comparison(
        flushes=flushes, cohort_scale=cohort_scale, dropout=dropout
    )
    for latency, row in report["latency"].items():
        tag = latency.replace(":", "")
        for name, _ in ASYNC_FEDERATIONS:
            entry = row[name]
            reached = entry["time_to_target"]
            stale = entry["mean_staleness"]
            emit(
                f"async_{tag}_{name}",
                1e6 * reached if reached is not None else 0.0,
                ("virtual_s" if reached is not None else "target_unreached")
                + f";fed={entry['federation_size']}"
                + (f";stale={stale:.2f}" if stale is not None else "")
                + f";dropped={entry['dropped']}",
            )
        speedup = row["recruited_speedup"]
        t_rec = row["recruited"]["time_to_target"]
        emit(
            f"async_{tag}_speedup",
            1e6 * t_rec if t_rec is not None else 0.0,
            (
                f"recruited_speedup={speedup:.2f}x"
                if speedup is not None
                else "recruited_speedup=n/a"
            )
            + f";target_loss={row['target_loss']:.4f}",
        )
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path}", flush=True)


# --------------------------------------------------------------------------
# population scale: recruitment + rounds from 10^3 to 10^5 clients
# --------------------------------------------------------------------------

def bench_population(
    populations: tuple[int, ...] = (1_000, 10_000, 100_000),
    rounds: int = 3,
    round_clients: int = 64,
    pool_rows: int = 256,
    out_path: str = "BENCH_population.json",
) -> None:
    """Population-scale curve: streaming nu-greedy recruitment (ingest pass
    vs finalize decision, with the exact ``recruit`` as parity oracle) and
    steady-state round time out of an LRU-pooled device cohort, at each
    population size.  The report asserts the contract on the way out:
    participant sets match the oracle at 10^3 (exact-buffer mode), the
    recruitment decision and the round time grow sub-linearly in population,
    and ``is_recruited`` membership stays O(1) amortized.  Writes
    ``BENCH_population.json``.
    """
    from repro.experiments.population import run_population_scale

    report = run_population_scale(
        populations=populations,
        rounds=rounds,
        round_clients=round_clients,
        pool_rows=pool_rows,
        verbose=False,
    )
    for entry in report["entries"]:
        pop = entry["population"]
        emit(
            f"population_{pop}_recruit",
            1e6 * entry["recruitment_decision_s"],
            f"mode={entry['streaming_mode']}"
            f";ingest_us_per_client={entry['recruitment_ingest_us_per_client']:.1f}"
            f";recruited={entry['num_recruited_streaming']}"
            + (
                f";match={entry['participant_match']}"
                f";jaccard={entry['overlap_jaccard']:.3f}"
                if "participant_match" in entry
                else ""
            ),
        )
        emit(
            f"population_{pop}_round",
            1e6 * entry["round_time_s"],
            f"pool_rows={entry['pool_rows']}"
            f";uploads={entry['pool_uploads_total']}"
            f";evictions={entry['pool_evictions_total']}",
        )
    if "population_ratio" in report:
        emit(
            "population_scaling",
            0.0,
            f"pop_ratio={report['population_ratio']:.0f}x"
            f";decision_ratio={report['recruitment_decision_ratio']:.2f}x"
            f";round_ratio={report['round_time_ratio']:.2f}x"
            f";sublinear={report['recruitment_sublinear'] and report['round_sublinear']}",
        )
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path}", flush=True)


# --------------------------------------------------------------------------
# privacy: DP-SGD and secure-aggregation per-round overhead
# --------------------------------------------------------------------------

def bench_privacy(
    rounds: int = 3,
    total_stays: int = 189 * 8,
    noise_multiplier: float = 1.0,
    out_path: str = "BENCH_privacy.json",
) -> None:
    """Privacy-tier cost at the paper's 189 clients, baseline in-file.

    For each staging mode the grid runs the unprotected federation and the
    in-jit DP-SGD federation under both engines (per-example clipping +
    noise ride the jitted round, so the interesting number is the
    steady-state per-round overhead), plus one masked-sum secure
    aggregation run — secagg's stacked mode forces the sequential engine,
    so its overhead is reported against the sequential baseline of the
    same staging.  DP rows carry the accountant's final epsilon.  Writes
    ``BENCH_privacy.json`` with every baseline next to its protected run.
    """
    import jax
    import numpy as np

    from repro.data.pipeline import build_client_datasets
    from repro.data.synth_eicu import generate_cohort
    from repro.experiments.paper import paper_scale_cohort_config
    from repro.federated.api import Federation, FederationConfig
    from repro.models.gru import GRUConfig, init_gru, make_loss_fn
    from repro.optim.adamw import AdamW
    from repro.privacy.dp import DPConfig

    cohort = generate_cohort(paper_scale_cohort_config(total_stays), seed=0)
    clients = build_client_datasets(cohort)
    model_cfg = GRUConfig()
    loss_fn = make_loss_fn(model_cfg)
    optimizer = AdamW(learning_rate=5e-3, weight_decay=5e-3)
    params0 = init_gru(jax.random.key(0), model_cfg)
    dp = DPConfig(clip_norm=1.0, noise_multiplier=noise_multiplier)

    def one(engine: str, staging: str, privacy=None, aggregator="fedavg"):
        cfg = FederationConfig(
            rounds=rounds, local_epochs=1, batch_size=128,
            aggregator=aggregator, seed=0, engine=engine, staging=staging,
            privacy=privacy,
        )
        fed = Federation(cfg, clients, loss_fn, optimizer)
        result = fed.run(params0)
        times = [r.wall_time_s for r in result.history]
        steady = float(np.mean(times[1:])) if len(times) > 1 else float(times[0])
        return {
            "round_time_s": steady,
            "effective_engine": fed.effective_engine,
            "epsilon": result.summary()["epsilon"],
        }

    report: dict = {
        "bench": "privacy",
        "clients": len(clients),
        "rounds": rounds,
        "noise_multiplier": noise_multiplier,
        "grid": {},
    }
    for staging in ("resident", "rebuild"):
        cell: dict = {}
        for engine in ("vectorized", "sequential"):
            base = one(engine, staging)
            protected = one(engine, staging, privacy=dp)
            overhead = protected["round_time_s"] / base["round_time_s"] - 1.0
            cell[engine] = {
                "unprotected": base,
                "dp": {**protected, "overhead_frac": overhead},
            }
            emit(
                f"privacy_{staging}_{engine}_dp",
                1e6 * protected["round_time_s"],
                f"overhead={100 * overhead:+.1f}%"
                f";eps={protected['epsilon']:.2f}",
            )
        seq_base = cell["sequential"]["unprotected"]["round_time_s"]
        secagg = one("sequential", staging, aggregator="secagg-fedavg")
        cell["secagg"] = {
            **secagg,
            "overhead_frac": secagg["round_time_s"] / seq_base - 1.0,
        }
        emit(
            f"privacy_{staging}_secagg",
            1e6 * secagg["round_time_s"],
            f"overhead={100 * cell['secagg']['overhead_frac']:+.1f}%"
            f";engine={secagg['effective_engine']}",
        )
        report["grid"][staging] = cell
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path}", flush=True)


# --------------------------------------------------------------------------
# kernels
# --------------------------------------------------------------------------

def bench_kernels(
    *,
    reps: int = 5,
    gru_batch: int = 128,
    lm_seq: int = 256,
    lm_heads: int = 4,
    out_path: str = "BENCH_kernels.json",
) -> None:
    """Training-grade kernel tier: fwd / bwd / local-step timings.

    Compares three backward pairings at the paper's GRU-eICU shape and a
    mamba2-130m-derived LM shape (head_dim/d_state from the zoo config,
    heads and sequence scaled for CPU interpret mode):

      oracle_vjp    — old pairing: backward recomputes the forward through
                      the jnp oracle, then transposes it
      residual_jnp  — new default off-TPU: single reverse scan over stashed
                      residuals, no forward recompute
      pallas_bwd    — the hand-written backward kernel (interpret mode here,
                      Mosaic-compiled on TPU)

    Also embeds the jaxpr recompute-elimination report (scan sites + FLOP
    accounting of the backward-only graph).  Writes ``BENCH_kernels.json``.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.gru_eicu import CONFIG as GRU_EICU
    from repro.configs.mamba2_130m import CONFIG as MAMBA_LM
    from repro.kernels.analysis import recompute_elimination_report
    from repro.kernels.gru_scan.kernel import gru_scan, gru_scan_bwd
    from repro.kernels.gru_scan.ops import gru_scan_op, gru_scan_oracle
    from repro.kernels.gru_scan.ref import gru_scan_bwd_ref, gru_scan_ref
    from repro.kernels.ssd.kernel import ssd_chunk_scan_bwd
    from repro.kernels.ssd.ops import ssd_chunk_scan, ssd_chunk_scan_oracle
    from repro.kernels.ssd.ref import (
        ssd_chunk_scan_bwd_ref,
        ssd_chunk_scan_ref,
        ssd_chunk_states_ref,
    )

    rng = np.random.default_rng(0)

    def timeit(fn, *args) -> float:
        jax.block_until_ready(fn(*args))  # warmup / compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        return 1e6 * (time.perf_counter() - t0) / reps

    def grad_fn(op, argnums):
        return jax.jit(jax.grad(lambda *a: jnp.sum(op(*a) ** 2), argnums=argnums))

    report: dict = {"bench": "kernels", "backend": jax.default_backend(), "reps": reps}

    # ---- GRU at the paper's eICU shape (hidden from repro.configs) -------
    t_len, n_hid = 24, GRU_EICU.hidden_dim
    xg = jnp.asarray(rng.normal(size=(gru_batch, t_len, 3 * n_hid)), jnp.float32)
    whh = jnp.asarray(rng.normal(size=(n_hid, 3 * n_hid)) * 0.3, jnp.float32)
    bhh = jnp.zeros(3 * n_hid)
    dy = jnp.asarray(rng.normal(size=(gru_batch, t_len, n_hid)), jnp.float32)
    h_seq = gru_scan_ref(xg, whh, bhh)

    err_fwd = float(jnp.max(jnp.abs(gru_scan(xg, whh, bhh) - h_seq)))
    _, oracle_vjp = jax.vjp(gru_scan_ref, xg, whh, bhh)
    g_oracle = oracle_vjp(dy)
    maxerr = lambda got: max(
        float(jnp.max(jnp.abs(a - b))) for a, b in zip(got, g_oracle)
    )
    jit_oracle_bwd = jax.jit(lambda ct: jax.vjp(gru_scan_ref, xg, whh, bhh)[1](ct))
    jit_resid_bwd = jax.jit(gru_scan_bwd_ref)
    pallas_bwd = lambda: gru_scan_bwd(xg, whh, bhh, h_seq, dy, interpret=True)

    gru = {
        "shape": {"batch": gru_batch, "seq": t_len, "hidden": n_hid},
        "fwd_us": {
            "pallas_interpret": timeit(gru_scan, xg, whh, bhh),
            "jnp_ref": timeit(jax.jit(gru_scan_ref), xg, whh, bhh),
        },
        "bwd_us": {
            "oracle_vjp": timeit(jit_oracle_bwd, dy),
            "residual_jnp": timeit(jit_resid_bwd, xg, whh, bhh, h_seq, dy),
            "pallas_interpret": timeit(pallas_bwd),
        },
        "local_step_us": {
            "oracle_vjp": timeit(grad_fn(gru_scan_oracle, (0, 1, 2)), xg, whh, bhh),
            "residual": timeit(grad_fn(gru_scan_op, (0, 1, 2)), xg, whh, bhh),
            "jnp_autodiff": timeit(grad_fn(gru_scan_ref, (0, 1, 2)), xg, whh, bhh),
        },
        "maxerr": {
            "fwd": err_fwd,
            "bwd_residual_vs_oracle": maxerr(jit_resid_bwd(xg, whh, bhh, h_seq, dy)),
            "bwd_pallas_vs_oracle": maxerr(pallas_bwd()),
        },
        "recompute": recompute_elimination_report(
            gru_scan_op, gru_scan_oracle, xg, whh, bhh
        ),
    }
    report["gru-eicu"] = gru
    emit("kernel_gru_fwd_interp", gru["fwd_us"]["pallas_interpret"], f"maxerr={err_fwd:.2e}")
    for path, us in gru["bwd_us"].items():
        emit(f"kernel_gru_bwd_{path}", us, "")
    for path, us in gru["local_step_us"].items():
        emit(f"kernel_gru_step_{path}", us, "")

    # ---- SSD at a mamba2-130m-derived LM shape ---------------------------
    s_cfg = MAMBA_LM.ssm
    b, s, h, p, n = 2, lm_seq, lm_heads, s_cfg.head_dim, s_cfg.d_state
    chunk = min(64, s)
    nc = s // chunk
    xc = jnp.asarray(rng.normal(size=(b, nc, chunk, h, p)), jnp.float32)
    dtc = jax.nn.softplus(jnp.asarray(rng.normal(size=(b, nc, chunk, h)), jnp.float32))
    a_dec = -jnp.exp(jnp.asarray(rng.normal(size=(h,)) * 0.5, jnp.float32))
    cum = jnp.cumsum(dtc * a_dec[None, None, None, :], axis=2)
    bm = jnp.asarray(rng.normal(size=(b, nc, chunk, n)) * 0.5, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, nc, chunk, n)) * 0.5, jnp.float32)
    dyc = jnp.asarray(rng.normal(size=(b, nc, chunk, h, p)), jnp.float32)
    ssd_args = (xc, dtc, cum, bm, cm)

    y_ref = ssd_chunk_scan_ref(*ssd_args)
    states = ssd_chunk_states_ref(*ssd_args)
    err_fwd = float(jnp.max(jnp.abs(ssd_chunk_scan(*ssd_args) - y_ref)))
    _, oracle_vjp = jax.vjp(ssd_chunk_scan_ref, *ssd_args)
    g_oracle = oracle_vjp(dyc)
    maxerr = lambda got: max(
        float(jnp.max(jnp.abs(a - b))) for a, b in zip(got, g_oracle)
    )
    jit_oracle_bwd = jax.jit(lambda ct: jax.vjp(ssd_chunk_scan_ref, *ssd_args)[1](ct))
    jit_resid_bwd = jax.jit(ssd_chunk_scan_bwd_ref)
    pallas_bwd = lambda: ssd_chunk_scan_bwd(*ssd_args, states, dyc, interpret=True)
    fwd_kernel = lambda: ssd_chunk_scan(*ssd_args)

    ssd = {
        "shape": {
            "arch": MAMBA_LM.name, "batch": b, "seq": s, "heads": h,
            "head_dim": p, "d_state": n, "chunk": chunk,
        },
        "fwd_us": {
            "pallas_interpret": timeit(fwd_kernel),
            "jnp_ref": timeit(jax.jit(ssd_chunk_scan_ref), *ssd_args),
        },
        "bwd_us": {
            "oracle_vjp": timeit(jit_oracle_bwd, dyc),
            "residual_jnp": timeit(jit_resid_bwd, *ssd_args, states, dyc),
            "pallas_interpret": timeit(pallas_bwd),
        },
        "local_step_us": {
            "oracle_vjp": timeit(grad_fn(ssd_chunk_scan_oracle, (0, 1, 3, 4)), *ssd_args),
            "residual": timeit(grad_fn(ssd_chunk_scan, (0, 1, 3, 4)), *ssd_args),
            "jnp_autodiff": timeit(grad_fn(ssd_chunk_scan_ref, (0, 1, 3, 4)), *ssd_args),
        },
        "maxerr": {
            "fwd": err_fwd,
            "bwd_residual_vs_oracle": maxerr(jit_resid_bwd(*ssd_args, states, dyc)),
            "bwd_pallas_vs_oracle": maxerr(pallas_bwd()),
        },
        "recompute": recompute_elimination_report(
            ssd_chunk_scan, ssd_chunk_scan_oracle, *ssd_args
        ),
    }
    report["mamba2-lm"] = ssd
    emit("kernel_ssd_fwd_interp", ssd["fwd_us"]["pallas_interpret"], f"maxerr={err_fwd:.2e}")
    for path, us in ssd["bwd_us"].items():
        emit(f"kernel_ssd_bwd_{path}", us, "")
    for path, us in ssd["local_step_us"].items():
        emit(f"kernel_ssd_step_{path}", us, "")

    report["recompute_eliminated"] = bool(
        gru["recompute"]["recompute_eliminated"]
        and ssd["recompute"]["recompute_eliminated"]
    )
    assert report["recompute_eliminated"], (
        "residual backward still contains a forward-recompute scan: "
        f"gru={gru['recompute']}, ssd={ssd['recompute']}"
    )
    emit("kernel_recompute_eliminated", 0.0, report["recompute_eliminated"])
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")


# --------------------------------------------------------------------------
# roofline (reads the dry-run sweep)
# --------------------------------------------------------------------------

def bench_roofline() -> None:
    results = Path(__file__).resolve().parent / "results" / "dryrun"
    if not results.exists():
        emit("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return
    for f in sorted(results.glob("*__single__baseline.json")):
        rec = json.loads(f.read_text())
        if "roofline" not in rec:
            continue
        r = rec["roofline"]
        dom_s = {"compute": r["compute_s"], "memory": r["memory_s"], "collective": r["collective_s"]}[r["dominant"]]
        useful = r["useful_flops_ratio"]
        emit(
            f"roofline_{rec['arch']}_{rec['shape']}",
            dom_s * 1e6,
            f"dominant={r['dominant']};useful={round(useful, 3) if useful else None}",
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1])
    ap.add_argument("--skip-paper", action="store_true")
    ap.add_argument(
        "--mode",
        choices=[
            "all", "cohort", "kernels", "paper", "paper189", "pipeline",
            "async", "service", "population", "privacy", "obs",
        ],
        default="all",
        help="'cohort' times sequential vs vectorized federated rounds only; "
        "'paper189' runs the full five-setting grid at 189 clients; "
        "'pipeline' compares rebuild-per-round vs device-resident staging; "
        "'async' simulates recruited vs all-clients time-to-target-loss "
        "under straggler latency models; 'service' probes the job-service "
        "envelope vs a direct Federation.run (merged into BENCH_pipeline.json); "
        "'population' sweeps streaming recruitment + LRU-pooled rounds from "
        "10^3 to 10^5 synthetic clients (BENCH_population.json); 'privacy' "
        "measures DP-SGD and secure-aggregation per-round overhead at 189 "
        "clients against the unprotected baseline (BENCH_privacy.json); "
        "'obs' probes tracer-off/tracer-on overhead in both engines at 189 "
        "clients and exports a sample Perfetto trace (BENCH_obs.json)",
    )
    ap.add_argument("--cohort-clients", type=int, nargs="+", default=[8, 32, 128])
    ap.add_argument("--paper189-rounds", type=int, default=3)
    ap.add_argument("--paper189-stays", type=int, default=189 * 23)
    ap.add_argument("--pipeline-rounds", type=int, default=4)
    ap.add_argument("--pipeline-stays", type=int, default=189 * 64)
    ap.add_argument(
        "--pipeline-chunk", type=int, default=48,
        help="pipeline: clients per vmapped call (4 chunks at 189 clients, "
        "so the double-buffered plan prefetch has chunks to overlap)",
    )
    ap.add_argument(
        "--async-flushes", type=int, default=8,
        help="async: buffered-aggregation flush budget per federation",
    )
    ap.add_argument(
        "--async-scale", type=float, default=0.05,
        help="async: cohort scale (heterogeneous synthetic eICU population)",
    )
    ap.add_argument(
        "--async-dropout", type=float, default=0.05,
        help="async: per-dispatch client dropout probability",
    )
    ap.add_argument(
        "--population-sizes", type=int, nargs="+",
        default=[1_000, 10_000, 100_000],
        help="population: synthetic client counts to sweep (CI uses a "
        "reduced scale)",
    )
    ap.add_argument(
        "--population-rounds", type=int, default=3,
        help="population: training rounds per size (round 0 pays compile)",
    )
    ap.add_argument(
        "--privacy-rounds", type=int, default=3,
        help="privacy: rounds per grid cell (round 0 pays compile)",
    )
    ap.add_argument(
        "--privacy-stays", type=int, default=189 * 8,
        help="privacy: total stays across the 189 clients (CI-scaled)",
    )
    ap.add_argument(
        "--privacy-noise", type=float, default=1.0,
        help="privacy: DP noise multiplier (sigma / clip_norm)",
    )
    ap.add_argument(
        "--obs-repeats", type=int, default=3,
        help="obs: alternating bare/off/on repeats per engine (floor estimator)",
    )
    ap.add_argument(
        "--mesh-auto", action="store_true",
        help="paper189/pipeline: shard the client axis over all visible devices",
    )
    ap.add_argument(
        "--kernel-reps", type=int, default=5,
        help="kernels: timed repetitions per path (CI uses a reduced count)",
    )
    ap.add_argument(
        "--kernel-gru-batch", type=int, default=128,
        help="kernels: GRU-eICU batch size (paper default 128)",
    )
    ap.add_argument(
        "--kernel-lm-seq", type=int, default=256,
        help="kernels: LM-shape sequence length (chunked at 64)",
    )
    ap.add_argument(
        "--kernel-lm-heads", type=int, default=4,
        help="kernels: LM-shape head count (mamba2-130m head_dim/d_state, "
        "heads reduced for CPU interpret mode)",
    )
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t0 = time.time()
    if args.mode == "paper189":
        bench_paper189(
            rounds=args.paper189_rounds,
            total_stays=args.paper189_stays,
            mesh_auto=args.mesh_auto,
        )
        print(f"# total benchmark time: {time.time()-t0:.1f}s")
        return
    if args.mode == "pipeline":
        bench_pipeline(
            rounds=args.pipeline_rounds,
            total_stays=args.pipeline_stays,
            cohort_chunk=args.pipeline_chunk,
            mesh_auto=args.mesh_auto,
        )
        print(f"# total benchmark time: {time.time()-t0:.1f}s")
        return
    if args.mode == "service":
        bench_service(rounds=args.pipeline_rounds)
        print(f"# total benchmark time: {time.time()-t0:.1f}s")
        return
    if args.mode == "population":
        bench_population(
            populations=tuple(args.population_sizes),
            rounds=args.population_rounds,
        )
        print(f"# total benchmark time: {time.time()-t0:.1f}s")
        return
    if args.mode == "privacy":
        bench_privacy(
            rounds=args.privacy_rounds,
            total_stays=args.privacy_stays,
            noise_multiplier=args.privacy_noise,
        )
        print(f"# total benchmark time: {time.time()-t0:.1f}s")
        return
    if args.mode == "obs":
        bench_obs(repeats=args.obs_repeats)
        print(f"# total benchmark time: {time.time()-t0:.1f}s")
        return
    if args.mode == "async":
        bench_async(
            flushes=args.async_flushes,
            cohort_scale=args.async_scale,
            dropout=args.async_dropout,
        )
        print(f"# total benchmark time: {time.time()-t0:.1f}s")
        return
    if args.mode in ("all", "cohort"):
        bench_cohort(client_counts=tuple(args.cohort_clients))
    if args.mode in ("all", "kernels"):
        bench_kernels(
            reps=args.kernel_reps,
            gru_batch=args.kernel_gru_batch,
            lm_seq=args.kernel_lm_seq,
            lm_heads=args.kernel_lm_heads,
        )
        bench_roofline()
    if args.mode in ("all", "paper") and not args.skip_paper:
        bench_paper_tables(args.scale, args.seeds)
        bench_fig2(args.scale)
    print(f"# total benchmark time: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
