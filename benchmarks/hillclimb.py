"""§Perf hillclimb driver: lower chosen (arch × shape × mesh) pairs under
variant knobs and report roofline-term deltas vs baseline.

    PYTHONPATH=src python -m benchmarks.hillclimb --pair deepseek --pair fed
"""

# NOTE: repro.launch.dryrun sets XLA_FLAGS (512 host devices) at import time,
# before jax initializes — keep this import first.
from repro.launch.dryrun import VARIANTS, run_combo  # noqa: E402

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"

# The three §Perf subjects (chosen per EXPERIMENTS.md §Roofline):
#   deepseek — most collective-bound pair (EP MoE all-to-all)
#   memory   — worst memory-bound serving pair
#   fed      — the paper's own technique at production scale (multi-pod FedAvg)
PAIRS: dict[str, dict] = {
    "deepseek": {
        "arch": "deepseek-v3-671b",
        "shape": "train_4k",
        "mesh": "single",
        "variants": ["baseline", "moe_tp", "capacity1", "capacity2", "noremat"],
    },
    "memory": {
        "arch": "llama4-scout-17b-a16e",
        "shape": "decode_32k",
        "mesh": "single",
        "variants": ["baseline", "moe_tp", "capacity1"],
    },
    "fed": {
        "arch": "qwen3-1.7b",
        "shape": "train_4k",
        "mesh": "multi",
        "variants": ["fed_k1", "fed_k4", "fed_k16"],
    },
    "attn": {
        "arch": "yi-9b",
        "shape": "prefill_32k",
        "mesh": "single",
        "variants": ["baseline", "kvchunk4096"],
    },
}


def per_token_norm(rec: dict) -> float:
    """Collective-term seconds normalized per local training step."""
    k = rec.get("tags", {}).get("fed_local_steps")
    return float(k) if k else 1.0


def report(pair_name: str, force: bool) -> None:
    spec = PAIRS[pair_name]
    print(f"\n=== {pair_name}: {spec['arch']} x {spec['shape']} x {spec['mesh']} ===")
    rows = []
    for variant in spec["variants"]:
        rec = run_combo(spec["arch"], spec["shape"], spec["mesh"], force=force, variant=variant)
        if "error" in rec:
            rows.append((variant, None))
            continue
        rows.append((variant, rec))
    base = next((r for v, r in rows if r is not None), None)
    if base is None:
        print("  all variants failed")
        return
    print(f"{'variant':14s} {'compute':>12s} {'memory':>12s} {'collective':>12s} {'dominant':>10s} {'norm':>6s}")
    for variant, rec in rows:
        if rec is None:
            print(f"{variant:14s}    FAILED")
            continue
        r = rec["roofline"]
        norm = per_token_norm(rec)
        print(
            f"{variant:14s} {r['compute_s']/norm:12.3e} {r['memory_s']/norm:12.3e} "
            f"{r['collective_s']/norm:12.3e} {r['dominant']:>10s} {norm:6.0f}"
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", action="append", choices=list(PAIRS), default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    for pair in args.pair or list(PAIRS):
        report(pair, args.force)


if __name__ == "__main__":
    main()
