"""Client-side local training for one round of FedAvg.

Each client receives the global parameters, trains for ``local_epochs`` on
its own data with a *locally initialized* AdamW (FedML-style: the optimizer
state never leaves the client and is reset each round), and returns only the
updated parameters plus its sample count.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.data.pipeline import ClientDataset, local_round_steps
from repro.optim.adamw import AdamW, apply_updates
from repro.privacy.dp import DPConfig, dp_value_and_grad, resolve_dp

PyTree = Any
LossFn = Callable[..., Any]  # loss(params, batch, rng) -> scalar


@dataclasses.dataclass
class LocalTrainer:
    """Shared, jitted local-training machinery reused across all clients.

    One jitted step serves every client because padded batches keep shapes
    static — a single compilation for the entire federation.
    """

    loss_fn: LossFn
    optimizer: AdamW
    batch_size: int
    local_epochs: int
    # In-jit DP-SGD (repro.privacy.dp), mirroring CohortTrainer.dp so the
    # sequential engine stays the vectorized engine's parity oracle under
    # DP.  None builds the original step closure untouched.
    dp: DPConfig | None = None

    def __post_init__(self) -> None:
        self.dp = resolve_dp(self.dp)
        if self.dp is None:

            def _step(params, opt_state, batch, rng):
                loss, grads = jax.value_and_grad(self.loss_fn)(params, batch, rng)
                updates, opt_state = self.optimizer.update(grads, opt_state, params)
                return apply_updates(params, updates), opt_state, loss

        else:
            dp_grad = dp_value_and_grad(self.loss_fn, self.dp)

            def _step(params, opt_state, batch, rng, noise_rng):
                loss, grads = dp_grad(params, batch, rng, noise_rng)
                updates, opt_state = self.optimizer.update(grads, opt_state, params)
                return apply_updates(params, updates), opt_state, loss

        self._step = jax.jit(_step)

    def train_client(
        self,
        params: PyTree,
        client: ClientDataset,
        rng: np.random.Generator,
        jax_rng: jax.Array,
    ) -> tuple[PyTree, float, int]:
        """Run local_epochs over the client's train split.

        Returns (updated params, mean train loss of last epoch, n_c).
        Steps executed counts toward the simulated training cost.
        """
        opt_state = self.optimizer.init(params)
        last_losses: list[float] = []
        for epoch in range(self.local_epochs):
            losses = []
            for x, y, mask in client.train.padded_batches(self.batch_size, rng):
                if self.dp is None:
                    jax_rng, sub = jax.random.split(jax_rng)
                    params, opt_state, loss = self._step(
                        params, opt_state, (x, y, mask), sub
                    )
                else:
                    # Same 3-way split as the vectorized DP step (next-chain,
                    # dropout, noise) so the engines consume identical keys.
                    keys = jax.random.split(jax_rng, 3)
                    jax_rng = keys[0]
                    params, opt_state, loss = self._step(
                        params, opt_state, (x, y, mask), keys[1], keys[2]
                    )
                losses.append(loss)
            last_losses = losses
        mean_loss = float(np.mean([float(l) for l in last_losses])) if last_losses else float("nan")
        return params, mean_loss, client.n_train

    def steps_per_round(self, client: ClientDataset) -> int:
        return local_round_steps(client.n_train, self.batch_size, self.local_epochs)
