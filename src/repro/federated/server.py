"""Legacy server facade — thin deprecation shims over ``repro.federated.api``.

``FederatedServer`` / ``FederatedConfig`` were the pre-policy orchestration
surface: one hard-wired pipeline of paper nu-greedy recruitment, uniform
per-round sampling, and FedAvg.  The runtime now lives in
:mod:`repro.federated.api` as a :class:`~repro.federated.api.Federation`
facade with pluggable ``RecruitmentPolicy`` / ``SelectionPolicy`` /
``Aggregator`` stages; the classes here only translate the old declarative
config onto those policies so every existing invocation keeps working::

    FederatedConfig(recruitment=RecruitmentConfig(...), participation_fraction=0.1)
        -> FederationConfig(recruitment=NuGreedyRecruitment(...),
                            selection=UniformSelection(fraction=0.1),
                            aggregator="fedavg")

New code should construct a ``Federation`` directly.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.recruitment import RecruitmentConfig, RecruitmentResult
from repro.data.pipeline import ClientDataset
from repro.federated.api import (
    ENGINES,
    Federation,
    FederationConfig,
    FederatedRunResult,
    NuGreedyRecruitment,
    RoundRecord,
    UniformSelection,
)
from repro.federated.cohort import STAGING_MODES
from repro.optim.adamw import AdamW

__all__ = [
    "ENGINES",
    "FederatedConfig",
    "FederatedRunResult",
    "FederatedServer",
    "RoundRecord",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FederatedConfig:
    """Deprecated: the pre-policy config.  Use ``FederationConfig`` instead.

    Field semantics are unchanged; ``to_federation()`` is the mapping onto
    the policy API (``recruitment=None`` -> ``"all"``, a
    ``RecruitmentConfig`` -> nu-greedy, ``participation_fraction`` ->
    uniform selection, aggregation is always FedAvg).
    """

    rounds: int = 15
    local_epochs: int = 4
    batch_size: int = 128
    # Per-round participation: None = all federation clients each round,
    # otherwise the random fraction sampled each round (paper uses 0.1).
    participation_fraction: float | None = None
    # Pre-federation recruitment: None disables (standard FL).
    recruitment: RecruitmentConfig | None = None
    seed: int = 0
    # "vectorized" trains the whole per-round cohort in one jitted vmap;
    # "sequential" is the per-client Python loop, kept as the reference
    # oracle (both produce matching aggregated params within 1e-5).
    engine: str = "vectorized"
    # Vectorized engine: max clients per vmapped call (None = all at once).
    cohort_chunk: int | None = None
    # Optional device mesh for the vectorized engine ("auto" = 1-D data mesh).
    mesh: Any = None
    # Vectorized engine: donate round buffers to the jitted step.
    donate_buffers: bool = True
    # "resident" uploads client data once + stages int32 plans per round;
    # "rebuild" re-uploads the full schedule every round.
    staging: str = "resident"
    # Resident staging: double-buffer chunk plans on a background thread.
    prefetch: bool = True

    def __post_init__(self) -> None:
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; choose from {ENGINES}")
        if self.staging not in STAGING_MODES:
            raise ValueError(
                f"unknown staging {self.staging!r}; choose from {STAGING_MODES}"
            )

    def to_federation(self) -> FederationConfig:
        """The policy-API equivalent of this legacy config."""
        recruitment = (
            "all" if self.recruitment is None else NuGreedyRecruitment(self.recruitment)
        )
        return FederationConfig(
            rounds=self.rounds,
            local_epochs=self.local_epochs,
            batch_size=self.batch_size,
            recruitment=recruitment,
            selection=UniformSelection(fraction=self.participation_fraction),
            aggregator="fedavg",
            seed=self.seed,
            engine=self.engine,
            cohort_chunk=self.cohort_chunk,
            mesh=self.mesh,
            donate_buffers=self.donate_buffers,
            staging=self.staging,
            prefetch=self.prefetch,
        )


class FederatedServer:
    """Deprecated: runs the FedAvg protocol via the ``Federation`` facade."""

    def __init__(
        self,
        config: FederatedConfig,
        clients: Sequence[ClientDataset],
        loss_fn: Callable[..., Any],
        optimizer: AdamW,
    ) -> None:
        warnings.warn(
            "FederatedServer is deprecated; use repro.federated.api.Federation "
            "with recruitment/selection/aggregator policies instead",
            DeprecationWarning,
            stacklevel=2,
        )
        self.config = config
        self.federation = Federation(config.to_federation(), clients, loss_fn, optimizer)

    @property
    def all_clients(self):
        return self.federation.all_clients

    @property
    def trainer(self):
        return self.federation.trainer

    @property
    def cohort_trainer(self):
        return self.federation.cohort_trainer

    def build_federation(self) -> tuple[np.ndarray, RecruitmentResult | None]:
        """Recruitment happens here — before the federation exists."""
        return self.federation.build_federation()

    def run(
        self,
        init_params: PyTree,
        progress: Callable[[RoundRecord], None] | None = None,
    ) -> FederatedRunResult:
        return self.federation.run(init_params, progress=progress)
