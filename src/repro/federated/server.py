"""Server-side orchestration of federated training (paper section 4.4).

The server (i) initializes the model, (ii) broadcasts it to the selected
clients, (iii) aggregates returned parameters with FedAvg, (iv) repeats for
``rounds`` communication rounds.  With recruitment enabled, the federation
is built from the recruited subset *before* round one — unrecruited clients
never receive the model at all (that is the point of the paper).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.core.recruitment import RecruitmentConfig, RecruitmentResult, recruit
from repro.data.pipeline import ClientDataset, cohort_steps_per_epoch
from repro.federated.client import LocalTrainer
from repro.federated.cohort import STAGING_MODES, CohortTrainer, chain_split_keys
from repro.federated.fedavg import aggregate
from repro.federated.selection import select_clients
from repro.optim.adamw import AdamW

PyTree = Any

ENGINES = ("sequential", "vectorized")


@dataclasses.dataclass(frozen=True)
class FederatedConfig:
    rounds: int = 15
    local_epochs: int = 4
    batch_size: int = 128
    # Per-round participation: None = all federation clients each round,
    # otherwise the random fraction sampled each round (paper uses 0.1).
    participation_fraction: float | None = None
    # Pre-federation recruitment: None disables (standard FL).
    recruitment: RecruitmentConfig | None = None
    seed: int = 0
    # "vectorized" trains the whole per-round cohort in one jitted vmap;
    # "sequential" is the per-client Python loop, kept as the reference
    # oracle (both produce matching aggregated params within 1e-5).
    engine: str = "vectorized"
    # Vectorized engine: max clients per vmapped call (None = all at once);
    # lower it to bound peak memory on big federations.
    cohort_chunk: int | None = None
    # Optional device mesh for the vectorized engine: shards the client
    # axis over the mesh's "data" axis via shard_map.  "auto" builds a 1-D
    # data mesh over every visible device (None when only one is visible).
    mesh: Any = None
    # Vectorized engine: donate round buffers to the jitted step (in-place
    # accumulator, eager release of consumed schedule chunks).  Keep on;
    # the switch exists to measure the memory difference.
    donate_buffers: bool = True
    # Vectorized engine: how batch data reaches the device each round.
    # "resident" (default) uploads the federation's train arrays once and
    # stages only compact int32 index plans per round, with the batch
    # gather happening on device; "rebuild" re-materializes and re-uploads
    # the full (clients, steps, batch, features) schedule every round
    # (PR 2's path, kept as the staging reference oracle).
    staging: str = "resident"
    # Resident staging: double-buffer chunk plans on a background thread
    # (build/upload chunk k+1 while chunk k trains).  Numerically a no-op.
    prefetch: bool = True

    def __post_init__(self) -> None:
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; choose from {ENGINES}")
        if self.staging not in STAGING_MODES:
            raise ValueError(
                f"unknown staging {self.staging!r}; choose from {STAGING_MODES}"
            )


@dataclasses.dataclass
class RoundRecord:
    round_index: int
    participant_ids: list[int]
    mean_local_loss: float
    local_steps: int
    comm_params: int       # parameter tensors exchanged (down + up), in clients
    wall_time_s: float


@dataclasses.dataclass
class FederatedRunResult:
    params: PyTree
    history: list[RoundRecord]
    recruitment: RecruitmentResult | None
    federation_ids: np.ndarray
    total_wall_time_s: float
    total_local_steps: int

    def summary(self) -> dict[str, Any]:
        return {
            "rounds": len(self.history),
            "federation_size": int(self.federation_ids.size),
            "recruited": None if self.recruitment is None else self.recruitment.num_recruited,
            "total_wall_time_s": self.total_wall_time_s,
            "total_local_steps": self.total_local_steps,
        }


class FederatedServer:
    """Runs the FedAvg protocol over in-process clients."""

    def __init__(
        self,
        config: FederatedConfig,
        clients: Sequence[ClientDataset],
        loss_fn: Callable[..., Any],
        optimizer: AdamW,
    ) -> None:
        self.config = config
        self.all_clients = {c.client_id: c for c in clients}
        self.trainer = LocalTrainer(
            loss_fn=loss_fn,
            optimizer=optimizer,
            batch_size=config.batch_size,
            local_epochs=config.local_epochs,
        )
        self.cohort_trainer = CohortTrainer(
            loss_fn=loss_fn,
            optimizer=optimizer,
            batch_size=config.batch_size,
            local_epochs=config.local_epochs,
            cohort_chunk=config.cohort_chunk,
            mesh=config.mesh,
            donate=config.donate_buffers,
            staging=config.staging,
            prefetch=config.prefetch,
        )

    def build_federation(self) -> tuple[np.ndarray, RecruitmentResult | None]:
        """Recruitment happens here — before the federation exists."""
        all_ids = np.array(sorted(self.all_clients), dtype=np.int64)
        if self.config.recruitment is None:
            return all_ids, None
        stats = [self.all_clients[i].stats() for i in all_ids]
        result = recruit(stats, self.config.recruitment)
        return np.sort(result.recruited_ids), result

    def run(
        self,
        init_params: PyTree,
        progress: Callable[[RoundRecord], None] | None = None,
    ) -> FederatedRunResult:
        cfg = self.config
        rng = np.random.default_rng(cfg.seed)
        jax_rng = jax.random.key(cfg.seed)

        federation_ids, recruitment = self.build_federation()
        if cfg.engine == "vectorized" and cfg.staging == "resident":
            # One host->device upload for the whole federation (only the
            # recruited clients — unrecruited ones never ship anything);
            # every round after this stages just an int32 index plan.
            self.cohort_trainer.attach_device_cohort(
                [self.all_clients[int(i)] for i in federation_ids]
            )
        params = init_params
        history: list[RoundRecord] = []
        # Pin the vectorized schedule's step axis to the federation-wide max
        # so every round shares one compiled shape whatever mix is sampled.
        federation_spe = cohort_steps_per_epoch(
            [self.all_clients[int(i)].n_train for i in federation_ids], cfg.batch_size
        )
        t_start = time.perf_counter()

        for rnd in range(cfg.rounds):
            t_round = time.perf_counter()
            participants = select_clients(
                rng, federation_ids, fraction=cfg.participation_fraction
            )
            if cfg.engine == "vectorized":
                cohort = [self.all_clients[int(cid)] for cid in participants]
                # One jitted scan replaces the per-client split chain —
                # bit-identical keys to the sequential loop, one dispatch.
                jax_rng, key_data = chain_split_keys(jax_rng, len(participants))
                params, per_losses, steps = self.cohort_trainer.train_cohort(
                    params, cohort, rng, key_data, steps_per_epoch=federation_spe
                )
                losses = per_losses.tolist()
            else:
                client_params, weights, losses, steps = [], [], [], 0
                for cid in participants:
                    client = self.all_clients[int(cid)]
                    jax_rng, sub = jax.random.split(jax_rng)
                    new_params, loss, n_c = self.trainer.train_client(params, client, rng, sub)
                    client_params.append(new_params)
                    weights.append(n_c)
                    losses.append(loss)
                    steps += self.trainer.steps_per_round(client)
                params = aggregate(client_params, weights)
            record = RoundRecord(
                round_index=rnd,
                participant_ids=[int(c) for c in participants],
                mean_local_loss=float(np.nanmean(losses)) if losses else float("nan"),
                local_steps=steps,
                comm_params=2 * len(participants),
                wall_time_s=time.perf_counter() - t_round,
            )
            history.append(record)
            if progress is not None:
                progress(record)

        return FederatedRunResult(
            params=params,
            history=history,
            recruitment=recruitment,
            federation_ids=federation_ids,
            total_wall_time_s=time.perf_counter() - t_start,
            total_local_steps=sum(r.local_steps for r in history),
        )
