"""Composable federation API: pluggable recruitment / selection / aggregation.

The paper's contribution is a *policy* — recruit clients from their output
distribution and sample size before the federation forms — yet the healthcare
FL literature treats recruitment, per-round selection, and aggregation as
interchangeable pipeline stages.  This module makes those three stages the
extension points of the runtime:

* ``RecruitmentPolicy`` — who joins the federation, decided once before
  round one from the disclosure tuples ``(P_co, n_c)``.  Built-ins:
  ``"nu-greedy"`` (the paper's greedy threshold rule, wrapping
  ``repro.core.recruitment``), ``"random-k"``, ``"top-n-samples"``, and
  ``"all"``.
* ``SelectionPolicy`` — which federation members train in a given round.
  Built-ins: ``"uniform"`` (the paper's uniform fraction/count sampling),
  ``"round-robin"`` (deterministic rotation), and ``"loss-weighted"``
  (sample proportional to last observed local loss).
* ``Aggregator`` — how client updates become the new global params.
  Built-ins: ``"fedavg"`` (weighted average, the engines' streamed in-jit
  reduction), ``"trimmed-mean"`` (coordinate-wise robust mean), and
  ``"hierarchical"`` (two-level FedAvg: regional sub-federations reduce —
  a psum per region under a mesh — then regions are averaged; the seed of
  the ROADMAP's multi-pod aggregation tier).

Every policy is resolvable from a string spec ``name`` or ``name:arg,...``
(``recruitment="nu-greedy"``, ``selection="uniform:0.1"``,
``aggregator="hierarchical:4"``) so :class:`FederationConfig` stays fully
declarative, or an instance can be passed directly.  User-defined policies
subclass the base classes and either register themselves
(:func:`register_recruitment` and friends) or are handed to the config as
objects — see ``examples/custom_policy.py``.

The round program
-----------------
:class:`Federation` decomposes the old monolithic ``FederatedServer.run``
loop into a fixed round program both engines, both staging modes, donation,
and shard_map flow through unchanged::

    build_federation -> select -> train -> aggregate -> record

How the *train -> aggregate* pair executes depends on the aggregator's
``mode``:

* ``"reduced"`` (fedavg) — the engine's own weighted-sum reduction *is* the
  aggregation: the vectorized engine streams it inside the jitted round
  (chunk accumulator, cross-shard psum), the sequential engine stacks the
  per-client params once.  This is bit-for-bit the pre-API hot path.
* ``"grouped"`` (hierarchical) — participants are partitioned by
  ``Aggregator.groups``; each group runs one engine round (FedAvg within
  the group, a single psum under a mesh), then the group means are combined
  by ``Aggregator.aggregate``.  Contiguous groups consume the shared RNG
  stream in the same client-major order as a flat round, so two-level
  FedAvg matches flat FedAvg within float tolerance.
* ``"stacked"`` (trimmed-mean) — the aggregator needs every client's
  params, which the vectorized engine never materializes (it reduces
  in-jit); these rounds run the per-client trainer and hand the stacked
  pytree to ``Aggregator.aggregate``.
* ``"buffered"`` (fedbuff, hierarchical-async) — a fourth delivery mode
  that never runs here: buffered aggregators are driven by the event loop
  of :class:`repro.federated.runtime.AsyncFederation`, and this facade
  rejects them at construction with a pointer to the async runtime.

Seeded-replay determinism
-------------------------
Every run is a pure function of ``FederationConfig.seed``.  Three
independent streams derive from it: the *recruitment* generator
(``default_rng([seed, 1])``, consumed once before round one), the shared
*batch-plan* generator (``default_rng(seed)``, consumed in client-major
order by selection and the schedule builders), and the jax *key chain*
(``jax.random.key(seed)``, advanced one ``split`` per cohort chunk /
sequential client via ``chain_split_keys``).  Policies draw only from the
generators they are handed at well-defined points, so two runs with equal
seeds replay bit-identically — and a run resumed from a
:class:`FederationSnapshot` (params + round index + both stream states +
adaptive policy state) continues exactly where the interrupted one left
off.  This contract is what the control plane's kill-and-resume parity
tests (`tests/test_federation_service.py`) pin down.

Legacy ``FederatedServer`` / ``FederatedConfig`` remain as thin deprecation
shims in ``repro.federated.server`` that map onto these policies.
"""

from __future__ import annotations

import dataclasses
import difflib
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.recruitment import (
    BALANCED,
    ClientStats,
    RecruitmentConfig,
    RecruitmentResult,
    preset_recruitment,
    recruit,
)
from repro.data.pipeline import ClientDataset, cohort_steps_per_epoch
from repro.federated.client import LocalTrainer
from repro.federated.cohort import STAGING_MODES, CohortTrainer, chain_split_keys
from repro.federated.fedavg import (
    aggregate_stacked,
    params_nbytes,
    trimmed_mean_stacked,
)
from repro.federated.selection import round_robin_clients, select_clients
from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import CompileWatcher
from repro.obs.trace import Tracer, resolve_tracer
from repro.optim.adamw import AdamW
from repro.privacy.accountant import RdpAccountant
from repro.privacy.dp import DPConfig, resolve_dp

PyTree = Any

ENGINES = ("sequential", "vectorized")
AGGREGATION_MODES = ("reduced", "grouped", "stacked")


# ---------------------------------------------------------------------------
# policy protocols
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecruitmentDecision:
    """What a recruitment policy returns: the federation, plus optional detail."""

    federation_ids: np.ndarray            # sorted client ids admitted to the federation
    detail: RecruitmentResult | None = None  # nu/iota accounting when the policy has it


class RecruitmentPolicy:
    """Decides, once, which candidate clients form the federation.

    Policies see only the disclosure tuples ``(P_co, n_c)`` — never raw
    features or model parameters — so recruitment stays model-agnostic.
    ``rng`` is a dedicated generator (independent of the per-round stream)
    for stochastic policies; deterministic policies ignore it.
    """

    def recruit(
        self, stats: Sequence[ClientStats], rng: np.random.Generator
    ) -> RecruitmentDecision:
        raise NotImplementedError


class SelectionPolicy:
    """Decides which federation members train in one round.

    ``rng`` is the run's shared numpy generator — the same stream the batch
    scheduler consumes, so engines stay in lockstep.  Implementations must
    return participant ids in sorted order (the cohort stacking order).
    ``observe`` is called after every round with the participants and their
    mean local losses, for adaptive policies; the default ignores it.
    """

    def select(
        self, round_index: int, federation_ids: np.ndarray, rng: np.random.Generator
    ) -> np.ndarray:
        raise NotImplementedError

    def observe(self, participant_ids: np.ndarray, losses: np.ndarray) -> None:
        pass

    def state_dict(self) -> dict:
        """JSON-serializable adaptive state for checkpoint/resume.

        Stateless policies (the default) return ``{}``; adaptive ones
        (e.g. loss-weighted) must round-trip everything ``observe``
        accumulated, or a resumed run diverges from the uninterrupted one.
        """
        return {}

    def load_state_dict(self, state: dict) -> None:
        pass


class Aggregator:
    """Combines one round's client updates into the new global params.

    ``mode`` tells the round program how updates must be delivered:
    ``"reduced"`` — the engine's weighted FedAvg reduction is this
    aggregator's exact result (the streamed hot path); ``"grouped"`` — run
    one engine round per ``groups(...)`` partition, then ``aggregate`` the
    stacked group means; ``"stacked"`` — materialize every client's params
    (per-client trainer) and ``aggregate`` the stacked pytree.
    """

    mode: str = "stacked"

    def aggregate(self, stacked: PyTree, weights: np.ndarray) -> PyTree:
        """Reduce a client-stacked pytree (leading client axis) to params."""
        raise NotImplementedError

    def groups(self, participant_ids: np.ndarray) -> list[np.ndarray]:
        """Partition participants for ``mode == "grouped"`` aggregators."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# string registries
# ---------------------------------------------------------------------------

_RECRUITMENTS: dict[str, Callable[..., RecruitmentPolicy]] = {}
_SELECTIONS: dict[str, Callable[..., SelectionPolicy]] = {}
_AGGREGATORS: dict[str, Callable[..., Aggregator]] = {}


def register_recruitment(name: str):
    """Register a recruitment factory under ``name`` (``@register_recruitment("x")``)."""
    def deco(factory):
        _RECRUITMENTS[name] = factory
        return factory
    return deco


def register_selection(name: str):
    def deco(factory):
        _SELECTIONS[name] = factory
        return factory
    return deco


def register_aggregator(name: str):
    def deco(factory):
        _AGGREGATORS[name] = factory
        return factory
    return deco


def _parse_arg(token: str):
    token = token.strip()
    try:
        return int(token)
    except ValueError:
        pass
    try:
        return float(token)
    except ValueError:
        return token


def _resolve(registry: dict, spec, kind: str, base: type):
    if isinstance(spec, base):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"{kind} must be a {base.__name__} or a spec string, got {type(spec).__name__}")
    name, _, rest = spec.partition(":")
    if name not in registry:
        known = ", ".join(sorted(registry))
        close = difflib.get_close_matches(name, registry, n=1)
        hint = f"; did you mean {close[0]!r}?" if close else ""
        raise ValueError(
            f"unknown {kind} policy {name!r}{hint}; choose from: {known}"
        )
    args = [_parse_arg(t) for t in rest.split(",")] if rest else []
    return registry[name](*args)


def resolve_recruitment(spec) -> RecruitmentPolicy:
    """``"nu-greedy"`` / ``"nu-greedy:0.5,0.5,0.1"`` / instance -> policy."""
    return _resolve(_RECRUITMENTS, spec, "recruitment", RecruitmentPolicy)


def resolve_selection(spec) -> SelectionPolicy:
    """``"uniform"`` / ``"uniform:0.1"`` / ``"round-robin:4"`` / instance -> policy."""
    return _resolve(_SELECTIONS, spec, "selection", SelectionPolicy)


def resolve_aggregator(spec) -> Aggregator:
    """``"fedavg"`` / ``"trimmed-mean:0.1"`` / ``"hierarchical:4"`` / instance -> policy."""
    return _resolve(_AGGREGATORS, spec, "aggregator", Aggregator)


def available_policies() -> dict[str, tuple[str, ...]]:
    """Registered spec names per stage — the discoverable policy surface."""
    return {
        "recruitment": tuple(sorted(_RECRUITMENTS)),
        "selection": tuple(sorted(_SELECTIONS)),
        "aggregator": tuple(sorted(_AGGREGATORS)),
    }


# ---------------------------------------------------------------------------
# recruitment policies
# ---------------------------------------------------------------------------


@register_recruitment("all")
class AllRecruitment(RecruitmentPolicy):
    """Everyone joins — standard FL (the paper's ac/sc baselines)."""

    def recruit(self, stats, rng) -> RecruitmentDecision:
        ids = np.array(sorted(s.client_id for s in stats), dtype=np.int64)
        return RecruitmentDecision(federation_ids=ids)


class NuGreedyRecruitment(RecruitmentPolicy):
    """The paper's greedy threshold rule (section 4.2) over nu_c.

    Spec forms: ``"nu-greedy"`` (BALANCED), ``"nu-greedy:quality-greedy"``
    (a section 6.2 preset), or ``"nu-greedy:gamma_dv,gamma_sa,gamma_th"``.
    """

    def __init__(self, config: RecruitmentConfig = BALANCED) -> None:
        self.config = config

    def recruit(self, stats, rng) -> RecruitmentDecision:
        result = recruit(stats, self.config)
        return RecruitmentDecision(
            federation_ids=np.sort(result.recruited_ids), detail=result
        )


@register_recruitment("nu-greedy")
def _nu_greedy(*args) -> NuGreedyRecruitment:
    if not args:
        return NuGreedyRecruitment(BALANCED)
    if len(args) == 1 and isinstance(args[0], str):
        return NuGreedyRecruitment(preset_recruitment(args[0]))
    if len(args) == 3:
        return NuGreedyRecruitment(RecruitmentConfig(*[float(a) for a in args]))
    raise ValueError(
        "nu-greedy spec takes no args, one preset name, or gamma_dv,gamma_sa,gamma_th"
    )


@register_recruitment("random-k")
class RandomKRecruitment(RecruitmentPolicy):
    """Recruit ``k`` clients uniformly at random — the recruitment control."""

    def __init__(self, k: int) -> None:
        if int(k) < 1:
            raise ValueError(f"random-k needs k >= 1, got {k}")
        self.k = int(k)

    def recruit(self, stats, rng) -> RecruitmentDecision:
        ids = np.array(sorted(s.client_id for s in stats), dtype=np.int64)
        k = min(self.k, len(ids))
        return RecruitmentDecision(np.sort(rng.choice(ids, size=k, replace=False)))


@register_recruitment("top-n-samples")
class TopNSamplesRecruitment(RecruitmentPolicy):
    """Recruit the ``n`` clients with the most local samples (ties: lower id)."""

    def __init__(self, n: int) -> None:
        if int(n) < 1:
            raise ValueError(f"top-n-samples needs n >= 1, got {n}")
        self.n = int(n)

    def recruit(self, stats, rng) -> RecruitmentDecision:
        ids = np.array([s.client_id for s in stats], dtype=np.int64)
        sizes = np.array([s.n for s in stats], dtype=np.int64)
        order = np.lexsort((ids, -sizes))
        return RecruitmentDecision(np.sort(ids[order[: min(self.n, len(ids))]]))


# ---------------------------------------------------------------------------
# selection policies
# ---------------------------------------------------------------------------


def _frac_or_count(arg) -> dict[str, Any]:
    """Spec arg -> kwargs: a float is a participation fraction, an int a count.

    The distinction is textual: ``"uniform:0.1"`` samples 10%,
    ``"uniform:12"`` samples 12 clients — so full participation by fraction
    must be spelled ``"uniform:1.0"`` (``"uniform:1"`` is a count of one).
    """
    if arg is None:
        return {}
    if isinstance(arg, float):
        return {"fraction": arg}
    if isinstance(arg, int):
        return {"count": arg}
    raise ValueError(f"selection arg must be a fraction or a count, got {arg!r}")


def _check_frac_count(fraction: float | None, count: int | None) -> None:
    """Fail at policy construction, not mid-run, on a bad participation spec."""
    if fraction is not None and count is not None:
        raise ValueError("give fraction or count, not both")
    if fraction is not None and not (0.0 < fraction <= 1.0):
        raise ValueError(f"fraction must be in (0, 1], got {fraction}")
    if count is not None and int(count) < 1:
        raise ValueError(f"count must be >= 1, got {count}")


class UniformSelection(SelectionPolicy):
    """The paper's per-round sampling: uniform without replacement.

    ``fraction``/``count`` both ``None`` means every federation member
    participates every round (the ac/arc settings).
    """

    def __init__(self, fraction: float | None = None, count: int | None = None) -> None:
        _check_frac_count(fraction, count)
        self.fraction, self.count = fraction, count

    def select(self, round_index, federation_ids, rng) -> np.ndarray:
        return select_clients(rng, federation_ids, fraction=self.fraction, count=self.count)


@register_selection("uniform")
def _uniform(arg=None) -> UniformSelection:
    return UniformSelection(**_frac_or_count(arg))


class RoundRobinSelection(SelectionPolicy):
    """Deterministic rotation through the sorted federation — no RNG at all.

    Every client participates at least once per ``ceil(N / k)`` consecutive
    rounds (exactly once when ``k`` divides ``N``; otherwise the wrapping
    window re-visits a few early ids each cycle), and per-round cohorts are
    reproducible independent of the seed.
    """

    def __init__(self, fraction: float | None = None, count: int | None = None) -> None:
        _check_frac_count(fraction, count)
        self.fraction, self.count = fraction, count

    def select(self, round_index, federation_ids, rng) -> np.ndarray:
        n = len(federation_ids)
        if self.fraction is None and self.count is None:
            count = n
        elif self.count is not None:
            count = min(int(self.count), n)
        else:
            count = max(1, int(round(self.fraction * n)))
        return round_robin_clients(round_index, federation_ids, count)


@register_selection("round-robin")
def _round_robin(arg=None) -> RoundRobinSelection:
    return RoundRobinSelection(**_frac_or_count(arg))


class LossWeightedSelection(SelectionPolicy):
    """Sample proportionally to each client's last observed local loss.

    Clients not yet observed weigh in at the mean observed loss (or
    uniformly before any observation), so round one degenerates to uniform
    sampling and coverage self-corrects as losses arrive.
    """

    def __init__(self, fraction: float | None = None, count: int | None = None) -> None:
        _check_frac_count(fraction, count)
        self.fraction, self.count = fraction, count
        self._loss: dict[int, float] = {}

    def observe(self, participant_ids, losses) -> None:
        for cid, loss in zip(np.asarray(participant_ids), np.asarray(losses)):
            if np.isfinite(loss):
                self._loss[int(cid)] = float(loss)

    def state_dict(self) -> dict:
        return {"loss": {str(cid): loss for cid, loss in self._loss.items()}}

    def load_state_dict(self, state: dict) -> None:
        self._loss = {int(cid): float(v) for cid, v in state.get("loss", {}).items()}

    def select(self, round_index, federation_ids, rng) -> np.ndarray:
        ids = np.asarray(federation_ids)
        n = len(ids)
        if self.fraction is None and self.count is None:
            count = n
        elif self.count is not None:
            count = min(int(self.count), n)
        else:
            count = max(1, int(round(self.fraction * n)))
        default = float(np.mean(list(self._loss.values()))) if self._loss else 1.0
        w = np.array([self._loss.get(int(c), default) for c in ids], dtype=np.float64)
        w = np.maximum(w, 1e-12)
        chosen = rng.choice(ids, size=count, replace=False, p=w / w.sum())
        return np.sort(chosen)


@register_selection("loss-weighted")
def _loss_weighted(arg=None) -> LossWeightedSelection:
    return LossWeightedSelection(**_frac_or_count(arg))


# ---------------------------------------------------------------------------
# aggregators
# ---------------------------------------------------------------------------


@register_aggregator("fedavg")
class FedAvgAggregator(Aggregator):
    """Sample-size-weighted parameter averaging (McMahan et al. 2017).

    ``mode = "reduced"``: the engines implement this exact reduction on
    their hot path (streamed chunk accumulator + psum), so no per-client
    params ever materialize.
    """

    mode = "reduced"

    def aggregate(self, stacked, weights):
        return aggregate_stacked(stacked, weights)


@register_aggregator("trimmed-mean")
class TrimmedMeanAggregator(Aggregator):
    """Coordinate-wise trimmed mean (Yin et al. 2018) — outlier-robust.

    Drops the ``floor(trim * C)`` smallest and largest values of every
    coordinate across the client axis, then averages the rest (unweighted,
    as in the robust-aggregation literature).  ``trim = 0`` is the plain
    coordinate mean.
    """

    mode = "stacked"

    def __init__(self, trim: float = 0.1) -> None:
        if not (0.0 <= trim < 0.5):
            hint = (
                f" — did you mean trim={min(trim / 2, 0.45):g} "
                "(the fraction trimmed from *each* tail)?"
                if 0.5 <= trim < 1.0
                else (
                    f" — to trim {trim:g} clients per tail out of C, pass "
                    f"the fraction {trim:g}/C"
                    if trim >= 1.0
                    else ""
                )
            )
            raise ValueError(
                f"trim fraction must be in [0, 0.5), got {trim}: trimming "
                f"half or more from both tails leaves no clients{hint}"
            )
        self.trim = float(trim)

    def aggregate(self, stacked, weights):
        return trimmed_mean_stacked(stacked, self.trim)


@register_aggregator("hierarchical")
class HierarchicalFedAvg(Aggregator):
    """Two-level FedAvg: regional sub-federations reduce first.

    Participants are split into ``num_regions`` contiguous groups; each
    group runs one engine round (its weighted sum is a single psum under a
    mesh), then the group means are FedAvg-ed with the groups' total sample
    weights.  Numerically this telescopes to flat FedAvg — the parity test
    — while structurally it is the ROADMAP's multi-pod aggregation tier:
    on a ``("pod", "data")`` mesh each region maps to a pod whose psum
    stays on local ICI before the small cross-pod combine.
    """

    mode = "grouped"

    def __init__(self, num_regions: int = 2) -> None:
        if int(num_regions) < 1:
            raise ValueError(f"hierarchical needs >= 1 region, got {num_regions}")
        self.num_regions = int(num_regions)

    def groups(self, participant_ids) -> list[np.ndarray]:
        ids = np.asarray(participant_ids)
        parts = np.array_split(ids, min(self.num_regions, len(ids)))
        return [p for p in parts if len(p)]

    def aggregate(self, stacked, weights):
        return aggregate_stacked(stacked, weights)


# ---------------------------------------------------------------------------
# run records
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoundRecord:
    round_index: int
    participant_ids: list[int]       # sorted — the cohort stacking order
    mean_local_loss: float
    local_steps: int
    params_down: int                 # parameter tensors broadcast server -> clients
    params_up: int                   # parameter tensors returned clients -> server
    bytes_transferred: int           # down + up, from the param pytree's real sizes
    wall_time_s: float
    # Async-runtime extras (None on synchronous rounds): the virtual-clock
    # time the flush happened at, and the mean staleness (in parameter
    # versions) of the updates folded into it.
    virtual_time: float | None = None
    staleness: float | None = None
    # DP runs only: the cumulative (epsilon, delta)-DP budget *through* this
    # round, from the run's Rényi accountant at the configured delta —
    # monotonically non-decreasing over a run.  None without a privacy
    # config.
    epsilon: float | None = None

    @property
    def round_time_s(self) -> float:
        """Host wall-clock this round took — the timing field's public name
        (``wall_time_s`` kept for compatibility with existing reports)."""
        return self.wall_time_s

    def to_state(self) -> dict:
        """JSON-serializable form — one JSONL line of the record stream.

        Serializes the canonical ``round_time_s`` name; ``from_state``
        still accepts the legacy ``wall_time_s`` key so run directories
        written before the rename keep resuming.
        """
        state = dataclasses.asdict(self)
        state["round_time_s"] = state.pop("wall_time_s")
        return state

    @classmethod
    def from_state(cls, state: dict) -> "RoundRecord":
        state = dict(state)
        if "round_time_s" in state:
            state["wall_time_s"] = state.pop("round_time_s")
        return cls(**state)


@dataclasses.dataclass
class FederatedRunResult:
    params: PyTree
    history: list[RoundRecord]
    recruitment: RecruitmentResult | None
    federation_ids: np.ndarray
    total_wall_time_s: float
    total_local_steps: int
    # Final observability snapshot (repro.obs.MetricsRegistry.snapshot()):
    # staging/pool counters, comms bytes, compile events, DP epsilon — the
    # run's whole metrics series folded to its last value.
    metrics: dict[str, Any] | None = None

    def summary(self) -> dict[str, Any]:
        # Async-runtime totals: the simulated clock at the last flush and
        # the mean update staleness — None on synchronous runs, where no
        # record carries a virtual time.
        async_records = [r for r in self.history if r.virtual_time is not None]
        return {
            "rounds": len(self.history),
            "federation_size": int(self.federation_ids.size),
            "recruited": None if self.recruitment is None else self.recruitment.num_recruited,
            "total_wall_time_s": self.total_wall_time_s,
            "total_round_time_s": sum(r.round_time_s for r in self.history),
            "total_local_steps": self.total_local_steps,
            "params_down": sum(r.params_down for r in self.history),
            "params_up": sum(r.params_up for r in self.history),
            "bytes_transferred": sum(r.bytes_transferred for r in self.history),
            "virtual_time": max(r.virtual_time for r in async_records)
            if async_records
            else None,
            # Weight each flush by its participant count so the figure
            # reads as mean staleness per *update*, not per flush — a
            # one-update forced flush must not count like a full buffer.
            "mean_staleness": float(
                np.average(
                    [r.staleness for r in async_records],
                    weights=[max(len(r.participant_ids), 1) for r in async_records],
                )
            )
            if async_records
            else None,
            # DP runs: the final cumulative privacy budget (the last
            # record's epsilon — the accountant only ever grows it).
            "epsilon": next(
                (
                    r.epsilon
                    for r in reversed(self.history)
                    if r.epsilon is not None
                ),
                None,
            ),
            # The final metrics snapshot — staged bytes, prefetch hits,
            # pool uploads/evictions, comms accounting — so summaries no
            # longer drop the staging/observability counters.
            "metrics": self.metrics,
        }


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FederationSnapshot:
    """Everything ``Federation.run`` needs to continue from a round boundary.

    Captured by the ``snapshot_hook`` after each round's record lands and
    fed back through ``Federation.run(..., resume=snapshot)``: the resumed
    run restores the parameter pytree exactly (npz round-trips are
    bit-exact), both PRNG streams (the numpy batch-plan generator's
    bit-generator state and the jax key chain's raw key data), the record
    history, and any adaptive selection-policy state — so it consumes the
    identical batches and keys the uninterrupted run would have, and the
    final params match to float tolerance.  Recruitment is *not*
    snapshotted: it derives deterministically from the seed and is re-run
    on resume.
    """

    round_index: int              # the next round to run
    params: PyTree
    np_rng_state: dict            # batch-plan generator bit_generator.state
    jax_key_data: np.ndarray      # raw key data of the per-chunk key chain
    history: list[RoundRecord]
    selection_state: dict

    def save(self, directory: str, extra_state: dict | None = None) -> None:
        """Persist atomically via ``repro.checkpoint.store`` (overwrites)."""
        from repro.checkpoint.store import save_federation_snapshot

        state = {
            "kind": "sync",
            "round_index": int(self.round_index),
            "np_rng_state": self.np_rng_state,
            "history": [r.to_state() for r in self.history],
            "selection_state": self.selection_state,
        }
        state.update(extra_state or {})
        save_federation_snapshot(
            directory,
            trees={"params": self.params},
            arrays={"jax_key_data": np.asarray(self.jax_key_data)},
            state=state,
        )

    @classmethod
    def load(cls, directory: str, like_params: PyTree) -> "FederationSnapshot":
        from repro.checkpoint.store import load_federation_snapshot

        trees, arrays, state = load_federation_snapshot(directory, like_params)
        if state.get("kind") != "sync":
            raise ValueError(
                f"snapshot in {directory} is {state.get('kind')!r}, not a "
                "synchronous federation snapshot"
            )
        return cls(
            round_index=int(state["round_index"]),
            params=trees["params"],
            np_rng_state=state["np_rng_state"],
            jax_key_data=arrays["jax_key_data"],
            history=[RoundRecord.from_state(r) for r in state["history"]],
            selection_state=state.get("selection_state", {}),
        )


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FederationConfig:
    """Declarative federation: every stage is a policy spec or instance."""

    rounds: int = 15
    local_epochs: int = 4
    batch_size: int = 128
    # Pipeline stages — spec strings ("nu-greedy", "uniform:0.1",
    # "hierarchical:4") or policy instances.
    recruitment: str | RecruitmentPolicy = "all"
    selection: str | SelectionPolicy = "uniform"
    aggregator: str | Aggregator = "fedavg"
    seed: int = 0
    # Engine / staging knobs, unchanged from the PR 3 runtime.
    engine: str = "vectorized"
    cohort_chunk: int | None = None
    mesh: Any = None
    donate_buffers: bool = True
    staging: str = "resident"
    prefetch: bool = True
    # Population scale: bound the device-resident cohort to this many bytes
    # (LRU pool of client rows, uploads only the round's sampled clients —
    # see repro.data.device_cohort).  None = bake the whole federation.
    resident_budget_bytes: int | None = None
    # In-jit DP-SGD (repro.privacy): a DPConfig, a job-spec dict
    # ({"clip_norm": ..., "noise_multiplier": ..., "delta": ...}), or None.
    # When set, every local step clips per-example gradients and adds
    # calibrated Gaussian noise inside the jitted step, and each
    # RoundRecord carries the accountant's cumulative epsilon.
    privacy: DPConfig | dict | None = None

    def __post_init__(self) -> None:
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; choose from {ENGINES}")
        if self.staging not in STAGING_MODES:
            raise ValueError(
                f"unknown staging {self.staging!r}; choose from {STAGING_MODES}"
            )


class Federation:
    """Runs the round program over in-process clients with pluggable policies.

    ``Federation(config, clients, loss_fn, optimizer)`` resolves the three
    policy stages up front (unknown spec strings fail here, not mid-run) and
    exposes the same engine surface the legacy server did
    (``cohort_trainer``, ``trainer``, ``build_federation``).
    """

    def __init__(
        self,
        config: FederationConfig,
        clients: Sequence[ClientDataset],
        loss_fn: Callable[..., Any],
        optimizer: AdamW,
        tracer: Tracer | None = None,
        metrics: MetricsRegistry | None = None,
        profiler: Any = None,
    ) -> None:
        self.config = config
        # Observability: the null tracer keeps the uninstrumented hot path
        # at a handful of no-op calls per round; the registry always exists
        # so run summaries carry the staging/comms counters either way.
        self.tracer = resolve_tracer(tracer)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.profiler = profiler
        self.recruitment_policy = resolve_recruitment(config.recruitment)
        self.selection_policy = resolve_selection(config.selection)
        self.aggregator = resolve_aggregator(config.aggregator)
        if self.aggregator.mode == "buffered":
            raise ValueError(
                f"aggregator {config.aggregator!r} is asynchronous "
                "(mode='buffered'); run it with "
                "repro.federated.runtime.AsyncFederation instead of the "
                "synchronous Federation"
            )
        if self.aggregator.mode not in AGGREGATION_MODES:
            raise ValueError(
                f"aggregator mode {self.aggregator.mode!r} not in {AGGREGATION_MODES}"
            )
        self.all_clients = {c.client_id: c for c in clients}
        self.dp = resolve_dp(config.privacy)
        self.trainer = LocalTrainer(
            loss_fn=loss_fn,
            optimizer=optimizer,
            batch_size=config.batch_size,
            local_epochs=config.local_epochs,
            dp=self.dp,
        )
        self.cohort_trainer = CohortTrainer(
            loss_fn=loss_fn,
            optimizer=optimizer,
            batch_size=config.batch_size,
            local_epochs=config.local_epochs,
            cohort_chunk=config.cohort_chunk,
            mesh=config.mesh,
            donate=config.donate_buffers,
            staging=config.staging,
            prefetch=config.prefetch,
            resident_budget_bytes=config.resident_budget_bytes,
            dp=self.dp,
            tracer=self.tracer,
        )

    @property
    def effective_engine(self) -> str:
        """The engine rounds actually run on.

        Stacked-mode aggregators need every client's params, which only the
        per-client trainer materializes — they run sequentially whatever
        ``config.engine`` says, and reports should say so.
        """
        return "sequential" if self.aggregator.mode == "stacked" else self.config.engine

    # -- stage 1: build_federation ------------------------------------------

    def build_federation(
        self, rng: np.random.Generator | None = None
    ) -> tuple[np.ndarray, RecruitmentResult | None]:
        """Recruitment happens here — before the federation exists.

        Stochastic recruitment draws from its own generator (derived from
        the seed, independent of the per-round stream), so the round-level
        sampling is identical across recruitment policies at a fixed seed.
        """
        if rng is None:
            rng = np.random.default_rng([self.config.seed, 1])
        all_ids = sorted(self.all_clients)
        stats = [self.all_clients[i].stats() for i in all_ids]
        decision = self.recruitment_policy.recruit(stats, rng)
        ids = np.sort(np.asarray(decision.federation_ids, dtype=np.int64))
        unknown = set(ids.tolist()) - set(all_ids)
        if unknown:
            raise ValueError(f"recruitment returned unknown client ids: {sorted(unknown)}")
        if ids.size == 0:
            raise ValueError("recruitment returned an empty federation")
        return ids, decision.detail

    # -- stages 3+4: train + aggregate --------------------------------------

    def _train_group(
        self, params: PyTree, group: np.ndarray, rng, jax_rng, spe: int
    ) -> tuple[PyTree, np.ndarray, int, jax.Array]:
        """One engine round over ``group``: FedAvg-reduced params.

        This is the pre-API hot path, untouched: the vectorized engine
        consumes one ``chain_split_keys`` chunk and streams the weighted
        sum inside its jitted round; the sequential engine splits one key
        per client and stacks once.
        """
        cohort = [self.all_clients[int(cid)] for cid in group]
        if self.config.engine == "vectorized":
            jax_rng, key_data = chain_split_keys(jax_rng, len(cohort))
            params, per_losses, steps = self.cohort_trainer.train_cohort(
                params, cohort, rng, key_data, steps_per_epoch=spe
            )
            return params, per_losses, steps, jax_rng
        client_params, weights, losses, steps = [], [], [], 0
        for client in cohort:
            jax_rng, sub = jax.random.split(jax_rng)
            new_params, loss, n_c = self.trainer.train_client(params, client, rng, sub)
            client_params.append(new_params)
            weights.append(n_c)
            losses.append(loss)
            steps += self.trainer.steps_per_round(client)
        stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves), *client_params)
        params = aggregate_stacked(stacked, np.asarray(weights, dtype=np.float32))
        return params, np.asarray(losses, dtype=np.float32), steps, jax_rng

    def _train_round(
        self, params: PyTree, participants: np.ndarray, rng, jax_rng, spe: int
    ) -> tuple[PyTree, np.ndarray, int, jax.Array]:
        """train -> aggregate for one round, dispatched on the aggregator mode."""
        mode = self.aggregator.mode
        if mode == "reduced":
            return self._train_group(params, participants, rng, jax_rng, spe)

        if mode == "grouped":
            groups = self.aggregator.groups(participants)
            flat = np.concatenate([np.asarray(g) for g in groups]) if groups else np.array([])
            if sorted(flat.tolist()) != sorted(np.asarray(participants).tolist()):
                raise ValueError("aggregator groups must partition the participants")
            group_params, group_w, losses, steps = [], [], [], 0
            for group in groups:
                p_g, losses_g, steps_g, jax_rng = self._train_group(
                    params, group, rng, jax_rng, spe
                )
                group_params.append(p_g)
                group_w.append(sum(self.all_clients[int(c)].n_train for c in group))
                losses.append(losses_g)
                steps += steps_g
            with self.tracer.span("aggregate", groups=len(groups)):
                stacked = jax.tree.map(
                    lambda *leaves: jnp.stack(leaves), *group_params
                )
                new_params = self.aggregator.aggregate(
                    stacked, np.asarray(group_w, dtype=np.float32)
                )
            return new_params, np.concatenate(losses), steps, jax_rng

        # mode == "stacked": the aggregator needs every client's params, which
        # the vectorized engine's in-jit reduction never materializes — these
        # rounds run the per-client trainer whatever the engine setting.
        client_params, weights, losses, steps = [], [], [], 0
        for cid in participants:
            client = self.all_clients[int(cid)]
            jax_rng, sub = jax.random.split(jax_rng)
            new_params, loss, n_c = self.trainer.train_client(params, client, rng, sub)
            client_params.append(new_params)
            weights.append(n_c)
            losses.append(loss)
            steps += self.trainer.steps_per_round(client)
        with self.tracer.span("aggregate", clients=len(participants)):
            stacked = jax.tree.map(
                lambda *leaves: jnp.stack(leaves), *client_params
            )
            new_params = self.aggregator.aggregate(
                stacked, np.asarray(weights, dtype=np.float32)
            )
        return new_params, np.asarray(losses, dtype=np.float32), steps, jax_rng

    # -- observability --------------------------------------------------------

    def _absorb_round_metrics(self, record: RoundRecord) -> None:
        """Fold a finished round into the metrics registry.

        Absorbs the comms accounting and per-round loss from the record
        plus the cohort engine's ad-hoc ``last_round_stats`` dict (staged
        bytes, prefetch hits, pool uploads/evictions) into the typed
        counters/gauges/histograms the control plane streams as
        ``metrics.jsonl``.
        """
        m = self.metrics
        m.counter("rounds.completed").inc()
        m.counter("comms.params_down").inc(record.params_down)
        m.counter("comms.params_up").inc(record.params_up)
        m.counter("comms.bytes_down").inc(record.bytes_transferred // 2)
        m.counter("comms.bytes_up").inc(
            record.bytes_transferred - record.bytes_transferred // 2
        )
        m.counter("train.local_steps").inc(record.local_steps)
        m.histogram("round.time_s").observe(record.wall_time_s)
        if np.isfinite(record.mean_local_loss):
            m.histogram("round.loss").observe(record.mean_local_loss)
        if record.epsilon is not None:
            m.gauge("privacy.epsilon").set(record.epsilon)
        if record.staleness is not None:
            m.histogram("async.staleness").observe(record.staleness)
        if record.virtual_time is not None:
            m.gauge("async.virtual_time").set(record.virtual_time)
        stats = self.cohort_trainer.last_round_stats
        if stats:
            m.counter("staging.bytes_staged").inc(stats.get("bytes_staged", 0))
            m.counter("staging.plans_prefetched").inc(
                stats.get("plans_prefetched", 0)
            )
            m.counter("staging.chunks").inc(stats.get("chunks", 0))
            m.gauge("staging.bytes_resident").set(stats.get("bytes_resident", 0))
            m.gauge("staging.peak_live_bytes").set(stats.get("peak_live_bytes", 0))
            if stats.get("pool"):
                m.counter("pool.uploads").inc(stats.get("pool_uploads", 0))
                m.counter("pool.evictions").inc(stats.get("pool_evictions", 0))
                m.counter("pool.hits").inc(stats.get("pool_hits", 0))
                m.counter("pool.bytes_uploaded").inc(
                    stats.get("pool_bytes_uploaded", 0)
                )

    # -- the round program ---------------------------------------------------

    def run(
        self,
        init_params: PyTree,
        progress: Callable[[RoundRecord], None] | None = None,
        snapshot_hook: Callable[[FederationSnapshot], None] | None = None,
        resume: FederationSnapshot | None = None,
    ) -> FederatedRunResult:
        """Run the round program (optionally resuming a snapshotted run).

        ``progress`` receives each :class:`RoundRecord` as it lands — the
        record stream the control plane fans out to subscribers.
        ``snapshot_hook`` receives a :class:`FederationSnapshot` after
        every round; the hook decides whether/where to persist it (it may
        also raise to preempt the run — nothing after the snapshot is
        lost).  ``resume`` continues a run from such a snapshot: the
        restored streams make the continuation consume the same batches
        and keys the uninterrupted run would have, so the final params
        agree to float tolerance.  ``total_wall_time_s`` counts only the
        resumed segment; ``history`` and ``total_local_steps`` span the
        whole run.
        """
        cfg = self.config
        rng = np.random.default_rng(cfg.seed)
        jax_rng = jax.random.key(cfg.seed)

        federation_ids, recruitment = self.build_federation()
        uses_cohort_engine = (
            cfg.engine == "vectorized" and self.aggregator.mode != "stacked"
        )
        if uses_cohort_engine and cfg.staging == "resident":
            # One host->device upload for the whole federation (only the
            # recruited clients — unrecruited ones never ship anything);
            # every round after this stages just an int32 index plan.
            # Stacked-mode aggregators never touch the cohort engine (their
            # rounds run the per-client trainer), so don't park the
            # federation's arrays on device for them.
            self.cohort_trainer.attach_device_cohort(
                [self.all_clients[int(i)] for i in federation_ids]
            )
        # One Rényi accountant per run: stepped once per round at that
        # round's client sampling rate, read for every RoundRecord.
        accountant = (
            RdpAccountant(self.dp.noise_multiplier, delta=self.dp.delta)
            if self.dp is not None
            else None
        )
        params = init_params
        history: list[RoundRecord] = []
        start_round = 0
        if resume is not None:
            if not (0 <= int(resume.round_index) <= cfg.rounds):
                raise ValueError(
                    f"snapshot round_index {resume.round_index} outside the "
                    f"configured {cfg.rounds}-round budget"
                )
            params = resume.params
            start_round = int(resume.round_index)
            rng.bit_generator.state = resume.np_rng_state
            jax_rng = jax.random.wrap_key_data(jnp.asarray(resume.jax_key_data))
            history = list(resume.history)
            self.selection_policy.load_state_dict(resume.selection_state)
            if accountant is not None:
                # Privacy loss composes over the whole run: replay the
                # completed rounds' sampling rates so the resumed segment's
                # epsilons continue the original accounting.
                for past in history:
                    accountant.step(
                        len(past.participant_ids) / federation_ids.size
                    )
        # Pin the vectorized schedule's step axis to the federation-wide max
        # so every round shares one compiled shape whatever mix is sampled.
        federation_spe = cohort_steps_per_epoch(
            [self.all_clients[int(i)].n_train for i in federation_ids], cfg.batch_size
        )
        # Communication accounting: each participant receives the full param
        # pytree and returns one of the same shape.
        n_tensors = len(jax.tree.leaves(init_params))
        model_nbytes = params_nbytes(init_params)
        tracer = self.tracer
        t_start = time.perf_counter()

        with CompileWatcher(self.metrics) as watcher:
            for rnd in range(start_round, cfg.rounds):
                if self.profiler is not None:
                    self.profiler.round_start(rnd)
                t_round = time.perf_counter()
                with tracer.span("select", round=rnd):
                    participants = np.asarray(
                        self.selection_policy.select(rnd, federation_ids, rng)
                    )
                if not (
                    len(participants) > 0
                    and np.all(np.diff(participants) > 0)
                    and set(participants.tolist()) <= set(federation_ids.tolist())
                ):
                    raise ValueError(
                        "selection must return a non-empty, strictly sorted subset of the federation"
                    )
                with tracer.span(
                    "train", round=rnd, participants=len(participants)
                ):
                    params, losses, steps, jax_rng = self._train_round(
                        params, participants, rng, jax_rng, federation_spe
                    )
                self.selection_policy.observe(participants, losses)
                epsilon = None
                if accountant is not None:
                    accountant.step(len(participants) / federation_ids.size)
                    epsilon = accountant.epsilon()
                wall = time.perf_counter() - t_round
                record = RoundRecord(
                    round_index=rnd,
                    participant_ids=[int(c) for c in participants],
                    mean_local_loss=float(np.nanmean(losses)) if len(losses) else float("nan"),
                    local_steps=steps,
                    params_down=len(participants) * n_tensors,
                    params_up=len(participants) * n_tensors,
                    bytes_transferred=2 * len(participants) * model_nbytes,
                    wall_time_s=wall,
                    epsilon=epsilon,
                )
                # The round span reuses the record's own start/duration so
                # the trace reconciles exactly with round_time_s.
                tracer.complete(
                    "round",
                    start=tracer.host_ts(t_round),
                    dur=wall,
                    round=rnd,
                    participants=len(participants),
                )
                history.append(record)
                watcher.poll()
                self._absorb_round_metrics(record)
                if progress is not None:
                    progress(record)
                if snapshot_hook is not None:
                    with tracer.span("checkpoint", round=rnd):
                        snapshot_hook(
                            FederationSnapshot(
                                round_index=rnd + 1,
                                params=params,
                                np_rng_state=rng.bit_generator.state,
                                jax_key_data=np.asarray(jax.random.key_data(jax_rng)),
                                history=list(history),
                                selection_state=self.selection_policy.state_dict(),
                            )
                        )
                if self.profiler is not None:
                    self.profiler.round_end(rnd)

        return FederatedRunResult(
            params=params,
            history=history,
            recruitment=recruitment,
            federation_ids=federation_ids,
            total_wall_time_s=time.perf_counter() - t_start,
            total_local_steps=sum(r.local_steps for r in history),
            metrics=self.metrics.snapshot(),
        )


# Registry side effects: importing the privacy tier's aggregator modules here
# makes "secagg-fedavg" and "krum" resolvable wherever the registry is.  The
# import sits at the bottom because those modules import back the registry
# helpers defined above — a deliberate, documented cycle-breaker.
from repro.privacy import adversary as _adversary  # noqa: E402,F401
from repro.privacy import secagg as _secagg  # noqa: E402,F401
