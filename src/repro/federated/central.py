"""Centralized training baseline (paper section 4.3).

Trains the same architecture on the pooled global train split — the upper
bound that federated training tries to approach without centralizing data.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.data.pipeline import ArrayDataset
from repro.optim.adamw import AdamW, apply_updates

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CentralConfig:
    epochs: int = 15
    batch_size: int = 128
    seed: int = 0


@dataclasses.dataclass
class CentralRunResult:
    params: PyTree
    epoch_losses: list[float]
    total_wall_time_s: float
    total_steps: int


def train_central(
    config: CentralConfig,
    dataset: ArrayDataset,
    init_params: PyTree,
    loss_fn: Callable[..., Any],
    optimizer: AdamW,
    progress: Callable[[int, float], None] | None = None,
) -> CentralRunResult:
    rng = np.random.default_rng(config.seed)
    jax_rng = jax.random.key(config.seed)

    @jax.jit
    def step(params, opt_state, batch, sub):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, sub)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    params = init_params
    opt_state = optimizer.init(params)
    epoch_losses: list[float] = []
    steps = 0
    t0 = time.perf_counter()
    for epoch in range(config.epochs):
        losses = []
        for x, y, mask in dataset.padded_batches(config.batch_size, rng):
            jax_rng, sub = jax.random.split(jax_rng)
            params, opt_state, loss = step(params, opt_state, (x, y, mask), sub)
            losses.append(loss)
            steps += 1
        mean = float(np.mean([float(l) for l in losses]))
        epoch_losses.append(mean)
        if progress is not None:
            progress(epoch, mean)
    return CentralRunResult(
        params=params,
        epoch_losses=epoch_losses,
        total_wall_time_s=time.perf_counter() - t0,
        total_steps=steps,
    )
