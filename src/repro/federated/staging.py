"""Double-buffered staging: build/upload chunk k+1 while chunk k trains.

``CohortTrainer`` consumes a federated round chunk by chunk.  Each chunk
needs host work (drawing the shuffle permutations into an index plan) and a
host->device transfer before its jitted step can run.  Done inline, that
work serializes with the round computation; done here, a single producer
thread stays exactly one chunk ahead of the consumer through a depth-1
queue — classic double buffering (the donated round path frees the memory
that makes the second buffer affordable).

One producer thread, processing chunks strictly in order, is load-bearing:
plan building consumes the shared numpy RNG stream, and the sequential /
rebuild / resident parity contract requires that stream to be drawn in
exactly the inline order.  ``StagingPipeline`` never reorders work — it
only overlaps it with the device.
"""

from __future__ import annotations

import logging
import queue
import threading
from typing import Any, Callable, Iterator, Sequence

from repro.obs.trace import resolve_tracer

_LOG = logging.getLogger(__name__)


class StagingPipeline:
    """Runs ``stage_fn`` over ``items`` one chunk ahead of iteration.

    ``stage_fn(item)`` is called on a background thread, strictly in item
    order, and results are handed out in the same order by ``__iter__``.
    ``depth`` bounds the staged-but-unconsumed run-ahead (depth 1 = while
    the consumer works on chunk k, exactly chunk k+1 is being staged —
    double buffering).  Exceptions raised by ``stage_fn`` surface on the
    consuming thread at the position the failed item would have occupied.

    ``prefetched`` counts chunks that were already staged when the consumer
    asked for them — the round's overlap win, reported in
    ``last_round_stats["plans_prefetched"]``.

    ``tracer`` (a ``repro.obs`` tracer; None = no-op) records a
    ``prefetch_wait`` span on the consumer whenever it blocks on a chunk
    that is not staged yet — the pipeline's stall time, visible next to
    the producer's ``stage`` spans in an exported trace.
    """

    def __init__(
        self,
        stage_fn: Callable[[Any], Any],
        items: Sequence[Any],
        *,
        depth: int = 1,
        join_timeout: float = 5.0,
        tracer: Any = None,
    ) -> None:
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._stage_fn = stage_fn
        self._tracer = resolve_tracer(tracer)
        self._items = list(items)
        self._join_timeout = join_timeout
        self._pending_exc: BaseException | None = None
        self.leaked = False
        self._queue: queue.Queue = queue.Queue()
        # The run-ahead bound.  The producer takes a slot BEFORE staging an
        # item and the consumer returns it when the item is handed out, so
        # at most ``depth`` staged-but-unconsumed chunks exist at any time
        # (depth 1 = while chunk k trains, only chunk k+1 is staged — true
        # double buffering; a bounded queue alone would let the producer
        # run a full chunk further ahead).
        self._slots = threading.Semaphore(depth)
        self._stop = threading.Event()
        self.prefetched = 0
        self._thread = threading.Thread(
            target=self._produce, name="cohort-staging", daemon=True
        )
        self._thread.start()

    def _produce(self) -> None:
        try:
            for item in self._items:
                if not self._acquire_slot():
                    return  # close() abandoned the pipeline mid-round
                staged = self._stage_fn(item)
                self._queue.put((staged, None))
        except BaseException as exc:  # surfaced on the consumer thread
            self._queue.put((None, exc))

    def _acquire_slot(self) -> bool:
        # Bounded wait that gives up if the consumer abandoned the pipeline
        # (close() sets the stop flag), so the worker can never hang.
        while not self._stop.is_set():
            if self._slots.acquire(timeout=0.1):
                return True
        return False

    def __iter__(self) -> Iterator[Any]:
        for _ in range(len(self._items)):
            try:
                staged, exc = self._queue.get_nowait()
                hit = True
            except queue.Empty:
                with self._tracer.span("prefetch_wait", track="staging"):
                    staged, exc = self._queue.get()
                hit = False
            self._slots.release()
            if exc is not None:
                # This exception is being delivered right now — close() must
                # not re-raise it a second time from the drain loop.
                self.close(raise_pending=False)
                raise exc
            if hit:
                self.prefetched += 1
            yield staged
        self.close()

    def close(self, raise_pending: bool = True) -> None:
        """Stop the producer and release the queue; idempotent.

        A ``stage_fn`` exception the consumer never collected (it can land in
        the queue while a round is being torn down) is re-raised here instead
        of being silently dropped by the drain loop; pass
        ``raise_pending=False`` from ``except``/``finally`` paths that are
        already propagating a different error.  A producer thread that fails
        to join within ``join_timeout`` is logged and flagged on
        ``self.leaked`` rather than silently abandoned.
        """
        self._stop.set()
        while True:
            try:
                _, exc = self._queue.get_nowait()
            except queue.Empty:
                break
            if exc is not None and self._pending_exc is None:
                self._pending_exc = exc
        self._thread.join(timeout=self._join_timeout)
        if self._thread.is_alive():
            if not self.leaked:
                _LOG.warning(
                    "staging producer thread failed to join within %.1fs; "
                    "daemon thread leaked (stage_fn stuck?)",
                    self._join_timeout,
                )
            self.leaked = True
        if raise_pending and self._pending_exc is not None:
            exc, self._pending_exc = self._pending_exc, None
            raise exc
