from repro.federated.central import CentralConfig, CentralRunResult, train_central
from repro.federated.client import LocalTrainer
from repro.federated.fedavg import aggregate, apply_delta, delta, params_nbytes, tree_allclose
from repro.federated.selection import select_clients
from repro.federated.server import (
    FederatedConfig,
    FederatedRunResult,
    FederatedServer,
    RoundRecord,
)

__all__ = [
    "CentralConfig",
    "CentralRunResult",
    "train_central",
    "LocalTrainer",
    "aggregate",
    "apply_delta",
    "delta",
    "params_nbytes",
    "tree_allclose",
    "select_clients",
    "FederatedConfig",
    "FederatedRunResult",
    "FederatedServer",
    "RoundRecord",
]
