from repro.federated.central import CentralConfig, CentralRunResult, train_central
from repro.federated.client import LocalTrainer
from repro.federated.cohort import STAGING_MODES, CohortTrainer, chain_split_keys
from repro.federated.staging import StagingPipeline
from repro.federated.fedavg import (
    aggregate,
    aggregate_stacked,
    apply_delta,
    delta,
    params_nbytes,
    tree_allclose,
    weighted_sum_stacked,
)
from repro.federated.selection import select_clients
from repro.federated.server import (
    ENGINES,
    FederatedConfig,
    FederatedRunResult,
    FederatedServer,
    RoundRecord,
)

__all__ = [
    "CentralConfig",
    "CentralRunResult",
    "train_central",
    "LocalTrainer",
    "CohortTrainer",
    "STAGING_MODES",
    "StagingPipeline",
    "chain_split_keys",
    "aggregate",
    "aggregate_stacked",
    "weighted_sum_stacked",
    "apply_delta",
    "delta",
    "params_nbytes",
    "tree_allclose",
    "select_clients",
    "ENGINES",
    "FederatedConfig",
    "FederatedRunResult",
    "FederatedServer",
    "RoundRecord",
]
