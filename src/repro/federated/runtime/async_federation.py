"""``AsyncFederation`` — the event-driven twin of the PR 4 ``Federation``.

The synchronous facade runs a fixed round program behind a barrier: select,
train everyone, aggregate, repeat.  This facade replaces the barrier with
the virtual-clock scheduler: every *task* (one client for ``fedbuff``, one
regional sub-federation for ``hierarchical-async``) is dispatched with the
current global params, takes its latency model's virtual time, and lands
in the server buffer when it completes; buffered aggregators decide when
the buffer flushes into a new parameter version.  Completed tasks wait for
the next flush before redispatching (dropped tasks retry immediately), so
a flush boundary is exactly a parameter-version boundary.

The engine hot path is untouched: each task executes through the same
``Federation._train_group`` primitive the synchronous round program uses —
one jitted/donated/shard_map'd ``CohortTrainer.train_cohort`` call per task
under the vectorized engine, the per-client oracle under the sequential
one.  The runtime only reorders *which* cohort chunks train against
*which* parameter version.  Because per-task plans and PRNG keys are drawn
from the same streams in dispatch order, the degenerate configuration
(``fedbuff:K`` with ``K`` = all participants and a zero-spread latency
model) consumes bit-identical batches and keys to a synchronous flat
FedAvg round — the 1e-5 parity gate of the tier-1 suite.

Timeline bookkeeping lands where the synchronous records already live:
each flush appends a :class:`~repro.federated.api.RoundRecord` whose
``virtual_time`` / ``staleness`` fields are populated, and
``FederatedRunResult.summary()`` totals them alongside the host wall
clock, so recruited-vs-all comparisons can quote *simulated
time-to-target-loss* — the paper's training-time claim under realistic
straggler behavior.

Seeded-replay determinism and checkpoint/resume
-----------------------------------------------
An async run is a pure function of the seed: the batch-plan generator and
jax key chain advance in *dispatch order* (which the deterministic
scheduler fixes), and all timeline randomness (latencies, dropouts,
persistent per-client rates) draws from the scheduler's own seeded stream
at dispatch.  A flush boundary is therefore a complete cut through the
run's state: global params + server version, the event heap (whose pending
completions carry already-trained updates), the ready/idle task queues,
all three stream states, and the latency model's drawn rates.
:class:`AsyncFederationSnapshot` captures exactly that cut; a run resumed
from it re-dispatches from identical streams and replays the remaining
timeline bit-identically — same virtual clock, same event order, same
batches and keys — which the control plane's kill-and-resume parity tests
assert (params to 1e-5, scheduler state exact).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import ClientDataset, cohort_steps_per_epoch
from repro.federated.api import (
    Aggregator,
    FederatedRunResult,
    Federation,
    FederationConfig,
    RoundRecord,
    resolve_aggregator,
)
from repro.federated.fedavg import params_nbytes
from repro.obs.profile import CompileWatcher
from repro.federated.runtime.latency import (
    DropoutModel,
    LatencyModel,
    resolve_dropout,
    resolve_latency,
)
from repro.federated.runtime.scheduler import Event, VirtualScheduler
from repro.federated.runtime.staleness import AsyncAggregator, AsyncUpdate
from repro.optim.adamw import AdamW
from repro.privacy.accountant import RdpAccountant

PyTree = Any

# Event kinds on the virtual timeline.
COMPLETE = "complete"   # a dispatched task finished (payload: _Completion)
FLUSH = "flush"         # the buffer crosses the aggregator's threshold


@dataclasses.dataclass(frozen=True)
class AsyncFederationConfig(FederationConfig):
    """Declarative async federation: ``FederationConfig`` + the time axis.

    Inherited fields keep their meaning, with two async readings:
    ``rounds`` budgets *flushes* (server parameter versions — the async
    unit of progress), and ``selection`` is unused (the dispatch model —
    every task retrains as soon as the version it waits for exists — takes
    the place of per-round sampling).  ``aggregator`` must resolve to a
    buffered aggregator (``"fedbuff:K"`` / ``"hierarchical-async:R"`` or
    an ``AsyncAggregator`` instance).
    """

    aggregator: str | Aggregator = "fedbuff"
    # Virtual-time models, resolvable from spec strings like the policies.
    latency: str | LatencyModel = "constant"
    dropout: str | float | DropoutModel = "never"
    # Max tasks training concurrently (FedBuff's M_max); None = no cap.
    concurrency: int | None = None
    # Early stops: flush-loss target and a virtual-clock ceiling.  Both
    # None means the run uses its full ``rounds`` flush budget.
    target_loss: float | None = None
    max_virtual_time: float | None = None

    def __post_init__(self) -> None:
        super().__post_init__()
        if int(self.rounds) < 1:
            raise ValueError(f"need rounds >= 1 flush budget, got {self.rounds}")
        if self.concurrency is not None and int(self.concurrency) < 1:
            raise ValueError(f"concurrency must be >= 1, got {self.concurrency}")
        if self.max_virtual_time is not None and not (self.max_virtual_time > 0):
            raise ValueError(
                f"max_virtual_time must be > 0, got {self.max_virtual_time}"
            )


@dataclasses.dataclass
class _Completion:
    """COMPLETE event payload: which task finished, and with what."""

    group_index: int
    update: AsyncUpdate | None  # None = the task dropped out (no result)


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PendingEvent:
    """A serializable image of one not-yet-popped scheduler event.

    ``group_index``/``update`` unpack the COMPLETE payload (``update`` is
    ``None`` for dropped tasks *and* for non-COMPLETE kinds); ``seq`` is
    preserved so restored simultaneity resolves exactly as scheduled.
    """

    time: float
    seq: int
    kind: str
    group_index: int | None
    update: AsyncUpdate | None


def _pack_update(
    prefix: str, update: AsyncUpdate, trees: dict, arrays: dict
) -> dict:
    """Split one AsyncUpdate into (scalar dict, named trees, named arrays)."""
    trees[f"{prefix}.params"] = update.params
    trees[f"{prefix}.anchor"] = update.anchor
    arrays[f"{prefix}.losses"] = np.asarray(update.losses, dtype=np.float32)
    arrays[f"{prefix}.client_ids"] = np.asarray(update.client_ids, dtype=np.int64)
    return {
        "ref": prefix,
        "weight": float(update.weight),
        "version": int(update.version),
        "local_steps": int(update.local_steps),
    }


def _unpack_update(entry: dict, trees: dict, arrays: dict) -> AsyncUpdate:
    prefix = entry["ref"]
    return AsyncUpdate(
        client_ids=np.asarray(arrays[f"{prefix}.client_ids"]),
        params=trees[f"{prefix}.params"],
        anchor=trees[f"{prefix}.anchor"],
        weight=float(entry["weight"]),
        version=int(entry["version"]),
        losses=np.asarray(arrays[f"{prefix}.losses"], dtype=np.float32),
        local_steps=int(entry["local_steps"]),
    )


@dataclasses.dataclass
class AsyncFederationSnapshot:
    """Everything ``AsyncFederation.run`` needs to continue from a flush.

    Captured by the ``snapshot_hook`` right after a flush's record lands
    and the idle tasks are requeued (the point where the loop's next action
    — dispatching ready tasks — is the same whether the run continues or
    resumes).  Pending completions on the event heap carry fully-trained
    updates (their params/anchors are serialized by value), so a resumed
    run never retrains work that was already in flight; it only replays
    the timeline forward from restored streams.
    """

    version: int                  # server parameter versions flushed so far
    params: PyTree
    np_rng_state: dict            # batch-plan generator state
    jax_key_data: np.ndarray      # per-task key chain raw data
    sched_state: dict             # virtual clock / seq / processed / stream
    events: list[PendingEvent]    # the un-popped event heap
    buffer: list[AsyncUpdate]     # completions awaiting the next flush
    ready: list[int]              # task groups waiting for a dispatch slot
    idle: list[int]               # task groups waiting for the next flush
    in_flight: int
    drought: int
    flush_pending: bool
    latency_state: dict           # drawn persistent per-client rates
    stats: dict
    history: list[RoundRecord]

    @property
    def round_index(self) -> int:
        """Flush count — the async analogue of the sync snapshot's field."""
        return self.version

    def save(self, directory: str, extra_state: dict | None = None) -> None:
        """Persist atomically via ``repro.checkpoint.store`` (overwrites)."""
        from repro.checkpoint.store import save_federation_snapshot

        trees: dict[str, Any] = {"params": self.params}
        arrays: dict[str, np.ndarray] = {
            "jax_key_data": np.asarray(self.jax_key_data)
        }
        events_state = []
        for i, event in enumerate(self.events):
            entry: dict[str, Any] = {
                "time": event.time,
                "seq": event.seq,
                "kind": event.kind,
                "group_index": event.group_index,
                "update": None,
            }
            if event.update is not None:
                entry["update"] = _pack_update(f"event{i}", event.update, trees, arrays)
            events_state.append(entry)
        buffer_state = [
            _pack_update(f"buffer{i}", u, trees, arrays)
            for i, u in enumerate(self.buffer)
        ]
        state = {
            "kind": "async",
            "version": int(self.version),
            "np_rng_state": self.np_rng_state,
            "sched": self.sched_state,
            "events": events_state,
            "buffer": buffer_state,
            "ready": [int(i) for i in self.ready],
            "idle": [int(i) for i in self.idle],
            "in_flight": int(self.in_flight),
            "drought": int(self.drought),
            "flush_pending": bool(self.flush_pending),
            "latency_state": self.latency_state,
            "stats": self.stats,
            "history": [r.to_state() for r in self.history],
        }
        state.update(extra_state or {})
        save_federation_snapshot(directory, trees=trees, arrays=arrays, state=state)

    @classmethod
    def load(cls, directory: str, like_params: PyTree) -> "AsyncFederationSnapshot":
        from repro.checkpoint.store import load_federation_snapshot

        trees, arrays, state = load_federation_snapshot(directory, like_params)
        if state.get("kind") != "async":
            raise ValueError(
                f"snapshot in {directory} is {state.get('kind')!r}, not an "
                "async federation snapshot"
            )
        events = []
        for entry in state["events"]:
            update = (
                _unpack_update(entry["update"], trees, arrays)
                if entry["update"] is not None
                else None
            )
            events.append(
                PendingEvent(
                    time=float(entry["time"]),
                    seq=int(entry["seq"]),
                    kind=entry["kind"],
                    group_index=entry["group_index"],
                    update=update,
                )
            )
        return cls(
            version=int(state["version"]),
            params=trees["params"],
            np_rng_state=state["np_rng_state"],
            jax_key_data=arrays["jax_key_data"],
            sched_state=state["sched"],
            events=events,
            buffer=[_unpack_update(e, trees, arrays) for e in state["buffer"]],
            ready=[int(i) for i in state["ready"]],
            idle=[int(i) for i in state["idle"]],
            in_flight=int(state["in_flight"]),
            drought=int(state["drought"]),
            flush_pending=bool(state["flush_pending"]),
            latency_state=state.get("latency_state", {}),
            stats=dict(state.get("stats", {})),
            history=[RoundRecord.from_state(r) for r in state["history"]],
        )


class AsyncFederation:
    """Runs buffered-async federated training on the virtual clock.

    ``AsyncFederation(config, clients, loss_fn, optimizer)`` resolves the
    buffered aggregator and the latency/dropout models up front (unknown
    specs fail here, not mid-run) and delegates recruitment and all
    training to an inner synchronous :class:`Federation` so the two
    facades share one engine surface.
    """

    def __init__(
        self,
        config: AsyncFederationConfig,
        clients: Sequence[ClientDataset],
        loss_fn: Callable[..., Any],
        optimizer: AdamW,
        tracer: Any = None,
        metrics: Any = None,
        profiler: Any = None,
    ) -> None:
        if not isinstance(config, AsyncFederationConfig):
            raise TypeError(
                f"AsyncFederation needs an AsyncFederationConfig, "
                f"got {type(config).__name__}"
            )
        self.config = config
        self.aggregator = resolve_aggregator(config.aggregator)
        if not isinstance(self.aggregator, AsyncAggregator):
            raise ValueError(
                f"aggregator {config.aggregator!r} is synchronous; the async "
                "runtime needs a buffered aggregator ('fedbuff:K', "
                "'hierarchical-async:R', or an AsyncAggregator instance) — "
                "or run it with the synchronous Federation facade"
            )
        self.latency_model = resolve_latency(config.latency)
        self.dropout_model = resolve_dropout(config.dropout)
        # The inner facade carries recruitment + both engines; its own
        # aggregator stage is fixed to the reduced hot path because every
        # async task *is* one FedAvg-reduced engine group.
        self._fed = Federation(
            FederationConfig(
                rounds=config.rounds,
                local_epochs=config.local_epochs,
                batch_size=config.batch_size,
                recruitment=config.recruitment,
                selection="uniform",
                aggregator="fedavg",
                seed=config.seed,
                engine=config.engine,
                cohort_chunk=config.cohort_chunk,
                mesh=config.mesh,
                donate_buffers=config.donate_buffers,
                staging=config.staging,
                prefetch=config.prefetch,
                resident_budget_bytes=config.resident_budget_bytes,
                privacy=config.privacy,
            ),
            clients,
            loss_fn,
            optimizer,
            tracer=tracer,
            metrics=metrics,
            profiler=profiler,
        )
        # One observability surface for both facades: the inner Federation
        # resolved the null tracer / built the registry; share them.
        self.tracer = self._fed.tracer
        self.metrics = self._fed.metrics
        self.profiler = self._fed.profiler
        self.last_run_stats: dict[str, Any] | None = None

    @property
    def cohort_trainer(self):
        return self._fed.cohort_trainer

    @property
    def trainer(self):
        return self._fed.trainer

    def build_federation(self):
        return self._fed.build_federation()

    # ------------------------------------------------------------------
    # the event loop
    # ------------------------------------------------------------------

    def run(
        self,
        init_params: PyTree,
        progress: Callable[[RoundRecord], None] | None = None,
        snapshot_hook: Callable[[AsyncFederationSnapshot], None] | None = None,
        resume: AsyncFederationSnapshot | None = None,
    ) -> FederatedRunResult:
        """Run the event loop; optionally checkpoint at every flush.

        ``snapshot_hook`` (if given) is called with a fresh
        :class:`AsyncFederationSnapshot` after each non-final flush, at the
        exact cut where resuming and continuing are indistinguishable.
        ``resume`` restores such a snapshot: streams, clock, queues, and
        in-flight completions are reinstated and the remaining timeline
        replays bit-identically.
        """
        cfg = self.config
        rng = np.random.default_rng(cfg.seed)      # the batch-plan stream
        jax_rng = jax.random.key(cfg.seed)         # the per-task key chain
        sched = VirtualScheduler(seed=cfg.seed, tracer=self.tracer)

        federation_ids, recruitment = self._fed.build_federation()
        members = {int(i): self._fed.all_clients[int(i)] for i in federation_ids}
        groups = self.aggregator.task_groups(federation_ids)
        flat = np.sort(np.concatenate([np.asarray(g) for g in groups]))
        if not np.array_equal(flat, np.sort(np.asarray(federation_ids))):
            raise ValueError("aggregator task groups must partition the federation")
        self.aggregator.prepare(len(groups))
        if cfg.engine == "vectorized" and cfg.staging == "resident":
            # One upload for the whole federation; every task then stages
            # only its int32 index plan against the resident arrays.
            self._fed.cohort_trainer.attach_device_cohort(list(members.values()))
        # Pin the step axis federation-wide so every task shares one
        # compiled shape whatever group mix the timeline produces.
        spe = cohort_steps_per_epoch(
            [c.n_train for c in members.values()], cfg.batch_size
        )
        total_weight = float(sum(c.n_train for c in members.values()))
        n_tensors = len(jax.tree.leaves(init_params))
        model_nbytes = params_nbytes(init_params)

        # DP runs carry one Rényi accountant across the whole event loop;
        # each flush composes its participant fraction and stamps the
        # record with the cumulative epsilon.
        accountant = (
            RdpAccountant(
                self._fed.dp.noise_multiplier, delta=self._fed.dp.delta
            )
            if self._fed.dp is not None
            else None
        )
        params = init_params
        version = 0
        buffer: list[AsyncUpdate] = []
        # Two waiting states: ``ready`` tasks have not yet trained against
        # the current parameter version and dispatch as soon as a
        # concurrency slot frees (FedBuff's M_max semantics — a completion
        # immediately funds the next dispatch, so a cap below the
        # federation size never starves the tail of the task list);
        # ``idle`` tasks have reported against the current version and
        # wait for the next flush.
        ready: collections.deque[int] = collections.deque(range(len(groups)))
        idle: list[int] = []
        in_flight = 0
        flush_pending = False
        history: list[RoundRecord] = []
        stats = {"tasks": 0, "dropped": 0, "forced_flushes": 0, "steps_trained": 0}
        # Consecutive fully-dropped completions since the last successful
        # one.  Under dropout=1.0 no update can ever reach the server, so
        # with no virtual-time ceiling the retry loop would spin forever;
        # the drought threshold turns that into a loud error.  (At any
        # p < 1 a run of this length has probability p**threshold —
        # vanishingly small for every non-degenerate model.)
        drought, drought_limit = 0, max(100, 20 * len(groups))
        if resume is not None:
            if not (0 <= int(resume.version) < int(cfg.rounds)):
                raise ValueError(
                    f"cannot resume at flush {resume.version} of a run with "
                    f"rounds={cfg.rounds} (already complete or corrupt)"
                )
            params = resume.params
            version = int(resume.version)
            rng.bit_generator.state = resume.np_rng_state
            jax_rng = jax.random.wrap_key_data(jnp.asarray(resume.jax_key_data))
            sched.restore(
                resume.sched_state,
                [
                    Event(
                        time=pe.time,
                        seq=pe.seq,
                        kind=pe.kind,
                        payload=_Completion(pe.group_index, pe.update)
                        if pe.kind == COMPLETE
                        else None,
                    )
                    for pe in resume.events
                ],
            )
            buffer = list(resume.buffer)
            ready = collections.deque(int(i) for i in resume.ready)
            idle = [int(i) for i in resume.idle]
            in_flight = int(resume.in_flight)
            drought = int(resume.drought)
            flush_pending = bool(resume.flush_pending)
            self.latency_model.load_state_dict(resume.latency_state)
            stats = {**stats, **resume.stats}
            history = list(resume.history)
            if accountant is not None:
                # Privacy loss composes across the resume cut: replay the
                # completed flushes' sampling rates before continuing.
                for past in history:
                    accountant.step(
                        len(past.participant_ids) / federation_ids.size
                    )
        t_start = time.perf_counter()
        t_last_flush = t_start
        tracer = self.tracer
        # Per-flush metric deltas: the stats dict is cumulative (and resume
        # restores it alongside the registry, which already folded the
        # pre-preemption values), so only the change since the last flush
        # is incremented into the counters.
        prev_stats = dict(stats)

        def absorb_async_metrics() -> None:
            m = self.metrics
            for key in ("tasks", "dropped", "forced_flushes"):
                delta = stats[key] - prev_stats.get(key, 0)
                if delta:
                    m.counter(f"async.{key}").inc(delta)
                prev_stats[key] = stats[key]
            m.gauge("async.in_flight").set(in_flight)
            m.gauge("async.buffered_updates").set(len(buffer))

        def make_snapshot() -> AsyncFederationSnapshot:
            return AsyncFederationSnapshot(
                version=version,
                params=params,
                np_rng_state=rng.bit_generator.state,
                jax_key_data=np.asarray(jax.random.key_data(jax_rng)),
                sched_state=sched.state_dict(),
                events=[
                    PendingEvent(
                        time=e.time,
                        seq=e.seq,
                        kind=e.kind,
                        group_index=e.payload.group_index
                        if e.kind == COMPLETE
                        else None,
                        update=e.payload.update if e.kind == COMPLETE else None,
                    )
                    for e in sched.pending()
                ],
                buffer=list(buffer),
                ready=list(ready),
                idle=list(idle),
                in_flight=in_flight,
                drought=drought,
                flush_pending=flush_pending,
                latency_state=self.latency_model.state_dict(),
                stats=dict(stats),
                history=list(history),
            )

        def dispatch(group_index: int) -> None:
            """Train one task eagerly and schedule its completion.

            Draw order is fixed per dispatch — every member's latency, then
            every member's dropout, then training for the survivors — so
            the latency/dropout stream and the batch/key streams advance
            identically on replay.
            """
            nonlocal jax_rng, in_flight
            group = groups[group_index]
            latency = max(
                self.latency_model.sample(int(cid), members[int(cid)].n_train, sched.rng)
                for cid in group
            )
            survivors = np.asarray(
                [cid for cid in group if not self.dropout_model.drops(int(cid), sched.rng)]
            )
            update = None
            with tracer.span("dispatch", group=group_index, latency=latency):
                if len(survivors):
                    task_params, losses, steps, jax_rng = self._fed._train_group(
                        params, survivors, rng, jax_rng, spe
                    )
                    stats["steps_trained"] += steps
                    update = AsyncUpdate(
                        client_ids=survivors,
                        params=task_params,
                        anchor=params,
                        weight=float(sum(members[int(c)].n_train for c in survivors)),
                        version=version,
                        losses=np.asarray(losses, dtype=np.float32),
                        local_steps=steps,
                    )
            stats["tasks"] += 1
            stats["dropped"] += len(group) - len(survivors)
            in_flight += 1
            sched.after(latency, COMPLETE, _Completion(group_index, update))
            if tracer.enabled:
                # The task on the virtual clock: dispatched now, completing
                # after its sampled latency, on its own per-client track —
                # with a flow arrow from the server's dispatch point so
                # straggler/dropout schedules read off the timeline.
                track = (
                    f"client:{int(group[0])}"
                    if len(group) == 1
                    else f"group:{group_index}"
                )
                fid = tracer.new_flow_id()
                tracer.flow_start("task", fid, ts=sched.now, track="server")
                tracer.complete(
                    "task",
                    start=sched.now,
                    dur=latency,
                    track=track,
                    clock="virtual",
                    group=group_index,
                    clients=[int(c) for c in group],
                    survivors=len(survivors),
                    version=version,
                    dropped=update is None,
                )
                tracer.flow_end("task", fid, ts=sched.now + latency, track=track)

        def dispatch_ready() -> None:
            """Dispatch ready tasks in queue order, respecting concurrency."""
            while ready and (cfg.concurrency is None or in_flight < cfg.concurrency):
                dispatch(ready.popleft())

        def flush() -> bool:
            """Fold the buffer into a new param version; True = keep going."""
            nonlocal params, version, buffer, t_last_flush
            updates, buffer = buffer, []
            staleness = self.aggregator.staleness_of(updates, version)
            params = self.aggregator.combine(params, updates, version, total_weight)
            version += 1
            participant_ids = sorted(
                {int(c) for u in updates for c in np.asarray(u.client_ids)}
            )
            losses = np.concatenate([u.losses for u in updates])
            k = sum(len(u.client_ids) for u in updates)
            epsilon = None
            if accountant is not None:
                accountant.step(len(participant_ids) / federation_ids.size)
                epsilon = accountant.epsilon()
            now_host = time.perf_counter()
            record = RoundRecord(
                round_index=version - 1,
                participant_ids=participant_ids,
                mean_local_loss=float(np.nanmean(losses)) if len(losses) else float("nan"),
                local_steps=sum(u.local_steps for u in updates),
                params_down=k * n_tensors,
                params_up=k * n_tensors,
                bytes_transferred=2 * k * model_nbytes,
                wall_time_s=now_host - t_last_flush,
                virtual_time=sched.now,
                staleness=float(staleness.mean()) if len(staleness) else 0.0,
                epsilon=epsilon,
            )
            # The flush span covers the whole inter-flush interval on the
            # host clock — its duration is exactly round_time_s — plus an
            # instant on the virtual timeline at the flush's event time.
            tracer.complete(
                "flush",
                start=tracer.host_ts(t_last_flush),
                dur=record.wall_time_s,
                version=version - 1,
                updates=len(updates),
                virtual_time=sched.now,
            )
            tracer.instant(
                "flush", ts=sched.now, clock="virtual",
                version=version - 1, staleness=record.staleness,
            )
            t_last_flush = now_host
            history.append(record)
            watcher.poll()
            absorb_async_metrics()
            self._fed._absorb_round_metrics(record)
            if self.profiler is not None:
                self.profiler.round_end(version - 1)
                self.profiler.round_start(version)
            if progress is not None:
                progress(record)
            if version >= cfg.rounds:
                return False
            if cfg.target_loss is not None and record.mean_local_loss <= cfg.target_loss:
                return False
            return True

        with CompileWatcher(self.metrics) as watcher:
            dispatch_ready()
            while True:
                if sched.empty:
                    if buffer and version < cfg.rounds:
                        # Every task has reported but the buffer never
                        # crossed the threshold (e.g. fedbuff:K over a
                        # federation of fewer than K tasks): flush what
                        # there is rather than deadlock — the
                        # semi-synchronous degenerate case.
                        stats["forced_flushes"] += 1
                        sched.schedule(sched.now, FLUSH)
                        flush_pending = True
                        continue
                    break
                if (
                    cfg.max_virtual_time is not None
                    and sched.peek_time() > cfg.max_virtual_time
                ):
                    break
                event = sched.pop()
                if event.kind == COMPLETE:
                    in_flight -= 1
                    done: _Completion = event.payload
                    if done.update is None:
                        # Dropped: the client retries immediately — it never
                        # blocks the buffer, so it cannot deadlock a flush.
                        # (in_flight just fell below any concurrency cap, so
                        # the retry always has a slot.)
                        drought += 1
                        if drought > drought_limit and cfg.max_virtual_time is None:
                            raise RuntimeError(
                                f"{drought} consecutive tasks dropped with no "
                                "update reaching the server; the dropout model "
                                "admits no progress — lower the dropout "
                                "probability or set max_virtual_time to bound "
                                "the simulation"
                            )
                        dispatch(done.group_index)
                        continue
                    drought = 0
                    buffer.append(done.update)
                    idle.append(done.group_index)
                    # The completion freed a concurrency slot: fund the next
                    # not-yet-trained task with it right away.
                    dispatch_ready()
                    if self.aggregator.ready(len(buffer)) and not flush_pending:
                        # Flush at the next event boundary (same time, later
                        # seq): simultaneous completions land in one flush.
                        sched.schedule(sched.now, FLUSH)
                        flush_pending = True
                elif event.kind == FLUSH:
                    flush_pending = False
                    if not buffer:
                        continue
                    if not flush():
                        break
                    # The new version exists: everyone who reported against
                    # the old one becomes ready again, behind any task still
                    # waiting for its first slot.
                    idle.sort()
                    ready.extend(idle)
                    idle.clear()
                    if snapshot_hook is not None:
                        # The cut point: buffer just flushed, idle requeued,
                        # nothing dispatched yet — resuming from here and
                        # continuing are the same next action.
                        with tracer.span("checkpoint", version=version):
                            snapshot_hook(make_snapshot())
                    dispatch_ready()
                else:  # pragma: no cover - no other kinds are scheduled
                    raise RuntimeError(f"unknown event kind {event.kind!r}")

        jax.block_until_ready(params)
        # Tail work since the last flush (dispatches that never flushed)
        # still lands in the counters before the final snapshot.
        absorb_async_metrics()
        self.metrics.gauge("async.virtual_time").set(sched.now)
        if self.profiler is not None:
            self.profiler.stop()
        self.last_run_stats = {
            **stats,
            "virtual_time": sched.now,
            "flushes": version,
            "events": sched.processed,
            "unflushed_updates": len(buffer),
            "groups": len(groups),
        }
        return FederatedRunResult(
            params=params,
            history=history,
            recruitment=recruitment,
            federation_ids=federation_ids,
            total_wall_time_s=time.perf_counter() - t_start,
            total_local_steps=sum(r.local_steps for r in history),
            metrics=self.metrics.snapshot(),
        )
