"""Per-client latency and dropout models for the async federation runtime.

The paper's training-time claim is about the wall-clock cost of *waiting
for hospitals*: a synchronous FedAvg round is as slow as its slowest
participant, and real eICU deployments see heavy-tailed straggler and
dropout behavior the repo's device timers cannot express.  These models put
that axis under experimental control: each one maps a client to the
virtual seconds its local-training task takes (and, for dropout, whether
the task fails), drawing from the scheduler's seeded stream so simulated
timelines replay deterministically.

Models resolve from the same string-spec grammar as the PR 4 policies
(``latency="lognormal:0.5"``, ``dropout="bernoulli:0.1"``):

* ``constant[:seconds]`` — every task takes the same time; the zero-spread
  model the sync-parity gate runs under.
* ``lognormal[:sigma[,median]]`` — each client draws a persistent rate
  ``median * exp(sigma * z)`` at first dispatch: mild, realistic speed
  heterogeneity (slow ICUs stay slow).
* ``pareto[:alpha[,scale]]`` — persistent per-client rates
  ``scale * (1 + Pareto(alpha))``: the heavy-tailed straggler regime
  (smaller ``alpha`` = fatter tail).
* ``trace[:per_sample[,base]]`` — deterministic
  ``base + per_sample * n_c``: compute time tracks local dataset size, the
  "big hospitals are slow hospitals" trace the recruitment trade-off is
  really about.

Dropout specs: ``never`` and ``bernoulli:p`` (each dispatch independently
fails with probability ``p``; the runtime retries the client after its
latency elapses).  ``resolve_dropout`` also accepts a bare float as
shorthand for ``bernoulli:p``.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from repro.federated.api import _resolve


class LatencyModel:
    """Maps one client task to its virtual duration.

    ``sample(client_id, n_samples, rng)`` returns the virtual seconds the
    client's next local-training task takes; ``rng`` is the scheduler's
    seeded stream.  Implementations that draw persistent per-client rates
    must draw lazily from ``rng`` on first sight of a client so the whole
    timeline stays a pure function of the seed and the dispatch order.
    """

    def sample(self, client_id: int, n_samples: int, rng: np.random.Generator) -> float:
        raise NotImplementedError

    @property
    def zero_spread(self) -> bool:
        """True when every client always takes the identical time."""
        return False

    def state_dict(self) -> dict:
        """JSON-serializable model state for checkpoint/resume.

        Stateless models return ``{}``.  Models with lazily-drawn
        persistent per-client rates must round-trip them: a resumed run's
        fresh instance would otherwise redraw rates from the restored
        stream, changing both the rates and every later draw.
        """
        return {}

    def load_state_dict(self, state: dict) -> None:
        pass


class DropoutModel:
    """Decides whether one dispatched task fails (no update reaches the server)."""

    def drops(self, client_id: int, rng: np.random.Generator) -> bool:
        raise NotImplementedError


_LATENCIES: dict[str, Callable[..., LatencyModel]] = {}
_DROPOUTS: dict[str, Callable[..., DropoutModel]] = {}


def register_latency(name: str):
    """Register a latency-model factory (``@register_latency("x")``)."""

    def deco(factory):
        _LATENCIES[name] = factory
        return factory

    return deco


def register_dropout(name: str):
    def deco(factory):
        _DROPOUTS[name] = factory
        return factory

    return deco


def resolve_latency(spec) -> LatencyModel:
    """``"constant"`` / ``"lognormal:0.5"`` / instance -> model."""
    return _resolve(_LATENCIES, spec, "latency", LatencyModel)


def resolve_dropout(spec) -> DropoutModel:
    """``"never"`` / ``"bernoulli:0.1"`` / bare probability / instance -> model."""
    if isinstance(spec, (int, float)) and not isinstance(spec, bool):
        return BernoulliDropout(float(spec))
    return _resolve(_DROPOUTS, spec, "dropout", DropoutModel)


def available_runtime_models() -> dict[str, tuple[str, ...]]:
    """Registered spec names — the discoverable runtime-model surface."""
    return {
        "latency": tuple(sorted(_LATENCIES)),
        "dropout": tuple(sorted(_DROPOUTS)),
    }


# ---------------------------------------------------------------------------
# latency models
# ---------------------------------------------------------------------------


@register_latency("constant")
class ConstantLatency(LatencyModel):
    """Every task takes exactly ``seconds`` — the zero-spread reference."""

    def __init__(self, seconds: float = 1.0) -> None:
        if not (float(seconds) > 0):
            raise ValueError(f"constant latency needs seconds > 0, got {seconds}")
        self.seconds = float(seconds)

    def sample(self, client_id, n_samples, rng) -> float:
        return self.seconds

    @property
    def zero_spread(self) -> bool:
        return True


class PersistentRateLatency(LatencyModel):
    """Base for models where a client's speed is a stable property.

    The per-client rate is drawn once, lazily, the first time the client is
    dispatched (so the draw order — and therefore the timeline — is fixed
    by the event order), and reused for every later dispatch: slow ICUs
    stay slow, which is what makes stragglers a *systematic* cost instead
    of noise that averages out.
    """

    def __init__(self) -> None:
        self._rate: dict[int, float] = {}

    def _draw(self, rng: np.random.Generator) -> float:
        raise NotImplementedError

    def sample(self, client_id, n_samples, rng) -> float:
        cid = int(client_id)
        if cid not in self._rate:
            self._rate[cid] = float(self._draw(rng))
        return self._rate[cid]

    def state_dict(self) -> dict:
        return {"rate": {str(cid): rate for cid, rate in self._rate.items()}}

    def load_state_dict(self, state: dict) -> None:
        self._rate = {int(cid): float(r) for cid, r in state.get("rate", {}).items()}


@register_latency("lognormal")
class LognormalLatency(PersistentRateLatency):
    """Rates ``median * exp(sigma * z)`` — multiplicative speed spread."""

    def __init__(self, sigma: float = 0.5, median: float = 1.0) -> None:
        super().__init__()
        if float(sigma) < 0:
            raise ValueError(f"lognormal needs sigma >= 0, got {sigma}")
        if not (float(median) > 0):
            raise ValueError(f"lognormal needs median > 0, got {median}")
        self.sigma, self.median = float(sigma), float(median)

    def _draw(self, rng) -> float:
        return self.median * float(np.exp(self.sigma * rng.standard_normal()))

    @property
    def zero_spread(self) -> bool:
        return self.sigma == 0.0


@register_latency("pareto")
class ParetoLatency(PersistentRateLatency):
    """Rates ``scale * (1 + Pareto(alpha))`` — heavy-tailed stragglers.

    ``alpha <= 1`` has infinite mean: a federation will reliably contain a
    client an order of magnitude slower than the median, the regime where
    synchronous rounds collapse and buffered async aggregation earns its
    keep.
    """

    def __init__(self, alpha: float = 1.5, scale: float = 1.0) -> None:
        super().__init__()
        if not (float(alpha) > 0):
            raise ValueError(f"pareto needs alpha > 0, got {alpha}")
        if not (float(scale) > 0):
            raise ValueError(f"pareto needs scale > 0, got {scale}")
        self.alpha, self.scale = float(alpha), float(scale)

    def _draw(self, rng) -> float:
        return self.scale * (1.0 + float(rng.pareto(self.alpha)))


@register_latency("trace")
class TraceLatency(LatencyModel):
    """Deterministic ``base + per_sample * n_c`` — compute tracks data size.

    The latency twin of the recruitment trade-off: the clients that
    contribute the most samples are exactly the ones a synchronous barrier
    waits longest for.
    """

    def __init__(self, per_sample: float = 0.01, base: float = 0.1) -> None:
        if float(per_sample) < 0 or float(base) < 0:
            raise ValueError(
                f"trace latency needs per_sample >= 0 and base >= 0, "
                f"got {per_sample}, {base}"
            )
        if float(per_sample) == 0 and float(base) == 0:
            raise ValueError("trace latency needs per_sample or base > 0")
        self.per_sample, self.base = float(per_sample), float(base)

    def sample(self, client_id, n_samples, rng) -> float:
        return self.base + self.per_sample * int(n_samples)


# ---------------------------------------------------------------------------
# dropout models
# ---------------------------------------------------------------------------


@register_dropout("never")
class NeverDropout(DropoutModel):
    """No task ever fails — the default, and the parity-gate setting."""

    def drops(self, client_id, rng) -> bool:
        return False


@register_dropout("bernoulli")
class BernoulliDropout(DropoutModel):
    """Each dispatch independently fails with probability ``p``."""

    def __init__(self, p: float = 0.1) -> None:
        if not (0.0 <= float(p) <= 1.0):
            raise ValueError(f"dropout probability must be in [0, 1], got {p}")
        self.p = float(p)

    def drops(self, client_id, rng) -> bool:
        return bool(rng.random() < self.p)
