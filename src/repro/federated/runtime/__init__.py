"""Async federation runtime: virtual-clock scheduling, stragglers, staleness.

Importing this package registers the buffered aggregators (``"fedbuff:K"``,
``"hierarchical-async:R"``) into the shared aggregator registry and exposes
the latency/dropout model registries (``"constant"``, ``"lognormal:0.5"``,
``"pareto:1.5"``, ``"trace"``, ``"bernoulli:0.1"``).  The entry point is
:class:`AsyncFederation` driven by an :class:`AsyncFederationConfig`;
:class:`AsyncFederationSnapshot` is its checkpoint/resume image (the
control plane in :mod:`repro.launch.federation_service` persists one at
every flush boundary).
"""

from repro.federated.runtime.async_federation import (
    AsyncFederation,
    AsyncFederationConfig,
    AsyncFederationSnapshot,
    PendingEvent,
)
from repro.federated.runtime.latency import (
    BernoulliDropout,
    ConstantLatency,
    DropoutModel,
    LatencyModel,
    LognormalLatency,
    NeverDropout,
    ParetoLatency,
    TraceLatency,
    available_runtime_models,
    register_dropout,
    register_latency,
    resolve_dropout,
    resolve_latency,
)
from repro.federated.runtime.scheduler import Event, VirtualScheduler
from repro.federated.runtime.staleness import (
    AsyncAggregator,
    AsyncUpdate,
    FedBuffAggregator,
    HierarchicalAsyncAggregator,
    polynomial_staleness_weight,
    staleness_weights,
)

__all__ = [
    "AsyncFederation",
    "AsyncFederationConfig",
    "AsyncFederationSnapshot",
    "PendingEvent",
    "AsyncAggregator",
    "AsyncUpdate",
    "FedBuffAggregator",
    "HierarchicalAsyncAggregator",
    "polynomial_staleness_weight",
    "staleness_weights",
    "Event",
    "VirtualScheduler",
    "LatencyModel",
    "DropoutModel",
    "ConstantLatency",
    "LognormalLatency",
    "ParetoLatency",
    "TraceLatency",
    "NeverDropout",
    "BernoulliDropout",
    "available_runtime_models",
    "register_latency",
    "register_dropout",
    "resolve_latency",
    "resolve_dropout",
]
