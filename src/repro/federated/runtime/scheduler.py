"""Deterministic virtual-clock discrete-event scheduler.

The async federation runtime replaces the synchronous round barrier with a
simulated timeline: client tasks, completions, and aggregator flushes are
*events* on a virtual clock, and the whole simulation is a single-threaded
walk over an event heap.  Two properties make the walk a reliable research
instrument:

* **Determinism** — the heap is keyed on ``(virtual_time, seq)`` where
  ``seq`` is the monotone insertion counter, so simultaneous events resolve
  in the order they were scheduled, never by payload identity or hash
  order.  Two runs that schedule the same events replay bit-identically.
* **Seeding** — the scheduler owns the run's stochastic stream
  (``self.rng``, derived from the seed): latency and dropout models draw
  from it at well-defined points (task dispatch), so the event *timeline*
  is a pure function of the seed even though the models are random.

The scheduler knows nothing about federated learning; it stores opaque
``(kind, payload)`` pairs and advances ``now`` as events pop.  The policy
of what each kind means lives in
:mod:`repro.federated.runtime.async_federation`.

Because the whole timeline is ``(clock, seq counter, heap, one seeded
stream)``, the scheduler is also trivially *checkpointable*:
``state_dict`` captures clock/counters/stream and ``restore`` reinstates
them together with a caller-provided pending-event list (original seqs
preserved), which is how a preempted async federation resumes with an
exact virtual clock — same ``now``, same event order, same future draws.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled occurrence on the virtual timeline.

    Ordering is fully determined by ``(time, seq)`` — ``seq`` is unique per
    scheduler, so comparison never falls through to ``kind``/``payload``.
    """

    time: float
    seq: int
    kind: str
    payload: Any = None

    @property
    def key(self) -> tuple[float, int]:
        return (self.time, self.seq)


class VirtualScheduler:
    """Event heap + virtual clock + the run's seeded stochastic stream.

    ``schedule`` may only target the present or future (an event in the
    past would mean the simulation's causality is broken — fail loudly).
    ``pop`` returns events in ``(time, seq)`` order and advances ``now``
    to the popped event's time; virtual time therefore never runs
    backwards.
    """

    def __init__(self, seed: int = 0, tracer: Any = None) -> None:
        self._heap: list[tuple[float, int, Event]] = []
        self._next_seq = 0
        self.now = 0.0
        self.processed = 0
        # The run's latency/dropout stream, independent of the batch
        # scheduler's and the recruitment generator's streams.
        self.rng = np.random.default_rng([int(seed), 0x5EED])
        # Observability: each popped event becomes an instant marker on the
        # virtual-clock "scheduler" track (None = the shared no-op tracer),
        # so the raw event walk is inspectable under the runtime's richer
        # dispatch/task/flush spans.
        from repro.obs.trace import resolve_tracer

        self.tracer = resolve_tracer(tracer)

    def __len__(self) -> int:
        return len(self._heap)

    @property
    def empty(self) -> bool:
        return not self._heap

    def schedule(self, at: float, kind: str, payload: Any = None) -> Event:
        """Insert an event at virtual time ``at`` (>= ``now``)."""
        at = float(at)
        if not np.isfinite(at):
            raise ValueError(f"event time must be finite, got {at}")
        if at < self.now:
            raise ValueError(
                f"cannot schedule {kind!r} at t={at} in the past (now={self.now})"
            )
        event = Event(time=at, seq=self._next_seq, kind=kind, payload=payload)
        self._next_seq += 1
        heapq.heappush(self._heap, (event.time, event.seq, event))
        return event

    def after(self, delay: float, kind: str, payload: Any = None) -> Event:
        """Insert an event ``delay`` virtual seconds from now."""
        if delay < 0:
            raise ValueError(f"delay must be >= 0, got {delay}")
        return self.schedule(self.now + float(delay), kind, payload)

    def peek_time(self) -> float | None:
        """Virtual time of the next event, or None when the heap is empty."""
        return self._heap[0][0] if self._heap else None

    def pop(self) -> Event:
        """Remove and return the earliest event, advancing the clock to it."""
        if not self._heap:
            raise IndexError("pop from an empty scheduler")
        _, _, event = heapq.heappop(self._heap)
        self.now = event.time
        self.processed += 1
        self.tracer.instant(
            event.kind, ts=event.time, track="scheduler", clock="virtual",
            seq=event.seq,
        )
        return event

    def pending(self) -> list[Event]:
        """The not-yet-popped events in ``(time, seq)`` order (a copy)."""
        return [event for _, _, event in sorted(self._heap, key=lambda e: e[:2])]

    def state_dict(self) -> dict:
        """Clock, counters, and stream state — JSON-serializable.

        Pending events are *not* included (their payloads are arbitrary
        objects); callers snapshot them via :meth:`pending` and hand them
        back to :meth:`restore`.
        """
        return {
            "now": self.now,
            "next_seq": self._next_seq,
            "processed": self.processed,
            "rng_state": self.rng.bit_generator.state,
        }

    def restore(self, state: dict, events: list[Event]) -> None:
        """Reinstate a snapshot: clock, counters, stream, pending events.

        Events keep their original ``seq`` values, so replayed simultaneity
        resolves exactly as it would have in the uninterrupted run.
        """
        self.now = float(state["now"])
        self._next_seq = int(state["next_seq"])
        self.processed = int(state["processed"])
        self.rng.bit_generator.state = state["rng_state"]
        self._heap = []
        for event in events:
            if event.time < self.now:
                raise ValueError(
                    f"restored event {event.kind!r} at t={event.time} is in "
                    f"the past (now={self.now})"
                )
            heapq.heappush(self._heap, (event.time, event.seq, event))
