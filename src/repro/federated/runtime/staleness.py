"""Staleness-aware buffered aggregation for the async runtime.

Under a synchronous barrier every update is computed against the current
global parameters.  Once the barrier is gone, an update arrives anchored at
whatever parameter *version* the client was dispatched with — its
**staleness** ``s = version_now - version_at_dispatch`` counts the flushes
that happened while it trained.  Stale gradients still carry signal but
point from an old iterate, so buffered-async FL discounts them smoothly:

    w(s) = (1 + s) ** -a        (polynomial decay, Nguyen et al. 2022)

``a = 0`` disables the discount, ``s = 0`` always weighs 1, and the weight
decays monotonically — the properties the tier-1 property tests pin down.

Two buffered aggregators register into the PR 4 aggregator registry (they
resolve via ``resolve_aggregator`` like any policy, but carry
``mode = "buffered"`` so the synchronous ``Federation`` rejects them and
points at ``AsyncFederation``):

* ``"fedbuff:K"`` — buffered async FedAvg: client completions accumulate
  in a buffer; every ``K`` completions the buffer flushes as one
  staleness-discounted, sample-weighted delta step.  With ``K`` = all
  participants and a zero-spread latency model every update has staleness
  0 and the flush *is* flat FedAvg — the parity gate.
* ``"hierarchical-async:R"`` — regional sub-federations: participants are
  partitioned into ``R`` contiguous regions, each region trains one
  synchronous engine round as a single task (one psum under a mesh), and
  the cross-pod combine happens whenever a region finishes, merging the
  region's delta scaled by its sample share and staleness discount.  This
  is ROADMAP scale step (b): the sync two-level ``"hierarchical:R"``
  promoted to stale-tolerant cross-pod combines.  ``R = 1`` degenerates to
  synchronous flat FedAvg (one region == the whole federation).

Checkpoint note: buffered aggregators hold no hidden state between
flushes — the buffer lives in ``AsyncFederation.run`` and every
:class:`AsyncUpdate` is a value object (client ids, trained params, the
anchor version they trained from), which is why an
``AsyncFederationSnapshot`` can serialize in-flight work by value and a
resumed run replays the remaining flush sequence bit-identically.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np

from repro.federated.api import Aggregator, register_aggregator

PyTree = Any

BUFFERED_MODE = "buffered"


def polynomial_staleness_weight(staleness, exponent: float = 0.5):
    """``(1 + s) ** -exponent`` — FedBuff's polynomial staleness discount.

    Accepts scalars or arrays; ``s = 0`` maps to exactly 1.0 and the weight
    is strictly positive and non-increasing in ``s``.
    """
    if float(exponent) < 0:
        raise ValueError(f"staleness exponent must be >= 0, got {exponent}")
    s = np.asarray(staleness, dtype=np.float64)
    if np.any(s < 0):
        raise ValueError(f"staleness must be >= 0, got {staleness}")
    out = (1.0 + s) ** (-float(exponent))
    return float(out) if np.isscalar(staleness) or out.ndim == 0 else out


def staleness_weights(
    sample_sizes: Sequence[float], staleness: Sequence[float], exponent: float = 0.5
) -> np.ndarray:
    """Normalized flush weights ``w_i ∝ n_i * (1 + s_i) ** -a`` (sum to 1)."""
    n = np.asarray(sample_sizes, dtype=np.float64)
    if n.size == 0:
        raise ValueError("nothing to weigh")
    if np.any(n < 0) or n.sum() <= 0:
        raise ValueError(f"invalid sample sizes: {sample_sizes}")
    w = n * polynomial_staleness_weight(np.asarray(staleness), exponent)
    return (w / w.sum()).astype(np.float64)


@dataclasses.dataclass
class AsyncUpdate:
    """One completed task, waiting in the server buffer for the next flush.

    ``params``/``anchor`` are immutable jax pytrees — holding both costs no
    copies, and the flush computes the task's delta ``params - anchor``
    (the local progress measured from the version it was dispatched with).
    """

    client_ids: np.ndarray   # sorted members that actually trained
    params: PyTree           # task result (group-FedAvg for region tasks)
    anchor: PyTree           # global params the task was dispatched with
    weight: float            # total local sample count of the members
    version: int             # server version at dispatch
    losses: np.ndarray       # per-member mean local losses
    local_steps: int         # real local steps the task executed


class AsyncAggregator(Aggregator):
    """Buffered aggregation driven by the event loop, not the round program.

    The synchronous ``Aggregator`` contract answers "how do one round's
    updates combine"; the async contract answers three event-loop
    questions instead — what the schedulable *task unit* is
    (``task_groups``), when the buffer flushes (``ready``), and how a
    flush folds buffered deltas into the global params (``combine``).
    ``mode = "buffered"`` keeps these out of the synchronous round program.
    """

    mode = BUFFERED_MODE
    staleness_exponent: float = 0.5

    def task_groups(self, federation_ids: np.ndarray) -> list[np.ndarray]:
        """Partition the federation into schedulable task units.

        Default: one task per client (fully async).  Region-structured
        aggregators return multi-client groups that train one synchronous
        engine round per task.
        """
        return [np.asarray([cid]) for cid in np.sort(np.asarray(federation_ids))]

    def prepare(self, num_tasks: int) -> None:
        """Called once per run, after the federation forms, with the task
        count — the hook where relative thresholds become absolute."""

    def ready(self, buffered: int) -> bool:
        """True when ``buffered`` pending updates should trigger a flush."""
        raise NotImplementedError

    def combine(
        self,
        params: PyTree,
        updates: Sequence[AsyncUpdate],
        version: int,
        total_weight: float,
    ) -> PyTree:
        """Fold the buffered updates into ``params`` at server ``version``."""
        raise NotImplementedError

    def staleness_of(self, updates: Sequence[AsyncUpdate], version: int) -> np.ndarray:
        return np.asarray([version - u.version for u in updates], dtype=np.float64)


def _apply_deltas(params: PyTree, updates: Sequence[AsyncUpdate], coeffs) -> PyTree:
    """``params + sum_i c_i * (update_i.params - update_i.anchor)`` per leaf."""
    cs = [float(c) for c in coeffs]

    def leafwise(p, *pairs):
        # pairs interleaves (new_0, anchor_0, new_1, anchor_1, ...)
        ct = np.promote_types(p.dtype, np.float32)
        out = p.astype(ct)
        for c, (new, anchor) in zip(cs, zip(pairs[0::2], pairs[1::2])):
            out = out + c * (new.astype(ct) - anchor.astype(ct))
        return out.astype(p.dtype)

    flat: list[PyTree] = []
    for u in updates:
        flat.extend((u.params, u.anchor))
    return jax.tree.map(leafwise, params, *flat)


@register_aggregator("fedbuff")
class FedBuffAggregator(AsyncAggregator):
    """Buffered async FedAvg: flush every ``buffer_size`` completions.

    Spec forms: ``"fedbuff:K"`` or ``"fedbuff:K,a"`` (``a`` = staleness
    exponent).  An integer ``K`` is an absolute buffer size; a float in
    ``(0, 1]`` is a *fraction of the federation's tasks*, resolved when
    the run starts — ``"fedbuff:0.25"`` flushes every quarter-federation,
    ``"fedbuff:1.0"`` waits for everyone (the same int-count/float-
    fraction grammar as ``"uniform:K"`` vs ``"uniform:0.1"``).  Each flush
    applies the sample-weighted, staleness-discounted mean of the buffered
    deltas, scaled by ``server_lr``::

        params += server_lr * sum_i w~_i * (params_i - anchor_i),
        w~_i ∝ n_i * (1 + s_i) ** -a  (normalized over the buffer)

    With ``buffer_size`` = all participants, zero latency spread, and the
    default ``server_lr = 1``, every ``s_i`` is 0 and every anchor is the
    current params, so the flush telescopes to flat FedAvg — the 1e-5
    parity gate against the synchronous engines.  Federations smaller than
    ``buffer_size`` still make progress: the runtime force-flushes when
    every task has reported and the buffer cannot grow further.
    """

    def __init__(
        self,
        buffer_size: int | float = 8,
        staleness_exponent: float = 0.5,
        server_lr: float = 1.0,
    ) -> None:
        # The int/float distinction is textual, like the selection specs:
        # 8 is a count, 0.25 a fraction of the federation's tasks.
        if isinstance(buffer_size, float) and not buffer_size.is_integer():
            if not (0.0 < buffer_size <= 1.0):
                raise ValueError(
                    f"fedbuff fractional buffer_size must be in (0, 1], got {buffer_size}"
                )
            self.buffer_fraction: float | None = float(buffer_size)
            self.buffer_size = 1  # concrete once prepare() sees the task count
        elif isinstance(buffer_size, float) and buffer_size == 1.0:
            self.buffer_fraction = 1.0  # "fedbuff:1.0" = the whole federation
            self.buffer_size = 1
        else:
            if int(buffer_size) < 1:
                raise ValueError(f"fedbuff needs buffer_size >= 1, got {buffer_size}")
            self.buffer_fraction = None
            self.buffer_size = int(buffer_size)
        if float(staleness_exponent) < 0:
            raise ValueError(
                f"fedbuff needs staleness_exponent >= 0, got {staleness_exponent}"
            )
        if not (float(server_lr) > 0):
            raise ValueError(f"fedbuff needs server_lr > 0, got {server_lr}")
        self.staleness_exponent = float(staleness_exponent)
        self.server_lr = float(server_lr)

    def prepare(self, num_tasks: int) -> None:
        if self.buffer_fraction is not None:
            self.buffer_size = max(1, round(self.buffer_fraction * num_tasks))

    def ready(self, buffered: int) -> bool:
        return buffered >= self.buffer_size

    def combine(self, params, updates, version, total_weight):
        coeffs = self.server_lr * staleness_weights(
            [u.weight for u in updates],
            self.staleness_of(updates, version),
            self.staleness_exponent,
        )
        return _apply_deltas(params, updates, coeffs)


@register_aggregator("hierarchical-async")
class HierarchicalAsyncAggregator(AsyncAggregator):
    """Async two-level FedAvg: regions combine cross-pod as they finish.

    Spec forms: ``"hierarchical-async:R"`` or ``"hierarchical-async:R,a"``.
    ``task_groups`` partitions the sorted federation into ``R`` contiguous
    regions (the same split as the sync ``"hierarchical:R"``); each task is
    one regional engine round, so under a ``("pod", "data")`` mesh the
    region's reduction stays a single on-pod psum.  The cross-pod combine
    runs whenever a region reports (``ready`` at 1 buffered update),
    merging the region's delta scaled by its sample share of the
    federation and the staleness discount::

        params += (n_region / n_total) * (1 + s) ** -a * (params_r - anchor_r)

    No region ever waits for another — a straggling pod delays only its own
    (discounted) contribution.  ``R = 1`` makes the whole federation one
    region, which reproduces synchronous flat FedAvg exactly (sample share
    1, staleness 0): the subsystem's second parity anchor.
    """

    def __init__(self, num_regions: int = 2, staleness_exponent: float = 0.5) -> None:
        if int(num_regions) < 1:
            raise ValueError(f"hierarchical-async needs >= 1 region, got {num_regions}")
        if float(staleness_exponent) < 0:
            raise ValueError(
                f"hierarchical-async needs staleness_exponent >= 0, "
                f"got {staleness_exponent}"
            )
        self.num_regions = int(num_regions)
        self.staleness_exponent = float(staleness_exponent)

    def task_groups(self, federation_ids) -> list[np.ndarray]:
        ids = np.sort(np.asarray(federation_ids))
        parts = np.array_split(ids, min(self.num_regions, len(ids)))
        return [p for p in parts if len(p)]

    def ready(self, buffered: int) -> bool:
        return buffered >= 1

    def combine(self, params, updates, version, total_weight):
        if not (float(total_weight) > 0):
            raise ValueError(f"total_weight must be > 0, got {total_weight}")
        discounts = polynomial_staleness_weight(
            self.staleness_of(updates, version), self.staleness_exponent
        )
        coeffs = np.atleast_1d(discounts) * np.asarray(
            [u.weight / float(total_weight) for u in updates]
        )
        return _apply_deltas(params, updates, coeffs)
