"""Vectorized cohort training: one jitted vmap trains every participant.

The sequential engine (``repro.federated.client.LocalTrainer``) dispatches
one jitted step per client per batch from Python, so a round's wall clock
scales linearly with federation size.  Here the global parameters are
broadcast across a leading client axis and a whole FedAvg round — every
participant's ``local_epochs`` of AdamW steps — runs inside a single
``jax.lax.scan`` over a ``jax.vmap``-ed per-client step.

This engine is orchestrated by the ``repro.federated.api.Federation``
round program: one ``train_cohort`` call is one FedAvg-reduced group
("reduced"-mode aggregation; "grouped" aggregators like hierarchical
FedAvg call it once per regional sub-federation), so new policies compose
around the hot path without forking it.

Parity with the sequential oracle is exact by construction:

* batch data consumes the shared numpy RNG in the same client-major order
  the sequential loop does, so each client sees identical shuffled batches;
* each client's jax PRNG chain is advanced only on its *real* steps (dummy
  padding steps are masked to exact no-ops on params, optimizer state, and
  the key), so per-step dropout keys match the sequential path;
* aggregation is the same FedAvg weighted mean: per-chunk unnormalized
  weighted sums accumulated into a running pytree, normalized once at the
  end of the round.

Staging (``staging=``) controls how a round's batches reach the device:

* ``"rebuild"`` — PR 2's path: every round re-materializes the full
  ``(clients, steps, batch, *features)`` schedule in numpy
  (``repro.data.pipeline.build_cohort_schedule``) and uploads O(dataset)
  bytes host->device.
* ``"resident"`` — client train arrays are uploaded **once** per
  federation (``repro.data.device_cohort``, sharded over the mesh when one
  is given) and a round stages only a compact ``(C, T, B)`` int32 index
  plan drawn from the *same* RNG stream; the jitted round gathers each
  step's batch from the resident arrays on device (``jnp.take`` along the
  per-client sample axis), and the per-example mask is derived on device
  as ``sample_idx < n_c``.  Per-round host->device traffic drops from
  O(C*T*B*features) floats to O(C*T*B) int32s.  With ``prefetch`` (the
  default) a ``StagingPipeline`` builds and uploads chunk k+1's plan on a
  background thread while chunk k's donated step runs, and all host syncs
  (per-chunk loss fetches) are deferred to the end of the round so XLA
  dispatch stays ahead of the device.

Memory (the 189-client paper federation): the round step is jitted with
``donate_argnums`` so the cross-chunk accumulator is updated *in place*
(XLA aliases the donated input to the output — no second params-sized
buffer per chunk), and the chunk's staged device buffers are released the
moment the step that consumed them returns.  On TPU/GPU the staged buffers
are additionally marked donated so XLA can reuse their memory for round
temporaries; XLA:CPU cannot consume a donation with no aliasable output,
so there the eager release is the mechanism.  The resident cohort arrays
themselves are never donated — they live for the federation.  Peak
live-buffer footprint is tracked per round in ``last_round_stats`` (see
``repro.launch.hlo_analysis.live_buffer_stats``).

Multi-device: pass ``mesh`` (or the string ``"auto"`` to build a 1-D
``("data",)`` mesh over every local device) to shard the client axis with
``shard_map``.  Cohorts that do not divide the axis size are padded with
weight-0 dummy clients whose steps are all masked no-ops, and aggregation
is a single cross-shard ``psum`` of the per-shard weighted sums — the only
collective in the round.  ``cohort_chunk`` bounds peak memory by processing
participants in chunks through the same donated accumulator.
"""

from __future__ import annotations

import dataclasses
import functools
import sys
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.device_cohort import (
    DeviceCohort,
    build_cohort_plan,
    build_device_cohort,
    pad_cohort_plan,
)
from repro.data.pipeline import (
    ClientDataset,
    build_cohort_schedule,
    cohort_steps_per_epoch,
    local_round_steps,
    pad_cohort_schedule,
)
from repro.federated.fedavg import weighted_sum_stacked
from repro.federated.staging import StagingPipeline
from repro.launch.hlo_analysis import live_buffer_stats
from repro.obs.trace import resolve_tracer
from repro.optim.adamw import AdamW, apply_updates
from repro.privacy.dp import DPConfig, dp_value_and_grad, resolve_dp

PyTree = Any
LossFn = Callable[..., Any]  # loss(params, batch, rng) -> scalar

STAGING_MODES = ("rebuild", "resident")


@functools.partial(jax.jit, static_argnums=1)
def _chain_split(key_data, n: int):
    def step(kd, _):
        ks = jax.random.split(jax.random.wrap_key_data(kd))
        return jax.random.key_data(ks[0]), jax.random.key_data(ks[1])

    return jax.lax.scan(step, key_data, None, length=n)


def chain_split_keys(key: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """``n`` sequential ``jax.random.split`` calls in one jitted scan.

    Bit-identical to the Python loop ``key, sub = jax.random.split(key)``
    repeated ``n`` times (the sequential server's per-client key chain), but
    one dispatch instead of ``n`` — at 189 clients the chained host loop
    costs ~0.2s per round, a measurable slice of a vectorized round.
    Returns the advanced key and the ``(n, ...)`` stacked sub-key data.
    The stacked data stays on device — the vectorized engine consumes it
    there, so round-tripping it through numpy would cost a device sync and
    a re-upload per round.
    """
    kd, subs = _chain_split(jax.random.key_data(key), n)
    return jax.random.wrap_key_data(kd), subs


@dataclasses.dataclass
class CohortTrainer:
    """Trains a whole cohort of clients per round in one jitted computation."""

    loss_fn: LossFn
    optimizer: AdamW
    batch_size: int
    local_epochs: int
    # Max clients per vmapped call; None = the whole cohort at once.
    cohort_chunk: int | None = None
    # Optional device mesh: shard the client axis over its "data" axis.
    # "auto" builds a ("data",) mesh over every local device (None if only
    # one device is visible — the degenerate mesh buys nothing).
    mesh: Any = None
    # Donate round buffers to the jitted step: the cross-chunk accumulator
    # is aliased in place and each chunk's staged buffers are released as
    # soon as the step consuming them returns.  Turn off only to diff
    # memory behavior.
    donate: bool = True
    # "rebuild" re-materializes and re-uploads the full batch schedule each
    # round (PR 2's path, kept as the staging reference); "resident" keeps
    # client data on device for the federation's lifetime and stages only
    # int32 index plans.  FederatedServer defaults to "resident".
    staging: str = "rebuild"
    # Resident staging: build/upload chunk k+1's plan on a background
    # thread while chunk k trains (double buffering).  Only engages when a
    # round has more than one chunk; numerically a no-op either way.
    prefetch: bool = True
    # Resident staging at population scale: bound the device cohort to this
    # many bytes.  When the full federation exceeds the budget, client rows
    # live in an LRU pool and only each round's cohort is uploaded
    # (repro.data.device_cohort.ensure_resident).  None = bake everything.
    resident_budget_bytes: int | None = None
    # Select a chunk whose client_rows are contiguous (and shard-aligned
    # under a mesh) with a static lax.slice instead of a row gather —
    # jnp.take with arbitrary indices forces GSPMD into a cross-shard
    # gather; a static slice partitions natively.  Off only for parity
    # diffing; numerically identical either way.
    slice_fastpath: bool = True
    # Sample live-buffer peaks into last_round_stats (two process-wide
    # jax.live_arrays() walks per chunk).  Cheap, but disable on
    # latency-critical loops that never read the stats.
    track_stats: bool = True
    # In-jit DP-SGD: per-example clipping + Gaussian noise inside the
    # jitted step (repro.privacy.dp).  None (the default) builds the
    # original step closure untouched — the unprotected hot path stays
    # bitwise identical.  Accepts a DPConfig or a job-spec dict.
    dp: DPConfig | None = None
    # Observability: a repro.obs Tracer records per-chunk "stage" spans
    # (on the staging track, whichever thread stages) and flows down to
    # the device-cohort pool.  None resolves to the shared no-op tracer.
    tracer: Any = None
    # Peak live-buffer footprint + staging accounting of the most recent
    # train_cohort call, populated after every round.
    last_round_stats: dict[str, Any] | None = dataclasses.field(default=None, init=False)

    def __post_init__(self) -> None:
        self.tracer = resolve_tracer(self.tracer)
        if self.staging not in STAGING_MODES:
            raise ValueError(
                f"unknown staging {self.staging!r}; choose from {STAGING_MODES}"
            )
        if isinstance(self.mesh, str):
            if self.mesh != "auto":
                raise ValueError(f"mesh must be a Mesh, None, or 'auto'; got {self.mesh!r}")
            from repro.launch.mesh import make_data_mesh

            self.mesh = make_data_mesh() if jax.device_count() > 1 else None
        mesh = self.mesh if self.mesh is not None and "data" in self.mesh.axis_names else None
        self._data_mesh = mesh
        self._num_shards = int(mesh.shape["data"]) if mesh is not None else 1
        self._device_cohort: DeviceCohort | None = None
        self.dp = resolve_dp(self.dp)

        if self.dp is None:

            def client_step(params, opt_state, key_data, batch, valid):
                """One masked local step; dummy steps are exact no-ops."""
                keys = jax.random.split(jax.random.wrap_key_data(key_data))
                loss, grads = jax.value_and_grad(self.loss_fn)(params, batch, keys[1])
                updates, opt_new = self.optimizer.update(grads, opt_state, params)
                params_new = apply_updates(params, updates)
                keep = lambda new, old: jnp.where(valid, new, old)
                params = jax.tree.map(keep, params_new, params)
                opt_state = jax.tree.map(keep, opt_new, opt_state)
                key_data = jnp.where(valid, jax.random.key_data(keys[0]), key_data)
                return params, opt_state, key_data, jnp.where(valid, loss, jnp.nan)

        else:
            dp_grad = dp_value_and_grad(self.loss_fn, self.dp)

            def client_step(params, opt_state, key_data, batch, valid):
                """One masked DP-SGD local step: clip per example, noise in-jit.

                The chain key splits 3 ways (next-chain, dropout, noise) so
                noise draws ride the same per-client key chain as dropout —
                seeded DP runs replay bit-identically.  Dummy steps stay
                exact no-ops: the key only advances on valid steps.
                """
                keys = jax.random.split(jax.random.wrap_key_data(key_data), 3)
                loss, grads = dp_grad(params, batch, keys[1], keys[2])
                updates, opt_new = self.optimizer.update(grads, opt_state, params)
                params_new = apply_updates(params, updates)
                keep = lambda new, old: jnp.where(valid, new, old)
                params = jax.tree.map(keep, params_new, params)
                opt_state = jax.tree.map(keep, opt_new, opt_state)
                key_data = jnp.where(valid, jax.random.key_data(keys[0]), key_data)
                return params, opt_state, key_data, jnp.where(valid, loss, jnp.nan)

        def train_one(params, x_c, y_c, m_c, v_c, key_data):
            """All local epochs for one client: a scan over the step axis."""
            opt_state = self.optimizer.init(params)

            def step(carry, inp):
                p, s, kd = carry
                xb, yb, mb, valid = inp
                p, s, kd, loss = client_step(p, s, kd, (xb, yb, mb), valid)
                return (p, s, kd), loss

            (params, _, _), losses = jax.lax.scan(
                step, (params, opt_state, key_data), (x_c, y_c, m_c, v_c)
            )
            return params, losses

        def train_one_resident(params, x_c, y_c, idx_c, v_c, key_data, n_c):
            """All local epochs for one client, gathering batches on device.

            ``x_c``/``y_c`` are the client's resident ``(max_n + 1, ...)``
            arrays; each scan step gathers its ``(B, ...)`` batch by index
            and derives the example mask as ``idx < n_c`` (padding slots
            point at the all-zero pad row, so the gathered batch is
            bit-identical to the rebuilt schedule's)."""
            opt_state = self.optimizer.init(params)

            def step(carry, inp):
                p, s, kd = carry
                ib, valid = inp
                batch = (
                    jnp.take(x_c, ib, axis=0),
                    jnp.take(y_c, ib, axis=0),
                    (ib < n_c).astype(jnp.float32),
                )
                p, s, kd, loss = client_step(p, s, kd, batch, valid)
                return (p, s, kd), loss

            (params, _, _), losses = jax.lax.scan(
                step, (params, opt_state, key_data), (idx_c, v_c)
            )
            return params, losses

        def train_block(params, x, y, mask, valid, key_data, weights, axis_name=None):
            """Train a block of clients and reduce to one weighted param sum.

            Inside shard_map each device holds one client shard and
            ``axis_name`` folds the cross-shard reduction into the same
            weighted sum — one psum of a params-sized tree, the round's
            only collective."""
            stacked, losses = jax.vmap(
                lambda xc, yc, mc, vc, kd: train_one(params, xc, yc, mc, vc, kd)
            )(x, y, mask, valid, key_data)
            return weighted_sum_stacked(stacked, weights, axis_name=axis_name), losses

        def train_block_resident(
            params, x, y, idx, valid, key_data, weights, axis_name=None
        ):
            stacked, losses = jax.vmap(
                lambda xc, yc, ic, vc, kd, nc: train_one_resident(
                    params, xc, yc, ic, vc, kd, nc
                )
            )(x, y, idx, valid, key_data, weights)
            return weighted_sum_stacked(stacked, weights, axis_name=axis_name), losses

        if mesh is not None:
            from jax.experimental.shard_map import shard_map

            sharded = functools.partial(
                shard_map,
                mesh=mesh,
                in_specs=(
                    P(), P("data"), P("data"), P("data"), P("data"), P("data"), P("data"),
                ),
                out_specs=(P(), P("data")),
                check_rep=False,
            )
            train_block = sharded(functools.partial(train_block, axis_name="data"))
            train_block_resident = sharded(
                functools.partial(train_block_resident, axis_name="data")
            )

        def per_client_losses(losses, valid):
            # Per-client mean loss over the LAST epoch's real steps (matching
            # the sequential LocalTrainer's reported loss).
            spe = losses.shape[1] // self.local_epochs
            last, last_valid = losses[:, -spe:], valid[:, -spe:]
            count = jnp.maximum(last_valid.sum(axis=1), 1)
            return jnp.where(last_valid, last, 0.0).sum(axis=1) / count

        def cohort_round(params, acc, x, y, mask, valid, key_data, weights):
            wsum, losses = train_block(params, x, y, mask, valid, key_data, weights)
            acc = jax.tree.map(jnp.add, acc, wsum)
            return acc, per_client_losses(losses, valid)

        def resident_block(params, acc, x_sel, y_sel, idx, valid, key_data, weights):
            wsum, losses = train_block_resident(
                params, x_sel, y_sel, idx, valid, key_data, weights
            )
            acc = jax.tree.map(jnp.add, acc, wsum)
            return acc, per_client_losses(losses, valid)

        def cohort_round_resident(
            params, acc, x_all, y_all, rows, idx, valid, key_data, weights
        ):
            # Select the chunk's client rows from the resident arrays on
            # device (under a mesh this is a GSPMD gather from the sharded
            # federation arrays, re-laid-out onto the cohort's data axis).
            x_sel = jnp.take(x_all, rows, axis=0)
            y_sel = jnp.take(y_all, rows, axis=0)
            if mesh is not None:
                sharding = NamedSharding(mesh, P("data"))
                x_sel = jax.lax.with_sharding_constraint(x_sel, sharding)
                y_sel = jax.lax.with_sharding_constraint(y_sel, sharding)
            return resident_block(params, acc, x_sel, y_sel, idx, valid, key_data, weights)

        def cohort_round_resident_full(params, acc, x_all, y_all, idx, valid, key_data, weights):
            # Full-cohort fast path: the chunk IS the resident federation in
            # row order (every all-participants round), so the row gather —
            # a round-sized device copy — is skipped and the resident
            # arrays feed the vmap directly.
            return resident_block(params, acc, x_all, y_all, idx, valid, key_data, weights)

        def cohort_round_resident_slice(
            params, acc, x_all, y_all, idx, valid, key_data, weights, start
        ):
            # Static-slice fast path: this chunk's client rows are the
            # contiguous run [start, start + C), so select them with a
            # static lax.slice.  ``start`` is a static argnum (one compile
            # per distinct chunk offset — a handful, reused every round):
            # the partitioner sees literal slice bounds and keeps a
            # shard-aligned chunk local instead of emitting the cross-shard
            # gather that jnp.take's arbitrary indices force.
            n = idx.shape[0]
            x_sel = jax.lax.slice_in_dim(x_all, start, start + n, axis=0)
            y_sel = jax.lax.slice_in_dim(y_all, start, start + n, axis=0)
            if mesh is not None:
                sharding = NamedSharding(mesh, P("data"))
                x_sel = jax.lax.with_sharding_constraint(x_sel, sharding)
                y_sel = jax.lax.with_sharding_constraint(y_sel, sharding)
            return resident_block(params, acc, x_sel, y_sel, idx, valid, key_data, weights)

        # Donation layout: the accumulator (argnum 1) aliases in place
        # everywhere; on TPU/GPU the per-round staged buffers are donated
        # too so XLA reuses their memory for round temporaries (XLA:CPU
        # warns on and ignores donations it cannot alias to an output).
        # The resident cohort arrays (argnums 2-3 of the resident round)
        # are never donated — they outlive every round.
        donate_argnums: tuple[int, ...] = ()
        donate_staged = self.donate and jax.default_backend() != "cpu"
        if self.donate:
            donate_argnums = (1,)
            if donate_staged:
                donate_argnums += (
                    (4, 5, 6, 7, 8) if self.staging == "resident" else (2, 3, 4, 5, 6, 7)
                )
        self._round = jax.jit(
            cohort_round_resident if self.staging == "resident" else cohort_round,
            donate_argnums=donate_argnums,
        )
        if self.staging == "resident":
            # signature drops the rows arg: staged buffers sit at 4..7
            full_donate: tuple[int, ...] = (1,) if self.donate else ()
            if donate_staged:
                full_donate += (4, 5, 6, 7)
            self._round_full = jax.jit(
                cohort_round_resident_full, donate_argnums=full_donate
            )
            # same staged layout as _round_full plus the static slice start
            self._round_slice = jax.jit(
                cohort_round_resident_slice,
                donate_argnums=full_donate,
                static_argnums=8,
            )

    # ------------------------------------------------------------------
    # staging helpers
    # ------------------------------------------------------------------

    def attach_device_cohort(self, clients: Sequence[ClientDataset]) -> DeviceCohort:
        """Upload a federation's train arrays once for resident staging.

        Rounds over any subset of ``clients`` then stage only index plans.
        ``FederatedServer`` calls this with the (possibly recruited)
        federation before round one; direct ``train_cohort`` callers may
        skip it, in which case the first resident round attaches its own
        cohort lazily.  With ``resident_budget_bytes`` set and a federation
        too large for it, the cohort is an LRU pool and rounds upload only
        their sampled clients.
        """
        self._device_cohort = build_device_cohort(
            clients,
            mesh=self._data_mesh,
            resident_budget_bytes=self.resident_budget_bytes,
            tracer=self.tracer,
        )
        return self._device_cohort

    def _ensure_device_cohort(self, clients: Sequence[ClientDataset]) -> DeviceCohort:
        dc = self._device_cohort
        if dc is not None and all(dc.owns(c) for c in clients):
            return dc
        return self.attach_device_cohort(clients)

    def _device_put_chunk(self, arrays: tuple) -> tuple:
        """Stage one chunk's host arrays in a single pytree ``device_put``,
        sharded over the mesh's data axis when one is present (every leaf
        carries the client axis first)."""
        if self._data_mesh is None:
            return jax.device_put(arrays)
        return jax.device_put(arrays, NamedSharding(self._data_mesh, P("data")))

    @staticmethod
    def _stack_key_data(client_keys) -> np.ndarray | jax.Array:
        """(C, ...) uint32 key data from typed keys, a key array, or raw data.

        Device inputs (the ``chain_split_keys`` output) stay on device —
        the round consumes them there."""
        if isinstance(client_keys, jax.Array) and jnp.issubdtype(
            client_keys.dtype, jax.dtypes.prng_key
        ):
            return jax.random.key_data(client_keys)
        if isinstance(client_keys, jax.Array):
            return client_keys
        if isinstance(client_keys, np.ndarray):
            return client_keys
        return np.stack([np.asarray(jax.random.key_data(k)) for k in client_keys])

    @staticmethod
    def _chunk_key_data(all_key_data, start: int, count: int, padded: int):
        """One chunk's key slice, zero-padded on the client axis to
        ``padded`` rows, staying on whichever side (host/device) the stacked
        keys already live.  The device path always materializes a fresh
        buffer: a full-range slice is an identity in jax, and the round
        step donates / eagerly deletes its staged inputs — handing it the
        caller's own array would destroy it as a side effect."""
        tail = all_key_data.shape[1:]
        if isinstance(all_key_data, jax.Array):
            sel = all_key_data[start : start + count]
            if padded == count:
                return jnp.copy(sel)
            return jnp.zeros((padded, *tail), all_key_data.dtype).at[:count].set(sel)
        out = np.zeros((padded, *tail), dtype=all_key_data.dtype)
        out[:count] = all_key_data[start : start + count]
        return out

    # ------------------------------------------------------------------
    # the round
    # ------------------------------------------------------------------

    def train_cohort(
        self,
        params: PyTree,
        clients: Sequence[ClientDataset],
        rng: np.random.Generator,
        client_keys: Sequence[jax.Array] | np.ndarray | jax.Array,
        steps_per_epoch: int | None = None,
    ) -> tuple[PyTree, np.ndarray, int]:
        """One FedAvg round over ``clients``.

        ``client_keys`` holds one jax PRNG key per client, in the same order
        the sequential engine would have split them — a list of typed keys,
        a typed key array, or the stacked ``(C, ...)`` key data straight
        from ``chain_split_keys`` (which stays on device).  Pass a
        federation-wide ``steps_per_epoch`` to pin the schedule's step axis
        across rounds — otherwise it tracks this cohort's largest client and
        a different participant mix can retrigger compilation.  Returns the
        round's aggregated params, per-client mean local losses, and the
        number of *real* (unpadded) local steps executed.
        """
        all_key_data = self._stack_key_data(client_keys)
        if len(clients) != len(all_key_data):
            raise ValueError("need exactly one PRNG key per client")
        sizes = [c.n_train for c in clients]
        spe = steps_per_epoch or cohort_steps_per_epoch(sizes, self.batch_size)
        if self.cohort_chunk is not None and self.cohort_chunk <= 0:
            raise ValueError(f"cohort_chunk must be positive, got {self.cohort_chunk}")
        chunk = self.cohort_chunk or len(clients)
        resident = self.staging == "resident"
        dcohort = self._ensure_device_cohort(clients) if resident else None
        pool_before = (0, 0, 0, 0)
        if resident and dcohort.is_pooled:
            # One residency pass per round, before any plan is staged: rows
            # are then stable for the whole round, so the prefetch thread's
            # plan building never races an eviction.
            pool_before = (
                dcohort.uploads,
                dcohort.evictions,
                dcohort.bytes_uploaded,
                dcohort.hits,
            )
            dcohort.ensure_resident(clients)

        baseline = live_buffer_stats() if self.track_stats else {"count": 0, "bytes": 0}
        peak = {"count": 0, "bytes": 0}

        def sample() -> None:
            if not self.track_stats:
                return
            now = live_buffer_stats()
            peak["count"] = max(peak["count"], now["count"] - baseline["count"])
            peak["bytes"] = max(peak["bytes"], now["bytes"] - baseline["bytes"])

        def _build_chunk(start: int) -> tuple[int, float, int, tuple, tuple]:
            """Build + upload one chunk's batch data.

            Returns (host bytes staged, chunk weight, real client count,
            (row-select path, slice start), device args for the round
            step).  Consumes ``rng`` — must run strictly in chunk order
            (the StagingPipeline's single ordered producer preserves this).
            """
            part = clients[start : start + chunk]
            if resident:
                plan = build_cohort_plan(
                    [c.n_train for c in part],
                    self.batch_size,
                    self.local_epochs,
                    rng,
                    steps_per_epoch=spe,
                    client_rows=[dcohort.row_of(c) for c in part],
                    pad_index=dcohort.pad_index,
                )
                weight = float(plan.weights.sum())
                plan = pad_cohort_plan(plan, self._num_shards, num_rows=dcohort.num_rows)
                key_data = self._chunk_key_data(
                    all_key_data, start, len(part), plan.num_clients
                )
                # Row-select path, best first: "full" — the chunk is the
                # whole resident federation in row order (every
                # all-participants round), no row select at all; "slice" —
                # the rows are one contiguous (and, under a mesh,
                # shard-aligned) run, a static lax.slice; "gather" — the
                # general jnp.take.
                full = plan.num_clients == dcohort.num_rows and np.array_equal(
                    plan.client_rows[: len(part)], np.arange(len(part))
                )
                kind, r0 = "gather", 0
                if full:
                    kind = "full"
                elif self.slice_fastpath:
                    r0 = int(plan.client_rows[0])
                    contiguous = np.array_equal(
                        plan.client_rows,
                        np.arange(
                            r0, r0 + plan.num_clients, dtype=plan.client_rows.dtype
                        ),
                    )
                    aligned = True
                    if self._num_shards > 1:
                        rps = dcohort.num_rows // self._num_shards
                        aligned = (
                            rps > 0
                            and r0 % rps == 0
                            and plan.num_clients % rps == 0
                        )
                    if contiguous and aligned:
                        kind = "slice"
                host: tuple = (plan.sample_idx, plan.step_valid, plan.weights)
                to_stage: tuple = (plan.sample_idx, plan.step_valid, key_data, plan.weights)
                if kind == "gather":
                    host = (plan.client_rows, *host)
                    to_stage = (plan.client_rows, *to_stage)
                staged = self._device_put_chunk(to_stage)
                path = (kind, r0)
            else:
                sched = build_cohort_schedule(
                    [c.train for c in part],
                    self.batch_size,
                    self.local_epochs,
                    rng,
                    steps_per_epoch=spe,
                )
                weight = float(sched.weights.sum())
                # Pad the client axis with weight-0 dummy clients so it
                # divides the mesh's data axis (all steps masked no-ops).
                sched = pad_cohort_schedule(sched, self._num_shards)
                key_data = self._chunk_key_data(
                    all_key_data, start, len(part), sched.num_clients
                )
                path = ("gather", 0)
                host = (sched.x, sched.y, sched.mask, sched.step_valid, sched.weights)
                staged = self._device_put_chunk(
                    (sched.x, sched.y, sched.mask, sched.step_valid, key_data, sched.weights)
                )
            nbytes = sum(a.nbytes for a in host)
            if isinstance(key_data, np.ndarray):
                nbytes += key_data.nbytes
            return nbytes, weight, len(part), path, staged

        def stage_chunk(start: int) -> tuple[int, float, int, tuple, tuple]:
            # The span lands on whichever thread stages — inline here, or
            # the StagingPipeline's producer during prefetch.
            with self.tracer.span("stage", track="staging", chunk=int(start)):
                return _build_chunk(start)

        acc = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.promote_types(p.dtype, jnp.float32)), params
        )
        total_weight = 0.0
        bytes_staged = 0
        num_chunks = 0
        # Per-chunk device loss arrays; fetched once after the whole round
        # is dispatched so chunk k+1 never blocks on chunk k's readback.
        chunk_losses: list[tuple[int, int, jax.Array]] = []
        starts = range(0, len(clients), chunk)
        pipeline: StagingPipeline | None = None
        if resident and self.prefetch and len(starts) > 1:
            pipeline = StagingPipeline(stage_chunk, starts, tracer=self.tracer)
            staged_chunks = iter(pipeline)
        else:
            staged_chunks = (stage_chunk(s) for s in starts)

        # Keeps the previous chunk's staged buffers alive into the next
        # iteration's first sample() so the plain (non-donated) path's
        # documented two-chunk window is actually observed in the stats.
        held: list[tuple] = []
        slice_chunks = 0
        try:
            for start, (nbytes, weight, count, path, args) in zip(starts, staged_chunks):
                total_weight += weight
                bytes_staged += nbytes
                # Sampled before the previous chunk's buffers (still
                # referenced by ``held`` on the non-donated path) are
                # released: the plain rebuild path holds two chunks of
                # schedule here, the donated path one.
                sample()
                held.clear()
                if resident:
                    kind, r0 = path
                    if kind == "full":
                        acc, losses = self._round_full(
                            params, acc, dcohort.x, dcohort.y, *args
                        )
                    elif kind == "slice":
                        slice_chunks += 1
                        acc, losses = self._round_slice(
                            params, acc, dcohort.x, dcohort.y, *args, r0
                        )
                    else:
                        acc, losses = self._round(params, acc, dcohort.x, dcohort.y, *args)
                else:
                    acc, losses = self._round(params, acc, *args)
                if self.donate:
                    # Realize the donation of the staged chunk: the step
                    # consumed it, free the device copies now instead of at
                    # Python GC time.  The resident cohort arrays are not
                    # part of ``args`` and stay alive.
                    for a in args:
                        if not a.is_deleted():
                            a.delete()
                sample()
                chunk_losses.append((start, count, losses))
                held.append(args)
                num_chunks += 1
        finally:
            if pipeline is not None:
                # Re-raise an uncollected staging exception only when this
                # round is not already propagating one — close() must never
                # mask the error that aborted the loop above.
                pipeline.close(raise_pending=sys.exc_info()[0] is None)

        per_losses = np.full(len(clients), np.nan, dtype=np.float32)
        for start, count, losses in chunk_losses:
            per_losses[start : start + count] = np.asarray(losses)[:count]

        new_params = jax.tree.map(
            lambda t, ref: (t / total_weight).astype(ref.dtype), acc, params
        )
        pooled = resident and dcohort.is_pooled
        self.last_round_stats = {
            "chunks": num_chunks,
            "shards": self._num_shards,
            "donated": self.donate,
            "staging": self.staging,
            "prefetch": pipeline is not None,
            "bytes_staged": bytes_staged,
            "bytes_resident": dcohort.nbytes if resident else 0,
            "plans_prefetched": pipeline.prefetched if pipeline is not None else 0,
            "peak_live_buffers": peak["count"],
            "peak_live_bytes": peak["bytes"],
            "slice_chunks": slice_chunks,
            "pool": pooled,
            "pool_rows": dcohort.pool_rows if pooled else 0,
            "pool_uploads": dcohort.uploads - pool_before[0] if pooled else 0,
            "pool_evictions": dcohort.evictions - pool_before[1] if pooled else 0,
            "pool_bytes_uploaded": dcohort.bytes_uploaded - pool_before[2] if pooled else 0,
            "pool_hits": dcohort.hits - pool_before[3] if pooled else 0,
        }
        real_steps = sum(local_round_steps(n, self.batch_size, self.local_epochs) for n in sizes)
        return new_params, per_losses, real_steps

    def steps_per_round(self, client: ClientDataset) -> int:
        return local_round_steps(client.n_train, self.batch_size, self.local_epochs)
