"""Vectorized cohort training: one jitted vmap trains every participant.

The sequential engine (``repro.federated.client.LocalTrainer``) dispatches
one jitted step per client per batch from Python, so a round's wall clock
scales linearly with federation size.  Here the global parameters are
broadcast across a leading client axis and a whole FedAvg round — every
participant's ``local_epochs`` of AdamW steps — runs inside a single
``jax.lax.scan`` over a ``jax.vmap``-ed per-client step, on a fixed-shape
``(clients, steps, batch, ...)`` schedule from
``repro.data.pipeline.build_cohort_schedule``.

Parity with the sequential oracle is exact by construction:

* the schedule consumes the shared numpy RNG in the same client-major order
  the sequential loop does, so each client sees identical shuffled batches;
* each client's jax PRNG chain is advanced only on its *real* steps (dummy
  padding steps are masked to exact no-ops on params, optimizer state, and
  the key), so per-step dropout keys match the sequential path;
* aggregation is the same FedAvg weighted mean, as one ``jnp.tensordot``
  over the stacked client axis.

Multi-device: pass ``mesh`` to shard the client axis over the mesh's
``data`` axis with ``shard_map`` (clients must divide the axis size).
``cohort_chunk`` bounds peak memory by processing participants in chunks
with an unnormalized weighted-sum accumulator across chunks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.data.pipeline import (
    ClientDataset,
    build_cohort_schedule,
    cohort_steps_per_epoch,
    local_round_steps,
)
from repro.federated.fedavg import weighted_sum_stacked
from repro.optim.adamw import AdamW, apply_updates

PyTree = Any
LossFn = Callable[..., Any]  # loss(params, batch, rng) -> scalar


@dataclasses.dataclass
class CohortTrainer:
    """Trains a whole cohort of clients per round in one jitted computation."""

    loss_fn: LossFn
    optimizer: AdamW
    batch_size: int
    local_epochs: int
    # Max clients per vmapped call; None = the whole cohort at once.
    cohort_chunk: int | None = None
    # Optional device mesh: shard the client axis over its "data" axis.
    mesh: Any = None

    def __post_init__(self) -> None:
        def client_step(params, opt_state, key_data, batch, valid):
            """One masked local step; dummy steps are exact no-ops."""
            keys = jax.random.split(jax.random.wrap_key_data(key_data))
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch, keys[1])
            updates, opt_new = self.optimizer.update(grads, opt_state, params)
            params_new = apply_updates(params, updates)
            keep = lambda new, old: jnp.where(valid, new, old)
            params = jax.tree.map(keep, params_new, params)
            opt_state = jax.tree.map(keep, opt_new, opt_state)
            key_data = jnp.where(valid, jax.random.key_data(keys[0]), key_data)
            return params, opt_state, key_data, jnp.where(valid, loss, jnp.nan)

        def train_one(params, x_c, y_c, m_c, v_c, key_data):
            """All local epochs for one client: a scan over the step axis."""
            opt_state = self.optimizer.init(params)

            def step(carry, inp):
                p, s, kd = carry
                xb, yb, mb, valid = inp
                p, s, kd, loss = client_step(p, s, kd, (xb, yb, mb), valid)
                return (p, s, kd), loss

            (params, _, _), losses = jax.lax.scan(
                step, (params, opt_state, key_data), (x_c, y_c, m_c, v_c)
            )
            return params, losses

        def train_stacked(params, x, y, mask, valid, key_data):
            return jax.vmap(
                lambda xc, yc, mc, vc, kd: train_one(params, xc, yc, mc, vc, kd)
            )(x, y, mask, valid, key_data)

        if self.mesh is not None and "data" in self.mesh.axis_names:
            from jax.experimental.shard_map import shard_map

            train_stacked = shard_map(
                train_stacked,
                mesh=self.mesh,
                in_specs=(P(), P("data"), P("data"), P("data"), P("data"), P("data")),
                out_specs=(P("data"), P("data")),
                check_rep=False,
            )

        def cohort_round(params, x, y, mask, valid, key_data, weights):
            stacked_params, losses = train_stacked(params, x, y, mask, valid, key_data)
            # Per-client mean loss over the LAST epoch's real steps (matching
            # the sequential LocalTrainer's reported loss).
            spe = losses.shape[1] // self.local_epochs
            last, last_valid = losses[:, -spe:], valid[:, -spe:]
            count = jnp.maximum(last_valid.sum(axis=1), 1)
            per_loss = jnp.where(last_valid, last, 0.0).sum(axis=1) / count
            return weighted_sum_stacked(stacked_params, weights), per_loss

        self._round = jax.jit(cohort_round)

    def train_cohort(
        self,
        params: PyTree,
        clients: Sequence[ClientDataset],
        rng: np.random.Generator,
        client_keys: Sequence[jax.Array],
        steps_per_epoch: int | None = None,
    ) -> tuple[PyTree, np.ndarray, int]:
        """One FedAvg round over ``clients``.

        ``client_keys`` holds one jax PRNG key per client, in the same order
        the sequential engine would have split them.  Pass a federation-wide
        ``steps_per_epoch`` to pin the schedule's step axis across rounds —
        otherwise it tracks this cohort's largest client and a different
        participant mix can retrigger compilation.  Returns the round's
        aggregated params, per-client mean local losses, and the number of
        *real* (unpadded) local steps executed.
        """
        if len(clients) != len(client_keys):
            raise ValueError("need exactly one PRNG key per client")
        sizes = [c.n_train for c in clients]
        spe = steps_per_epoch or cohort_steps_per_epoch(sizes, self.batch_size)
        chunk = self.cohort_chunk or len(clients)
        if chunk <= 0:
            raise ValueError(f"cohort_chunk must be positive, got {chunk}")

        acc: PyTree | None = None
        total_weight = 0.0
        per_losses = np.full(len(clients), np.nan, dtype=np.float32)
        for start in range(0, len(clients), chunk):
            part = clients[start : start + chunk]
            sched = build_cohort_schedule(
                [c.train for c in part],
                self.batch_size,
                self.local_epochs,
                rng,
                steps_per_epoch=spe,
            )
            key_data = jnp.stack(
                [jax.random.key_data(k) for k in client_keys[start : start + chunk]]
            )
            wsum, losses = self._round(
                params, sched.x, sched.y, sched.mask, sched.step_valid, key_data, sched.weights
            )
            acc = wsum if acc is None else jax.tree.map(jnp.add, acc, wsum)
            total_weight += float(sched.weights.sum())
            per_losses[start : start + len(part)] = np.asarray(losses)

        new_params = jax.tree.map(
            lambda t, ref: (t / total_weight).astype(ref.dtype), acc, params
        )
        real_steps = sum(local_round_steps(n, self.batch_size, self.local_epochs) for n in sizes)
        return new_params, per_losses, real_steps

    def steps_per_round(self, client: ClientDataset) -> int:
        return local_round_steps(client.n_train, self.batch_size, self.local_epochs)
