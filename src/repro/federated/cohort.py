"""Vectorized cohort training: one jitted vmap trains every participant.

The sequential engine (``repro.federated.client.LocalTrainer``) dispatches
one jitted step per client per batch from Python, so a round's wall clock
scales linearly with federation size.  Here the global parameters are
broadcast across a leading client axis and a whole FedAvg round — every
participant's ``local_epochs`` of AdamW steps — runs inside a single
``jax.lax.scan`` over a ``jax.vmap``-ed per-client step, on a fixed-shape
``(clients, steps, batch, ...)`` schedule from
``repro.data.pipeline.build_cohort_schedule``.

Parity with the sequential oracle is exact by construction:

* the schedule consumes the shared numpy RNG in the same client-major order
  the sequential loop does, so each client sees identical shuffled batches;
* each client's jax PRNG chain is advanced only on its *real* steps (dummy
  padding steps are masked to exact no-ops on params, optimizer state, and
  the key), so per-step dropout keys match the sequential path;
* aggregation is the same FedAvg weighted mean: per-chunk unnormalized
  weighted sums accumulated into a running pytree, normalized once at the
  end of the round.

Memory (the 189-client paper federation): the round step is jitted with
``donate_argnums`` so the cross-chunk accumulator is updated *in place*
(XLA aliases the donated input to the output — no second params-sized
buffer per chunk), and the chunk's device-resident schedule buffers are
released the moment the step that consumed them returns.  On TPU/GPU the
schedule buffers are additionally marked donated so XLA can reuse their
memory for round temporaries; XLA:CPU cannot consume a donation with no
aliasable output, so there the eager release is the mechanism.  Peak
live-buffer footprint is tracked per round in ``last_round_stats`` (see
``repro.launch.hlo_analysis.live_buffer_stats``) — the donated path holds
one chunk of schedule in device memory where the plain path holds two.

Multi-device: pass ``mesh`` (or the string ``"auto"`` to build a 1-D
``("data",)`` mesh over every local device) to shard the client axis with
``shard_map``.  Cohorts that do not divide the axis size are padded with
weight-0 dummy clients whose steps are all masked no-ops, and aggregation
is a single cross-shard ``psum`` of the per-shard weighted sums — the only
collective in the round.  ``cohort_chunk`` bounds peak memory by processing
participants in chunks through the same donated accumulator.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.pipeline import (
    ClientDataset,
    build_cohort_schedule,
    cohort_steps_per_epoch,
    local_round_steps,
    pad_cohort_schedule,
)
from repro.federated.fedavg import weighted_sum_stacked
from repro.launch.hlo_analysis import live_buffer_stats
from repro.optim.adamw import AdamW, apply_updates

PyTree = Any
LossFn = Callable[..., Any]  # loss(params, batch, rng) -> scalar


@functools.partial(jax.jit, static_argnums=1)
def _chain_split(key_data, n: int):
    def step(kd, _):
        ks = jax.random.split(jax.random.wrap_key_data(kd))
        return jax.random.key_data(ks[0]), jax.random.key_data(ks[1])

    return jax.lax.scan(step, key_data, None, length=n)


def chain_split_keys(key: jax.Array, n: int) -> tuple[jax.Array, np.ndarray]:
    """``n`` sequential ``jax.random.split`` calls in one jitted scan.

    Bit-identical to the Python loop ``key, sub = jax.random.split(key)``
    repeated ``n`` times (the sequential server's per-client key chain), but
    one dispatch instead of ``n`` — at 189 clients the chained host loop
    costs ~0.2s per round, a measurable slice of a vectorized round.
    Returns the advanced key and the ``(n, ...)`` stacked sub-key data.
    """
    kd, subs = _chain_split(jax.random.key_data(key), n)
    return jax.random.wrap_key_data(kd), np.asarray(subs)


@dataclasses.dataclass
class CohortTrainer:
    """Trains a whole cohort of clients per round in one jitted computation."""

    loss_fn: LossFn
    optimizer: AdamW
    batch_size: int
    local_epochs: int
    # Max clients per vmapped call; None = the whole cohort at once.
    cohort_chunk: int | None = None
    # Optional device mesh: shard the client axis over its "data" axis.
    # "auto" builds a ("data",) mesh over every local device (None if only
    # one device is visible — the degenerate mesh buys nothing).
    mesh: Any = None
    # Donate round buffers to the jitted step: the cross-chunk accumulator
    # is aliased in place and each chunk's schedule is released as soon as
    # the step consuming it returns.  Turn off only to diff memory behavior.
    donate: bool = True
    # Sample live-buffer peaks into last_round_stats (two process-wide
    # jax.live_arrays() walks per chunk).  Cheap, but disable on
    # latency-critical loops that never read the stats.
    track_stats: bool = True
    # Peak live-buffer footprint of the most recent train_cohort call
    # (deltas vs the call's entry), populated after every round.
    last_round_stats: dict[str, Any] | None = dataclasses.field(default=None, init=False)

    def __post_init__(self) -> None:
        if isinstance(self.mesh, str):
            if self.mesh != "auto":
                raise ValueError(f"mesh must be a Mesh, None, or 'auto'; got {self.mesh!r}")
            from repro.launch.mesh import make_data_mesh

            self.mesh = make_data_mesh() if jax.device_count() > 1 else None
        mesh = self.mesh if self.mesh is not None and "data" in self.mesh.axis_names else None
        self._num_shards = int(mesh.shape["data"]) if mesh is not None else 1

        def client_step(params, opt_state, key_data, batch, valid):
            """One masked local step; dummy steps are exact no-ops."""
            keys = jax.random.split(jax.random.wrap_key_data(key_data))
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch, keys[1])
            updates, opt_new = self.optimizer.update(grads, opt_state, params)
            params_new = apply_updates(params, updates)
            keep = lambda new, old: jnp.where(valid, new, old)
            params = jax.tree.map(keep, params_new, params)
            opt_state = jax.tree.map(keep, opt_new, opt_state)
            key_data = jnp.where(valid, jax.random.key_data(keys[0]), key_data)
            return params, opt_state, key_data, jnp.where(valid, loss, jnp.nan)

        def train_one(params, x_c, y_c, m_c, v_c, key_data):
            """All local epochs for one client: a scan over the step axis."""
            opt_state = self.optimizer.init(params)

            def step(carry, inp):
                p, s, kd = carry
                xb, yb, mb, valid = inp
                p, s, kd, loss = client_step(p, s, kd, (xb, yb, mb), valid)
                return (p, s, kd), loss

            (params, _, _), losses = jax.lax.scan(
                step, (params, opt_state, key_data), (x_c, y_c, m_c, v_c)
            )
            return params, losses

        def train_block(params, x, y, mask, valid, key_data, weights, axis_name=None):
            """Train a block of clients and reduce to one weighted param sum.

            Inside shard_map each device holds one client shard and
            ``axis_name`` folds the cross-shard reduction into the same
            weighted sum — one psum of a params-sized tree, the round's
            only collective."""
            stacked, losses = jax.vmap(
                lambda xc, yc, mc, vc, kd: train_one(params, xc, yc, mc, vc, kd)
            )(x, y, mask, valid, key_data)
            return weighted_sum_stacked(stacked, weights, axis_name=axis_name), losses

        if mesh is not None:
            from jax.experimental.shard_map import shard_map

            train_block = shard_map(
                functools.partial(train_block, axis_name="data"),
                mesh=mesh,
                in_specs=(
                    P(), P("data"), P("data"), P("data"), P("data"), P("data"), P("data"),
                ),
                out_specs=(P(), P("data")),
                check_rep=False,
            )

        def cohort_round(params, acc, x, y, mask, valid, key_data, weights):
            wsum, losses = train_block(params, x, y, mask, valid, key_data, weights)
            acc = jax.tree.map(jnp.add, acc, wsum)
            # Per-client mean loss over the LAST epoch's real steps (matching
            # the sequential LocalTrainer's reported loss).
            spe = losses.shape[1] // self.local_epochs
            last, last_valid = losses[:, -spe:], valid[:, -spe:]
            count = jnp.maximum(last_valid.sum(axis=1), 1)
            per_loss = jnp.where(last_valid, last, 0.0).sum(axis=1) / count
            return acc, per_loss

        donate_argnums: tuple[int, ...] = ()
        if self.donate:
            donate_argnums = (1,)  # the accumulator aliases in place everywhere
            if jax.default_backend() != "cpu":
                # XLA:CPU warns on (and ignores) donations it cannot alias to
                # an output; TPU/GPU reuse them for round temporaries.
                donate_argnums += (2, 3, 4, 5, 6, 7)
        self._round = jax.jit(cohort_round, donate_argnums=donate_argnums)

    def _device_schedule(self, sched, key_data: np.ndarray) -> tuple[jax.Array, ...]:
        """Move one chunk's schedule to device, sharded over the mesh if any."""
        arrays = (sched.x, sched.y, sched.mask, sched.step_valid, key_data, sched.weights)
        if self.mesh is None or "data" not in self.mesh.axis_names:
            return tuple(jax.device_put(a) for a in arrays)
        sharding = NamedSharding(self.mesh, P("data"))
        return tuple(jax.device_put(a, sharding) for a in arrays)

    @staticmethod
    def _stack_key_data(client_keys) -> np.ndarray:
        """(C, ...) uint32 key data from typed keys, a key array, or raw data."""
        if isinstance(client_keys, jax.Array) and jnp.issubdtype(
            client_keys.dtype, jax.dtypes.prng_key
        ):
            return np.asarray(jax.random.key_data(client_keys))
        if isinstance(client_keys, (np.ndarray, jax.Array)):
            return np.asarray(client_keys)
        return np.stack([np.asarray(jax.random.key_data(k)) for k in client_keys])

    def train_cohort(
        self,
        params: PyTree,
        clients: Sequence[ClientDataset],
        rng: np.random.Generator,
        client_keys: Sequence[jax.Array] | np.ndarray | jax.Array,
        steps_per_epoch: int | None = None,
    ) -> tuple[PyTree, np.ndarray, int]:
        """One FedAvg round over ``clients``.

        ``client_keys`` holds one jax PRNG key per client, in the same order
        the sequential engine would have split them — a list of typed keys,
        a typed key array, or the stacked ``(C, ...)`` key data straight
        from ``chain_split_keys``.  Pass a federation-wide
        ``steps_per_epoch`` to pin the schedule's step axis across rounds —
        otherwise it tracks this cohort's largest client and a different
        participant mix can retrigger compilation.  Returns the round's
        aggregated params, per-client mean local losses, and the number of
        *real* (unpadded) local steps executed.
        """
        all_key_data = self._stack_key_data(client_keys)
        if len(clients) != len(all_key_data):
            raise ValueError("need exactly one PRNG key per client")
        sizes = [c.n_train for c in clients]
        spe = steps_per_epoch or cohort_steps_per_epoch(sizes, self.batch_size)
        if self.cohort_chunk is not None and self.cohort_chunk <= 0:
            raise ValueError(f"cohort_chunk must be positive, got {self.cohort_chunk}")
        chunk = self.cohort_chunk or len(clients)

        baseline = live_buffer_stats() if self.track_stats else {"count": 0, "bytes": 0}
        peak = {"count": 0, "bytes": 0}

        def sample() -> None:
            if not self.track_stats:
                return
            now = live_buffer_stats()
            peak["count"] = max(peak["count"], now["count"] - baseline["count"])
            peak["bytes"] = max(peak["bytes"], now["bytes"] - baseline["bytes"])

        acc = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.promote_types(p.dtype, jnp.float32)), params
        )
        total_weight = 0.0
        per_losses = np.full(len(clients), np.nan, dtype=np.float32)
        num_chunks = 0
        args: tuple[jax.Array, ...] = ()
        for start in range(0, len(clients), chunk):
            part = clients[start : start + chunk]
            sched = build_cohort_schedule(
                [c.train for c in part],
                self.batch_size,
                self.local_epochs,
                rng,
                steps_per_epoch=spe,
            )
            total_weight += float(sched.weights.sum())
            # Pad the client axis with weight-0 dummy clients so it divides
            # the mesh's data axis (their steps are all masked no-ops).
            sched = pad_cohort_schedule(sched, self._num_shards)
            key_data = np.zeros(
                (sched.num_clients, *all_key_data.shape[1:]), dtype=all_key_data.dtype
            )
            key_data[: len(part)] = all_key_data[start : start + chunk]
            staged = self._device_schedule(sched, key_data)
            # Sampled before the previous chunk's buffers (still referenced by
            # ``args`` on the non-donated path) are released: the plain path
            # holds two chunks of schedule here, the donated path one.
            sample()
            args = staged
            acc, losses = self._round(params, acc, *args)
            if self.donate:
                # Realize the donation of the schedule: the step consumed it,
                # free the device copies now instead of at Python GC time.
                for a in args:
                    if not a.is_deleted():
                        a.delete()
            sample()
            per_losses[start : start + len(part)] = np.asarray(losses)[: len(part)]
            num_chunks += 1

        new_params = jax.tree.map(
            lambda t, ref: (t / total_weight).astype(ref.dtype), acc, params
        )
        self.last_round_stats = {
            "chunks": num_chunks,
            "shards": self._num_shards,
            "donated": self.donate,
            "peak_live_buffers": peak["count"],
            "peak_live_bytes": peak["bytes"],
        }
        real_steps = sum(local_round_steps(n, self.batch_size, self.local_epochs) for n in sizes)
        return new_params, per_losses, real_steps

    def steps_per_round(self, client: ClientDataset) -> int:
        return local_round_steps(client.n_train, self.batch_size, self.local_epochs)
