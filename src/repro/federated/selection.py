"""Per-round client selection.

The paper uses the standard FedAvg procedure: each round, either all clients
in the federation participate or a random subset (10% in their experiments)
is sampled uniformly without replacement.
"""

from __future__ import annotations

import numpy as np


def select_clients(
    rng: np.random.Generator,
    client_ids: np.ndarray,
    fraction: float | None = None,
    count: int | None = None,
) -> np.ndarray:
    """Uniform random subset of ``client_ids`` for one training round.

    Exactly one of ``fraction`` / ``count`` may be given; neither means all
    clients participate.  Sampling matches the paper: at least one client,
    without replacement.
    """
    client_ids = np.asarray(client_ids)
    if fraction is not None and count is not None:
        raise ValueError("give fraction or count, not both")
    if fraction is None and count is None:
        return client_ids.copy()
    if fraction is not None:
        if not (0.0 < fraction <= 1.0):
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        count = max(1, int(round(fraction * len(client_ids))))
    count = min(int(count), len(client_ids))
    return rng.choice(client_ids, size=count, replace=False)
