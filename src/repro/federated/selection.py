"""Per-round client selection primitives.

The paper uses the standard FedAvg procedure: each round, either all clients
in the federation participate or a random subset (10% in their experiments)
is sampled uniformly without replacement.  Policy classes live in
``repro.federated.api``; this module holds the pure sampling functions.

All selectors return participant ids in **sorted order**.  The participant
list is the cohort stacking order (and lands verbatim in
``RoundRecord.participant_ids``), so an unsorted ``rng.choice`` draw would
leak the draw order into results and records; sorting makes the cohort
layout a function of *which* clients were picked, not of how the sampler
happened to emit them.
"""

from __future__ import annotations

import numpy as np


def select_clients(
    rng: np.random.Generator,
    client_ids: np.ndarray,
    fraction: float | None = None,
    count: int | None = None,
) -> np.ndarray:
    """Uniform random subset of ``client_ids`` for one training round.

    Exactly one of ``fraction`` / ``count`` may be given; neither means all
    clients participate.  Sampling matches the paper: at least one client,
    without replacement.  Returns sorted ids.
    """
    client_ids = np.asarray(client_ids)
    if fraction is not None and count is not None:
        raise ValueError("give fraction or count, not both")
    if fraction is None and count is None:
        return np.sort(client_ids)
    if fraction is not None:
        if not (0.0 < fraction <= 1.0):
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        count = max(1, int(round(fraction * len(client_ids))))
    count = min(int(count), len(client_ids))
    return np.sort(rng.choice(client_ids, size=count, replace=False))


def round_robin_clients(
    round_index: int, client_ids: np.ndarray, count: int
) -> np.ndarray:
    """Deterministic rotation: round ``r`` takes the wrapped window of size
    ``count`` starting at ``(r * count) % N`` over the sorted ids.  Every
    client participates at least once per ``ceil(N / count)`` consecutive
    rounds — exactly once when ``count`` divides ``N``, otherwise the
    wrap-around window re-visits a few early ids each cycle.  No RNG is
    consumed.  Returns sorted ids.
    """
    ids = np.sort(np.asarray(client_ids))
    n = len(ids)
    if n == 0:
        raise ValueError("empty federation")
    count = max(1, min(int(count), n))
    start = (round_index * count) % n
    picked = np.take(ids, np.arange(start, start + count), mode="wrap")
    return np.sort(picked)
