"""FedAvg parameter aggregation (McMahan et al. 2017).

``aggregate`` is the server-side weighted average of client parameter
pytrees; weights default to local sample sizes n_c (the original FedAvg
weighting).  ``uniform`` weights reproduce plain parameter averaging.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def aggregate(params_list: Sequence[PyTree], weights: Sequence[float] | None = None) -> PyTree:
    """Weighted average of pytrees: sum_c w_c * params_c / sum_c w_c."""
    if not params_list:
        raise ValueError("nothing to aggregate")
    if weights is None:
        weights = [1.0] * len(params_list)
    w = np.asarray(weights, dtype=np.float64)
    if np.any(w < 0) or w.sum() <= 0:
        raise ValueError(f"invalid aggregation weights: {weights}")
    stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves), *params_list)
    return aggregate_stacked(stacked, (w / w.sum()).astype(np.float32))


def aggregate_stacked(stacked: PyTree, weights) -> PyTree:
    """FedAvg over a client-stacked pytree in one contraction per leaf.

    Every leaf carries a leading client axis; the weighted average is a
    single ``jnp.tensordot`` over that axis, which XLA fuses far better than
    a per-client Python loop.  Safe to call inside jit (no value-dependent
    validation); weights need not be pre-normalized.
    """
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)

    def _avg(leaf):
        # Contract in the leaf's own precision (promoted to at least f32)
        # so float64 params keep their full accuracy.
        ct = jnp.promote_types(leaf.dtype, jnp.float32)
        out = jnp.tensordot(w.astype(ct), leaf.astype(ct), axes=((0,), (0,)))
        return out.astype(leaf.dtype)

    return jax.tree.map(_avg, stacked)


def weighted_sum_stacked(stacked: PyTree, weights, axis_name: str | None = None) -> PyTree:
    """Unnormalized ``sum_c w_c * leaf_c`` — the chunked-cohort accumulator.

    Inside ``shard_map`` pass ``axis_name`` to fold the cross-shard reduction
    into the same contraction: each shard sums its local clients, then one
    ``psum`` of the params-sized tree completes the FedAvg numerator — the
    only collective a sharded cohort round needs.
    """
    w = jnp.asarray(weights, dtype=jnp.float32)

    def _sum(leaf):
        ct = jnp.promote_types(leaf.dtype, jnp.float32)
        out = jnp.tensordot(w.astype(ct), leaf.astype(ct), axes=((0,), (0,)))
        if axis_name is not None:
            out = jax.lax.psum(out, axis_name)
        return out

    return jax.tree.map(_sum, stacked)


def trimmed_mean_stacked(stacked: PyTree, trim: float) -> PyTree:
    """Coordinate-wise trimmed mean over the leading client axis.

    For every scalar coordinate, drop the ``floor(trim * C)`` smallest and
    largest client values and average the survivors — the classic robust
    aggregation rule (Yin et al. 2018).  Unweighted by construction (a
    weighted trim would let a heavy outlier buy its way back in);
    ``trim = 0`` degenerates to the plain coordinate mean.
    """
    if not (0.0 <= trim < 0.5):
        hint = (
            f" — did you mean trim={min(trim / 2, 0.45):g} "
            "(the fraction trimmed from *each* tail)?"
            if 0.5 <= trim < 1.0
            else (
                f" — to trim {trim:g} clients per tail out of C, pass "
                f"the fraction {trim:g}/C"
                if trim >= 1.0
                else ""
            )
        )
        raise ValueError(
            f"trim fraction must be in [0, 0.5), got {trim}: trimming half "
            f"or more from both tails leaves no clients{hint}"
        )

    def _trim(leaf):
        c = leaf.shape[0]
        # trim < 0.5 guarantees 2k < c, so at least one client survives.
        k = int(np.floor(trim * c))
        ct = jnp.promote_types(leaf.dtype, jnp.float32)
        kept = jnp.sort(leaf.astype(ct), axis=0)[k : c - k]
        return jnp.mean(kept, axis=0).astype(leaf.dtype)

    return jax.tree.map(_trim, stacked)


def delta(new: PyTree, old: PyTree) -> PyTree:
    return jax.tree.map(lambda a, b: a - b, new, old)


def apply_delta(params: PyTree, d: PyTree, scale: float = 1.0) -> PyTree:
    return jax.tree.map(lambda p, u: p + scale * u, params, d)


def tree_allclose(a: PyTree, b: PyTree, atol: float = 1e-6) -> bool:
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.allclose(x, y, atol=atol) for x, y in zip(leaves_a, leaves_b))


def params_nbytes(params: PyTree) -> int:
    return sum(int(p.size) * p.dtype.itemsize for p in jax.tree.leaves(params))
