"""Entry point for ``python -m repro.obs report <run_dir>``."""

from repro.obs.report import main

if __name__ == "__main__":
    raise SystemExit(main())
