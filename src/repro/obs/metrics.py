"""Typed metrics registry with a single JSON-safe ``snapshot()`` schema.

Absorbs the stack's ad-hoc stat dicts — ``CohortTrainer.last_round_stats``
staging/pool counters, async runtime task/drop tallies, comms byte
accounting, DP epsilon, per-round loss — into three primitive types:

- :class:`Counter` — monotone cumulative totals (bytes staged, uploads).
- :class:`Gauge` — last-written values (epsilon, resident bytes).
- :class:`Histogram` — count/sum/min/max/last over observations
  (round wall time, per-round loss, staleness).

``snapshot()`` returns plain ints/floats only, so it streams as one
``metrics.jsonl`` line per round next to ``records.jsonl`` and rides
inside federation snapshots (``load_snapshot`` restores it, letting a
resumed run continue the series instead of restarting counters at zero).
"""

from __future__ import annotations

import math
from typing import Any, Mapping


class Counter:
    """Monotone cumulative counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, amount: int | float = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease (inc by {amount})")
        self.value += amount


class Gauge:
    """Last-written value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Running count/sum/min/max/last over observed values."""

    __slots__ = ("name", "count", "sum", "min", "max", "last")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.last = 0.0

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        self.last = value

    def snapshot(self) -> dict[str, float]:
        out = {"count": self.count, "sum": self.sum, "last": self.last}
        if self.count:
            out["min"] = self.min
            out["max"] = self.max
            out["mean"] = self.sum / self.count
        return out


class MetricsRegistry:
    """Get-or-create registry of named, typed metrics.

    Re-requesting a name with a different type raises — the schema is
    part of the contract ``metrics.jsonl`` consumers rely on.
    """

    def __init__(self) -> None:
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, kind: type) -> Any:
        metric = self._metrics.get(name)
        if metric is None:
            metric = kind(name)
            self._metrics[name] = metric
        elif type(metric) is not kind:
            raise TypeError(
                f"metric {name!r} already registered as {type(metric).__name__}, "
                f"requested {kind.__name__}"
            )
        return metric

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    # ---- snapshot / restore ---------------------------------------------
    def snapshot(self) -> dict[str, Any]:
        counters: dict[str, Any] = {}
        gauges: dict[str, Any] = {}
        histograms: dict[str, Any] = {}
        for name, metric in sorted(self._metrics.items()):
            if isinstance(metric, Counter):
                counters[name] = metric.value
            elif isinstance(metric, Gauge):
                gauges[name] = metric.value
            else:
                histograms[name] = metric.snapshot()
        return {"counters": counters, "gauges": gauges, "histograms": histograms}

    def load_snapshot(self, state: Mapping[str, Any] | None) -> None:
        """Restore a prior ``snapshot()`` so a resumed run continues it."""
        if not state:
            return
        for name, value in state.get("counters", {}).items():
            counter = self.counter(name)
            counter.value = value
        for name, value in state.get("gauges", {}).items():
            self.gauge(name).set(value)
        for name, row in state.get("histograms", {}).items():
            hist = self.histogram(name)
            hist.count = int(row.get("count", 0))
            hist.sum = float(row.get("sum", 0.0))
            hist.last = float(row.get("last", 0.0))
            hist.min = float(row.get("min", math.inf))
            hist.max = float(row.get("max", -math.inf))
