"""Observability layer: spans, metrics, and profiling hooks.

Zero-dependency (stdlib-only at import time) tracing + metrics subsystem
threaded through the federated stack:

- :mod:`repro.obs.trace` — a bounded-ring span :class:`Tracer` with a
  Chrome/Perfetto ``trace.json`` exporter; :data:`NULL_TRACER` is the
  default everywhere so the instrumented-off hot path stays free.
- :mod:`repro.obs.metrics` — typed counters/gauges/histograms behind a
  :class:`MetricsRegistry` with a single ``snapshot()`` schema, streamed
  as ``metrics.jsonl`` by the control plane and carried inside federation
  snapshots so resume continues the series.
- :mod:`repro.obs.profile` — optional ``jax.profiler`` capture around
  designated rounds and compile-event capture (counts/times as metrics).

``python -m repro.obs report <run_dir>`` renders a per-phase time
breakdown and the top-k slowest clients from an exported trace.
"""

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import NULL_TRACER, NullTracer, SpanEvent, Tracer, resolve_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "SpanEvent",
    "Tracer",
    "resolve_tracer",
]
