"""Profiling hooks: ``jax.profiler`` round capture and compile-event metrics.

Two optional instruments, both wired through the job spec's strict
``observability`` section:

- :class:`RoundProfiler` captures a ``jax.profiler`` trace around the
  first N rounds of a run (the designated rounds), writing TensorBoard-
  loadable artifacts under ``<run_dir>/jax_profile``.
- :class:`CompileWatcher` registers a ``jax.monitoring`` listener and
  counts compile events and their durations, surfacing them as
  ``jit.compiles`` / ``jit.compile_time_s`` counters and a per-round
  ``jit.round_compiles`` gauge — hot-path recompilation becomes an
  assertable regression rather than a silent slowdown.

Both degrade to no-ops when jax is missing or the monitoring API is
unavailable, keeping ``repro.obs`` importable without jax.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro.obs.metrics import MetricsRegistry

# Defaults for the job spec's ``observability`` section.  ``None`` for the
# section itself means "observability off" (same tri-state contract as the
# ``privacy`` section).
OBSERVABILITY_DEFAULTS: dict[str, Any] = {
    "trace": True,
    "trace_capacity": 65536,
    "jax_profile_rounds": 0,
}


@dataclasses.dataclass(frozen=True)
class ObservabilityConfig:
    """Validated ``observability`` job-spec section."""

    trace: bool = True
    trace_capacity: int = 65536
    jax_profile_rounds: int = 0


def resolve_observability(section: Mapping[str, Any] | None) -> ObservabilityConfig | None:
    """Strictly validate an ``observability`` section (``None`` = off)."""
    if section is None:
        return None
    if not isinstance(section, Mapping):
        raise ValueError(f"observability section must be an object or null, got {section!r}")
    merged = dict(OBSERVABILITY_DEFAULTS)
    for key, value in section.items():
        if key not in OBSERVABILITY_DEFAULTS:
            raise ValueError(
                f"unknown observability key {key!r}; valid keys: "
                f"{sorted(OBSERVABILITY_DEFAULTS)}"
            )
        merged[key] = value
    if not isinstance(merged["trace"], bool):
        raise ValueError(f"observability.trace must be a bool, got {merged['trace']!r}")
    for key in ("trace_capacity", "jax_profile_rounds"):
        value = merged[key]
        if not isinstance(value, int) or isinstance(value, bool) or value < 0:
            raise ValueError(f"observability.{key} must be a non-negative int, got {value!r}")
    if merged["trace_capacity"] < 1:
        raise ValueError("observability.trace_capacity must be >= 1")
    return ObservabilityConfig(**merged)


class RoundProfiler:
    """Capture a ``jax.profiler`` trace around the first ``rounds`` rounds.

    ``round_start``/``round_end`` are called by the round program with the
    global round index; capture begins at the first observed round and
    stops after ``rounds`` rounds have ended (so a resumed run profiles
    its own first rounds, where recompilation would show up).
    """

    def __init__(self, rounds: int, log_dir: str):
        self.rounds = int(rounds)
        self.log_dir = str(log_dir)
        self._active = False
        self._seen = 0
        self._failed = False

    def round_start(self, round_index: int) -> None:
        if self._failed or self.rounds <= 0 or self._active or self._seen >= self.rounds:
            return
        try:
            import jax.profiler

            jax.profiler.start_trace(self.log_dir)
            self._active = True
        except Exception:
            # Missing profiler backend must never take down a training run.
            self._failed = True

    def round_end(self, round_index: int) -> None:
        if not self._active:
            return
        self._seen += 1
        if self._seen >= self.rounds:
            self.stop()

    def stop(self) -> None:
        if not self._active:
            return
        try:
            import jax.profiler

            jax.profiler.stop_trace()
        except Exception:
            pass
        self._active = False


# One process-global jax.monitoring listener fans out to live watchers;
# jax exposes register but no public unregister, so the listener is
# installed once and consults this list.
_ACTIVE_WATCHERS: list["CompileWatcher"] = []
_LISTENER_STATE = {"installed": False, "available": True}


def _install_listener() -> bool:
    if _LISTENER_STATE["installed"]:
        return True
    if not _LISTENER_STATE["available"]:
        return False
    try:
        import jax.monitoring

        def on_event(event: str, **kw: Any) -> None:
            if "compile" in event:
                for watcher in _ACTIVE_WATCHERS:
                    watcher.compiles += 1

        def on_duration(event: str, duration: float, **kw: Any) -> None:
            if "compile" in event:
                for watcher in _ACTIVE_WATCHERS:
                    watcher.compile_time_s += duration

        jax.monitoring.register_event_listener(on_event)
        jax.monitoring.register_event_duration_secs_listener(on_duration)
        _LISTENER_STATE["installed"] = True
        return True
    except Exception:
        _LISTENER_STATE["available"] = False
        return False


class CompileWatcher:
    """Count jax compile events/durations while active; feed a registry.

    Used as a context manager around a run's round loop; ``poll`` after
    each round folds deltas into ``jit.compiles`` / ``jit.compile_time_s``
    counters and sets the ``jit.round_compiles`` gauge so a steady-state
    round recompiling shows up as a nonzero gauge.
    """

    def __init__(self, metrics: MetricsRegistry | None):
        self.metrics = metrics
        self.compiles = 0
        self.compile_time_s = 0.0
        self._polled_compiles = 0
        self._polled_time_s = 0.0
        self.available = False

    def __enter__(self) -> "CompileWatcher":
        self.available = _install_listener()
        if self.available:
            _ACTIVE_WATCHERS.append(self)
        return self

    def __exit__(self, *exc_info: object) -> None:
        if self.available and self in _ACTIVE_WATCHERS:
            _ACTIVE_WATCHERS.remove(self)

    def poll(self) -> int:
        """Fold deltas since the last poll into the registry; return delta."""
        delta = self.compiles - self._polled_compiles
        delta_t = self.compile_time_s - self._polled_time_s
        self._polled_compiles = self.compiles
        self._polled_time_s = self.compile_time_s
        if self.metrics is not None:
            if delta:
                self.metrics.counter("jit.compiles").inc(delta)
            if delta_t > 0:
                self.metrics.counter("jit.compile_time_s").inc(delta_t)
            self.metrics.gauge("jit.round_compiles").set(delta)
        return delta
