"""``python -m repro.obs report <run_dir>``: render a run's telemetry.

Reads whatever observability artifacts the run directory holds —
``trace.json`` (Chrome trace events), ``metrics.jsonl`` (per-round
registry snapshots), ``records.jsonl`` (round records) — and prints a
per-phase time breakdown table plus the top-k slowest clients from the
virtual-clock task spans.  Robust to partial runs: each table is skipped
with a note when its source file is absent.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Iterable, TextIO

TRACE_FILE = "trace.json"
METRICS_FILE = "metrics.jsonl"
RECORDS_FILE = "records.jsonl"


def _fmt_table(rows: list[list[str]], header: list[str], out: TextIO) -> None:
    widths = [len(h) for h in header]
    for row in rows:
        widths = [max(w, len(cell)) for w, cell in zip(widths, row)]
    line = "  ".join(h.ljust(w) for h, w in zip(header, widths))
    out.write(line.rstrip() + "\n")
    out.write("  ".join("-" * w for w in widths) + "\n")
    for row in rows:
        out.write("  ".join(cell.ljust(w) for cell, w in zip(row, widths)).rstrip() + "\n")


def _load_trace_events(path: str) -> list[dict[str, Any]]:
    with open(path) as fh:
        doc = json.load(fh)
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else doc
    return [ev for ev in events if isinstance(ev, dict)]


def phase_breakdown(events: Iterable[dict[str, Any]]) -> dict[str, dict[str, dict[str, float]]]:
    """Per-clock (``cat``), per-phase-name count/total from complete spans."""
    out: dict[str, dict[str, dict[str, float]]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        clock = ev.get("cat", "host")
        row = out.setdefault(clock, {}).setdefault(ev["name"], {"count": 0, "total_s": 0.0})
        row["count"] += 1
        row["total_s"] += ev.get("dur", 0.0) / 1e6
    return out


def slowest_tracks(events: Iterable[dict[str, Any]], top_k: int) -> list[tuple[str, float, int]]:
    """Top-k tracks by total virtual 'task' span time (slowest clients)."""
    names: dict[tuple[int, int], str] = {}
    totals: dict[tuple[int, int], tuple[float, int]] = {}
    for ev in events:
        key = (ev.get("pid", 0), ev.get("tid", 0))
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[key] = ev.get("args", {}).get("name", str(key))
        elif ev.get("ph") == "X" and ev.get("cat") == "virtual" and ev.get("name") == "task":
            total, count = totals.get(key, (0.0, 0))
            totals[key] = (total + ev.get("dur", 0.0) / 1e6, count + 1)
    ranked = sorted(totals.items(), key=lambda kv: -kv[1][0])[:top_k]
    return [(names.get(key, str(key)), total, count) for key, (total, count) in ranked]


def _read_jsonl(path: str) -> list[dict[str, Any]]:
    rows = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def render_report(run_dir: str, top_k: int = 5, out: TextIO | None = None) -> int:
    out = out or sys.stdout
    if not os.path.isdir(run_dir):
        out.write(f"error: run dir not found: {run_dir}\n")
        return 2
    out.write(f"# observability report: {run_dir}\n")

    records_path = os.path.join(run_dir, RECORDS_FILE)
    if os.path.exists(records_path):
        records = _read_jsonl(records_path)
        total = sum(r.get("round_time_s", r.get("wall_time_s", 0.0)) for r in records)
        out.write(f"\nrounds: {len(records)}   total round time: {total:.3f}s\n")
    else:
        out.write(f"\n(no {RECORDS_FILE})\n")

    trace_path = os.path.join(run_dir, TRACE_FILE)
    if os.path.exists(trace_path):
        events = _load_trace_events(trace_path)
        breakdown = phase_breakdown(events)
        for clock in ("host", "virtual"):
            phases = breakdown.get(clock)
            if not phases:
                continue
            grand = sum(row["total_s"] for row in phases.values())
            out.write(f"\n## per-phase time breakdown ({clock} clock)\n")
            rows = [
                [
                    name,
                    f"{int(row['count'])}",
                    f"{row['total_s']:.4f}",
                    f"{100.0 * row['total_s'] / grand:.1f}%" if grand else "-",
                ]
                for name, row in sorted(phases.items(), key=lambda kv: -kv[1]["total_s"])
            ]
            _fmt_table(rows, ["phase", "count", "total_s", "share"], out)
        slow = slowest_tracks(events, top_k)
        if slow:
            out.write(f"\n## top-{top_k} slowest clients (virtual task time)\n")
            _fmt_table(
                [[track, f"{total:.4f}", f"{count}"] for track, total, count in slow],
                ["client", "task_s", "tasks"],
                out,
            )
    else:
        out.write(f"\n(no {TRACE_FILE}: submit with an 'observability' section to record spans)\n")

    metrics_path = os.path.join(run_dir, METRICS_FILE)
    if os.path.exists(metrics_path):
        lines = _read_jsonl(metrics_path)
        if lines:
            last = lines[-1]
            out.write(f"\n## final metrics snapshot ({len(lines)} rounds streamed)\n")
            rows = [[name, f"{value}"] for name, value in sorted(last.get("counters", {}).items())]
            rows += [[name, f"{value:.6g}"] for name, value in sorted(last.get("gauges", {}).items())]
            _fmt_table(rows, ["metric", "value"], out)
    else:
        out.write(f"\n(no {METRICS_FILE})\n")
    return 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs", description="Observability report tooling."
    )
    sub = parser.add_subparsers(dest="command", required=True)
    report = sub.add_parser("report", help="render a run directory's telemetry")
    report.add_argument("run_dir", help="run directory (job.json, records.jsonl, ...)")
    report.add_argument("--top", type=int, default=5, help="top-k slowest clients")
    args = parser.parse_args(argv)
    if args.command == "report":
        return render_report(args.run_dir, top_k=args.top)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
