"""Span tracer with a bounded ring and a Chrome/Perfetto exporter.

The tracer records three flavours of event into a fixed-capacity deque:

- **complete spans** — a name, a start time, a duration, and a track.
  Host-clock spans (``clock="host"``) are measured with
  ``time.perf_counter`` relative to the tracer's birth; virtual-clock
  spans (``clock="virtual"``) carry the discrete-event scheduler's
  simulated seconds so straggler latencies render on their own timeline.
- **instants** — zero-duration markers (flush points, pool uploads).
- **flows** — ``s``/``f`` arrow pairs linking a dispatch on the server
  track to the task it spawned on a per-client track.

``export_chrome`` writes the ring in Chrome trace-event JSON, loadable in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.  Host and
virtual clocks export as two separate processes so both timelines are
visible side by side; async tasks land on per-client tracks with flow
arrows from their dispatch, which makes straggler and dropout schedules
visually inspectable.

The default tracer everywhere is :data:`NULL_TRACER`, whose methods are
no-ops and whose ``span`` context manager is a shared singleton — the
instrumented-off overhead is a handful of attribute lookups per round.
"""

from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Any, Callable, Iterable

HOST_CLOCK = "host"
VIRTUAL_CLOCK = "virtual"

# Chrome trace-event phase codes used by the exporter.
_PH_COMPLETE = "X"
_PH_INSTANT = "i"
_PH_FLOW_START = "s"
_PH_FLOW_END = "f"
_PH_METADATA = "M"

# Stable pids for the two clock domains in the exported trace.
_PID_BY_CLOCK = {HOST_CLOCK: 1, VIRTUAL_CLOCK: 2}


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One ring entry: a complete span, an instant, or a flow endpoint."""

    name: str
    phase: str
    ts: float
    dur: float
    track: str
    clock: str
    args: dict[str, Any] | None = None
    flow_id: int | None = None


class _SpanContext:
    """Context manager that records a host-clock complete span on exit."""

    __slots__ = ("_tracer", "_name", "_track", "_args", "_start")

    def __init__(self, tracer: "Tracer", name: str, track: str, args: dict[str, Any] | None):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._args = args
        self._start = 0.0

    def __enter__(self) -> "_SpanContext":
        self._start = self._tracer.now()
        return self

    def __exit__(self, *exc_info: object) -> None:
        tracer = self._tracer
        tracer.complete(
            self._name,
            start=self._start,
            dur=tracer.now() - self._start,
            track=self._track,
            **(self._args or {}),
        )


class _NullContext:
    """Shared do-nothing context manager returned by the null tracer."""

    __slots__ = ()

    def __enter__(self) -> "_NullContext":
        return self

    def __exit__(self, *exc_info: object) -> None:
        return None


_NULL_CONTEXT = _NullContext()


class Tracer:
    """Bounded-ring span recorder.

    Appends are lock-free (``deque.append`` is atomic) so the staging
    producer thread may record spans concurrently with the round program.
    When the ring is full the oldest events are dropped and ``dropped``
    counts them (best effort under concurrency).
    """

    enabled = True

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._events: deque[SpanEvent] = deque(maxlen=self.capacity)
        self._birth = time.perf_counter()
        self.dropped = 0
        self._next_flow_id = 0

    # ---- clock ----------------------------------------------------------
    def now(self) -> float:
        """Seconds since tracer creation on the host clock."""
        return time.perf_counter() - self._birth

    def host_ts(self, perf_counter_value: float) -> float:
        """Convert a raw ``time.perf_counter()`` reading to tracer time."""
        return perf_counter_value - self._birth

    # ---- recording ------------------------------------------------------
    def _push(self, event: SpanEvent) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(event)

    def span(self, name: str, track: str = "server", **args: Any) -> _SpanContext:
        """Context manager recording a host-clock span around the body."""
        return _SpanContext(self, name, track, args or None)

    def wrap(self, name: str, track: str = "server") -> Callable:
        """Decorator form of :meth:`span`."""

        def decorate(fn: Callable) -> Callable:
            def wrapped(*a: Any, **kw: Any) -> Any:
                with self.span(name, track=track):
                    return fn(*a, **kw)

            wrapped.__name__ = getattr(fn, "__name__", name)
            wrapped.__doc__ = fn.__doc__
            return wrapped

        return decorate

    def complete(
        self,
        name: str,
        *,
        start: float,
        dur: float,
        track: str = "server",
        clock: str = HOST_CLOCK,
        **args: Any,
    ) -> None:
        """Record a complete span with explicit start/duration."""
        self._push(SpanEvent(name, _PH_COMPLETE, float(start), float(dur), track, clock, args or None))

    def instant(
        self,
        name: str,
        *,
        ts: float | None = None,
        track: str = "server",
        clock: str = HOST_CLOCK,
        **args: Any,
    ) -> None:
        """Record a zero-duration marker."""
        when = self.now() if ts is None else float(ts)
        self._push(SpanEvent(name, _PH_INSTANT, when, 0.0, track, clock, args or None))

    def new_flow_id(self) -> int:
        fid = self._next_flow_id
        self._next_flow_id = fid + 1
        return fid

    def flow_start(
        self, name: str, flow_id: int, *, ts: float, track: str = "server", clock: str = VIRTUAL_CLOCK
    ) -> None:
        self._push(SpanEvent(name, _PH_FLOW_START, float(ts), 0.0, track, clock, None, flow_id))

    def flow_end(
        self, name: str, flow_id: int, *, ts: float, track: str, clock: str = VIRTUAL_CLOCK
    ) -> None:
        self._push(SpanEvent(name, _PH_FLOW_END, float(ts), 0.0, track, clock, None, flow_id))

    # ---- inspection -----------------------------------------------------
    def events(self) -> list[SpanEvent]:
        return list(self._events)

    def spans(self, name: str | None = None, clock: str | None = None) -> list[SpanEvent]:
        """Complete spans, optionally filtered by name and clock."""
        out = []
        for ev in self._events:
            if ev.phase != _PH_COMPLETE:
                continue
            if name is not None and ev.name != name:
                continue
            if clock is not None and ev.clock != clock:
                continue
            out.append(ev)
        return out

    def summary(self) -> dict[str, dict[str, dict[str, float]]]:
        """Per-clock, per-name span counts and total seconds."""
        out: dict[str, dict[str, dict[str, float]]] = {}
        for ev in self._events:
            if ev.phase != _PH_COMPLETE:
                continue
            per_clock = out.setdefault(ev.clock, {})
            row = per_clock.setdefault(ev.name, {"count": 0, "total_s": 0.0})
            row["count"] += 1
            row["total_s"] += ev.dur
        return out

    # ---- export ---------------------------------------------------------
    def to_chrome(self) -> dict[str, Any]:
        """Render the ring as a Chrome trace-event document."""
        return events_to_chrome(self._events)

    def export_chrome(self, path: str) -> str:
        doc = self.to_chrome()
        with open(path, "w") as fh:
            json.dump(doc, fh)
            fh.write("\n")
        return path


class NullTracer(Tracer):
    """Do-nothing tracer: the default on every instrumented hot path."""

    enabled = False

    def __init__(self) -> None:
        super().__init__(capacity=1)

    def span(self, name: str, track: str = "server", **args: Any) -> _NullContext:  # type: ignore[override]
        return _NULL_CONTEXT

    def complete(self, name: str, **kw: Any) -> None:  # type: ignore[override]
        return None

    def instant(self, name: str, **kw: Any) -> None:  # type: ignore[override]
        return None

    def flow_start(self, name: str, flow_id: int, **kw: Any) -> None:  # type: ignore[override]
        return None

    def flow_end(self, name: str, flow_id: int, **kw: Any) -> None:  # type: ignore[override]
        return None

    def wrap(self, name: str, track: str = "server") -> Callable:  # type: ignore[override]
        def decorate(fn: Callable) -> Callable:
            return fn

        return decorate


NULL_TRACER = NullTracer()


def resolve_tracer(tracer: Tracer | None) -> Tracer:
    """``None`` means "not instrumented": substitute the shared null tracer."""
    return NULL_TRACER if tracer is None else tracer


def events_to_chrome(events: Iterable[SpanEvent]) -> dict[str, Any]:
    """Convert span events to the Chrome trace-event JSON document.

    Host-clock events export under pid 1 ("host clock"), virtual-clock
    events under pid 2 ("virtual clock"); each distinct track becomes a
    named thread so Perfetto renders per-client rows.  Timestamps are
    microseconds as the format requires.
    """
    trace_events: list[dict[str, Any]] = []
    tids: dict[tuple[int, str], int] = {}

    def tid_for(pid: int, track: str) -> int:
        key = (pid, track)
        if key not in tids:
            tid = len([k for k in tids if k[0] == pid]) + 1
            tids[key] = tid
            trace_events.append(
                {
                    "name": "thread_name",
                    "ph": _PH_METADATA,
                    "pid": pid,
                    "tid": tid,
                    "args": {"name": track},
                }
            )
        return tids[key]

    for pid, label in ((1, "host clock"), (2, "virtual clock")):
        trace_events.append(
            {"name": "process_name", "ph": _PH_METADATA, "pid": pid, "tid": 0, "args": {"name": label}}
        )

    for ev in events:
        pid = _PID_BY_CLOCK.get(ev.clock, 1)
        entry: dict[str, Any] = {
            "name": ev.name,
            "ph": ev.phase,
            "pid": pid,
            "tid": tid_for(pid, ev.track),
            "ts": ev.ts * 1e6,
            "cat": ev.clock,
        }
        if ev.phase == _PH_COMPLETE:
            entry["dur"] = ev.dur * 1e6
        if ev.phase == _PH_INSTANT:
            entry["s"] = "t"
        if ev.flow_id is not None:
            entry["id"] = ev.flow_id
            if ev.phase == _PH_FLOW_END:
                entry["bp"] = "e"
        if ev.args:
            entry["args"] = {k: _json_safe(v) for k, v in ev.args.items()}
        trace_events.append(entry)

    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def _json_safe(value: Any) -> Any:
    """Coerce numpy scalars/arrays in span args to plain JSON types."""
    if isinstance(value, (str, bool, int, float)) or value is None:
        return value
    if hasattr(value, "item") and not hasattr(value, "__len__"):
        return value.item()
    if isinstance(value, (list, tuple)) or hasattr(value, "tolist"):
        seq = value.tolist() if hasattr(value, "tolist") else list(value)
        return [_json_safe(v) for v in seq]
    return str(value)
