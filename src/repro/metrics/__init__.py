from repro.metrics.regression import evaluate_predictions, mae, mape, mse, msle
from repro.metrics.stats import welch_t_test, significance_stars

__all__ = [
    "evaluate_predictions",
    "mae",
    "mape",
    "mse",
    "msle",
    "welch_t_test",
    "significance_stars",
]
