"""Welch's t-test (no scipy offline) for the paper's significance stars.

The paper marks federated models vs. the standard approach (Federated-SC) at
the 5% (*) and 1% (**) levels across seeds.  We implement Welch's unequal-
variance t-test with a high-accuracy t-distribution CDF via the regularized
incomplete beta function (continued-fraction evaluation, Numerical Recipes
style) — pure numpy.
"""

from __future__ import annotations

import math

import numpy as np


def _betacf(a: float, b: float, x: float, max_iter: int = 200, eps: float = 3e-12) -> float:
    """Continued fraction for the incomplete beta function."""
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c = 1.0
    d = 1.0 - qab * x / qap
    if abs(d) < 1e-30:
        d = 1e-30
    d = 1.0 / d
    h = d
    for m in range(1, max_iter + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < 1e-30:
            d = 1e-30
        c = 1.0 + aa / c
        if abs(c) < 1e-30:
            c = 1e-30
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < 1e-30:
            d = 1e-30
        c = 1.0 + aa / c
        if abs(c) < 1e-30:
            c = 1e-30
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < eps:
            break
    return h


def _betainc(a: float, b: float, x: float) -> float:
    """Regularized incomplete beta I_x(a, b)."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_beta = math.lgamma(a + b) - math.lgamma(a) - math.lgamma(b)
    front = math.exp(ln_beta + a * math.log(x) + b * math.log(1.0 - x))
    if x < (a + 1.0) / (a + b + 2.0):
        return front * _betacf(a, b, x) / a
    return 1.0 - front * _betacf(b, a, 1.0 - x) / b


def t_sf(t: float, df: float) -> float:
    """Two-sided survival p-value for |T| >= |t| with df degrees of freedom."""
    x = df / (df + t * t)
    return _betainc(df / 2.0, 0.5, x)


def welch_t_test(a: np.ndarray, b: np.ndarray) -> tuple[float, float]:
    """Welch's t statistic and two-sided p-value for samples a vs b."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    na, nb = len(a), len(b)
    if na < 2 or nb < 2:
        return float("nan"), float("nan")
    va, vb = a.var(ddof=1), b.var(ddof=1)
    se2 = va / na + vb / nb
    if se2 == 0.0:
        return 0.0 if a.mean() == b.mean() else float("inf"), 1.0 if a.mean() == b.mean() else 0.0
    t = (a.mean() - b.mean()) / math.sqrt(se2)
    df = se2**2 / ((va / na) ** 2 / (na - 1) + (vb / nb) ** 2 / (nb - 1))
    return float(t), float(t_sf(abs(t), df))


def significance_stars(p: float) -> str:
    if math.isnan(p):
        return ""
    if p < 0.01:
        return "**"
    if p < 0.05:
        return "*"
    return ""
