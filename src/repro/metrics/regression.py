"""Paper section 4.5 evaluation metrics (eq. 6-7)."""

from __future__ import annotations

import numpy as np


def mae(y: np.ndarray, y_hat: np.ndarray) -> float:
    return float(np.mean(np.abs(y - y_hat)))


def mape(y: np.ndarray, y_hat: np.ndarray) -> float:
    return float(np.mean(np.abs((y - y_hat) / y)))


def mse(y: np.ndarray, y_hat: np.ndarray) -> float:
    return float(np.mean((y - y_hat) ** 2))


def msle(y: np.ndarray, y_hat: np.ndarray) -> float:
    return float(np.mean((np.log1p(y) - np.log1p(y_hat)) ** 2))


def evaluate_predictions(y: np.ndarray, y_hat: np.ndarray) -> dict[str, float]:
    y = np.asarray(y, dtype=np.float64)
    y_hat = np.asarray(y_hat, dtype=np.float64)
    return {
        "mae": mae(y, y_hat),
        "mape": mape(y, y_hat),
        "mse": mse(y, y_hat),
        "msle": msle(y, y_hat),
    }
