"""Jitted public wrapper: full GRU layer = hoisted MXU matmul + Pallas scan.

``interpret=True`` is forced on CPU (this container); on a real TPU the same
call compiles the Mosaic kernel.

``pallas_call`` has no reverse-mode rule, so the op carries a
``custom_vjp``: forward runs the kernel, backward recomputes through the
pure-jnp oracle (rematerialization — the standard pairing for hand-written
forward kernels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gru_scan.kernel import gru_scan
from repro.kernels.gru_scan.ref import gru_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.custom_vjp
def gru_scan_op(x_gates: jnp.ndarray, w_hh: jnp.ndarray, b_hh: jnp.ndarray) -> jnp.ndarray:
    return gru_scan(x_gates, w_hh, b_hh, interpret=not _on_tpu())


def _fwd(x_gates, w_hh, b_hh):
    return gru_scan_op(x_gates, w_hh, b_hh), (x_gates, w_hh, b_hh)


def _bwd(residuals, cotangent):
    x_gates, w_hh, b_hh = residuals
    _, vjp = jax.vjp(gru_scan_ref, x_gates, w_hh, b_hh)
    return vjp(cotangent)


gru_scan_op.defvjp(_fwd, _bwd)


def gru_sequence(
    x: jnp.ndarray,       # (B, T, F)
    w_ih: jnp.ndarray,    # (F, 3N)
    w_hh: jnp.ndarray,    # (N, 3N)
    b_ih: jnp.ndarray,    # (3N,)
    b_hh: jnp.ndarray,    # (3N,)
) -> jnp.ndarray:
    """Hidden sequence (B, T, N) for one GRU layer."""
    x_gates = x @ w_ih + b_ih  # one large MXU matmul over all timesteps
    return gru_scan_op(x_gates, w_hh, b_hh)
