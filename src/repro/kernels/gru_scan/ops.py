"""Jitted public wrapper: full GRU layer = hoisted MXU matmul + Pallas scan.

Backend selection lives in ``repro.kernels.backend``: interpret mode is
forced off-TPU, and ``REPRO_PALLAS_INTERPRET=1`` forces every path —
including the backward kernel — through interpret-mode ``pallas_call``.

``pallas_call`` has no reverse-mode rule, so the op carries a
``custom_vjp``.  The forward stashes its own output (the hidden-state
sequence) as the residual; the backward is then a *single* reverse-time
pass — the hand-written Pallas kernel on TPU, the pure-jnp
``gru_scan_bwd_ref`` reverse scan elsewhere.  Neither reruns the forward,
unlike the previous oracle-recompute pairing (``jax.vjp(gru_scan_ref)``),
which is kept below as ``gru_scan_oracle`` purely for benchmarking.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.gru_scan.kernel import gru_scan, gru_scan_bwd
from repro.kernels.gru_scan.ref import gru_scan_bwd_ref, gru_scan_ref


@jax.custom_vjp
def gru_scan_op(x_gates: jnp.ndarray, w_hh: jnp.ndarray, b_hh: jnp.ndarray) -> jnp.ndarray:
    return gru_scan(x_gates, w_hh, b_hh, interpret=backend.interpret())


def _fwd(x_gates, w_hh, b_hh):
    h_seq = gru_scan(x_gates, w_hh, b_hh, interpret=backend.interpret())
    return h_seq, (x_gates, w_hh, b_hh, h_seq)


def _bwd(residuals, cotangent):
    x_gates, w_hh, b_hh, h_seq = residuals
    if backend.pallas_backward():
        return gru_scan_bwd(
            x_gates, w_hh, b_hh, h_seq, cotangent, interpret=backend.interpret()
        )
    return gru_scan_bwd_ref(x_gates, w_hh, b_hh, h_seq, cotangent)


gru_scan_op.defvjp(_fwd, _bwd)


@jax.custom_vjp
def gru_scan_oracle(x_gates: jnp.ndarray, w_hh: jnp.ndarray, b_hh: jnp.ndarray) -> jnp.ndarray:
    """The pre-residual pairing (benchmark baseline only): Pallas forward,
    backward recomputes the whole forward through the jnp oracle and
    transposes it."""
    return gru_scan(x_gates, w_hh, b_hh, interpret=backend.interpret())


def _oracle_fwd(x_gates, w_hh, b_hh):
    return gru_scan_oracle(x_gates, w_hh, b_hh), (x_gates, w_hh, b_hh)


def _oracle_bwd(residuals, cotangent):
    _, vjp = jax.vjp(gru_scan_ref, *residuals)
    return vjp(cotangent)


gru_scan_oracle.defvjp(_oracle_fwd, _oracle_bwd)


def gru_sequence(
    x: jnp.ndarray,       # (B, T, F)
    w_ih: jnp.ndarray,    # (F, 3N)
    w_hh: jnp.ndarray,    # (N, 3N)
    b_ih: jnp.ndarray,    # (3N,)
    b_hh: jnp.ndarray,    # (3N,)
) -> jnp.ndarray:
    """Hidden sequence (B, T, N) for one GRU layer."""
    x_gates = x @ w_ih + b_ih  # one large MXU matmul over all timesteps
    return gru_scan_op(x_gates, w_hh, b_hh)
