"""Fused GRU recurrence — Pallas TPU kernel.

TPU adaptation of the paper's compute hot spot (the 2-layer GRU runs over
every ICU stay at every local client step).  The input projections
``x_t @ W_ih + b_ih`` for ALL timesteps are hoisted out of the recurrence as
one large MXU matmul (done in ops.py); the kernel then keeps the hidden
state ``h`` and the recurrent weights ``W_hh`` resident in VMEM and walks
the T timesteps with a ``fori_loop`` — the sequential part never round-trips
through HBM, which is what makes a recurrence bandwidth-hostile when
implemented naively.

Grid: batch tiles.  Per program instance the VMEM working set is
``(B_TILE, T, 3N) + (N, 3N) + (B_TILE, N)`` — for the paper's N=32 this is
a few hundred KB; B_TILE=128 keeps the per-step ``(B_TILE, N) @ (N, 3N)``
matmul MXU-shaped on the batch dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_kernel(xg_ref, w_hh_ref, b_hh_ref, out_ref):
    """xg: (B_TILE, T, 3N) precomputed input gates; out: (B_TILE, T, N)."""
    b_tile, t_len, three_n = xg_ref.shape
    n = three_n // 3
    w_hh = w_hh_ref[...].astype(jnp.float32)        # (N, 3N) resident in VMEM
    b_hh = b_hh_ref[...].astype(jnp.float32)        # (3N,)

    def step(t, h):
        gx = xg_ref[:, t, :].astype(jnp.float32)    # (B_TILE, 3N)
        gh = h @ w_hh + b_hh[None, :]
        xr, xz, xn = gx[:, :n], gx[:, n : 2 * n], gx[:, 2 * n :]
        hr, hz, hn = gh[:, :n], gh[:, n : 2 * n], gh[:, 2 * n :]
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        cand = jnp.tanh(xn + r * hn)
        h_new = (1.0 - z) * cand + z * h
        out_ref[:, t, :] = h_new.astype(out_ref.dtype)
        return h_new

    h0 = jnp.zeros((b_tile, n), dtype=jnp.float32)
    jax.lax.fori_loop(0, t_len, step, h0)


@functools.partial(jax.jit, static_argnames=("b_tile", "interpret"))
def gru_scan(
    x_gates: jnp.ndarray,   # (B, T, 3N) = x @ W_ih + b_ih
    w_hh: jnp.ndarray,      # (N, 3N)
    b_hh: jnp.ndarray,      # (3N,)
    *,
    b_tile: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Hidden-state sequence (B, T, N)."""
    b, t, three_n = x_gates.shape
    n = three_n // 3
    b_tile = min(b_tile, b)
    num_tiles = -(-b // b_tile)
    pad = num_tiles * b_tile - b
    if pad:
        x_gates = jnp.pad(x_gates, ((0, pad), (0, 0), (0, 0)))

    out = pl.pallas_call(
        _gru_kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((b_tile, t, three_n), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, three_n), lambda i: (0, 0)),
            pl.BlockSpec((three_n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b_tile, t, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_tiles * b_tile, t, n), x_gates.dtype),
        interpret=interpret,
    )(x_gates, w_hh, b_hh)
    return out[:b]
