"""Fused GRU recurrence — Pallas TPU kernel.

TPU adaptation of the paper's compute hot spot (the 2-layer GRU runs over
every ICU stay at every local client step).  The input projections
``x_t @ W_ih + b_ih`` for ALL timesteps are hoisted out of the recurrence as
one large MXU matmul (done in ops.py); the kernel then keeps the hidden
state ``h`` and the recurrent weights ``W_hh`` resident in VMEM and walks
the T timesteps with a ``fori_loop`` — the sequential part never round-trips
through HBM, which is what makes a recurrence bandwidth-hostile when
implemented naively.

Grid: batch tiles.  Per program instance the VMEM working set is
``(B_TILE, T, 3N) + (N, 3N) + (B_TILE, N)`` — for the paper's N=32 this is
a few hundred KB; B_TILE=128 keeps the per-step ``(B_TILE, N) @ (N, 3N)``
matmul MXU-shaped on the batch dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_kernel(xg_ref, w_hh_ref, b_hh_ref, out_ref):
    """xg: (B_TILE, T, 3N) precomputed input gates; out: (B_TILE, T, N)."""
    b_tile, t_len, three_n = xg_ref.shape
    n = three_n // 3
    w_hh = w_hh_ref[...].astype(jnp.float32)        # (N, 3N) resident in VMEM
    b_hh = b_hh_ref[...].astype(jnp.float32)        # (3N,)

    def step(t, h):
        gx = xg_ref[:, t, :].astype(jnp.float32)    # (B_TILE, 3N)
        gh = h @ w_hh + b_hh[None, :]
        xr, xz, xn = gx[:, :n], gx[:, n : 2 * n], gx[:, 2 * n :]
        hr, hz, hn = gh[:, :n], gh[:, n : 2 * n], gh[:, 2 * n :]
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        cand = jnp.tanh(xn + r * hn)
        h_new = (1.0 - z) * cand + z * h
        out_ref[:, t, :] = h_new.astype(out_ref.dtype)
        return h_new

    h0 = jnp.zeros((b_tile, n), dtype=jnp.float32)
    jax.lax.fori_loop(0, t_len, step, h0)


@functools.partial(jax.jit, static_argnames=("b_tile", "interpret"))
def gru_scan(
    x_gates: jnp.ndarray,   # (B, T, 3N) = x @ W_ih + b_ih
    w_hh: jnp.ndarray,      # (N, 3N)
    b_hh: jnp.ndarray,      # (3N,)
    *,
    b_tile: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Hidden-state sequence (B, T, N)."""
    b, t, three_n = x_gates.shape
    n = three_n // 3
    b_tile = min(b_tile, b)
    num_tiles = -(-b // b_tile)
    pad = num_tiles * b_tile - b
    if pad:
        x_gates = jnp.pad(x_gates, ((0, pad), (0, 0), (0, 0)))

    out = pl.pallas_call(
        _gru_kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((b_tile, t, three_n), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, three_n), lambda i: (0, 0)),
            pl.BlockSpec((three_n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b_tile, t, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_tiles * b_tile, t, n), x_gates.dtype),
        interpret=interpret,
    )(x_gates, w_hh, b_hh)
    return out[:b]


def _gru_bwd_kernel(xg_ref, w_hh_ref, b_hh_ref, h_ref, dy_ref, dxg_ref, dw_ref, db_ref):
    """Reverse-time backward over one batch tile.

    Gates are rebuilt from the stashed hidden states (one (B_TILE, N) @
    (N, 3N) matmul per step — the forward's own cost) instead of rerunning
    the forward scan.  Weight cotangents use the grid-reduction pattern:
    the dw/db output blocks ignore the tile index, so revisits are
    consecutive; tile 0 zero-initialises, every tile accumulates.
    """
    b_tile, t_len, three_n = xg_ref.shape
    n = three_n // 3
    tile = pl.program_id(0)
    w_hh = w_hh_ref[...].astype(jnp.float32)
    b_hh = b_hh_ref[...].astype(jnp.float32)

    @pl.when(tile == 0)
    def _zero_accumulators():
        dw_ref[...] = jnp.zeros_like(dw_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    def step(k, carry):
        dh, dw, db = carry
        t = t_len - 1 - k
        tm1 = jnp.maximum(t - 1, 0)
        gx = xg_ref[:, t, :].astype(jnp.float32)                      # (B, 3N)
        h_prev = jnp.where(t > 0, h_ref[:, tm1, :].astype(jnp.float32), 0.0)
        dy_t = dy_ref[:, t, :].astype(jnp.float32)
        gh = h_prev @ w_hh + b_hh[None, :]
        xr, xz, xn = gx[:, :n], gx[:, n : 2 * n], gx[:, 2 * n :]
        hr, hz, hn = gh[:, :n], gh[:, n : 2 * n], gh[:, 2 * n :]
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        cand = jnp.tanh(xn + r * hn)

        dh_total = dy_t + dh
        dz = dh_total * (h_prev - cand)
        da_n = dh_total * (1.0 - z) * (1.0 - cand * cand)
        da_r = da_n * hn * r * (1.0 - r)
        da_z = dz * z * (1.0 - z)
        d_gx = jnp.concatenate([da_r, da_z, da_n], axis=-1)           # (B, 3N)
        d_gh = jnp.concatenate([da_r, da_z, da_n * r], axis=-1)       # (B, 3N)
        dxg_ref[:, t, :] = d_gx.astype(dxg_ref.dtype)

        dh_new = dh_total * z + d_gh @ w_hh.T
        return dh_new, dw + h_prev.T @ d_gh, db + d_gh.sum(axis=0)

    carry0 = (
        jnp.zeros((b_tile, n), dtype=jnp.float32),
        jnp.zeros((n, three_n), dtype=jnp.float32),
        jnp.zeros((three_n,), dtype=jnp.float32),
    )
    _, dw_tile, db_tile = jax.lax.fori_loop(0, t_len, step, carry0)
    dw_ref[...] += dw_tile.astype(dw_ref.dtype)
    db_ref[...] += db_tile.astype(db_ref.dtype)


@functools.partial(jax.jit, static_argnames=("b_tile", "interpret"))
def gru_scan_bwd(
    x_gates: jnp.ndarray,   # (B, T, 3N)
    w_hh: jnp.ndarray,      # (N, 3N)
    b_hh: jnp.ndarray,      # (3N,)
    h_seq: jnp.ndarray,     # (B, T, N)  forward output (residual)
    dy: jnp.ndarray,        # (B, T, N)  output cotangent
    *,
    b_tile: int = 128,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-pass Pallas backward: ``(dx_gates, dw_hh, db_hh)``."""
    b, t, three_n = x_gates.shape
    n = three_n // 3
    b_tile = min(b_tile, b)
    num_tiles = -(-b // b_tile)
    pad = num_tiles * b_tile - b
    if pad:
        # Zero-padded rows contribute zero to every cotangent.
        x_gates = jnp.pad(x_gates, ((0, pad), (0, 0), (0, 0)))
        h_seq = jnp.pad(h_seq, ((0, pad), (0, 0), (0, 0)))
        dy = jnp.pad(dy, ((0, pad), (0, 0), (0, 0)))

    dxg, dw_hh, db_hh = pl.pallas_call(
        _gru_bwd_kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((b_tile, t, three_n), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, three_n), lambda i: (0, 0)),
            pl.BlockSpec((three_n,), lambda i: (0,)),
            pl.BlockSpec((b_tile, t, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((b_tile, t, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b_tile, t, three_n), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, three_n), lambda i: (0, 0)),
            pl.BlockSpec((three_n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles * b_tile, t, three_n), x_gates.dtype),
            jax.ShapeDtypeStruct((n, three_n), jnp.float32),
            jax.ShapeDtypeStruct((three_n,), jnp.float32),
        ],
        interpret=interpret,
    )(x_gates, w_hh, b_hh, h_seq, dy)
    return dxg[:b], dw_hh.astype(w_hh.dtype), db_hh.astype(b_hh.dtype)
