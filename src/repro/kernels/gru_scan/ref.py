"""Pure-jnp oracle for the fused GRU recurrence kernel.

``gru_scan_ref`` is the forward oracle.  ``gru_scan_bwd_ref`` is the
hand-derived residual backward: given the forward's own hidden-state
sequence as the residual, one reverse-time ``lax.scan`` produces all three
cotangents — no forward recompute, unlike the ``jax.vjp(gru_scan_ref, ...)``
oracle pairing it replaces on the hot path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gru_scan_ref(x_gates: jnp.ndarray, w_hh: jnp.ndarray, b_hh: jnp.ndarray) -> jnp.ndarray:
    """x_gates: (B, T, 3N) precomputed input projections -> h_seq (B, T, N)."""
    b, t, three_n = x_gates.shape
    n = three_n // 3

    def step(h, gx):
        gh = h @ w_hh.astype(jnp.float32) + b_hh.astype(jnp.float32)
        xr, xz, xn = jnp.split(gx.astype(jnp.float32), 3, axis=-1)
        hr, hz, hn = jnp.split(gh, 3, axis=-1)
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        cand = jnp.tanh(xn + r * hn)
        h_new = (1.0 - z) * cand + z * h
        return h_new, h_new

    h0 = jnp.zeros((b, n), dtype=jnp.float32)
    _, h_seq = jax.lax.scan(step, h0, jnp.swapaxes(x_gates, 0, 1))
    return jnp.swapaxes(h_seq, 0, 1).astype(x_gates.dtype)


def gru_scan_bwd_ref(
    x_gates: jnp.ndarray,  # (B, T, 3N) forward input
    w_hh: jnp.ndarray,     # (N, 3N)
    b_hh: jnp.ndarray,     # (3N,)
    h_seq: jnp.ndarray,    # (B, T, N)  forward output (the residual)
    dy: jnp.ndarray,       # (B, T, N)  output cotangent
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Residual backward: one reverse scan, zero forward recompute.

    Gates are rebuilt per step from ``h_{t-1}`` (read out of ``h_seq``) —
    one (B, N) @ (N, 3N) matmul, the same cost the forward paid, instead of
    rerunning the whole forward scan and then transposing it.
    Returns ``(dx_gates, dw_hh, db_hh)``.
    """
    b, t, three_n = x_gates.shape
    n = three_n // 3
    w32 = w_hh.astype(jnp.float32)
    b32 = b_hh.astype(jnp.float32)
    h32 = h_seq.astype(jnp.float32)
    h_prev_seq = jnp.concatenate(
        [jnp.zeros((b, 1, n), dtype=jnp.float32), h32[:, :-1]], axis=1
    )

    def step(carry, inputs):
        dh, dw, db = carry
        gx, h_prev, dy_t = inputs                       # (B,3N), (B,N), (B,N)
        gh = h_prev @ w32 + b32
        xr, xz, xn = jnp.split(gx.astype(jnp.float32), 3, axis=-1)
        hr, hz, hn = jnp.split(gh, 3, axis=-1)
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        cand = jnp.tanh(xn + r * hn)

        dh_total = dy_t.astype(jnp.float32) + dh
        dz = dh_total * (h_prev - cand)
        da_n = dh_total * (1.0 - z) * (1.0 - cand * cand)
        da_r = da_n * hn * r * (1.0 - r)
        da_z = dz * z * (1.0 - z)
        d_gx = jnp.concatenate([da_r, da_z, da_n], axis=-1)           # (B, 3N)
        d_gh = jnp.concatenate([da_r, da_z, da_n * r], axis=-1)       # (B, 3N)

        dh_new = dh_total * z + d_gh @ w32.T
        dw_new = dw + h_prev.T @ d_gh
        db_new = db + d_gh.sum(axis=0)
        return (dh_new, dw_new, db_new), d_gx

    carry0 = (
        jnp.zeros((b, n), dtype=jnp.float32),
        jnp.zeros((n, three_n), dtype=jnp.float32),
        jnp.zeros((three_n,), dtype=jnp.float32),
    )
    xs = (
        jnp.swapaxes(x_gates, 0, 1),
        jnp.swapaxes(h_prev_seq, 0, 1),
        jnp.swapaxes(dy, 0, 1),
    )
    (_, dw_hh, db_hh), d_gx_seq = jax.lax.scan(step, carry0, xs, reverse=True)
    dx_gates = jnp.swapaxes(d_gx_seq, 0, 1).astype(x_gates.dtype)
    return dx_gates, dw_hh.astype(w_hh.dtype), db_hh.astype(b_hh.dtype)
