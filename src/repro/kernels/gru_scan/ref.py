"""Pure-jnp oracle for the fused GRU recurrence kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gru_scan_ref(x_gates: jnp.ndarray, w_hh: jnp.ndarray, b_hh: jnp.ndarray) -> jnp.ndarray:
    """x_gates: (B, T, 3N) precomputed input projections -> h_seq (B, T, N)."""
    b, t, three_n = x_gates.shape
    n = three_n // 3

    def step(h, gx):
        gh = h @ w_hh.astype(jnp.float32) + b_hh.astype(jnp.float32)
        xr, xz, xn = jnp.split(gx.astype(jnp.float32), 3, axis=-1)
        hr, hz, hn = jnp.split(gh, 3, axis=-1)
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        cand = jnp.tanh(xn + r * hn)
        h_new = (1.0 - z) * cand + z * h
        return h_new, h_new

    h0 = jnp.zeros((b, n), dtype=jnp.float32)
    _, h_seq = jax.lax.scan(step, h0, jnp.swapaxes(x_gates, 0, 1))
    return jnp.swapaxes(h_seq, 0, 1).astype(x_gates.dtype)
