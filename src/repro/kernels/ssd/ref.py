"""Pure-jnp oracle for the SSD kernel: the naive per-step recurrence.

    S_t = exp(dt_t A) * S_{t-1} + dt_t * B_t x_t^T
    y_t = C_t . S_t

Run step-by-step over the *unchunked* sequence — slow but unambiguous.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)   post-softplus
    a: jnp.ndarray,      # (H,)        negative decay rates
    b_mat: jnp.ndarray,  # (B, S, N)
    c_mat: jnp.ndarray,  # (B, S, N)
) -> jnp.ndarray:
    batch, s, h, p = x.shape
    n = b_mat.shape[-1]

    def step(state, inputs):
        x_t, dt_t, b_t, c_t = inputs          # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dt_t * a[None, :])    # (B, H)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bn,bh,bhp->bhpn", b_t, dt_t, x_t
        )
        y_t = jnp.einsum("bn,bhpn->bhp", c_t, state)
        return state, y_t

    state0 = jnp.zeros((batch, h, p, n), dtype=jnp.float32)
    _, ys = jax.lax.scan(
        step,
        state0,
        (
            jnp.moveaxis(x, 1, 0).astype(jnp.float32),
            jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
            jnp.moveaxis(b_mat, 1, 0).astype(jnp.float32),
            jnp.moveaxis(c_mat, 1, 0).astype(jnp.float32),
        ),
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B, S, H, P)


def ssd_chunk_scan_ref(
    xc: jnp.ndarray,     # (B, NC, L, H, P)
    dtc: jnp.ndarray,    # (B, NC, L, H)
    cum: jnp.ndarray,    # (B, NC, L, H)
    bc: jnp.ndarray,     # (B, NC, L, N)
    cc: jnp.ndarray,     # (B, NC, L, N)
) -> jnp.ndarray:
    """Chunk-layout oracle mirroring the Pallas kernel's math exactly
    (same inputs / outputs; used as its custom_vjp backward)."""
    b, nc, l_len, h, p = xc.shape
    idx = jnp.arange(l_len)
    causal = idx[:, None] >= idx[None, :]

    def body(state, inputs):
        x_k, dt_k, cum_k, b_k, c_k = inputs
        cb = jnp.einsum("bln,bmn->blm", c_k, b_k)
        diff = cum_k[:, :, None, :] - cum_k[:, None, :, :]
        decay = jnp.exp(jnp.where(causal[None, :, :, None], diff, -1e30))
        w = cb[:, :, :, None] * decay * dt_k[:, None, :, :]
        y_intra = jnp.einsum("blmh,bmhp->blhp", w, x_k)
        y_inter = jnp.einsum("bln,bhpn,blh->blhp", c_k, state, jnp.exp(cum_k))
        chunk_decay = jnp.exp(cum_k[:, -1, :])
        in_decay = jnp.exp(cum_k[:, -1:, :] - cum_k) * dt_k
        state = state * chunk_decay[:, :, None, None] + jnp.einsum(
            "bln,blh,blhp->bhpn", b_k, in_decay, x_k
        )
        return state, y_intra + y_inter

    f32 = lambda a: jnp.moveaxis(a, 1, 0).astype(jnp.float32)
    state0 = jnp.zeros((b, h, p, bc.shape[-1]), dtype=jnp.float32)
    _, ys = jax.lax.scan(body, state0, (f32(xc), f32(dtc), f32(cum), f32(bc), f32(cc)))
    return jnp.moveaxis(ys, 0, 1).astype(xc.dtype)


def ssd_chunk_states_ref(
    xc: jnp.ndarray,
    dtc: jnp.ndarray,
    cum: jnp.ndarray,
    bc: jnp.ndarray,
    cc: jnp.ndarray,
) -> jnp.ndarray:
    """Chunk-entry states S_k (B, NC, H, P, N) — the residual the backward
    consumes.  S_0 = 0; S_{k+1} = S_k * exp(cum_k[-1]) + sum_l B_l (indec_l x_l)."""
    b, nc, l_len, h, p = xc.shape
    n = bc.shape[-1]

    def body(state, inputs):
        x_k, dt_k, cum_k, b_k = inputs
        entry = state
        chunk_decay = jnp.exp(cum_k[:, -1, :])
        in_decay = jnp.exp(cum_k[:, -1:, :] - cum_k) * dt_k
        state = state * chunk_decay[:, :, None, None] + jnp.einsum(
            "bln,blh,blhp->bhpn", b_k, in_decay, x_k
        )
        return state, entry

    f32 = lambda a: jnp.moveaxis(a, 1, 0).astype(jnp.float32)
    state0 = jnp.zeros((b, h, p, n), dtype=jnp.float32)
    _, entries = jax.lax.scan(body, state0, (f32(xc), f32(dtc), f32(cum), f32(bc)))
    return jnp.moveaxis(entries, 0, 1)  # (B, NC, H, P, N) fp32


def ssd_chunk_scan_bwd_ref(
    xc: jnp.ndarray,      # (B, NC, L, H, P)
    dtc: jnp.ndarray,     # (B, NC, L, H)
    cum: jnp.ndarray,     # (B, NC, L, H)
    bc: jnp.ndarray,      # (B, NC, L, N)
    cc: jnp.ndarray,      # (B, NC, L, N)
    states: jnp.ndarray,  # (B, NC, H, P, N) chunk-entry states (residual)
    dy: jnp.ndarray,      # (B, NC, L, H, P) output cotangent
) -> tuple[jnp.ndarray, ...]:
    """Residual backward: one reverse scan over chunks, no forward recompute.

    Treats ``cum`` as an independent input (callers' cumsum transposes via
    JAX).  Returns ``(dxc, ddtc, dcum, dbc, dcc)``.
    """
    b, nc, l_len, h, p = xc.shape
    idx = jnp.arange(l_len)
    causal = idx[:, None] >= idx[None, :]

    def body(ds_carry, inputs):
        x_k, dt_k, cum_k, b_k, c_k, s_k, dy_k = inputs
        cb = jnp.einsum("bln,bmn->blm", c_k, b_k)
        diff = cum_k[:, :, None, :] - cum_k[:, None, :, :]
        decay = jnp.exp(jnp.where(causal[None, :, :, None], diff, -1e30))

        # intra-chunk quadratic form
        w = cb[:, :, :, None] * decay * dt_k[:, None, :, :]
        dw = jnp.einsum("blhp,bmhp->blmh", dy_k, x_k)
        dx = jnp.einsum("blmh,blhp->bmhp", w, dy_k)
        dcb = jnp.einsum("blmh,blmh->blm", dw, decay * dt_k[:, None, :, :])
        ddt = jnp.einsum("blmh->bmh", dw * cb[:, :, :, None] * decay)
        term = dw * cb[:, :, :, None] * dt_k[:, None, :, :] * decay
        dcum_k = term.sum(axis=2) - term.sum(axis=1)
        dc = jnp.einsum("blm,bmn->bln", dcb, b_k)
        db = jnp.einsum("blm,bln->bmn", dcb, c_k)

        # inter-chunk: carried-state contribution
        sd = jnp.exp(cum_k)
        d_cs = dy_k * sd[:, :, :, None]
        dc = dc + jnp.einsum("blhp,bhpn->bln", d_cs, s_k)
        ds_from_y = jnp.einsum("blhp,bln->bhpn", d_cs, c_k)
        y_inter = jnp.einsum("bln,bhpn->blhp", c_k, s_k) * sd[:, :, :, None]
        dcum_k = dcum_k + jnp.einsum("blhp,blhp->blh", dy_k, y_inter)

        # state-update transpose
        cd = jnp.exp(cum_k[:, -1, :])
        indec = jnp.exp(cum_k[:, -1:, :] - cum_k) * dt_k
        ds_in = ds_carry * cd[:, :, None, None] + ds_from_y
        g = jnp.einsum("bhpn,bln,blhp->blh", ds_carry, b_k, x_k)
        db = db + jnp.einsum("bhpn,blh,blhp->bln", ds_carry, indec, x_k)
        dx = dx + jnp.einsum("bhpn,bln,blh->blhp", ds_carry, b_k, indec)
        ddt = ddt + g * jnp.exp(cum_k[:, -1:, :] - cum_k)
        dcum_k = dcum_k - g * indec
        last = jnp.einsum("bhpn,bhpn->bh", ds_carry, s_k) * cd + (g * indec).sum(axis=1)
        dcum_k = dcum_k.at[:, -1, :].add(last)
        return ds_in, (dx, ddt, dcum_k, db, dc)

    f32 = lambda a: jnp.moveaxis(a, 1, 0).astype(jnp.float32)
    ds0 = jnp.zeros((b, h, p, bc.shape[-1]), dtype=jnp.float32)
    _, (dxs, ddts, dcums, dbs, dcs) = jax.lax.scan(
        body,
        ds0,
        (f32(xc), f32(dtc), f32(cum), f32(bc), f32(cc), f32(states), f32(dy)),
        reverse=True,
    )
    unstack = lambda a, like: jnp.moveaxis(a, 0, 1).astype(like.dtype)
    return (
        unstack(dxs, xc),
        unstack(ddts, dtc),
        unstack(dcums, cum),
        unstack(dbs, bc),
        unstack(dcs, cc),
    )
