"""Pure-jnp oracle for the SSD kernel: the naive per-step recurrence.

    S_t = exp(dt_t A) * S_{t-1} + dt_t * B_t x_t^T
    y_t = C_t . S_t

Run step-by-step over the *unchunked* sequence — slow but unambiguous.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)   post-softplus
    a: jnp.ndarray,      # (H,)        negative decay rates
    b_mat: jnp.ndarray,  # (B, S, N)
    c_mat: jnp.ndarray,  # (B, S, N)
) -> jnp.ndarray:
    batch, s, h, p = x.shape
    n = b_mat.shape[-1]

    def step(state, inputs):
        x_t, dt_t, b_t, c_t = inputs          # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dt_t * a[None, :])    # (B, H)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bn,bh,bhp->bhpn", b_t, dt_t, x_t
        )
        y_t = jnp.einsum("bn,bhpn->bhp", c_t, state)
        return state, y_t

    state0 = jnp.zeros((batch, h, p, n), dtype=jnp.float32)
    _, ys = jax.lax.scan(
        step,
        state0,
        (
            jnp.moveaxis(x, 1, 0).astype(jnp.float32),
            jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
            jnp.moveaxis(b_mat, 1, 0).astype(jnp.float32),
            jnp.moveaxis(c_mat, 1, 0).astype(jnp.float32),
        ),
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B, S, H, P)


def ssd_chunk_scan_ref(
    xc: jnp.ndarray,     # (B, NC, L, H, P)
    dtc: jnp.ndarray,    # (B, NC, L, H)
    cum: jnp.ndarray,    # (B, NC, L, H)
    bc: jnp.ndarray,     # (B, NC, L, N)
    cc: jnp.ndarray,     # (B, NC, L, N)
) -> jnp.ndarray:
    """Chunk-layout oracle mirroring the Pallas kernel's math exactly
    (same inputs / outputs; used as its custom_vjp backward)."""
    b, nc, l_len, h, p = xc.shape
    idx = jnp.arange(l_len)
    causal = idx[:, None] >= idx[None, :]

    def body(state, inputs):
        x_k, dt_k, cum_k, b_k, c_k = inputs
        cb = jnp.einsum("bln,bmn->blm", c_k, b_k)
        diff = cum_k[:, :, None, :] - cum_k[:, None, :, :]
        decay = jnp.exp(jnp.where(causal[None, :, :, None], diff, -1e30))
        w = cb[:, :, :, None] * decay * dt_k[:, None, :, :]
        y_intra = jnp.einsum("blmh,bmhp->blhp", w, x_k)
        y_inter = jnp.einsum("bln,bhpn,blh->blhp", c_k, state, jnp.exp(cum_k))
        chunk_decay = jnp.exp(cum_k[:, -1, :])
        in_decay = jnp.exp(cum_k[:, -1:, :] - cum_k) * dt_k
        state = state * chunk_decay[:, :, None, None] + jnp.einsum(
            "bln,blh,blhp->bhpn", b_k, in_decay, x_k
        )
        return state, y_intra + y_inter

    f32 = lambda a: jnp.moveaxis(a, 1, 0).astype(jnp.float32)
    state0 = jnp.zeros((b, h, p, bc.shape[-1]), dtype=jnp.float32)
    _, ys = jax.lax.scan(body, state0, (f32(xc), f32(dtc), f32(cum), f32(bc), f32(cc)))
    return jnp.moveaxis(ys, 0, 1).astype(xc.dtype)
