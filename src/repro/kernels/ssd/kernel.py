"""Mamba2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060, sec. 6): within a
chunk of length L the dual quadratic form ``(C B^T ⊙ decay)`` runs on the
MXU; the inter-chunk state ``S (H, P, N)`` is carried in a VMEM *scratch*
buffer across sequential grid steps — the TPU grid executes in order, so the
innermost grid axis (chunks) implements the recurrence without HBM
round-trips of the state.

Grid: ``(batch, head_tiles, chunks)`` with chunks innermost.  Per-cell VMEM:
``x (L, Ht, P) + decay (L, L, Ht) + state (Ht, P, N)`` — with L=256, Ht=4,
P=64, N=128 about 1.6 MB, comfortably inside a v5e core's 16 MB VMEM
alongside double-buffered input blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, out_ref, state_ref):
    """Blocks (leading (1, 1) grid dims indexed away):

    x: (L, Ht, P), dt/cum: (L, Ht), b/c: (L, N) — shared across heads,
    out: (L, Ht, P); state scratch: (Ht, P, N) fp32, persists across chunks.
    """
    chunk_idx = pl.program_id(2)

    @pl.when(chunk_idx == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, Ht, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L, Ht)
    cum = cum_ref[0, 0].astype(jnp.float32)      # (L, Ht)
    b_mat = b_ref[0, 0].astype(jnp.float32)      # (L, N)
    c_mat = c_ref[0, 0].astype(jnp.float32)      # (L, N)
    state = state_ref[...]                       # (Ht, P, N)

    l_len = x.shape[0]
    idx = jax.lax.iota(jnp.int32, l_len)
    causal = idx[:, None] >= idx[None, :]

    # intra-chunk quadratic ("attention") form — MXU matmul C B^T
    cb = jnp.dot(c_mat, b_mat.T, preferred_element_type=jnp.float32)   # (L, L)
    diff = cum[:, None, :] - cum[None, :, :]                            # (L, L, Ht)
    decay = jnp.exp(jnp.where(causal[:, :, None], diff, -1e30))
    w = cb[:, :, None] * decay * dt[None, :, :]                         # (L, L, Ht)
    y_intra = jnp.einsum("lmh,mhp->lhp", w, x)

    # inter-chunk: contribution of the carried state
    state_decay = jnp.exp(cum)                                          # (L, Ht)
    y_inter = jnp.einsum("ln,hpn->lhp", c_mat, state) * state_decay[:, :, None]

    out_ref[0, 0] = (y_intra + y_inter).astype(out_ref.dtype)

    # state update for the next chunk
    chunk_decay = jnp.exp(cum[-1, :])                                   # (Ht,)
    in_decay = jnp.exp(cum[-1:, :] - cum) * dt                          # (L, Ht)
    state_new = state * chunk_decay[:, None, None] + jnp.einsum(
        "ln,lh,lhp->hpn", b_mat, in_decay, x
    )
    state_ref[...] = state_new


def _ssd_kernel_with_states(
    x_ref, dt_ref, cum_ref, b_ref, c_ref, out_ref, entry_ref, state_ref
):
    """Forward that additionally records the chunk-entry state S_k — the
    residual the hand-written backward consumes."""
    chunk_idx = pl.program_id(2)

    @pl.when(chunk_idx == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    entry_ref[0, 0] = state_ref[...].astype(entry_ref.dtype)
    _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, out_ref, state_ref)


@functools.partial(jax.jit, static_argnames=("h_tile", "interpret", "return_states"))
def ssd_chunk_scan(
    x: jnp.ndarray,      # (B, NC, L, H, P) fp32
    dt: jnp.ndarray,     # (B, NC, L, H)
    cum: jnp.ndarray,    # (B, NC, L, H)  within-chunk cumulative log-decay
    b_mat: jnp.ndarray,  # (B, NC, L, N)
    c_mat: jnp.ndarray,  # (B, NC, L, N)
    *,
    h_tile: int = 4,
    interpret: bool = True,
    return_states: bool = False,
):
    """Returns y (B, NC, L, H, P); with ``return_states`` also the fp32
    chunk-entry states (B, NC, H, P, N)."""
    batch, nc, l_len, h, p = x.shape
    n = b_mat.shape[-1]
    h_tile = min(h_tile, h)
    assert h % h_tile == 0, f"h_tile {h_tile} must divide head count {h}"
    ht_tiles = h // h_tile

    y_spec = pl.BlockSpec((1, 1, l_len, h_tile, p), lambda b, hh, c: (b, c, 0, hh, 0))
    y_shape = jax.ShapeDtypeStruct((batch, nc, l_len, h, p), x.dtype)
    if return_states:
        kernel = _ssd_kernel_with_states
        out_specs = [
            y_spec,
            pl.BlockSpec((1, 1, h_tile, p, n), lambda b, hh, c: (b, c, hh, 0, 0)),
        ]
        out_shape = [y_shape, jax.ShapeDtypeStruct((batch, nc, h, p, n), jnp.float32)]
    else:
        kernel = _ssd_kernel
        out_specs = y_spec
        out_shape = y_shape

    return pl.pallas_call(
        kernel,
        grid=(batch, ht_tiles, nc),               # chunks innermost: sequential state
        in_specs=[
            pl.BlockSpec((1, 1, l_len, h_tile, p), lambda b, hh, c: (b, c, 0, hh, 0)),
            pl.BlockSpec((1, 1, l_len, h_tile), lambda b, hh, c: (b, c, 0, hh)),
            pl.BlockSpec((1, 1, l_len, h_tile), lambda b, hh, c: (b, c, 0, hh)),
            pl.BlockSpec((1, 1, l_len, n), lambda b, hh, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, l_len, n), lambda b, hh, c: (b, c, 0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((h_tile, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, cum, b_mat, c_mat)


def _ssd_bwd_kernel(
    x_ref, dt_ref, cum_ref, b_ref, c_ref, s_ref, dy_ref,
    dx_ref, ddt_ref, dcum_ref, db_ref, dc_ref, ds_ref,
):
    """Reverse-chunk backward for one batch element, full head dim.

    The grid walks chunks last-to-first (index maps flip the chunk axis), so
    the dS carry lives in VMEM scratch exactly like the forward's state.
    Head tiling is dropped: dB/dC are shared across heads, and splitting
    heads across grid steps would interleave non-consecutive revisits of
    those output blocks — full-H blocks keep every output written once.
    """
    chunk_idx = pl.program_id(1)

    @pl.when(chunk_idx == 0)
    def _reset():  # first visit = last chunk: final state has no cotangent
        ds_ref[...] = jnp.zeros_like(ds_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, H, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L, H)
    cum = cum_ref[0, 0].astype(jnp.float32)      # (L, H)
    b_mat = b_ref[0, 0].astype(jnp.float32)      # (L, N)
    c_mat = c_ref[0, 0].astype(jnp.float32)      # (L, N)
    s_k = s_ref[0, 0].astype(jnp.float32)        # (H, P, N) chunk-entry state
    dy = dy_ref[0, 0].astype(jnp.float32)        # (L, H, P)
    ds = ds_ref[...]                             # (H, P, N) carry

    l_len = x.shape[0]
    idx = jax.lax.iota(jnp.int32, l_len)
    causal = idx[:, None] >= idx[None, :]

    cb = jnp.dot(c_mat, b_mat.T, preferred_element_type=jnp.float32)    # (L, L)
    diff = cum[:, None, :] - cum[None, :, :]                            # (L, L, H)
    decay = jnp.exp(jnp.where(causal[:, :, None], diff, -1e30))

    # intra-chunk quadratic form transpose
    w = cb[:, :, None] * decay * dt[None, :, :]
    dw = jnp.einsum("lhp,mhp->lmh", dy, x)
    dx = jnp.einsum("lmh,lhp->mhp", w, dy)
    dcb = jnp.einsum("lmh,lmh->lm", dw, decay * dt[None, :, :])
    ddt = jnp.einsum("lmh->mh", dw * cb[:, :, None] * decay)
    term = dw * cb[:, :, None] * dt[None, :, :] * decay
    dcum = term.sum(axis=1) - term.sum(axis=0)
    dc = jnp.dot(dcb, b_mat, preferred_element_type=jnp.float32)
    db = jnp.dot(dcb.T, c_mat, preferred_element_type=jnp.float32)

    # inter-chunk carried-state contribution
    sd = jnp.exp(cum)
    d_cs = dy * sd[:, :, None]
    dc += jnp.einsum("lhp,hpn->ln", d_cs, s_k)
    ds_from_y = jnp.einsum("lhp,ln->hpn", d_cs, c_mat)
    y_inter = jnp.einsum("ln,hpn->lhp", c_mat, s_k) * sd[:, :, None]
    dcum += jnp.einsum("lhp,lhp->lh", dy, y_inter)

    # state-update transpose
    cd = jnp.exp(cum[-1, :])
    indec = jnp.exp(cum[-1:, :] - cum) * dt
    ds_in = ds * cd[:, None, None] + ds_from_y
    g = jnp.einsum("hpn,ln,lhp->lh", ds, b_mat, x)
    db += jnp.einsum("hpn,lh,lhp->ln", ds, indec, x)
    dx += jnp.einsum("hpn,ln,lh->lhp", ds, b_mat, indec)
    ddt += g * jnp.exp(cum[-1:, :] - cum)
    dcum -= g * indec
    last = jnp.einsum("hpn,hpn->h", ds, s_k) * cd + (g * indec).sum(axis=0)
    dcum = dcum.at[-1, :].add(last)

    dx_ref[0, 0] = dx.astype(dx_ref.dtype)
    ddt_ref[0, 0] = ddt.astype(ddt_ref.dtype)
    dcum_ref[0, 0] = dcum.astype(dcum_ref.dtype)
    db_ref[0, 0] = db.astype(db_ref.dtype)
    dc_ref[0, 0] = dc.astype(dc_ref.dtype)
    ds_ref[...] = ds_in


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_scan_bwd(
    x: jnp.ndarray,       # (B, NC, L, H, P)
    dt: jnp.ndarray,      # (B, NC, L, H)
    cum: jnp.ndarray,     # (B, NC, L, H)
    b_mat: jnp.ndarray,   # (B, NC, L, N)
    c_mat: jnp.ndarray,   # (B, NC, L, N)
    states: jnp.ndarray,  # (B, NC, H, P, N) fp32 chunk-entry states
    dy: jnp.ndarray,      # (B, NC, L, H, P)
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, ...]:
    """Single-pass Pallas backward: ``(dx, ddt, dcum, db, dc)``."""
    batch, nc, l_len, h, p = x.shape
    n = b_mat.shape[-1]
    rev = lambda c: nc - 1 - c

    return pl.pallas_call(
        _ssd_bwd_kernel,
        grid=(batch, nc),                         # chunks innermost, reversed
        in_specs=[
            pl.BlockSpec((1, 1, l_len, h, p), lambda b, c: (b, rev(c), 0, 0, 0)),
            pl.BlockSpec((1, 1, l_len, h), lambda b, c: (b, rev(c), 0, 0)),
            pl.BlockSpec((1, 1, l_len, h), lambda b, c: (b, rev(c), 0, 0)),
            pl.BlockSpec((1, 1, l_len, n), lambda b, c: (b, rev(c), 0, 0)),
            pl.BlockSpec((1, 1, l_len, n), lambda b, c: (b, rev(c), 0, 0)),
            pl.BlockSpec((1, 1, h, p, n), lambda b, c: (b, rev(c), 0, 0, 0)),
            pl.BlockSpec((1, 1, l_len, h, p), lambda b, c: (b, rev(c), 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, l_len, h, p), lambda b, c: (b, rev(c), 0, 0, 0)),
            pl.BlockSpec((1, 1, l_len, h), lambda b, c: (b, rev(c), 0, 0)),
            pl.BlockSpec((1, 1, l_len, h), lambda b, c: (b, rev(c), 0, 0)),
            pl.BlockSpec((1, 1, l_len, n), lambda b, c: (b, rev(c), 0, 0)),
            pl.BlockSpec((1, 1, l_len, n), lambda b, c: (b, rev(c), 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(dt.shape, dt.dtype),
            jax.ShapeDtypeStruct(cum.shape, cum.dtype),
            jax.ShapeDtypeStruct(b_mat.shape, b_mat.dtype),
            jax.ShapeDtypeStruct(c_mat.shape, c_mat.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, cum, b_mat, c_mat, states, dy)
