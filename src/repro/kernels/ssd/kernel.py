"""Mamba2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060, sec. 6): within a
chunk of length L the dual quadratic form ``(C B^T ⊙ decay)`` runs on the
MXU; the inter-chunk state ``S (H, P, N)`` is carried in a VMEM *scratch*
buffer across sequential grid steps — the TPU grid executes in order, so the
innermost grid axis (chunks) implements the recurrence without HBM
round-trips of the state.

Grid: ``(batch, head_tiles, chunks)`` with chunks innermost.  Per-cell VMEM:
``x (L, Ht, P) + decay (L, L, Ht) + state (Ht, P, N)`` — with L=256, Ht=4,
P=64, N=128 about 1.6 MB, comfortably inside a v5e core's 16 MB VMEM
alongside double-buffered input blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, out_ref, state_ref):
    """Blocks (leading (1, 1) grid dims indexed away):

    x: (L, Ht, P), dt/cum: (L, Ht), b/c: (L, N) — shared across heads,
    out: (L, Ht, P); state scratch: (Ht, P, N) fp32, persists across chunks.
    """
    chunk_idx = pl.program_id(2)

    @pl.when(chunk_idx == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, Ht, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L, Ht)
    cum = cum_ref[0, 0].astype(jnp.float32)      # (L, Ht)
    b_mat = b_ref[0, 0].astype(jnp.float32)      # (L, N)
    c_mat = c_ref[0, 0].astype(jnp.float32)      # (L, N)
    state = state_ref[...]                       # (Ht, P, N)

    l_len = x.shape[0]
    idx = jax.lax.iota(jnp.int32, l_len)
    causal = idx[:, None] >= idx[None, :]

    # intra-chunk quadratic ("attention") form — MXU matmul C B^T
    cb = jnp.dot(c_mat, b_mat.T, preferred_element_type=jnp.float32)   # (L, L)
    diff = cum[:, None, :] - cum[None, :, :]                            # (L, L, Ht)
    decay = jnp.exp(jnp.where(causal[:, :, None], diff, -1e30))
    w = cb[:, :, None] * decay * dt[None, :, :]                         # (L, L, Ht)
    y_intra = jnp.einsum("lmh,mhp->lhp", w, x)

    # inter-chunk: contribution of the carried state
    state_decay = jnp.exp(cum)                                          # (L, Ht)
    y_inter = jnp.einsum("ln,hpn->lhp", c_mat, state) * state_decay[:, :, None]

    out_ref[0, 0] = (y_intra + y_inter).astype(out_ref.dtype)

    # state update for the next chunk
    chunk_decay = jnp.exp(cum[-1, :])                                   # (Ht,)
    in_decay = jnp.exp(cum[-1:, :] - cum) * dt                          # (L, Ht)
    state_new = state * chunk_decay[:, None, None] + jnp.einsum(
        "ln,lh,lhp->hpn", b_mat, in_decay, x
    )
    state_ref[...] = state_new


@functools.partial(jax.jit, static_argnames=("h_tile", "interpret"))
def ssd_chunk_scan(
    x: jnp.ndarray,      # (B, NC, L, H, P) fp32
    dt: jnp.ndarray,     # (B, NC, L, H)
    cum: jnp.ndarray,    # (B, NC, L, H)  within-chunk cumulative log-decay
    b_mat: jnp.ndarray,  # (B, NC, L, N)
    c_mat: jnp.ndarray,  # (B, NC, L, N)
    *,
    h_tile: int = 4,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns y (B, NC, L, H, P)."""
    batch, nc, l_len, h, p = x.shape
    n = b_mat.shape[-1]
    h_tile = min(h_tile, h)
    assert h % h_tile == 0, f"h_tile {h_tile} must divide head count {h}"
    ht_tiles = h // h_tile

    return pl.pallas_call(
        _ssd_kernel,
        grid=(batch, ht_tiles, nc),               # chunks innermost: sequential state
        in_specs=[
            pl.BlockSpec((1, 1, l_len, h_tile, p), lambda b, hh, c: (b, c, 0, hh, 0)),
            pl.BlockSpec((1, 1, l_len, h_tile), lambda b, hh, c: (b, c, 0, hh)),
            pl.BlockSpec((1, 1, l_len, h_tile), lambda b, hh, c: (b, c, 0, hh)),
            pl.BlockSpec((1, 1, l_len, n), lambda b, hh, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, l_len, n), lambda b, hh, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, l_len, h_tile, p), lambda b, hh, c: (b, c, 0, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, nc, l_len, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((h_tile, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, cum, b_mat, c_mat)
