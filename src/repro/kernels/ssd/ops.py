"""Jitted public wrapper for the SSD Pallas kernel.

Accepts the chunked layout produced by ``repro.models.mamba2``; backend
selection (interpret mode, backward routing, ``REPRO_PALLAS_INTERPRET``)
lives in ``repro.kernels.backend``.  ``ssd_full`` is the convenience entry
point taking an unchunked sequence (used by tests to sweep shapes against
the oracle).

``pallas_call`` has no reverse-mode rule, so the op carries a
``custom_vjp``.  The forward stashes the chunk-entry states S_k as the
residual; the backward is then one reverse pass over chunks — the
hand-written Pallas kernel on TPU, the pure-jnp ``ssd_chunk_scan_bwd_ref``
reverse scan elsewhere.  The previous oracle-recompute pairing is kept as
``ssd_chunk_scan_oracle`` purely for benchmarking.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.ssd.kernel import ssd_chunk_scan as _kernel
from repro.kernels.ssd.kernel import ssd_chunk_scan_bwd as _kernel_bwd
from repro.kernels.ssd.ref import ssd_chunk_scan_bwd_ref, ssd_chunk_scan_ref


def _pick_h_tile(h: int) -> int:
    for cand in (4, 2, 1):
        if h % cand == 0:
            return cand
    return 1


@jax.custom_vjp
def ssd_chunk_scan(xc, dtc, cum, bc, cc):
    """Chunked inputs (B, NC, L, ...) -> y (B, NC, L, H, P)."""
    h = xc.shape[3]
    return _kernel(
        xc, dtc, cum, bc, cc, h_tile=_pick_h_tile(h), interpret=backend.interpret()
    )


def _fwd(xc, dtc, cum, bc, cc):
    h = xc.shape[3]
    y, states = _kernel(
        xc,
        dtc,
        cum,
        bc,
        cc,
        h_tile=_pick_h_tile(h),
        interpret=backend.interpret(),
        return_states=True,
    )
    return y, (xc, dtc, cum, bc, cc, states)


def _bwd(residuals, cotangent):
    xc, dtc, cum, bc, cc, states = residuals
    if backend.pallas_backward():
        return _kernel_bwd(
            xc, dtc, cum, bc, cc, states, cotangent, interpret=backend.interpret()
        )
    return ssd_chunk_scan_bwd_ref(xc, dtc, cum, bc, cc, states, cotangent)


ssd_chunk_scan.defvjp(_fwd, _bwd)


@jax.custom_vjp
def ssd_chunk_scan_oracle(xc, dtc, cum, bc, cc):
    """The pre-residual pairing (benchmark baseline only): Pallas forward,
    backward recomputes the whole forward through the jnp oracle."""
    h = xc.shape[3]
    return _kernel(
        xc, dtc, cum, bc, cc, h_tile=_pick_h_tile(h), interpret=backend.interpret()
    )


def _oracle_fwd(xc, dtc, cum, bc, cc):
    return ssd_chunk_scan_oracle(xc, dtc, cum, bc, cc), (xc, dtc, cum, bc, cc)


def _oracle_bwd(residuals, cotangent):
    _, vjp = jax.vjp(ssd_chunk_scan_ref, *residuals)
    return vjp(cotangent)


ssd_chunk_scan_oracle.defvjp(_oracle_fwd, _oracle_bwd)


def ssd_full(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)
    a: jnp.ndarray,      # (H,)
    b_mat: jnp.ndarray,  # (B, S, N)
    c_mat: jnp.ndarray,  # (B, S, N)
    chunk: int = 64,
) -> jnp.ndarray:
    """Unchunked convenience wrapper: pads, chunks, runs the kernel."""
    b, s, h, p = x.shape
    n = b_mat.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    bc = b_mat.reshape(b, nc, chunk, n)
    cc = c_mat.reshape(b, nc, chunk, n)
    dac = dtc * a[None, None, None, :]
    cum = jnp.cumsum(dac, axis=2)
    y = ssd_chunk_scan(xc, dtc, cum, bc, cc)
    return y.reshape(b, nc * chunk, h, p)[:, :s]
