"""Jitted public wrapper for the SSD Pallas kernel.

Accepts the chunked layout produced by ``repro.models.mamba2`` and forces
interpret mode off-TPU.  ``ssd_full`` is the convenience entry point taking
an unchunked sequence (used by tests to sweep shapes against the oracle).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_chunk_scan as _kernel
from repro.kernels.ssd.ref import ssd_chunk_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_h_tile(h: int) -> int:
    for cand in (4, 2, 1):
        if h % cand == 0:
            return cand
    return 1


@jax.custom_vjp
def ssd_chunk_scan(xc, dtc, cum, bc, cc):
    """Chunked inputs (B, NC, L, ...) -> y (B, NC, L, H, P).

    Forward: Pallas kernel.  Backward: recompute through the jnp oracle
    (``pallas_call`` has no reverse-mode rule) — remat-style custom_vjp.
    """
    h = xc.shape[3]
    return _kernel(xc, dtc, cum, bc, cc, h_tile=_pick_h_tile(h), interpret=not _on_tpu())


def _fwd(xc, dtc, cum, bc, cc):
    return ssd_chunk_scan(xc, dtc, cum, bc, cc), (xc, dtc, cum, bc, cc)


def _bwd(residuals, cotangent):
    _, vjp = jax.vjp(ssd_chunk_scan_ref, *residuals)
    return vjp(cotangent)


ssd_chunk_scan.defvjp(_fwd, _bwd)


def ssd_full(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)
    a: jnp.ndarray,      # (H,)
    b_mat: jnp.ndarray,  # (B, S, N)
    c_mat: jnp.ndarray,  # (B, S, N)
    chunk: int = 64,
) -> jnp.ndarray:
    """Unchunked convenience wrapper: pads, chunks, runs the kernel."""
    b, s, h, p = x.shape
    n = b_mat.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    bc = b_mat.reshape(b, nc, chunk, n)
    cc = c_mat.reshape(b, nc, chunk, n)
    dac = dtc * a[None, None, None, :]
    cum = jnp.cumsum(dac, axis=2)
    y = ssd_chunk_scan(xc, dtc, cum, bc, cc)
    return y.reshape(b, nc * chunk, h, p)[:, :s]
