"""Jaxpr op accounting for the kernel tier.

The point of the residual backward is structural: the cotangent pass must
be a *single* reverse scan, not recompute-forward-then-transpose.  That
claim is checkable from the jaxpr — count ``scan`` sites, ``dot_general``
FLOPs, and weighted primitive totals in the backward graph and compare the
residual pairing against the oracle-recompute pairing.

``backward_stats`` builds ``jax.vjp(fn, *args)`` and walks the jaxpr of the
cotangent application (forward residuals are baked in as constants, so only
backward work is counted).  ``recompute_elimination_report`` packages the
comparison the benchmarks and the roofline report assert on.
"""

from __future__ import annotations

import dataclasses
from math import prod
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class OpStats:
    """Weighted op counts for one jaxpr (loop bodies scaled by trip count)."""

    scans: int = 0              # scan *sites* (a second site = a recompute pass)
    while_loops: int = 0
    pallas_calls: int = 0
    dot_general_flops: float = 0.0
    weighted_eqns: float = 0.0  # primitives × loop trip counts — total op traffic

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    batch = prod(lhs.shape[i] for i in lb)
    k = prod(lhs.shape[i] for i in lc)
    m = prod(lhs.shape[i] for i in range(len(lhs.shape)) if i not in lc and i not in lb)
    n = prod(rhs.shape[i] for i in range(len(rhs.shape)) if i not in rc and i not in rb)
    return 2.0 * batch * m * n * k


def _walk(jaxpr, stats: OpStats, mult: float) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        stats.weighted_eqns += mult
        if name == "dot_general":
            stats.dot_general_flops += mult * _dot_flops(eqn)
        elif name == "scan":
            stats.scans += 1
            _walk(eqn.params["jaxpr"].jaxpr, stats, mult * eqn.params["length"])
        elif name == "while":
            stats.while_loops += 1
            _walk(eqn.params["cond_jaxpr"].jaxpr, stats, mult)
            _walk(eqn.params["body_jaxpr"].jaxpr, stats, mult)
        elif name == "cond":
            for branch in eqn.params["branches"]:
                _walk(branch.jaxpr, stats, mult)
        elif "pallas_call" in name:
            stats.pallas_calls += 1
        else:
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                sub = eqn.params.get(key)
                if sub is not None:
                    _walk(getattr(sub, "jaxpr", sub), stats, mult)


def backward_stats(fn: Callable, *args) -> OpStats:
    """Op stats of the *backward-only* graph of ``fn`` at ``args``."""
    out, vjp_fn = jax.vjp(fn, *args)
    cotangent = jax.tree_util.tree_map(jnp.ones_like, out)
    closed = jax.make_jaxpr(vjp_fn)(cotangent)
    stats = OpStats()
    _walk(closed.jaxpr, stats, 1.0)
    return stats


def recompute_elimination_report(
    residual_fn: Callable, oracle_fn: Callable, *args
) -> dict[str, Any]:
    """Compare residual vs oracle backward graphs at the same inputs.

    ``recompute_eliminated`` is the structural claim: the residual backward
    has strictly fewer scan passes than the oracle (no second forward scan)
    and no more total op traffic.
    """
    residual = backward_stats(residual_fn, *args)
    oracle = backward_stats(oracle_fn, *args)
    eliminated = (
        residual.scans < oracle.scans
        and residual.weighted_eqns <= oracle.weighted_eqns
    )
    return {
        "residual_bwd": residual.as_dict(),
        "oracle_bwd": oracle.as_dict(),
        "recompute_eliminated": bool(eliminated),
    }
