"""Shared backend selection for the Pallas kernel tier.

Both kernel families (``gru_scan``, ``ssd``) need the same three decisions,
previously copy-pasted as private ``_on_tpu()`` probes:

* ``on_tpu()``      — is the default JAX backend a real TPU?
* ``interpret()``   — should ``pallas_call`` run in interpret mode?  True
  off-TPU (CPU containers, CI) so the same kernel source stays executable
  everywhere; on TPU the Mosaic compiler takes over.
* ``pallas_backward()`` — should the *backward* pass use the hand-written
  Pallas kernel (True on TPU) or the pure-jnp residual reverse scan (the
  off-TPU default, which is faster than interpret-mode emulation on CPU)?

The ``REPRO_PALLAS_INTERPRET`` environment variable overrides both
``interpret()`` and ``pallas_backward()`` to True, forcing every path —
including the backward kernels — through interpret-mode ``pallas_call`` on
any backend.  CI uses this to exercise the backward kernels without TPU
hardware; it is read at trace time, so set it before the first jit.
"""

from __future__ import annotations

import os

import jax

_ENV_INTERPRET = "REPRO_PALLAS_INTERPRET"
_TRUTHY = frozenset({"1", "true", "yes", "on"})


def _env_forced() -> bool:
    return os.environ.get(_ENV_INTERPRET, "").strip().lower() in _TRUTHY


def on_tpu() -> bool:
    """True when the default JAX backend is a real TPU."""
    return jax.default_backend() == "tpu"


def interpret() -> bool:
    """Interpret-mode flag for ``pallas_call`` (True off-TPU or when forced)."""
    if _env_forced():
        return True
    return not on_tpu()


def pallas_backward() -> bool:
    """Route the backward pass through the Pallas backward kernel?

    True on TPU (compiled Mosaic) or when ``REPRO_PALLAS_INTERPRET`` forces
    interpret-mode coverage; otherwise False and the pure-jnp residual
    reverse scan runs instead.
    """
    return on_tpu() or _env_forced()
