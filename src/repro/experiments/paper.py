"""The paper's experiments, end to end, on the synthetic eICU cohort.

Five model settings (paper section 6):

  central        — pooled training, 15 epochs (upper bound)
  federated-ac   — all 189 clients, all participate each round
  federated-sc   — all clients in federation, 10% sampled per round (the
                   "standard FL" baseline the paper tests against)
  federated-arc  — recruited clients only, all participate
  federated-src  — recruited clients only, 10% sampled per round

plus the section 6.2 ablations (quality-greedy / data-greedy) and the
gamma_th sweep of Fig. 2.  Each run reports the paper's four metrics plus
wall-time tau and simulated local-step counts.

Every federated setting is expressed as a *policy combination* for the
``Federation`` facade (``policies_for``): a recruitment spec, a selection
spec, and an aggregator spec — three strings.  New scenarios (random
recruitment controls, trimmed-mean robustness, regional hierarchies) are
one registry entry each; see ``repro.federated.api``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np

from repro.core.recruitment import DATA_GREEDY, QUALITY_GREEDY
from repro.data.pipeline import (
    ArrayDataset,
    build_client_datasets,
    cohort_steps_per_epoch,
    global_dataset,
)
from repro.data.synth_eicu import NUM_HOSPITALS, Cohort, CohortConfig, generate_cohort
from repro.federated.api import Federation, FederationConfig
from repro.federated.central import CentralConfig, train_central
from repro.federated.cohort import CohortTrainer, chain_split_keys
from repro.federated.runtime import AsyncFederation, AsyncFederationConfig
from repro.metrics.regression import evaluate_predictions
from repro.models.gru import GRUConfig, gru_apply, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

MODEL_SETTINGS = (
    "central",
    "federated-ac",
    "federated-sc",
    "federated-arc",
    "federated-src",
    "federated-src-qg",
    "federated-src-dg",
)


@dataclasses.dataclass(frozen=True)
class ExperimentConfig:
    """Paper-faithful defaults (Tables 1 and 3)."""

    cohort_scale: float = 1.0      # 1.0 = full 89,127-stay cohort
    rounds: int = 15
    local_epochs: int = 4
    central_epochs: int = 15
    batch_size: int = 128
    learning_rate: float = 5e-3
    weight_decay: float = 5e-3
    participation_fraction: float = 0.1
    gamma_dv: float = 0.5
    gamma_sa: float = 0.5
    gamma_th: float = 0.1
    use_pallas: bool = False
    # Federated training engine: "vectorized" (one jitted vmap per round)
    # or "sequential" (per-client Python loop, the reference oracle).
    engine: str = "vectorized"
    # Vectorized engine: clients per vmapped call (None = whole cohort).
    cohort_chunk: int | None = None
    # Vectorized engine: device mesh for the client axis (None, a Mesh, or
    # "auto" for a 1-D data mesh over every visible device).
    mesh: Any = None
    # Vectorized engine: donate round buffers (in-place accumulator, eager
    # release of consumed schedule chunks).
    donate_buffers: bool = True
    # Vectorized engine: "resident" uploads client data once and stages
    # int32 index plans per round (on-device batch gather); "rebuild"
    # re-uploads the full schedule every round (the staging reference).
    staging: str = "resident"
    # Resident staging: double-buffer chunk plans on a background thread.
    prefetch: bool = True
    # Policy overrides for the Federation facade.  ``selection=None``
    # derives the paper's uniform sampling from the setting; ``aggregator``
    # is any registry spec or instance ("fedavg", "trimmed-mean:0.1",
    # "hierarchical:4", ...).
    selection: Any = None
    aggregator: Any = "fedavg"
    # In-jit DP-SGD: None (unprotected), a DPConfig, or a job-spec dict
    # ({"clip_norm": ..., "noise_multiplier": ..., "delta": ...}).
    privacy: Any = None


def policies_for(setting: str, exp: ExperimentConfig) -> dict[str, Any]:
    """One paper setting -> the three policy specs of the Federation facade.

    This is the whole translation table of section 6: ac/sc/arc/src and the
    6.2 ablations are each a (recruitment, selection, aggregator) triple.
    """
    if setting == "federated-src-qg":
        rec: Any = f"nu-greedy:{QUALITY_GREEDY.gamma_dv},{QUALITY_GREEDY.gamma_sa},{exp.gamma_th}"
    elif setting == "federated-src-dg":
        rec = f"nu-greedy:{DATA_GREEDY.gamma_dv},{DATA_GREEDY.gamma_sa},{exp.gamma_th}"
    elif setting in ("federated-arc", "federated-src"):
        rec = f"nu-greedy:{exp.gamma_dv},{exp.gamma_sa},{exp.gamma_th}"
    else:
        rec = "all"
    if exp.selection is not None:
        sel: Any = exp.selection
    elif setting in ("federated-ac", "federated-arc"):
        sel = "uniform"  # everyone, every round
    else:
        # float() keeps the spec grammar honest: in a spec string an int is
        # a count, a float a fraction — participation_fraction=1 must render
        # as "uniform:1.0" (everyone), not "uniform:1" (one client).
        sel = f"uniform:{float(exp.participation_fraction)}"
    return {"recruitment": rec, "selection": sel, "aggregator": exp.aggregator}


def build_cohort(exp: ExperimentConfig, seed: int) -> Cohort:
    cfg = CohortConfig()
    if exp.cohort_scale != 1.0:
        cfg = cfg.scaled(exp.cohort_scale)
    return generate_cohort(cfg, seed=seed)


def run_setting(
    setting: str,
    exp: ExperimentConfig,
    cohort: Cohort,
    seed: int,
    progress: Any | None = None,
) -> dict[str, Any]:
    """Train one model setting and evaluate on the hold-out test split."""
    if setting not in MODEL_SETTINGS:
        raise ValueError(f"unknown setting {setting}; choose from {MODEL_SETTINGS}")

    model_cfg = GRUConfig(use_pallas=exp.use_pallas)
    loss_fn = make_loss_fn(model_cfg)
    optimizer = AdamW(learning_rate=exp.learning_rate, weight_decay=exp.weight_decay)
    init_params = init_gru(jax.random.key(seed), model_cfg)
    test = global_dataset(cohort, Cohort.TEST)

    info: dict[str, Any] = {"setting": setting, "seed": seed}
    if setting == "central":
        result = train_central(
            CentralConfig(epochs=exp.central_epochs, batch_size=exp.batch_size, seed=seed),
            global_dataset(cohort, Cohort.TRAIN),
            init_params,
            loss_fn,
            optimizer,
        )
        params = result.params
        info.update(
            tau_s=result.total_wall_time_s,
            local_steps=result.total_steps,
            federation_size=None,
            recruited=None,
            engine=None,
            round_times_s=None,
            cohort_stats=None,
        )
    else:
        clients = build_client_datasets(cohort)
        fed_cfg = FederationConfig(
            rounds=exp.rounds,
            local_epochs=exp.local_epochs,
            batch_size=exp.batch_size,
            **policies_for(setting, exp),
            seed=seed,
            engine=exp.engine,
            cohort_chunk=exp.cohort_chunk,
            mesh=exp.mesh,
            donate_buffers=exp.donate_buffers,
            staging=exp.staging,
            prefetch=exp.prefetch,
            privacy=exp.privacy,
        )
        federation = Federation(fed_cfg, clients, loss_fn, optimizer)
        result = federation.run(init_params, progress=progress)
        params = result.params
        summary = result.summary()
        info.update(
            tau_s=result.total_wall_time_s,
            local_steps=result.total_local_steps,
            federation_size=int(result.federation_ids.size),
            recruited=None if result.recruitment is None else result.recruitment.num_recruited,
            # What actually ran: stacked-mode aggregators force the
            # per-client path regardless of the configured engine.
            engine=federation.effective_engine,
            round_times_s=[r.wall_time_s for r in result.history],
            cohort_stats=federation.cohort_trainer.last_round_stats,
            comm={k: summary[k] for k in ("params_down", "params_up", "bytes_transferred")},
            epsilon=summary["epsilon"],
        )

    y_hat = np.asarray(_predict(params, model_cfg, test))
    info["metrics"] = evaluate_predictions(test.y, y_hat)
    return info


def _predict(params, model_cfg: GRUConfig, dataset: ArrayDataset, batch: int = 2048) -> np.ndarray:
    fn = jax.jit(lambda p, x: gru_apply(p, model_cfg, x))
    outs = []
    for start in range(0, len(dataset), batch):
        outs.append(np.asarray(fn(params, dataset.x[start : start + batch])))
    return np.concatenate(outs)


def paper_scale_cohort_config(total_stays: int = 189 * 23) -> CohortConfig:
    """A 189-hospital cohort sized for CI hardware.

    The paper's full cohort (89,127 stays) is CPU-hostile, but the scale
    dimension the engines care about is the *client count*, so this keeps
    all 189 hospitals and shrinks per-hospital data to ~23 stays each —
    the dispatch-bound many-small-hospitals regime the vectorized engine
    exists for (the eICU tail, not the big academic centers).  The split
    is hospital-stratified so every client lands the same local train size:
    each survives the ``min_train=2`` cut (the federation really is 189
    clients) and the vectorized schedule's shared step axis is exactly
    every client's real step count (no masked padding in the benchmark).
    """
    num = NUM_HOSPITALS
    return CohortConfig(
        total_stays=max(total_stays, num * 8),
        min_hospital_size=max(total_stays // num, 8),
        split_mode="stratified",
    )


PAPER_SCALE_SETTINGS = (
    "central",
    "federated-ac",
    "federated-sc",
    "federated-arc",
    "federated-src",
)


def _mean_round_time(info: dict[str, Any]) -> float:
    """Steady-state seconds per round: drop round 0 (it pays compilation)
    and take the median (robust to noisy-neighbor spikes on CI hosts)."""
    times = info.get("round_times_s")
    if not times:
        return float(info["tau_s"])
    return float(np.median(times[1:] if len(times) > 1 else times))


def run_paper_scale(
    *,
    rounds: int = 3,
    local_epochs: int = 1,
    batch_size: int = 4,
    seed: int = 0,
    total_stays: int = 189 * 23,
    engines: tuple[str, ...] = ("vectorized", "sequential"),
    mesh: Any = None,
    settings: tuple[str, ...] = PAPER_SCALE_SETTINGS,
    use_pallas: bool = False,
    verbose: bool = True,
) -> dict[str, Any]:
    """The paper's full five-setting grid at 189 clients, under both engines.

    The workload behind ``python benchmarks/run.py --mode paper189``: every
    model setting of section 6 runs end to end on a 189-hospital cohort,
    each federated setting once per engine, recording per-setting
    steady-state round time, test metrics, and the vectorized engine's
    peak live-buffer footprint.  A donation probe additionally runs one
    all-clients round with buffer donation on and off and records both
    footprints — the documented memory win of the donated path.
    """
    cohort_cfg = paper_scale_cohort_config(total_stays=total_stays)
    cohort = generate_cohort(cohort_cfg, seed=seed)
    clients = build_client_datasets(cohort)
    base = ExperimentConfig(
        rounds=rounds,
        local_epochs=local_epochs,
        central_epochs=rounds * local_epochs,
        batch_size=batch_size,
        mesh=mesh,
        use_pallas=use_pallas,
    )

    report: dict[str, Any] = {}
    for setting in settings:
        row: dict[str, Any] = {}
        setting_engines = ("vectorized",) if setting == "central" else engines
        for engine in setting_engines:
            exp = dataclasses.replace(base, engine=engine)
            out = run_setting(setting, exp, cohort, seed=seed)
            if setting == "central":
                # central has no rounds; its comparable unit is one epoch
                unit_time = out["tau_s"] / max(base.central_epochs, 1)
                time_unit = "epoch"
            else:
                unit_time = _mean_round_time(out)
                time_unit = "round"
            entry = {
                "tau_s": out["tau_s"],
                "round_time_s": unit_time,
                "time_unit": time_unit,
                "metrics": out["metrics"],
                "local_steps": out["local_steps"],
                "federation_size": out["federation_size"],
                "recruited": out["recruited"],
                "cohort_stats": out.get("cohort_stats"),
            }
            row["n/a" if setting == "central" else engine] = entry
            if verbose:
                print(
                    f"  [paper189 {setting}/{engine}] round={entry['round_time_s']:.3f}s "
                    f"tau={out['tau_s']:.1f}s msle={out['metrics']['msle']:.4f}",
                    flush=True,
                )
        if setting != "central" and set(("vectorized", "sequential")) <= set(row):
            row["speedup"] = row["sequential"]["round_time_s"] / row["vectorized"]["round_time_s"]
        report[setting] = row

    # Donation probe: one all-participants round, donated vs plain buffers.
    model_cfg = GRUConfig(use_pallas=base.use_pallas)
    loss_fn = make_loss_fn(model_cfg)
    memory: dict[str, Any] = {}
    for donate in (True, False):
        trainer = CohortTrainer(
            loss_fn=loss_fn,
            optimizer=AdamW(learning_rate=base.learning_rate, weight_decay=base.weight_decay),
            batch_size=batch_size,
            local_epochs=local_epochs,
            cohort_chunk=max(1, (len(clients) + 1) // 2),  # 2 chunks: cross-chunk peak
            mesh=mesh,
            donate=donate,
        )
        params = init_gru(jax.random.key(seed), model_cfg)
        keys = list(jax.random.split(jax.random.key(seed), len(clients)))
        new_params, _, _ = trainer.train_cohort(
            params, clients, np.random.default_rng(seed), keys
        )
        jax.block_until_ready(new_params)
        memory["donated" if donate else "plain"] = trainer.last_round_stats
    memory["donated_peak_lower"] = (
        memory["donated"]["peak_live_bytes"] < memory["plain"]["peak_live_bytes"]
    )

    return {
        "bench": "paper189",
        "num_clients": len(clients),
        "rounds": rounds,
        "local_epochs": local_epochs,
        "batch_size": batch_size,
        "total_stays": cohort_cfg.total_stays,
        "seed": seed,
        "settings": report,
        "memory": memory,
    }


STAGING_VARIANTS = ("rebuild", "rebuild-chunked", "resident", "resident-noprefetch")


def run_staging_comparison(
    *,
    rounds: int = 4,
    local_epochs: int = 1,
    batch_size: int = 32,
    seed: int = 0,
    total_stays: int = 189 * 64,
    mesh: Any = None,
    cohort_chunk: int | None = 48,
    variants: tuple[str, ...] = STAGING_VARIANTS,
    repeats: int = 2,
    verbose: bool = True,
) -> dict[str, Any]:
    """Rebuild-per-round vs device-resident staging at 189 clients.

    The workload behind ``python benchmarks/run.py --mode pipeline``: the
    full 189-hospital federation trains ``rounds`` all-participant rounds
    under each staging variant of the vectorized engine, and the report
    records per-variant steady-state round time, per-round host->device
    ``bytes_staged``, prefetch hit counts, and the two headline ratios —
    ``speedup`` (rebuild round time over resident) and ``bytes_ratio``
    (rebuild staged bytes over resident; the resident plan is O(C*T*B)
    int32s against the rebuild path's O(C*T*B*features) floats).  A
    ``max_param_diff`` parity guard across variants rides along so a bench
    run can never silently report a fast-but-wrong pipeline.

    Variant configs mirror how each path ships: ``rebuild`` is PR 2's
    vectorized engine at its benched defaults (whole cohort per call);
    ``resident`` runs chunked (``cohort_chunk``, 4 chunks at 189 clients)
    with the double-buffered plan prefetch; ``rebuild-chunked`` and
    ``resident-noprefetch`` isolate the chunking and prefetch terms.
    The model is bench-scale (hidden 8, one layer): the client axis and
    the staging path are the dimensions under test, and the paper model's
    CPU FLOPs would swamp the host-staging term this bench measures —
    CI-hardware convention shared with the tier-1 scale suites.
    """
    cohort_cfg = paper_scale_cohort_config(total_stays=total_stays)
    cohort = generate_cohort(cohort_cfg, seed=seed)
    clients = build_client_datasets(cohort)
    model_cfg = GRUConfig(hidden_dim=8, num_layers=1)
    loss_fn = make_loss_fn(model_cfg)
    params0 = init_gru(jax.random.key(seed), model_cfg)

    if isinstance(mesh, str):
        # Resolve "auto" here (mirroring CohortTrainer) so the report's
        # mesh label and chunk policy reflect the mesh that actually ran —
        # on a 1-device host "auto" degenerates to no mesh at all.
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh() if jax.device_count() > 1 else None
    # Chunking stays on under a mesh: an all-participant round's chunks are
    # contiguous runs of resident rows, so the engine's static-slice fast
    # path selects them without the cross-shard gather that used to force
    # cohort_chunk=None here.
    configs: dict[str, dict[str, Any]] = {
        "rebuild": {"staging": "rebuild", "cohort_chunk": None},
        "rebuild-chunked": {"staging": "rebuild", "cohort_chunk": cohort_chunk},
        "resident": {"staging": "resident", "prefetch": True, "cohort_chunk": cohort_chunk},
        "resident-noprefetch": {
            "staging": "resident", "prefetch": False, "cohort_chunk": cohort_chunk,
        },
    }
    results: dict[str, Any] = {}
    params_by_variant: dict[str, Any] = {}
    for variant in variants:
        fed_cfg = FederationConfig(
            rounds=rounds,
            local_epochs=local_epochs,
            batch_size=batch_size,
            selection="uniform",  # all 189 clients, every round
            seed=seed,
            engine="vectorized",
            mesh=mesh,
            **configs[variant],
        )
        # Best-of-``repeats`` over whole federations: CI containers see
        # multi-second throttling windows that can swallow one variant's
        # entire run, and the minimum of per-run medians is the standard
        # noise-robust estimate of a variant's true per-round cost.  The
        # whole entry (stats, tau, parity params) comes from the winning
        # repeat so the report never mixes measurements across runs.
        best: dict[str, Any] | None = None
        for _ in range(max(repeats, 1)):
            federation = Federation(
                fed_cfg,
                clients,
                loss_fn,
                AdamW(learning_rate=5e-3, weight_decay=5e-3),
            )
            out = federation.run(params0)
            stats = federation.cohort_trainer.last_round_stats or {}
            round_time = _mean_round_time(
                {
                    "round_times_s": [r.wall_time_s for r in out.history],
                    "tau_s": out.total_wall_time_s,
                }
            )
            if best is not None and round_time >= best["round_time_s"]:
                continue
            best = {
                "round_time_s": round_time,
                "tau_s": out.total_wall_time_s,
                "bytes_staged_per_round": stats.get("bytes_staged", 0),
                "bytes_resident": stats.get("bytes_resident", 0),
                "plans_prefetched": stats.get("plans_prefetched", 0),
                "chunks": stats.get("chunks", 0),
                "shards": stats.get("shards", 1),
                "params": out.params,
            }
        entry = {k: v for k, v in best.items() if k != "params"}
        results[variant] = entry
        params_by_variant[variant] = best["params"]
        if verbose:
            print(
                f"  [pipeline {variant}] round={entry['round_time_s']:.3f}s "
                f"staged={entry['bytes_staged_per_round']:,}B "
                f"prefetched={entry['plans_prefetched']}",
                flush=True,
            )

    report: dict[str, Any] = {
        "bench": "staging_pipeline",
        "num_clients": len(clients),
        "rounds": rounds,
        "local_epochs": local_epochs,
        "batch_size": batch_size,
        "cohort_chunk": cohort_chunk,
        "total_stays": cohort_cfg.total_stays,
        "mesh": "data" if mesh is not None else None,
        "seed": seed,
        "repeats": repeats,
        "variants": results,
    }
    if "rebuild" in results and "resident" in results:
        report["speedup"] = (
            results["rebuild"]["round_time_s"] / results["resident"]["round_time_s"]
        )
        report["bytes_ratio"] = results["rebuild"]["bytes_staged_per_round"] / max(
            results["resident"]["bytes_staged_per_round"], 1
        )
        if "rebuild-chunked" in results:
            report["speedup_vs_chunked_rebuild"] = (
                results["rebuild-chunked"]["round_time_s"]
                / results["resident"]["round_time_s"]
            )
        ref = jax.tree.leaves(params_by_variant["rebuild"])
        diffs = [
            float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
            for other in params_by_variant.values()
            for a, b in zip(ref, jax.tree.leaves(other))
        ]
        report["max_param_diff"] = max(diffs)
    return report


def run_facade_overhead(
    *,
    rounds: int = 9,
    local_epochs: int = 1,
    batch_size: int = 8,
    seed: int = 0,
    total_stays: int = 189 * 16,
    repeats: int = 3,
    verbose: bool = True,
) -> dict[str, Any]:
    """The facade tax: ``Federation.run`` vs the bare PR-3 hot loop.

    Both drive the identical workload — the full 189-client federation,
    all participants every round, resident staging, one ``chain_split_keys``
    + ``train_cohort`` per round — but the bare loop has zero policy
    dispatch, no selection call, no comm accounting, no ``RoundRecord``.
    The facade's round program must cost <= 2% over that floor (the bench
    records the measured fraction in ``BENCH_pipeline.json``; per-round
    training dominates by orders of magnitude, so anything above noise
    level indicates the round program grew a hot-path sin).

    A 2% budget is far below CI containers' round-to-round throttling
    noise (individual rounds swing +-25%), so the estimator is the *floor*:
    the minimum steady-state round over ``repeats`` alternating bare/facade
    runs.  Timing noise on this workload is strictly additive, so the
    per-path minimum converges on the true per-round cost as samples grow
    (``rounds`` x ``repeats`` per path) and the facade/bare floor ratio
    isolates the systematic overhead — a median would report the
    throttling weather instead.  The report carries the per-repeat floors
    (``bare_floors`` / ``facade_floors``): their spread is the probe's own
    resolution, and an |overhead_frac| inside that spread — negative
    values included — reads as "no overhead resolvable", not as a
    measured speedup.
    """
    cohort_cfg = paper_scale_cohort_config(total_stays=total_stays)
    cohort = generate_cohort(cohort_cfg, seed=seed)
    clients = build_client_datasets(cohort)
    model_cfg = GRUConfig(hidden_dim=8, num_layers=1)
    loss_fn = make_loss_fn(model_cfg)
    params0 = init_gru(jax.random.key(seed), model_cfg)

    def bare_rounds() -> list[float]:
        trainer = CohortTrainer(
            loss_fn=loss_fn,
            optimizer=AdamW(learning_rate=5e-3, weight_decay=5e-3),
            batch_size=batch_size,
            local_epochs=local_epochs,
            staging="resident",
        )
        trainer.attach_device_cohort(clients)
        rng = np.random.default_rng(seed)
        jax_rng = jax.random.key(seed)
        spe = cohort_steps_per_epoch([c.n_train for c in clients], batch_size)
        params, times = params0, []
        for _ in range(rounds):
            t0 = time.perf_counter()
            jax_rng, key_data = chain_split_keys(jax_rng, len(clients))
            params, _, _ = trainer.train_cohort(
                params, clients, rng, key_data, steps_per_epoch=spe
            )
            times.append(time.perf_counter() - t0)
        jax.block_until_ready(params)
        return times

    def facade_rounds() -> list[float]:
        federation = Federation(
            FederationConfig(
                rounds=rounds, local_epochs=local_epochs, batch_size=batch_size,
                recruitment="all", selection="uniform", aggregator="fedavg", seed=seed,
            ),
            clients,
            loss_fn,
            AdamW(learning_rate=5e-3, weight_decay=5e-3),
        )
        out = federation.run(params0)
        jax.block_until_ready(out.params)
        return [r.wall_time_s for r in out.history]

    def floor(times: list[float]) -> float:
        return float(np.min(times[1:] if len(times) > 1 else times))

    # Alternate the two paths so a throttling window cannot hit only one.
    bare_floors, facade_floors = [], []
    for _ in range(max(repeats, 1)):
        bare_floors.append(floor(bare_rounds()))
        facade_floors.append(floor(facade_rounds()))
    bare, facade = min(bare_floors), min(facade_floors)
    overhead = facade / bare - 1.0
    report = {
        "bench": "facade_overhead",
        "num_clients": len(clients),
        "rounds": rounds,
        "batch_size": batch_size,
        "repeats": repeats,
        "bare_round_s": bare,
        "facade_round_s": facade,
        "bare_floors": bare_floors,
        "facade_floors": facade_floors,
        "overhead_frac": overhead,
        "budget_frac": 0.02,
        "within_budget": bool(overhead <= 0.02),
    }
    if verbose:
        print(
            f"  [facade] bare={bare:.4f}s facade={facade:.4f}s "
            f"overhead={100 * overhead:+.2f}% (budget 2%)",
            flush=True,
        )
    return report


def run_obs_overhead(
    *,
    rounds: int = 10,
    flushes: int = 10,
    local_epochs: int = 1,
    batch_size: int = 8,
    seed: int = 0,
    total_stays: int = 189 * 16,
    buffer_size: int = 32,
    repeats: int = 3,
    trace_capacity: int = 262144,
    trace_path: str | None = None,
    verbose: bool = True,
) -> dict[str, Any]:
    """The observability tax: tracer-off and tracer-on vs the bare loop.

    Three sync variants drive the identical 189-client workload — the bare
    PR-3 hot loop, ``Federation.run`` with the default null tracer, and
    ``Federation.run`` with a live :class:`repro.obs.trace.Tracer` — plus an
    off/on pair through the async virtual-clock engine (fedbuff, constant
    latency, no dropout, so every flush is the same unit of work).  Budgets:
    instrumented-off <= 1% over the bare loop (the null tracer is a handful
    of attribute lookups per round; anything more is a hot-path sin) and
    tracer-on <= 5% over tracer-off in both engines.  The async off path
    reuses the sync path's null-tracer primitives, so its off budget rides
    the sync probe.

    Same floor estimator as :func:`run_facade_overhead`: CI throttling noise
    is strictly additive, so the per-variant minimum steady-state round over
    alternating repeats converges on the true cost, and the floor ratios
    isolate the systematic overhead.
    """
    from repro.obs.trace import Tracer

    cohort_cfg = paper_scale_cohort_config(total_stays=total_stays)
    cohort = generate_cohort(cohort_cfg, seed=seed)
    clients = build_client_datasets(cohort)
    model_cfg = GRUConfig(hidden_dim=8, num_layers=1)
    loss_fn = make_loss_fn(model_cfg)
    params0 = init_gru(jax.random.key(seed), model_cfg)

    def optimizer() -> AdamW:
        return AdamW(learning_rate=5e-3, weight_decay=5e-3)

    def bare_rounds() -> list[float]:
        trainer = CohortTrainer(
            loss_fn=loss_fn,
            optimizer=optimizer(),
            batch_size=batch_size,
            local_epochs=local_epochs,
            staging="resident",
        )
        trainer.attach_device_cohort(clients)
        rng = np.random.default_rng(seed)
        jax_rng = jax.random.key(seed)
        spe = cohort_steps_per_epoch([c.n_train for c in clients], batch_size)
        params, times = params0, []
        for _ in range(rounds):
            t0 = time.perf_counter()
            jax_rng, key_data = chain_split_keys(jax_rng, len(clients))
            params, _, _ = trainer.train_cohort(
                params, clients, rng, key_data, steps_per_epoch=spe
            )
            times.append(time.perf_counter() - t0)
        jax.block_until_ready(params)
        return times

    def sync_rounds(tracer: Tracer | None) -> list[float]:
        federation = Federation(
            FederationConfig(
                rounds=rounds, local_epochs=local_epochs, batch_size=batch_size,
                recruitment="all", selection="uniform", aggregator="fedavg", seed=seed,
            ),
            clients,
            loss_fn,
            optimizer(),
            tracer=tracer,
        )
        out = federation.run(params0)
        jax.block_until_ready(out.params)
        return [r.wall_time_s for r in out.history]

    def async_flushes(tracer: Tracer | None) -> list[float]:
        federation = AsyncFederation(
            AsyncFederationConfig(
                rounds=flushes, local_epochs=local_epochs, batch_size=batch_size,
                recruitment="all", aggregator=f"fedbuff:{buffer_size}",
                latency="constant", dropout="never", seed=seed,
            ),
            clients,
            loss_fn,
            optimizer(),
            tracer=tracer,
        )
        out = federation.run(params0)
        jax.block_until_ready(out.params)
        return [r.wall_time_s for r in out.history]

    def floor(times: list[float]) -> float:
        return float(np.min(times[1:] if len(times) > 1 else times))

    # Alternate every variant inside each repeat so a throttling window
    # cannot hit only one path.
    floors: dict[str, list[float]] = {
        "bare": [], "sync_off": [], "sync_on": [], "async_off": [], "async_on": [],
    }
    trace_stats: dict[str, Any] = {}
    last_async_tracer: Tracer | None = None
    for _ in range(max(repeats, 1)):
        floors["bare"].append(floor(bare_rounds()))
        floors["sync_off"].append(floor(sync_rounds(None)))
        sync_tracer = Tracer(capacity=trace_capacity)
        floors["sync_on"].append(floor(sync_rounds(sync_tracer)))
        floors["async_off"].append(floor(async_flushes(None)))
        async_tracer = Tracer(capacity=trace_capacity)
        floors["async_on"].append(floor(async_flushes(async_tracer)))
        trace_stats = {
            "sync_events": len(sync_tracer.events()),
            "async_events": len(async_tracer.events()),
            "sync_dropped": sync_tracer.dropped,
            "async_dropped": async_tracer.dropped,
        }
        last_async_tracer = async_tracer
    best = {name: min(values) for name, values in floors.items()}
    sync_off = best["sync_off"] / best["bare"] - 1.0
    sync_on = best["sync_on"] / best["sync_off"] - 1.0
    async_on = best["async_on"] / best["async_off"] - 1.0
    budget_off, budget_on = 0.01, 0.05
    report = {
        "bench": "obs_overhead",
        "num_clients": len(clients),
        "rounds": rounds,
        "flushes": flushes,
        "batch_size": batch_size,
        "repeats": repeats,
        "floors": floors,
        "sync": {
            "bare_round_s": best["bare"],
            "off_round_s": best["sync_off"],
            "on_round_s": best["sync_on"],
            "overhead_off_frac": sync_off,
            "overhead_on_frac": sync_on,
        },
        "async": {
            "off_flush_s": best["async_off"],
            "on_flush_s": best["async_on"],
            "overhead_on_frac": async_on,
        },
        "trace": trace_stats,
        "budget_off_frac": budget_off,
        "budget_on_frac": budget_on,
        "within_budget": bool(
            sync_off <= budget_off and sync_on <= budget_on and async_on <= budget_on
        ),
    }
    if trace_path is not None and last_async_tracer is not None:
        report["trace"]["sample_path"] = last_async_tracer.export_chrome(trace_path)
    if verbose:
        print(
            f"  [obs sync] bare={best['bare']:.4f}s off={best['sync_off']:.4f}s "
            f"on={best['sync_on']:.4f}s off_overhead={100 * sync_off:+.2f}% "
            f"on_overhead={100 * sync_on:+.2f}% (budgets 1%/5%)",
            flush=True,
        )
        print(
            f"  [obs async] off={best['async_off']:.4f}s on={best['async_on']:.4f}s "
            f"on_overhead={100 * async_on:+.2f}% (budget 5%)",
            flush=True,
        )
    return report


ASYNC_LATENCY_MODELS = ("lognormal:0.6", "pareto:1.2")

ASYNC_FEDERATIONS = (("all-clients", "all"), ("recruited", None))  # None -> nu-greedy


def time_to_target(history, target_loss: float) -> float | None:
    """First virtual time the *running best* flush loss reaches the target.

    The running minimum makes the crossing monotone (per-flush losses are
    noisy at small buffer sizes), so two federations compared at the same
    target answer exactly the paper's question: which one got there first
    on the simulated clock.  ``None`` if the run never reached the target.
    """
    best = float("inf")
    for record in history:
        if np.isfinite(record.mean_local_loss):
            best = min(best, record.mean_local_loss)
        if best <= target_loss:
            return record.virtual_time
    return None


def shared_time_to_target(
    histories: dict[str, Any],
) -> tuple[float, dict[str, float | None]]:
    """Shared target loss + per-run virtual time to reach it.

    The target is the *worse* of the runs' best finite flush losses — the
    first level every run demonstrably reaches, so the comparison never
    rewards a run for a target only it attained.  If any run posts no
    finite loss at all (divergence, or zero flushes) no shared target
    exists: the target is NaN and every time is ``None``.  The single
    definition both ``run_async_comparison`` and the async example quote.
    """
    finals = {}
    for name, history in histories.items():
        finite = [r.mean_local_loss for r in history if np.isfinite(r.mean_local_loss)]
        finals[name] = min(finite) if finite else float("nan")
    comparable = bool(finals) and all(np.isfinite(v) for v in finals.values())
    target = max(finals.values()) if comparable else float("nan")
    times = {
        name: time_to_target(history, target) if comparable else None
        for name, history in histories.items()
    }
    return target, times


def run_async_comparison(
    *,
    flushes: int = 8,
    local_epochs: int = 1,
    batch_size: int = 16,
    seed: int = 0,
    cohort_scale: float = 0.05,
    buffer_frac: float = 0.25,
    dropout: float = 0.05,
    latency_models: tuple[str, ...] = ASYNC_LATENCY_MODELS,
    verbose: bool = True,
) -> dict[str, Any]:
    """Recruited vs all-clients federations on simulated time-to-target-loss.

    The workload behind ``python benchmarks/run.py --mode async``: the
    paper's section-6 claim — recruiting fewer, better clients cuts
    *training time* without sacrificing predictive power — measured on the
    axis the synchronous engines cannot express: a virtual wall clock with
    per-client straggler latencies and dropout.  For each latency model the
    ``"all"`` and nu-greedy federations each run a ``fedbuff`` async
    federation (buffer = ``buffer_frac`` of the federation, so both flush
    at the same *relative* cadence), and the report records the full loss
    trajectory against virtual time plus the headline number: the
    simulated time to reach a shared target loss (the worse of the two
    final running-best losses, so both federations provably reach it) and
    the recruited federation's speedup on that clock.

    The cohort is the *heterogeneous* synthetic eICU population (not the
    stratified paper-scale grid): recruitment needs real disclosure spread
    to choose from, and the straggler models need real size spread to
    punish.  The model is bench-scale (hidden 8) — the dimension under
    test is the timeline, not the FLOPs.
    """
    cohort = generate_cohort(CohortConfig().scaled(cohort_scale), seed=seed)
    clients = build_client_datasets(cohort)
    model_cfg = GRUConfig(hidden_dim=8, num_layers=1)
    loss_fn = make_loss_fn(model_cfg)
    params0 = init_gru(jax.random.key(seed), model_cfg)

    report: dict[str, Any] = {
        "bench": "async_runtime",
        "num_clients": len(clients),
        "flushes": flushes,
        "local_epochs": local_epochs,
        "batch_size": batch_size,
        "buffer_frac": buffer_frac,
        "dropout": dropout,
        "cohort_scale": cohort_scale,
        "seed": seed,
        "latency": {},
    }
    base = ExperimentConfig()
    recruited_spec = f"nu-greedy:{base.gamma_dv},{base.gamma_sa},{base.gamma_th}"
    for latency in latency_models:
        row: dict[str, Any] = {}
        histories: dict[str, Any] = {}
        for name, rec in ASYNC_FEDERATIONS:
            federation = AsyncFederation(
                AsyncFederationConfig(
                    rounds=flushes,
                    local_epochs=local_epochs,
                    batch_size=batch_size,
                    recruitment=rec if rec is not None else recruited_spec,
                    # A fractional buffer resolves against the federation
                    # that actually forms, so both settings flush at the
                    # same relative cadence.
                    aggregator=f"fedbuff:{float(buffer_frac)}",
                    latency=latency,
                    dropout=dropout,
                    seed=seed,
                ),
                clients,
                loss_fn,
                AdamW(learning_rate=base.learning_rate, weight_decay=base.weight_decay),
            )
            out = federation.run(params0)
            stats = federation.last_run_stats or {}
            losses = [r.mean_local_loss for r in out.history]
            row[name] = {
                "federation_size": int(out.federation_ids.size),
                "recruited": None
                if out.recruitment is None
                else out.recruitment.num_recruited,
                "buffer_size": federation.aggregator.buffer_size,
                "flushes": len(out.history),
                "virtual_time": stats.get("virtual_time"),
                "mean_staleness": out.summary()["mean_staleness"],
                "tasks": stats.get("tasks"),
                "dropped": stats.get("dropped"),
                "final_loss": float(np.nanmin(losses)) if losses else float("nan"),
                "trajectory": [
                    (r.virtual_time, r.mean_local_loss) for r in out.history
                ],
                "tau_s": out.total_wall_time_s,
            }
            histories[name] = out.history
        target, times = shared_time_to_target(histories)
        for name, _ in ASYNC_FEDERATIONS:
            row[name]["time_to_target"] = times[name]
        row["target_loss"] = target
        t_all = row["all-clients"]["time_to_target"]
        t_rec = row["recruited"]["time_to_target"]
        row["recruited_speedup"] = (
            t_all / t_rec if t_all is not None and t_rec is not None and t_rec > 0 else None
        )
        report["latency"][latency] = row
        if verbose:
            for name, _ in ASYNC_FEDERATIONS:
                entry = row[name]
                reached = entry["time_to_target"]
                stale = entry["mean_staleness"]
                print(
                    f"  [async {latency} {name}] fed={entry['federation_size']} "
                    f"t_target="
                    + (f"{reached:.2f}s(v) " if reached is not None else "unreached ")
                    + (f"stale={stale:.2f} " if stale is not None else "")
                    + f"dropped={entry['dropped']}",
                    flush=True,
                )
            if row["recruited_speedup"] is not None:
                print(
                    f"  [async {latency}] recruited reaches loss<="
                    f"{target:.4f} {row['recruited_speedup']:.2f}x sooner "
                    "on the virtual clock",
                    flush=True,
                )
    return report


def job_spec_for(setting: str, exp: ExperimentConfig, seed: int = 0) -> dict[str, Any]:
    """One section-6 setting -> a control-plane job spec (a submit file).

    The declarative twin of :func:`run_setting`: the same
    ``policies_for`` translation table rendered as the JSON the
    :mod:`repro.launch.federation_service` CLI accepts, so every paper
    setting can run as a submitted job with checkpoint/resume and a
    streamed record file.  ``central`` is pooled training, not a
    federation — it has no job-spec form.
    """
    if setting == "central":
        raise ValueError("'central' is pooled training, not a federated job")
    if setting not in MODEL_SETTINGS:
        raise ValueError(f"unknown setting {setting}; choose from {MODEL_SETTINGS}")
    if exp.mesh not in (None, "auto"):
        raise ValueError(
            "job specs are JSON: mesh must be null or 'auto' (drive the "
            "Federation facade directly to pass a Mesh object)"
        )
    policies = policies_for(setting, exp)
    if not all(isinstance(v, str) for v in policies.values()):
        raise ValueError(
            "job specs are JSON: policy overrides must be spec strings, "
            "not instances"
        )
    return {
        "name": setting,
        "mode": "sync",
        "rounds": exp.rounds,
        "local_epochs": exp.local_epochs,
        "batch_size": exp.batch_size,
        "seed": seed,
        **policies,
        "engine": exp.engine,
        "cohort_chunk": exp.cohort_chunk,
        "mesh": exp.mesh,
        "staging": exp.staging,
        "prefetch": exp.prefetch,
        "donate_buffers": exp.donate_buffers,
        "data": {"scale": exp.cohort_scale, "seed": seed},
        "model": {"use_pallas": exp.use_pallas},
        "optimizer": {
            "learning_rate": exp.learning_rate,
            "weight_decay": exp.weight_decay,
        },
    }


def run_settings_as_jobs(
    exp: ExperimentConfig,
    run_root: str,
    *,
    settings: tuple[str, ...] = ("federated-ac", "federated-src"),
    seed: int = 0,
    verbose: bool = True,
) -> dict[str, Any]:
    """Submit section-6 settings through the control plane.

    Each setting becomes one run directory under ``run_root`` (job.json,
    records.jsonl, checkpoint/, final/, result.json).  Test-split metric
    evaluation stays with :func:`run_setting`; this driver exists so the
    paper grid exercises — and is recoverable through — the service path.
    """
    import os

    from repro.launch.federation_service import submit_job

    results: dict[str, Any] = {}
    for setting in settings:
        spec = job_spec_for(setting, exp, seed=seed)
        out = submit_job(spec, os.path.join(run_root, setting))
        if verbose:
            s = out["summary"]
            print(
                f"  [job {setting}] rounds={s['rounds']} "
                f"federation={s['federation_size']} "
                f"tau={s['total_wall_time_s']:.1f}s",
                flush=True,
            )
        results[setting] = out
    return results


def run_service_overhead(
    *,
    rounds: int = 6,
    local_epochs: int = 1,
    batch_size: int = 8,
    seed: int = 0,
    scale: float = 0.02,
    checkpoint_every: int = 2,
    repeats: int = 3,
    verbose: bool = True,
) -> dict[str, Any]:
    """The control-plane tax: a submitted job vs direct ``Federation.run``.

    Both paths execute the identical workload — ``build_workload`` on the
    same normalized spec, then the same facade run — but the submitted job
    also pays validation + spec hashing, job.json persistence, the per
    round JSONL record stream, snapshots at ``checkpoint_every``, and the
    final-params save.  That whole service envelope must cost <= 2% over
    the direct run.

    Same estimator story as :func:`run_facade_overhead`: CI noise dwarfs
    the budget, timing noise is additive, so each path's *floor* over
    alternating end-to-end repeats (first repeat excluded per path — it
    pays jit compilation) isolates the systematic cost; per-repeat totals
    ship in the report so the probe's own resolution is visible.
    """
    import tempfile

    from repro.launch.federation_service import (
        build_workload,
        federation_config_from_spec,
        submit_job,
        validate_job_spec,
    )

    spec = validate_job_spec(
        {
            "name": "service-overhead",
            "mode": "sync",
            "rounds": rounds,
            "local_epochs": local_epochs,
            "batch_size": batch_size,
            "seed": seed,
            "recruitment": "all",
            "selection": "uniform",
            "checkpoint_every": checkpoint_every,
            "data": {"scale": scale, "seed": seed, "split_mode": "stratified"},
            "model": {"hidden_dim": 8, "num_layers": 1},
        }
    )

    def direct_total() -> float:
        t0 = time.perf_counter()
        workload = build_workload(spec)
        federation = Federation(
            federation_config_from_spec(spec),
            workload.clients,
            workload.loss_fn,
            workload.optimizer,
        )
        out = federation.run(workload.init_params)
        jax.block_until_ready(out.params)
        return time.perf_counter() - t0

    def service_total() -> float:
        with tempfile.TemporaryDirectory() as run_dir:
            t0 = time.perf_counter()
            submit_job(spec, run_dir)
            return time.perf_counter() - t0

    # Alternate the paths so a throttling window cannot hit only one; the
    # first repeat of each pays compilation and is excluded from the floor.
    direct_totals, service_totals = [], []
    for _ in range(max(repeats, 1) + 1):
        direct_totals.append(direct_total())
        service_totals.append(service_total())
    direct = float(np.min(direct_totals[1:]))
    service = float(np.min(service_totals[1:]))
    overhead = service / direct - 1.0
    report = {
        "bench": "service_overhead",
        "rounds": rounds,
        "batch_size": batch_size,
        "checkpoint_every": checkpoint_every,
        "repeats": repeats,
        "direct_total_s": direct,
        "service_total_s": service,
        "direct_totals": direct_totals,
        "service_totals": service_totals,
        "overhead_frac": overhead,
        "budget_frac": 0.02,
        "within_budget": bool(overhead <= 0.02),
    }
    if verbose:
        print(
            f"  [service] direct={direct:.4f}s submitted={service:.4f}s "
            f"overhead={100 * overhead:+.2f}% (budget 2%)",
            flush=True,
        )
    return report


def run_seeds(
    setting: str, exp: ExperimentConfig, seeds: list[int], verbose: bool = True
) -> dict[str, Any]:
    """Multi-seed runs -> mean/std per metric (paper reports mean +/- std)."""
    runs = []
    for seed in seeds:
        cohort = build_cohort(exp, seed=seed)
        out = run_setting(setting, exp, cohort, seed=seed)
        if verbose:
            m = out["metrics"]
            print(
                f"  [{setting} seed={seed}] mae={m['mae']:.3f} mape={m['mape']:.3f} "
                f"mse={m['mse']:.2f} msle={m['msle']:.3f} tau={out['tau_s']:.1f}s",
                flush=True,
            )
        runs.append(out)
    agg: dict[str, Any] = {"setting": setting, "seeds": seeds, "runs": runs}
    for key in ("mae", "mape", "mse", "msle"):
        vals = np.array([r["metrics"][key] for r in runs])
        agg[key] = {"mean": float(vals.mean()), "std": float(vals.std(ddof=1) if len(vals) > 1 else 0.0),
                    "values": vals.tolist()}
    taus = np.array([r["tau_s"] for r in runs])
    agg["tau_s"] = {"mean": float(taus.mean()), "std": float(taus.std(ddof=1) if len(taus) > 1 else 0.0),
                    "values": taus.tolist()}
    agg["local_steps"] = int(np.mean([r["local_steps"] for r in runs]))
    agg["federation_size"] = runs[0]["federation_size"]
    agg["recruited"] = runs[0]["recruited"]
    return agg


def run_privacy_frontier(
    exp: ExperimentConfig | None = None,
    *,
    setting: str = "federated-ac",
    clip_norm: float = 1.0,
    noise_multipliers: tuple = (0.5, 1.0, 2.0),
    attacks: tuple = ("label-flip", "scaled-update"),
    attack_fractions: tuple = (0.1, 0.2, 0.3),
    aggregators: tuple = ("fedavg", "trimmed-mean:0.35", "krum:4"),
    attack_scale: float = 50.0,
    scenario_seed: int = 5,
    seed: int = 0,
    verbose: bool = True,
) -> dict[str, Any]:
    """The two privacy-tier frontiers on one cohort.

    ``utility``: test metrics vs the accountant's final ``(epsilon,
    delta)`` across noise multipliers, with the unprotected run as the
    epsilon = None anchor — the utility cost of DP at the paper's
    setting.  ``robustness``: test metrics for every (aggregator, attack,
    attacker fraction) cell, with each aggregator's clean run as its own
    baseline — what plain FedAvg loses under attack and the robust rules
    retain.  Metrics come from the hold-out test split, which no attacker
    touches.
    """
    from repro.privacy.adversary import ScenarioConfig, apply_scenario
    from repro.privacy.dp import DPConfig

    exp = exp or ExperimentConfig()
    cohort = build_cohort(exp, seed=seed)
    clients = build_client_datasets(cohort)
    test = global_dataset(cohort, Cohort.TEST)
    model_cfg = GRUConfig(use_pallas=exp.use_pallas)
    loss_fn = make_loss_fn(model_cfg)
    optimizer = AdamW(learning_rate=exp.learning_rate, weight_decay=exp.weight_decay)
    init_params = init_gru(jax.random.key(seed), model_cfg)

    def one_run(privacy=None, aggregator=None, scenario=None) -> dict[str, Any]:
        policies = policies_for(setting, exp)
        if aggregator is not None:
            policies["aggregator"] = aggregator
        fed_cfg = FederationConfig(
            rounds=exp.rounds,
            local_epochs=exp.local_epochs,
            batch_size=exp.batch_size,
            **policies,
            seed=seed,
            engine=exp.engine,
            cohort_chunk=exp.cohort_chunk,
            mesh=exp.mesh,
            donate_buffers=exp.donate_buffers,
            staging=exp.staging,
            prefetch=exp.prefetch,
            privacy=privacy,
        )
        federation = Federation(fed_cfg, clients, loss_fn, optimizer)
        if scenario is not None:
            apply_scenario(federation, scenario)
        result = federation.run(init_params)
        y_hat = np.asarray(_predict(result.params, model_cfg, test))
        return {
            "metrics": evaluate_predictions(test.y, y_hat),
            "epsilon": result.summary()["epsilon"],
            "tau_s": result.total_wall_time_s,
            "engine": federation.effective_engine,
        }

    out: dict[str, Any] = {
        "setting": setting,
        "seed": seed,
        "clip_norm": clip_norm,
        "utility": [],
        "robustness": [],
    }

    baseline = one_run()
    out["utility"].append({"privacy": None, "epsilon": None, **baseline})
    if verbose:
        m = baseline["metrics"]
        print(f"  [privacy {setting}] unprotected mae={m['mae']:.3f}", flush=True)
    for nm in noise_multipliers:
        dp = DPConfig(clip_norm=clip_norm, noise_multiplier=float(nm))
        run = one_run(privacy=dp)
        out["utility"].append({"privacy": dp.to_state(), **run})
        if verbose:
            m = run["metrics"]
            print(
                f"  [privacy {setting}] sigma/C={nm:g} "
                f"eps={run['epsilon']:.2f} mae={m['mae']:.3f}",
                flush=True,
            )

    for aggregator in aggregators:
        clean = one_run(aggregator=aggregator)
        out["robustness"].append(
            {"aggregator": aggregator, "attack": None, "fraction": 0.0, **clean}
        )
        for attack in attacks:
            for fraction in attack_fractions:
                scenario = ScenarioConfig(
                    attack=attack,
                    fraction=float(fraction),
                    scale=attack_scale,
                    seed=scenario_seed,
                )
                run = one_run(aggregator=aggregator, scenario=scenario)
                out["robustness"].append(
                    {
                        "aggregator": aggregator,
                        "attack": attack,
                        "fraction": float(fraction),
                        **run,
                    }
                )
                if verbose:
                    m = run["metrics"]
                    print(
                        f"  [privacy {setting}] {aggregator} {attack}@{fraction:g} "
                        f"mae={m['mae']:.3f} (clean {clean['metrics']['mae']:.3f})",
                        flush=True,
                    )
    return out
