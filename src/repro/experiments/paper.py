"""The paper's experiments, end to end, on the synthetic eICU cohort.

Five model settings (paper section 6):

  central        — pooled training, 15 epochs (upper bound)
  federated-ac   — all 189 clients, all participate each round
  federated-sc   — all clients in federation, 10% sampled per round (the
                   "standard FL" baseline the paper tests against)
  federated-arc  — recruited clients only, all participate
  federated-src  — recruited clients only, 10% sampled per round

plus the section 6.2 ablations (quality-greedy / data-greedy) and the
gamma_th sweep of Fig. 2.  Each run reports the paper's four metrics plus
wall-time tau and simulated local-step counts.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.core.recruitment import (
    BALANCED,
    DATA_GREEDY,
    QUALITY_GREEDY,
    RecruitmentConfig,
)
from repro.data.pipeline import ArrayDataset, build_client_datasets, global_dataset
from repro.data.synth_eicu import NUM_HOSPITALS, Cohort, CohortConfig, generate_cohort
from repro.federated.central import CentralConfig, train_central
from repro.federated.server import FederatedConfig, FederatedServer
from repro.metrics.regression import evaluate_predictions
from repro.models.gru import GRUConfig, gru_apply, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

MODEL_SETTINGS = (
    "central",
    "federated-ac",
    "federated-sc",
    "federated-arc",
    "federated-src",
    "federated-src-qg",
    "federated-src-dg",
)


@dataclasses.dataclass(frozen=True)
class ExperimentConfig:
    """Paper-faithful defaults (Tables 1 and 3)."""

    cohort_scale: float = 1.0      # 1.0 = full 89,127-stay cohort
    rounds: int = 15
    local_epochs: int = 4
    central_epochs: int = 15
    batch_size: int = 128
    learning_rate: float = 5e-3
    weight_decay: float = 5e-3
    participation_fraction: float = 0.1
    gamma_dv: float = 0.5
    gamma_sa: float = 0.5
    gamma_th: float = 0.1
    use_pallas: bool = False
    # Federated training engine: "vectorized" (one jitted vmap per round)
    # or "sequential" (per-client Python loop, the reference oracle).
    engine: str = "vectorized"
    # Vectorized engine: clients per vmapped call (None = whole cohort).
    cohort_chunk: int | None = None
    # Vectorized engine: device mesh for the client axis (None, a Mesh, or
    # "auto" for a 1-D data mesh over every visible device).
    mesh: Any = None
    # Vectorized engine: donate round buffers (in-place accumulator, eager
    # release of consumed schedule chunks).
    donate_buffers: bool = True


def recruitment_for(setting: str, exp: ExperimentConfig) -> RecruitmentConfig | None:
    if setting in ("central", "federated-ac", "federated-sc"):
        return None
    if setting == "federated-src-qg":
        return dataclasses.replace(QUALITY_GREEDY, gamma_th=exp.gamma_th)
    if setting == "federated-src-dg":
        return dataclasses.replace(DATA_GREEDY, gamma_th=exp.gamma_th)
    return RecruitmentConfig(exp.gamma_dv, exp.gamma_sa, exp.gamma_th)


def participation_for(setting: str, exp: ExperimentConfig) -> float | None:
    if setting in ("federated-ac", "federated-arc"):
        return None  # everyone, every round
    return exp.participation_fraction


def build_cohort(exp: ExperimentConfig, seed: int) -> Cohort:
    cfg = CohortConfig()
    if exp.cohort_scale != 1.0:
        cfg = cfg.scaled(exp.cohort_scale)
    return generate_cohort(cfg, seed=seed)


def run_setting(
    setting: str,
    exp: ExperimentConfig,
    cohort: Cohort,
    seed: int,
    progress: Any | None = None,
) -> dict[str, Any]:
    """Train one model setting and evaluate on the hold-out test split."""
    if setting not in MODEL_SETTINGS:
        raise ValueError(f"unknown setting {setting}; choose from {MODEL_SETTINGS}")

    model_cfg = GRUConfig(use_pallas=exp.use_pallas)
    loss_fn = make_loss_fn(model_cfg)
    optimizer = AdamW(learning_rate=exp.learning_rate, weight_decay=exp.weight_decay)
    init_params = init_gru(jax.random.key(seed), model_cfg)
    test = global_dataset(cohort, Cohort.TEST)

    info: dict[str, Any] = {"setting": setting, "seed": seed}
    if setting == "central":
        result = train_central(
            CentralConfig(epochs=exp.central_epochs, batch_size=exp.batch_size, seed=seed),
            global_dataset(cohort, Cohort.TRAIN),
            init_params,
            loss_fn,
            optimizer,
        )
        params = result.params
        info.update(
            tau_s=result.total_wall_time_s,
            local_steps=result.total_steps,
            federation_size=None,
            recruited=None,
            engine=None,
            round_times_s=None,
            cohort_stats=None,
        )
    else:
        clients = build_client_datasets(cohort)
        fed_cfg = FederatedConfig(
            rounds=exp.rounds,
            local_epochs=exp.local_epochs,
            batch_size=exp.batch_size,
            participation_fraction=participation_for(setting, exp),
            recruitment=recruitment_for(setting, exp),
            seed=seed,
            engine=exp.engine,
            cohort_chunk=exp.cohort_chunk,
            mesh=exp.mesh,
            donate_buffers=exp.donate_buffers,
        )
        server = FederatedServer(fed_cfg, clients, loss_fn, optimizer)
        result = server.run(init_params, progress=progress)
        params = result.params
        info.update(
            tau_s=result.total_wall_time_s,
            local_steps=result.total_local_steps,
            federation_size=int(result.federation_ids.size),
            recruited=None if result.recruitment is None else result.recruitment.num_recruited,
            engine=exp.engine,
            round_times_s=[r.wall_time_s for r in result.history],
            cohort_stats=server.cohort_trainer.last_round_stats,
        )

    y_hat = np.asarray(_predict(params, model_cfg, test))
    info["metrics"] = evaluate_predictions(test.y, y_hat)
    return info


def _predict(params, model_cfg: GRUConfig, dataset: ArrayDataset, batch: int = 2048) -> np.ndarray:
    fn = jax.jit(lambda p, x: gru_apply(p, model_cfg, x))
    outs = []
    for start in range(0, len(dataset), batch):
        outs.append(np.asarray(fn(params, dataset.x[start : start + batch])))
    return np.concatenate(outs)


def paper_scale_cohort_config(total_stays: int = 189 * 23) -> CohortConfig:
    """A 189-hospital cohort sized for CI hardware.

    The paper's full cohort (89,127 stays) is CPU-hostile, but the scale
    dimension the engines care about is the *client count*, so this keeps
    all 189 hospitals and shrinks per-hospital data to ~23 stays each —
    the dispatch-bound many-small-hospitals regime the vectorized engine
    exists for (the eICU tail, not the big academic centers).  The split
    is hospital-stratified so every client lands the same local train size:
    each survives the ``min_train=2`` cut (the federation really is 189
    clients) and the vectorized schedule's shared step axis is exactly
    every client's real step count (no masked padding in the benchmark).
    """
    num = NUM_HOSPITALS
    return CohortConfig(
        total_stays=max(total_stays, num * 8),
        min_hospital_size=max(total_stays // num, 8),
        split_mode="stratified",
    )


PAPER_SCALE_SETTINGS = (
    "central",
    "federated-ac",
    "federated-sc",
    "federated-arc",
    "federated-src",
)


def _mean_round_time(info: dict[str, Any]) -> float:
    """Steady-state seconds per round: drop round 0 (it pays compilation)
    and take the median (robust to noisy-neighbor spikes on CI hosts)."""
    times = info.get("round_times_s")
    if not times:
        return float(info["tau_s"])
    return float(np.median(times[1:] if len(times) > 1 else times))


def run_paper_scale(
    *,
    rounds: int = 3,
    local_epochs: int = 1,
    batch_size: int = 4,
    seed: int = 0,
    total_stays: int = 189 * 23,
    engines: tuple[str, ...] = ("vectorized", "sequential"),
    mesh: Any = None,
    settings: tuple[str, ...] = PAPER_SCALE_SETTINGS,
    verbose: bool = True,
) -> dict[str, Any]:
    """The paper's full five-setting grid at 189 clients, under both engines.

    The workload behind ``python benchmarks/run.py --mode paper189``: every
    model setting of section 6 runs end to end on a 189-hospital cohort,
    each federated setting once per engine, recording per-setting
    steady-state round time, test metrics, and the vectorized engine's
    peak live-buffer footprint.  A donation probe additionally runs one
    all-clients round with buffer donation on and off and records both
    footprints — the documented memory win of the donated path.
    """
    from repro.federated.cohort import CohortTrainer

    cohort_cfg = paper_scale_cohort_config(total_stays=total_stays)
    cohort = generate_cohort(cohort_cfg, seed=seed)
    clients = build_client_datasets(cohort)
    base = ExperimentConfig(
        rounds=rounds,
        local_epochs=local_epochs,
        central_epochs=rounds * local_epochs,
        batch_size=batch_size,
        mesh=mesh,
    )

    report: dict[str, Any] = {}
    for setting in settings:
        row: dict[str, Any] = {}
        setting_engines = ("vectorized",) if setting == "central" else engines
        for engine in setting_engines:
            exp = dataclasses.replace(base, engine=engine)
            out = run_setting(setting, exp, cohort, seed=seed)
            if setting == "central":
                # central has no rounds; its comparable unit is one epoch
                unit_time = out["tau_s"] / max(base.central_epochs, 1)
                time_unit = "epoch"
            else:
                unit_time = _mean_round_time(out)
                time_unit = "round"
            entry = {
                "tau_s": out["tau_s"],
                "round_time_s": unit_time,
                "time_unit": time_unit,
                "metrics": out["metrics"],
                "local_steps": out["local_steps"],
                "federation_size": out["federation_size"],
                "recruited": out["recruited"],
                "cohort_stats": out.get("cohort_stats"),
            }
            row["n/a" if setting == "central" else engine] = entry
            if verbose:
                print(
                    f"  [paper189 {setting}/{engine}] round={entry['round_time_s']:.3f}s "
                    f"tau={out['tau_s']:.1f}s msle={out['metrics']['msle']:.4f}",
                    flush=True,
                )
        if setting != "central" and set(("vectorized", "sequential")) <= set(row):
            row["speedup"] = row["sequential"]["round_time_s"] / row["vectorized"]["round_time_s"]
        report[setting] = row

    # Donation probe: one all-participants round, donated vs plain buffers.
    model_cfg = GRUConfig(use_pallas=base.use_pallas)
    loss_fn = make_loss_fn(model_cfg)
    memory: dict[str, Any] = {}
    for donate in (True, False):
        trainer = CohortTrainer(
            loss_fn=loss_fn,
            optimizer=AdamW(learning_rate=base.learning_rate, weight_decay=base.weight_decay),
            batch_size=batch_size,
            local_epochs=local_epochs,
            cohort_chunk=max(1, (len(clients) + 1) // 2),  # 2 chunks: cross-chunk peak
            mesh=mesh,
            donate=donate,
        )
        params = init_gru(jax.random.key(seed), model_cfg)
        keys = list(jax.random.split(jax.random.key(seed), len(clients)))
        new_params, _, _ = trainer.train_cohort(
            params, clients, np.random.default_rng(seed), keys
        )
        jax.block_until_ready(new_params)
        memory["donated" if donate else "plain"] = trainer.last_round_stats
    memory["donated_peak_lower"] = (
        memory["donated"]["peak_live_bytes"] < memory["plain"]["peak_live_bytes"]
    )

    return {
        "bench": "paper189",
        "num_clients": len(clients),
        "rounds": rounds,
        "local_epochs": local_epochs,
        "batch_size": batch_size,
        "total_stays": cohort_cfg.total_stays,
        "seed": seed,
        "settings": report,
        "memory": memory,
    }


def run_seeds(
    setting: str, exp: ExperimentConfig, seeds: list[int], verbose: bool = True
) -> dict[str, Any]:
    """Multi-seed runs -> mean/std per metric (paper reports mean +/- std)."""
    runs = []
    for seed in seeds:
        cohort = build_cohort(exp, seed=seed)
        out = run_setting(setting, exp, cohort, seed=seed)
        if verbose:
            m = out["metrics"]
            print(
                f"  [{setting} seed={seed}] mae={m['mae']:.3f} mape={m['mape']:.3f} "
                f"mse={m['mse']:.2f} msle={m['msle']:.3f} tau={out['tau_s']:.1f}s",
                flush=True,
            )
        runs.append(out)
    agg: dict[str, Any] = {"setting": setting, "seeds": seeds, "runs": runs}
    for key in ("mae", "mape", "mse", "msle"):
        vals = np.array([r["metrics"][key] for r in runs])
        agg[key] = {"mean": float(vals.mean()), "std": float(vals.std(ddof=1) if len(vals) > 1 else 0.0),
                    "values": vals.tolist()}
    taus = np.array([r["tau_s"] for r in runs])
    agg["tau_s"] = {"mean": float(taus.mean()), "std": float(taus.std(ddof=1) if len(taus) > 1 else 0.0),
                    "values": taus.tolist()}
    agg["local_steps"] = int(np.mean([r["local_steps"] for r in runs]))
    agg["federation_size"] = runs[0]["federation_size"]
    agg["recruited"] = runs[0]["recruited"]
    return agg
