"""The paper's experiments, end to end, on the synthetic eICU cohort.

Five model settings (paper section 6):

  central        — pooled training, 15 epochs (upper bound)
  federated-ac   — all 189 clients, all participate each round
  federated-sc   — all clients in federation, 10% sampled per round (the
                   "standard FL" baseline the paper tests against)
  federated-arc  — recruited clients only, all participate
  federated-src  — recruited clients only, 10% sampled per round

plus the section 6.2 ablations (quality-greedy / data-greedy) and the
gamma_th sweep of Fig. 2.  Each run reports the paper's four metrics plus
wall-time tau and simulated local-step counts.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.core.recruitment import (
    BALANCED,
    DATA_GREEDY,
    QUALITY_GREEDY,
    RecruitmentConfig,
)
from repro.data.pipeline import ArrayDataset, build_client_datasets, global_dataset
from repro.data.synth_eicu import Cohort, CohortConfig, generate_cohort
from repro.federated.central import CentralConfig, train_central
from repro.federated.server import FederatedConfig, FederatedServer
from repro.metrics.regression import evaluate_predictions
from repro.models.gru import GRUConfig, gru_apply, init_gru, make_loss_fn
from repro.optim.adamw import AdamW

MODEL_SETTINGS = (
    "central",
    "federated-ac",
    "federated-sc",
    "federated-arc",
    "federated-src",
    "federated-src-qg",
    "federated-src-dg",
)


@dataclasses.dataclass(frozen=True)
class ExperimentConfig:
    """Paper-faithful defaults (Tables 1 and 3)."""

    cohort_scale: float = 1.0      # 1.0 = full 89,127-stay cohort
    rounds: int = 15
    local_epochs: int = 4
    central_epochs: int = 15
    batch_size: int = 128
    learning_rate: float = 5e-3
    weight_decay: float = 5e-3
    participation_fraction: float = 0.1
    gamma_dv: float = 0.5
    gamma_sa: float = 0.5
    gamma_th: float = 0.1
    use_pallas: bool = False
    # Federated training engine: "vectorized" (one jitted vmap per round)
    # or "sequential" (per-client Python loop, the reference oracle).
    engine: str = "vectorized"
    # Vectorized engine: clients per vmapped call (None = whole cohort).
    cohort_chunk: int | None = None


def recruitment_for(setting: str, exp: ExperimentConfig) -> RecruitmentConfig | None:
    if setting in ("central", "federated-ac", "federated-sc"):
        return None
    if setting == "federated-src-qg":
        return dataclasses.replace(QUALITY_GREEDY, gamma_th=exp.gamma_th)
    if setting == "federated-src-dg":
        return dataclasses.replace(DATA_GREEDY, gamma_th=exp.gamma_th)
    return RecruitmentConfig(exp.gamma_dv, exp.gamma_sa, exp.gamma_th)


def participation_for(setting: str, exp: ExperimentConfig) -> float | None:
    if setting in ("federated-ac", "federated-arc"):
        return None  # everyone, every round
    return exp.participation_fraction


def build_cohort(exp: ExperimentConfig, seed: int) -> Cohort:
    cfg = CohortConfig()
    if exp.cohort_scale != 1.0:
        cfg = cfg.scaled(exp.cohort_scale)
    return generate_cohort(cfg, seed=seed)


def run_setting(
    setting: str,
    exp: ExperimentConfig,
    cohort: Cohort,
    seed: int,
    progress: Any | None = None,
) -> dict[str, Any]:
    """Train one model setting and evaluate on the hold-out test split."""
    if setting not in MODEL_SETTINGS:
        raise ValueError(f"unknown setting {setting}; choose from {MODEL_SETTINGS}")

    model_cfg = GRUConfig(use_pallas=exp.use_pallas)
    loss_fn = make_loss_fn(model_cfg)
    optimizer = AdamW(learning_rate=exp.learning_rate, weight_decay=exp.weight_decay)
    init_params = init_gru(jax.random.key(seed), model_cfg)
    test = global_dataset(cohort, Cohort.TEST)

    info: dict[str, Any] = {"setting": setting, "seed": seed}
    if setting == "central":
        result = train_central(
            CentralConfig(epochs=exp.central_epochs, batch_size=exp.batch_size, seed=seed),
            global_dataset(cohort, Cohort.TRAIN),
            init_params,
            loss_fn,
            optimizer,
        )
        params = result.params
        info.update(
            tau_s=result.total_wall_time_s,
            local_steps=result.total_steps,
            federation_size=None,
            recruited=None,
        )
    else:
        clients = build_client_datasets(cohort)
        fed_cfg = FederatedConfig(
            rounds=exp.rounds,
            local_epochs=exp.local_epochs,
            batch_size=exp.batch_size,
            participation_fraction=participation_for(setting, exp),
            recruitment=recruitment_for(setting, exp),
            seed=seed,
            engine=exp.engine,
            cohort_chunk=exp.cohort_chunk,
        )
        server = FederatedServer(fed_cfg, clients, loss_fn, optimizer)
        result = server.run(init_params, progress=progress)
        params = result.params
        info.update(
            tau_s=result.total_wall_time_s,
            local_steps=result.total_local_steps,
            federation_size=int(result.federation_ids.size),
            recruited=None if result.recruitment is None else result.recruitment.num_recruited,
        )

    y_hat = np.asarray(_predict(params, model_cfg, test))
    info["metrics"] = evaluate_predictions(test.y, y_hat)
    return info


def _predict(params, model_cfg: GRUConfig, dataset: ArrayDataset, batch: int = 2048) -> np.ndarray:
    fn = jax.jit(lambda p, x: gru_apply(p, model_cfg, x))
    outs = []
    for start in range(0, len(dataset), batch):
        outs.append(np.asarray(fn(params, dataset.x[start : start + batch])))
    return np.concatenate(outs)


def run_seeds(
    setting: str, exp: ExperimentConfig, seeds: list[int], verbose: bool = True
) -> dict[str, Any]:
    """Multi-seed runs -> mean/std per metric (paper reports mean +/- std)."""
    runs = []
    for seed in seeds:
        cohort = build_cohort(exp, seed=seed)
        out = run_setting(setting, exp, cohort, seed=seed)
        if verbose:
            m = out["metrics"]
            print(
                f"  [{setting} seed={seed}] mae={m['mae']:.3f} mape={m['mape']:.3f} "
                f"mse={m['mse']:.2f} msle={m['msle']:.3f} tau={out['tau_s']:.1f}s",
                flush=True,
            )
        runs.append(out)
    agg: dict[str, Any] = {"setting": setting, "seeds": seeds, "runs": runs}
    for key in ("mae", "mape", "mse", "msle"):
        vals = np.array([r["metrics"][key] for r in runs])
        agg[key] = {"mean": float(vals.mean()), "std": float(vals.std(ddof=1) if len(vals) > 1 else 0.0),
                    "values": vals.tolist()}
    taus = np.array([r["tau_s"] for r in runs])
    agg["tau_s"] = {"mean": float(taus.mean()), "std": float(taus.std(ddof=1) if len(taus) > 1 else 0.0),
                    "values": taus.tolist()}
    agg["local_steps"] = int(np.mean([r["local_steps"] for r in runs]))
    agg["federation_size"] = runs[0]["federation_size"]
    agg["recruited"] = runs[0]["recruited"]
    return agg
