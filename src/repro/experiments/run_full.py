"""Full paper-faithful experiment run (invoked in background; writes JSON +
markdown consumed by EXPERIMENTS.md).

    python -m repro.experiments.run_full --scale 1.0 --seeds 0 1 2
"""

from __future__ import annotations

import argparse
import json
import time

from repro.experiments.paper import ExperimentConfig
from repro.experiments.tables import (
    run_fig2,
    run_table4,
    run_table5,
    save,
    to_markdown_table4,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1, 2])
    ap.add_argument("--fig2-seeds", type=int, nargs="+", default=[0, 1])
    ap.add_argument("--skip-fig2", action="store_true")
    args = ap.parse_args()

    exp = ExperimentConfig(cohort_scale=args.scale)
    t0 = time.time()

    print(f"=== Table 4 (scale={args.scale}, seeds={args.seeds}) ===", flush=True)
    t4 = run_table4(exp, args.seeds)
    save(t4, f"table4_scale{args.scale}.json")
    print(to_markdown_table4(t4), flush=True)

    print("=== Table 5 (QG/DG ablations) ===", flush=True)
    t5 = run_table5(exp, args.seeds)
    save(t5, f"table5_scale{args.scale}.json")
    print(to_markdown_table4(t5), flush=True)

    if not args.skip_fig2:
        print("=== Fig 2 (gamma_th sweep) ===", flush=True)
        fig2 = run_fig2(exp, args.fig2_seeds, [0.05, 0.1, 0.2, 0.4, 0.7, 1.0])
        save(fig2, f"fig2_scale{args.scale}.json")

    print(f"total experiment time: {(time.time()-t0)/60:.1f} min", flush=True)


if __name__ == "__main__":
    main()
