"""Produce the paper's tables/figures from experiment runs.

  Table 4 — central + Federated-{AC, SC, ARC, SRC} with significance stars
            vs Federated-SC (Welch, * p<0.05, ** p<0.01 across seeds)
  Table 5 — quality-greedy / data-greedy recruitment ablations
  Fig. 2  — gamma_th sweep: runtime vs MSLE / MAE vs number recruited
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any

import numpy as np

from repro.experiments.paper import ExperimentConfig, run_seeds
from repro.metrics.stats import significance_stars, welch_t_test

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "paper"


def run_table4(exp: ExperimentConfig, seeds: list[int]) -> dict[str, Any]:
    settings = ["central", "federated-ac", "federated-sc", "federated-arc", "federated-src"]
    results = {s: run_seeds(s, exp, seeds) for s in settings}
    _attach_significance(results, baseline="federated-sc")
    return results


def run_table5(exp: ExperimentConfig, seeds: list[int]) -> dict[str, Any]:
    settings = ["federated-src-qg", "federated-src-dg"]
    return {s: run_seeds(s, exp, seeds) for s in settings}


def run_fig2(exp: ExperimentConfig, seeds: list[int], gamma_ths: list[float]) -> list[dict]:
    points = []
    for gth in gamma_ths:
        e = dataclasses.replace(exp, gamma_th=gth)
        agg = run_seeds("federated-src", e, seeds)
        points.append(
            {
                "gamma_th": gth,
                "recruited": agg["recruited"],
                "msle": agg["msle"],
                "mae": agg["mae"],
                "tau_s": agg["tau_s"],
                "local_steps": agg["local_steps"],
            }
        )
        print(f"  [fig2 gamma_th={gth}] recruited={agg['recruited']} "
              f"msle={agg['msle']['mean']:.3f} tau={agg['tau_s']['mean']:.1f}s", flush=True)
    return points


def _attach_significance(results: dict[str, Any], baseline: str) -> None:
    base = results[baseline]
    for name, agg in results.items():
        stars = {}
        if name != baseline:
            for metric in ("mae", "mape", "mse", "msle"):
                _, p = welch_t_test(
                    np.asarray(agg[metric]["values"]), np.asarray(base[metric]["values"])
                )
                stars[metric] = {"p": p, "stars": significance_stars(p)}
        agg["significance_vs_sc"] = stars


def to_markdown_table4(results: dict[str, Any]) -> str:
    header = "| Model | MAE | MAPE | MSE | MSLE | tau(s) | clients | steps |\n|---|---|---|---|---|---|---|---|"
    rows = [header]
    label = {
        "central": "Central", "federated-ac": "Federated-AC", "federated-sc": "Federated-SC",
        "federated-arc": "Federated-ARC", "federated-src": "Federated-SRC",
        "federated-src-qg": "Federated-SRC-QG", "federated-src-dg": "Federated-SRC-DG",
    }
    for name, agg in results.items():
        sig = agg.get("significance_vs_sc", {})
        def cell(metric):
            s = sig.get(metric, {}).get("stars", "")
            return f"{agg[metric]['mean']:.2f} ± {agg[metric]['std']:.2f}{s}"
        fed = agg["federation_size"] if agg["federation_size"] is not None else "-"
        rows.append(
            f"| {label.get(name, name)} | {cell('mae')} | {cell('mape')} | {cell('mse')} "
            f"| {cell('msle')} | {agg['tau_s']['mean']:.0f} ± {agg['tau_s']['std']:.0f} "
            f"| {fed} | {agg['local_steps']} |"
        )
    return "\n".join(rows)


def save(obj: Any, name: str) -> Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = RESULTS_DIR / name
    out.write_text(json.dumps(obj, indent=1))
    return out
