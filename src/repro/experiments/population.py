"""Population-scale tier: recruitment + rounds at 10^3 — 10^5 clients.

The paper recruits from 189 ICUs; the ROADMAP north star is cross-device
scale.  This experiment measures the two costs that must stay flat as the
population grows past anything that fits one resident array:

* **recruitment** — the streaming nu-greedy path
  (``repro.core.recruitment.StreamingRecruiter``) split into its two
  phases: *ingest* (one bounded-memory pass over the disclosure stream;
  inherently one visit per client, reported as per-client microseconds)
  and the *decision* (``finalize()`` — sort the bounded candidate pool and
  cross iota; this is the server-side cost that replaces the exact
  oracle's full-population ``np.stack`` + argsort and must stay flat).
  The exact ``recruit`` runs alongside as the parity/tolerance oracle.
* **per-round training** — a ``CohortTrainer`` with
  ``resident_budget_bytes`` bounding the device cohort to an LRU pool:
  each round samples a fixed ``round_clients`` cohort out of the full
  population and uploads only the rows not already resident, so
  steady-state round time tracks the cohort, not the population.

``benchmarks/run.py --mode population`` drives this and writes
``BENCH_population.json``.
"""

from __future__ import annotations

import time
from typing import Any, Iterator, Sequence

import numpy as np

from repro.core.recruitment import (
    ClientStats,
    RecruitmentConfig,
    StreamingRecruiter,
    StreamingRecruitmentConfig,
    recruit,
)
from repro.data.pipeline import ArrayDataset, ClientDataset

NUM_BINS = 10
SEQ_LEN, FEAT = 4, 6          # bench-scale features: the client *count* is
BATCH_SIZE = 4                # the dimension under test, not model FLOPs
N_RANGE = (3, 9)              # per-client stays; fixed so shapes (and the
                              # compiled round) are identical across scales

# The candidate pool is the decision's memory bound and must hold the
# recruited prefix (nu-greedy recruits a roughly population-independent
# *fraction*, so the absolute prefix grows with P).  The sweep pins the pool
# and picks gamma_th so the 10^5 prefix (~11%) still fits — that fixed pool
# is exactly what makes the finalize decision flat while the exact oracle's
# full-population sort keeps growing.
STREAM_POOL = 16_384
BENCH_RECRUITMENT = RecruitmentConfig(gamma_dv=0.5, gamma_sa=0.5, gamma_th=0.05)


def synthetic_population_stats(
    num_clients: int, seed: int = 0, chunk: int = 4096
) -> Iterator[ClientStats]:
    """Disclosure stream for a heavy-tailed, non-IID synthetic population.

    Sizes are lognormal (median ~20 stays, heavy right tail); each client's
    LoS histogram is a multinomial draw from its own mixture of a global
    prototype and client-specific noise.  Generated in vectorized chunks so
    the generator itself holds O(chunk) state — the stream really is a
    stream, even at 10^5 clients.
    """
    rng = np.random.default_rng(seed)
    prototype = rng.dirichlet(np.full(NUM_BINS, 2.0))
    start = 0
    while start < num_clients:
        m = min(chunk, num_clients - start)
        sizes = np.maximum(rng.lognormal(3.0, 1.0, size=m).astype(np.int64), 1)
        local = rng.dirichlet(np.full(NUM_BINS, 0.5), size=m)
        mix = rng.uniform(0.2, 0.9, size=(m, 1))
        probs = mix * prototype[None, :] + (1.0 - mix) * local
        counts = rng.multinomial(sizes, probs)
        for i in range(m):
            yield ClientStats(
                client_id=start + i, counts=counts[i], n=int(sizes[i])
            )
        start += m


def synthetic_population_clients(
    num_clients: int, seed: int = 0
) -> list[ClientDataset]:
    """Tiny per-client datasets for population-scale round timing.

    One vectorized draw for the whole population; each client's arrays are
    views into it, so 10^5 clients cost one ~100MB host allocation and no
    per-client RNG calls.
    """
    rng = np.random.default_rng(seed)
    lo, hi = N_RANGE
    sizes = rng.integers(lo, hi, size=num_clients)
    n_max = hi - 1
    x_all = rng.normal(size=(num_clients, n_max, SEQ_LEN, FEAT)).astype(np.float32)
    y_all = rng.uniform(0.5, 20.0, size=(num_clients, n_max)).astype(np.float32)
    clients = []
    for i in range(num_clients):
        n = int(sizes[i])
        ds = ArrayDataset(x_all[i, :n], y_all[i, :n])
        clients.append(ClientDataset(client_id=i, train=ds, val=ds))
    return clients


def _time_membership(result: Any, population: int, lookups: int = 2000) -> float:
    """ns per ``is_recruited`` lookup, including the one-time set build."""
    ids = np.random.default_rng(1).integers(0, population, size=lookups)
    t0 = time.perf_counter()
    hits = sum(result.is_recruited(int(i)) for i in ids)
    elapsed = time.perf_counter() - t0
    assert 0 <= hits <= lookups
    return 1e9 * elapsed / lookups


def run_population_scale(
    populations: Sequence[int] = (1_000, 10_000, 100_000),
    *,
    rounds: int = 3,
    round_clients: int = 64,
    pool_rows: int = 256,
    exact_limit: int = 100_000,
    config: RecruitmentConfig = BENCH_RECRUITMENT,
    stream_pool: int = STREAM_POOL,
    seed: int = 0,
    verbose: bool = True,
) -> dict[str, Any]:
    """Recruitment + per-round cost from 10^3 to 10^5 synthetic clients.

    Per population: streaming recruitment (ingest + decision, timed
    separately), the exact oracle for parity/tolerance (up to
    ``exact_limit``), an O(1)-membership micro-assertion on
    ``is_recruited``, and ``rounds`` training rounds of a fixed
    ``round_clients``-client cohort out of an LRU-pooled device cohort of
    ``pool_rows`` rows.  The summary asserts the population contract: the
    recruitment *decision* and the steady-state round time grow sub-linearly
    in population size (the one-pass ingest is inherently linear and is
    reported per client), and streaming matches the exact participant set
    whenever the population fits the exact buffer (the 10^3 leg).
    """
    import jax

    from repro.federated.cohort import CohortTrainer, chain_split_keys
    from repro.models.gru import GRUConfig, init_gru, make_loss_fn
    from repro.optim.adamw import AdamW

    model_cfg = GRUConfig(input_dim=FEAT, hidden_dim=4, num_layers=1)
    loss_fn = make_loss_fn(model_cfg)
    params0 = init_gru(jax.random.key(seed), model_cfg)
    n_max = N_RANGE[1] - 1
    row_bytes = (n_max + 1) * SEQ_LEN * FEAT * 4 + (n_max + 1) * 4
    budget = pool_rows * row_bytes
    # steps_per_epoch pinned to the population-wide max so every cohort and
    # every scale reuses one compiled round.
    spe = -(-n_max // BATCH_SIZE)

    entries: list[dict[str, Any]] = []
    for pop in populations:
        # -- recruitment: one streaming pass + the finalize decision -------
        recruiter = StreamingRecruiter(
            config, stream=StreamingRecruitmentConfig(pool_size=stream_pool)
        )
        t0 = time.perf_counter()
        recruiter.extend(synthetic_population_stats(pop, seed=seed))
        ingest_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        streamed = recruiter.finalize()
        decision_s = time.perf_counter() - t0

        entry: dict[str, Any] = {
            "population": int(pop),
            "recruitment_ingest_s": ingest_s,
            "recruitment_ingest_us_per_client": 1e6 * ingest_s / pop,
            "recruitment_decision_s": decision_s,
            "streaming_mode": streamed.mode,
            "num_recruited_streaming": streamed.num_recruited,
            "pool_exhausted": streamed.pool_exhausted,
        }

        if pop <= exact_limit:
            stats = list(synthetic_population_stats(pop, seed=seed))
            t0 = time.perf_counter()
            exact = recruit(stats, config)
            entry["recruitment_exact_s"] = time.perf_counter() - t0
            entry["num_recruited_exact"] = exact.num_recruited
            streamed_set = set(streamed.recruited_ids.tolist())
            exact_set = set(exact.recruited_ids.tolist())
            entry["overlap_jaccard"] = len(streamed_set & exact_set) / max(
                len(streamed_set | exact_set), 1
            )
            entry["participant_match"] = streamed_set == exact_set
            if streamed.mode == "exact":
                # acceptance contract: within the exact buffer the streaming
                # path IS the oracle — identical participant sets.
                assert entry["participant_match"], (
                    f"streaming/exact participant sets diverged at {pop} clients"
                )
            # O(1) amortized membership: timed on the result with the larger
            # recruited set so the old O(R)-scan regression would show.
            entry["membership_ns_per_lookup"] = _time_membership(exact, pop)
        else:
            entry["membership_ns_per_lookup"] = _time_membership(streamed, pop)

        # -- per-round cost out of the LRU-pooled device cohort ------------
        clients = synthetic_population_clients(pop, seed=seed)
        trainer = CohortTrainer(
            loss_fn=loss_fn,
            optimizer=AdamW(learning_rate=5e-3, weight_decay=5e-3),
            batch_size=BATCH_SIZE,
            local_epochs=1,
            staging="resident",
            resident_budget_bytes=budget,
        )
        dcohort = trainer.attach_device_cohort(clients)
        sample_rng = np.random.default_rng([seed, 2])
        key = jax.random.key(seed)
        params = params0
        round_times: list[float] = []
        for _ in range(rounds):
            cohort_ids = np.sort(
                sample_rng.choice(pop, size=round_clients, replace=False)
            )
            cohort = [clients[int(i)] for i in cohort_ids]
            t0 = time.perf_counter()
            key, subs = chain_split_keys(key, len(cohort))
            params, _, _ = trainer.train_cohort(
                params, cohort, sample_rng, subs, steps_per_epoch=spe
            )
            jax.block_until_ready(params)
            round_times.append(time.perf_counter() - t0)
        stats_round = trainer.last_round_stats or {}
        entry.update(
            {
                # steady state: round 0 pays compilation
                "round_time_s": float(np.median(round_times[1:]))
                if len(round_times) > 1
                else round_times[0],
                "round_times_s": round_times,
                "pool_rows": dcohort.pool_rows,
                "pool_uploads_total": dcohort.uploads,
                "pool_evictions_total": dcohort.evictions,
                "pool_bytes_resident": dcohort.nbytes,
                "last_round_pool_uploads": stats_round.get("pool_uploads", 0),
                "slice_chunks_last_round": stats_round.get("slice_chunks", 0),
            }
        )
        entries.append(entry)
        if verbose:
            print(
                f"  [population {pop:>7,}] ingest={ingest_s:.2f}s "
                f"decision={decision_s * 1e3:.1f}ms "
                f"round={entry['round_time_s'] * 1e3:.1f}ms "
                f"recruited={streamed.num_recruited} ({streamed.mode})",
                flush=True,
            )

    report: dict[str, Any] = {
        "bench": "population_scale",
        "populations": [int(p) for p in populations],
        "rounds": rounds,
        "round_clients": round_clients,
        "pool_rows": pool_rows,
        "seed": seed,
        "entries": entries,
    }
    if len(entries) >= 2:
        first, last = entries[0], entries[-1]
        pop_ratio = last["population"] / first["population"]
        decision_ratio = last["recruitment_decision_s"] / max(
            first["recruitment_decision_s"], 1e-9
        )
        round_ratio = last["round_time_s"] / max(first["round_time_s"], 1e-9)
        membership = [e["membership_ns_per_lookup"] for e in entries]
        membership_ratio = max(membership) / max(min(membership), 1e-9)
        report.update(
            {
                "population_ratio": pop_ratio,
                "recruitment_decision_ratio": decision_ratio,
                "round_time_ratio": round_ratio,
                "membership_ns_ratio": membership_ratio,
                # the population contract, asserted: decision + round cost
                # grow sub-linearly (at most half the population growth)
                "recruitment_sublinear": bool(decision_ratio < pop_ratio / 2),
                "round_sublinear": bool(round_ratio < pop_ratio / 2),
            }
        )
        # Asserted only across a real spread: below 10x the millisecond-scale
        # timings are noise, not a scaling law.
        if pop_ratio >= 10:
            assert report["recruitment_sublinear"], (
                f"recruitment decision scaled {decision_ratio:.1f}x over a "
                f"{pop_ratio:.0f}x population — not sub-linear"
            )
            assert report["round_sublinear"], (
                f"round time scaled {round_ratio:.1f}x over a "
                f"{pop_ratio:.0f}x population — not sub-linear"
            )
        # O(1) amortized membership: per-lookup cost must not track the
        # population (generous 50x guard vs the ~{pop_ratio}x an O(R) scan
        # would show).
        assert membership_ratio < 50, (
            f"is_recruited lookups scaled {membership_ratio:.0f}x with "
            "population — membership is no longer O(1)"
        )
    return report
