"""Beyond-paper ablation: how non-IID strength drives recruitment's value.

The paper's SRC-beats-SC result depends on how heterogeneous the hospitals
are.  We sweep the generator's per-hospital LoS shift (mu_shift) and compare
standard FedAvg (SC) with recruited FedAvg (SRC) at each level: recruitment
should matter more as heterogeneity grows.

    python -m repro.experiments.noniid_ablation --scale 0.3 --seeds 0 1
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.data.synth_eicu import CohortConfig, generate_cohort
from repro.experiments.paper import ExperimentConfig, run_setting
from repro.experiments.tables import save


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1])
    ap.add_argument("--shifts", type=float, nargs="+", default=[0.1, 0.35, 0.8, 1.4])
    ap.add_argument(
        "--toxic-clients",
        action="store_true",
        help="real-eICU fidelity mode: tiny hospitals (min 5 stays) with "
        "heterogeneous charting quality (feature noise x0.7-2.5)",
    )
    args = ap.parse_args()

    exp = ExperimentConfig(cohort_scale=args.scale)
    rows = []
    for shift in args.shifts:
        per_setting = {"federated-sc": [], "federated-src": []}
        taus = {"federated-sc": [], "federated-src": []}
        recruited = None
        for seed in args.seeds:
            base = CohortConfig(hospital_mu_shift=shift)
            if args.toxic_clients:
                base = dataclasses.replace(
                    base, min_hospital_size=5, hospital_noise_scale=(0.7, 2.5)
                )
            base = base.scaled(args.scale)
            if args.toxic_clients:
                base = dataclasses.replace(base, min_hospital_size=5)
            cohort = generate_cohort(base, seed=seed)
            for setting in per_setting:
                out = run_setting(setting, exp, cohort, seed=seed)
                per_setting[setting].append(out["metrics"]["msle"])
                taus[setting].append(out["tau_s"])
                if setting == "federated-src":
                    recruited = out["recruited"]
        row = {
            "mu_shift": shift,
            "recruited": recruited,
            "sc_msle": float(np.mean(per_setting["federated-sc"])),
            "src_msle": float(np.mean(per_setting["federated-src"])),
            "sc_tau": float(np.mean(taus["federated-sc"])),
            "src_tau": float(np.mean(taus["federated-src"])),
        }
        row["src_advantage"] = row["sc_msle"] - row["src_msle"]
        rows.append(row)
        print(json.dumps(row), flush=True)

    suffix = "_toxic" if args.toxic_clients else ""
    save(rows, f"noniid_ablation_scale{args.scale}{suffix}.json")
    print("\n| mu_shift | recruited | SC msle | SRC msle | SRC advantage | SC tau | SRC tau |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        print(
            f"| {r['mu_shift']} | {r['recruited']} | {r['sc_msle']:.4f} | {r['src_msle']:.4f} "
            f"| {r['src_advantage']:+.4f} | {r['sc_tau']:.0f}s | {r['src_tau']:.0f}s |"
        )


if __name__ == "__main__":
    main()
