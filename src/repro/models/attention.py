"""Attention layers: GQA (qk-norm, sliding-window) and DeepSeek MLA.

Long-sequence prefill uses a flash-style *blockwise* attention (lax.scan over
KV chunks with an online softmax) so the S x S score matrix is never
materialized — at 32k prefill that is the difference between ~MBs and ~TBs
of activation memory per chip.

Decode paths operate on explicit caches:
  * GQA: ring-buffer KV cache (full-window or sliding-window);
  * MLA: the compressed latent cache (c_kv + shared k_rope) with the
    weight-absorption trick, which is the whole point of MLA at decode time.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.models.layers import apply_rope, dense_init, rmsnorm, rmsnorm_init

PyTree = Any

NEG_INF = -1e30


# ==========================================================================
# blockwise (flash-style) attention core
# ==========================================================================

def blockwise_attention(
    q: jnp.ndarray,          # (B, S, H, Dk)
    k: jnp.ndarray,          # (B, S, Hkv, Dk)
    v: jnp.ndarray,          # (B, S, Hkv, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    kv_chunk: int = 1024,
    scale: float | None = None,
) -> jnp.ndarray:
    """Memory-bounded attention with online softmax.  Returns (B, S, H, Dv).

    GQA is handled by reshaping H query heads into (Hkv, group) — no KV
    repetition in memory.
    """
    b, s, h, dk = q.shape
    t = k.shape[1]                            # KV length (== s for self-attn)
    hkv = k.shape[2]
    dv = v.shape[-1]
    group = h // hkv
    scale = dk ** -0.5 if scale is None else scale

    kv_chunk = min(kv_chunk, t)
    num_chunks = -(-t // kv_chunk)
    pad = num_chunks * kv_chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qf = (q.astype(jnp.float32) * scale).reshape(b, s, hkv, group, dk)
    kf = k.astype(jnp.float32).reshape(b, num_chunks, kv_chunk, hkv, dk)
    vf = v.astype(jnp.float32).reshape(b, num_chunks, kv_chunk, hkv, dv)

    q_pos = jnp.arange(s)

    def body(carry, inputs):
        m, l, acc = carry                     # (B,S,Hkv,G), same, (B,S,Hkv,G,Dv)
        k_c, v_c, c_idx = inputs              # (B,C,Hkv,Dk), (B,C,Hkv,Dv), ()
        kv_pos = c_idx * kv_chunk + jnp.arange(kv_chunk)
        #        b=batch s=q h=kv-heads g=group c=kv-chunk d=dk
        scores = jnp.einsum("bshgd,bchd->bshgc", qf, k_c)
        mask = jnp.broadcast_to(kv_pos[None, :] < t, (s, kv_chunk))  # pad mask
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        mask_b = mask[None, :, None, None, :]
        scores = jnp.where(mask_b, scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        # explicit mask multiply: a fully-masked chunk must contribute 0,
        # not exp(NEG_INF - NEG_INF) = 1
        p = jnp.exp(scores - m_new[..., None]) * mask_b
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bshgc,bchd->bshgd", p, v_c)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, hkv, group), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, s, hkv, group), dtype=jnp.float32)
    acc0 = jnp.zeros((b, s, hkv, group, dv), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, acc0),
        (
            jnp.moveaxis(kf, 1, 0),
            jnp.moveaxis(vf, 1, 0),
            jnp.arange(num_chunks),
        ),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, s, h, dv).astype(q.dtype)


# ==========================================================================
# GQA attention layer
# ==========================================================================

def gqa_init(key: jax.Array, cfg: ArchConfig, dtype) -> PyTree:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "w_q": dense_init(k1, cfg.d_model, cfg.num_heads * hd, dtype),
        "w_k": dense_init(k2, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "w_v": dense_init(k3, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "w_o": dense_init(k4, cfg.num_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        params["q_norm"] = rmsnorm_init(hd, dtype)
        params["k_norm"] = rmsnorm_init(hd, dtype)
    return params


def _project_qkv(params: PyTree, cfg: ArchConfig, x: jnp.ndarray, positions: jnp.ndarray):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["w_q"]).reshape(b, s, cfg.num_heads, hd)
    k = (x @ params["w_k"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ params["w_v"]).reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_apply(
    params: PyTree,
    cfg: ArchConfig,
    x: jnp.ndarray,
    *,
    causal: bool = True,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill).  x: (B, S, D)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = blockwise_attention(
        q, k, v, causal=causal, window=cfg.sliding_window, kv_chunk=kv_chunk
    )
    return out.reshape(b, s, -1) @ params["w_o"]


# --- decode cache ---------------------------------------------------------

def gqa_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype) -> PyTree:
    """Ring-buffer cache.  With a sliding window the buffer is window-sized."""
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, size, cfg.num_kv_heads, hd), dtype=dtype),
        "v": jnp.zeros((batch, size, cfg.num_kv_heads, hd), dtype=dtype),
        "slot_pos": jnp.full((size,), -1, dtype=jnp.int32),
    }


def gqa_decode(
    params: PyTree,
    cfg: ArchConfig,
    x: jnp.ndarray,          # (B, 1, D) — one new token
    cache: PyTree,
    pos: jnp.ndarray,        # scalar int32 — absolute position of the new token
) -> tuple[jnp.ndarray, PyTree]:
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    size = cache["k"].shape[1]
    slot = pos % size
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    slot_pos = cache["slot_pos"].at[slot].set(pos)

    group = cfg.num_heads // cfg.num_kv_heads
    qf = (q.astype(jnp.float32) * hd**-0.5).reshape(b, cfg.num_kv_heads, group, hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if cfg.sliding_window is not None:
        valid = valid & (slot_pos > pos - cfg.sliding_window)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", attn, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.num_heads * hd).astype(x.dtype)
    new_cache = {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}
    return out @ params["w_o"], new_cache


# ==========================================================================
# MLA (DeepSeek-V3 multi-head latent attention)
# ==========================================================================

def mla_init(key: jax.Array, cfg: ArchConfig, dtype) -> PyTree:
    m: MLAConfig = cfg.mla
    h = cfg.num_heads
    keys = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(keys[0], cfg.d_model, m.q_lora_rank, dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "w_uq": dense_init(
            keys[1], m.q_lora_rank, h * (m.qk_nope_head_dim + m.qk_rope_head_dim), dtype
        ),
        "w_dkv": dense_init(keys[2], cfg.d_model, m.kv_lora_rank, dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "w_kr": dense_init(keys[3], cfg.d_model, m.qk_rope_head_dim, dtype),
        # stored (rank, H, head_dim) so decode can absorb them per head
        "w_uk": (
            jax.random.truncated_normal(keys[4], -2, 2, (m.kv_lora_rank, h, m.qk_nope_head_dim))
            * m.kv_lora_rank**-0.5
        ).astype(dtype),
        "w_uv": (
            jax.random.truncated_normal(keys[5], -2, 2, (m.kv_lora_rank, h, m.v_head_dim))
            * m.kv_lora_rank**-0.5
        ).astype(dtype),
        "w_o": dense_init(keys[6], h * m.v_head_dim, cfg.d_model, dtype),
    }


def _mla_queries(params: PyTree, cfg: ArchConfig, x: jnp.ndarray, positions: jnp.ndarray):
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    c_q = rmsnorm(params["q_norm"], x @ params["w_dq"], cfg.norm_eps)
    q = (c_q @ params["w_uq"]).reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(
    params: PyTree, cfg: ArchConfig, x: jnp.ndarray, *, kv_chunk: int = 1024
) -> jnp.ndarray:
    """Train / prefill MLA with full-rank keys/values (standard formulation)."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    positions = jnp.arange(s)[None, :]
    q_nope, q_rope = _mla_queries(params, cfg, x, positions)

    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"], cfg.norm_eps)   # (B,S,R)
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhd->bshd", c_kv, params["w_uv"])

    # fold the shared rope key into every head and run one blockwise attention
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = blockwise_attention(q, k, v, causal=True, kv_chunk=kv_chunk, scale=scale)
    return out.reshape(b, s, h * m.v_head_dim) @ params["w_o"]


def mla_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype) -> PyTree:
    m: MLAConfig = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype=dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype=dtype),
    }


def mla_decode(
    params: PyTree,
    cfg: ArchConfig,
    x: jnp.ndarray,          # (B, 1, D)
    cache: PyTree,
    pos: jnp.ndarray,
) -> tuple[jnp.ndarray, PyTree]:
    """Weight-absorbed decode over the compressed latent cache.

    Scores  = q_nope W_uk . c_kv  +  q_rope . k_rope     (per head)
    Output  = (attn . c_kv) W_uv                          (per head)
    Only (kv_lora_rank + rope_dim) floats per token are cached.
    """
    m: MLAConfig = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _mla_queries(params, cfg, x, positions)    # (B,1,H,*)

    c_kv_new = rmsnorm(params["kv_norm"], x @ params["w_dkv"], cfg.norm_eps)
    k_rope_new = apply_rope((x @ params["w_kr"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), pos, axis=1
    )
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), pos, axis=1
    )

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    # absorb W_uk: query in latent space (B,H,R)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32), params["w_uk"].astype(jnp.float32))
    scores = jnp.einsum("bhr,bsr->bhs", q_lat, c_kv.astype(jnp.float32))
    scores += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32), k_rope.astype(jnp.float32))
    scores *= scale
    mask = jnp.arange(c_kv.shape[1]) <= pos
    scores = jnp.where(mask[None, None, :], scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhs,bsr->bhr", attn, c_kv.astype(jnp.float32))   # (B,H,R)
    out = jnp.einsum("bhr,rhd->bhd", out_lat, params["w_uv"].astype(jnp.float32))
    out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
    return out @ params["w_o"], {"c_kv": c_kv, "k_rope": k_rope}
