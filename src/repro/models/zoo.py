"""Unified model API over every assigned architecture family.

``Model(cfg)`` exposes the functional surface the launcher, trainer, and
server consume::

    params = model.init(key)
    loss, aux = model.loss(params, batch)                  # train
    logits = model.forward_logits(params, batch)           # prefill
    cache  = model.init_cache(batch_size, max_len)
    logits, cache = model.decode_step(params, tok, cache, pos)   # serve

Batches are dicts: ``tokens``/``labels`` (B, S) int32 plus, per modality,
``patch_embeds`` (VLM) or ``src_embeds`` (audio enc-dec) — the stub frontends
per the harness carve-out.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import Activation, ArchConfig, ArchType
from repro.distribution.sharding import DATA, MODEL, constrain
from repro.models.attention import gqa_cache_init
from repro.models.layers import dense_init, embed_init, mlp_param_count, rmsnorm, rmsnorm_init
from repro.models.mamba2 import mamba2_cache_init, mamba2_param_count
from repro.models.moe import moe_param_count
from repro.models.transformer import (
    _self_attn_cache_init,
    dec_block_apply,
    dec_block_decode,
    dec_block_init,
    dense_block_apply,
    dense_block_decode,
    dense_block_init,
    hybrid_layout,
    mamba_block_apply,
    mamba_block_decode,
    mamba_block_init,
    moe_layout,
    run_stack,
    run_stack_decode,
    stack_init,
)

PyTree = Any


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    use_pallas: bool = False
    remat: bool = True
    loss_chunk: int = 512  # sequence chunk for the memory-bounded CE

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        dtype = _dtype(cfg)
        key, k_embed, k_head, k_body = jax.random.split(key, 4)
        params: dict[str, Any] = {
            "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
            "ln_f": rmsnorm_init(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)

        at = cfg.arch_type
        if at in (ArchType.DENSE, ArchType.VLM):
            params["blocks"] = stack_init(
                lambda k: dense_block_init(k, cfg, dtype, use_moe=False), k_body, cfg.num_layers
            )
        elif at == ArchType.MOE:
            first, n_moe, n_inter = moe_layout(cfg)
            k1, k2, k3 = jax.random.split(k_body, 3)
            if first:
                params["first_blocks"] = stack_init(
                    lambda k: dense_block_init(k, cfg, dtype, use_moe=False), k1, first
                )
            if cfg.moe.moe_every == 1:
                params["moe_blocks"] = stack_init(
                    lambda k: dense_block_init(k, cfg, dtype, use_moe=True), k2, n_moe
                )
            else:
                def pair_init(k):
                    ka, kb = jax.random.split(k)
                    return {
                        "dense": dense_block_init(ka, cfg, dtype, use_moe=False),
                        "moe": dense_block_init(kb, cfg, dtype, use_moe=True),
                    }
                params["pair_blocks"] = stack_init(pair_init, k2, n_moe)
                tail = n_inter - n_moe
                if tail > 0:
                    params["tail_blocks"] = stack_init(
                        lambda k: dense_block_init(k, cfg, dtype, use_moe=False), k3, tail
                    )
        elif at == ArchType.SSM:
            params["blocks"] = stack_init(
                lambda k: mamba_block_init(k, cfg, dtype), k_body, cfg.num_layers
            )
        elif at == ArchType.HYBRID:
            groups, per_group, tail = hybrid_layout(cfg)
            k1, k2, k3 = jax.random.split(k_body, 3)
            params["group_mamba"] = stack_init(
                lambda k: stack_init(lambda kk: mamba_block_init(kk, cfg, dtype), k, per_group),
                k1,
                groups,
            )
            params["shared_attn"] = dense_block_init(k2, cfg, dtype, use_moe=False)
            if tail:
                params["tail_blocks"] = stack_init(
                    lambda k: mamba_block_init(k, cfg, dtype), k3, tail
                )
        elif at == ArchType.ENCDEC:
            k1, k2 = jax.random.split(k_body)
            params["enc_blocks"] = stack_init(
                lambda k: dense_block_init(k, cfg, dtype, use_moe=False), k1, cfg.encoder_layers
            )
            params["enc_ln"] = rmsnorm_init(cfg.d_model, dtype)
            params["blocks"] = stack_init(
                lambda k: dec_block_init(k, cfg, dtype), k2, cfg.num_layers
            )
        else:
            raise ValueError(f"unknown arch_type {at}")

        if cfg.frontend is not None:
            key, k_fp = jax.random.split(key)
            params["frontend_proj"] = dense_init(k_fp, cfg.d_model, cfg.d_model, dtype)
        if cfg.mtp:
            key, k_mtp1, k_mtp2 = jax.random.split(key, 3)
            params["mtp"] = {
                "proj": dense_init(k_mtp1, 2 * cfg.d_model, cfg.d_model, dtype),
                "block": dense_block_init(k_mtp2, cfg, dtype, use_moe=False),
                "ln": rmsnorm_init(cfg.d_model, dtype),
            }
        return params

    # --------------------------------------------------------------- forward
    def _embed_inputs(self, params: PyTree, batch: dict[str, jnp.ndarray]) -> jnp.ndarray:
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        x = constrain(x, DATA, None, None)
        if cfg.arch_type == ArchType.VLM:
            patches = batch["patch_embeds"] @ params["frontend_proj"]
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        return x

    def _backbone(self, params: PyTree, x: jnp.ndarray, enc: jnp.ndarray | None = None):
        """Run the layer stacks.  Returns (hidden, aux_loss)."""
        cfg = self.cfg
        at = cfg.arch_type
        remat = self.remat
        aux_total = jnp.zeros((), jnp.float32)

        def dense_body(use_moe):
            def body(p, h):
                return dense_block_apply(p, cfg, h, use_moe=use_moe)
            return body

        if at in (ArchType.DENSE, ArchType.VLM):
            x, aux = run_stack(params["blocks"], x, dense_body(False), remat=remat)
            aux_total += aux
        elif at == ArchType.MOE:
            if "first_blocks" in params:
                x, aux = run_stack(params["first_blocks"], x, dense_body(False), remat=remat)
                aux_total += aux
            if "moe_blocks" in params:
                x, aux = run_stack(params["moe_blocks"], x, dense_body(True), remat=remat)
                aux_total += aux
            if "pair_blocks" in params:
                def pair_body(p, h):
                    h, a1 = dense_block_apply(p["dense"], cfg, h, use_moe=False)
                    h, a2 = dense_block_apply(p["moe"], cfg, h, use_moe=True)
                    return h, a1 + a2
                x, aux = run_stack(params["pair_blocks"], x, pair_body, remat=remat)
                aux_total += aux
            if "tail_blocks" in params:
                x, aux = run_stack(params["tail_blocks"], x, dense_body(False), remat=remat)
                aux_total += aux
        elif at == ArchType.SSM:
            def body(p, h):
                return mamba_block_apply(p, cfg, h, use_pallas=self.use_pallas), jnp.zeros((), jnp.float32)
            x, _ = run_stack(params["blocks"], x, body, remat=remat)
        elif at == ArchType.HYBRID:
            shared = params["shared_attn"]

            def group_body(p, h):
                def inner(pp, hh):
                    return mamba_block_apply(pp, cfg, hh, use_pallas=self.use_pallas), jnp.zeros((), jnp.float32)
                h, _ = run_stack(p, h, inner, remat=False)
                h, _ = dense_block_apply(shared, cfg, h, use_moe=False)
                return h, jnp.zeros((), jnp.float32)

            x, _ = run_stack(params["group_mamba"], x, group_body, remat=remat)
            if "tail_blocks" in params:
                def body(p, h):
                    return mamba_block_apply(p, cfg, h, use_pallas=self.use_pallas), jnp.zeros((), jnp.float32)
                x, _ = run_stack(params["tail_blocks"], x, body, remat=remat)
        elif at == ArchType.ENCDEC:
            assert enc is not None, "encoder-decoder needs encoder output"
            def body(p, h):
                return dec_block_apply(p, cfg, h, enc), jnp.zeros((), jnp.float32)
            x, _ = run_stack(params["blocks"], x, body, remat=remat)
        return x, aux_total

    def _encode(self, params: PyTree, src_embeds: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = src_embeds @ params["frontend_proj"]

        def body(p, h):
            return dense_block_apply(p, cfg, h, use_moe=False, causal=False)

        x, _ = run_stack(params["enc_blocks"], x, body, remat=self.remat)
        return rmsnorm(params["enc_ln"], x, cfg.norm_eps)

    def hidden(self, params: PyTree, batch: dict[str, jnp.ndarray]) -> tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        enc = None
        if cfg.arch_type == ArchType.ENCDEC:
            enc = self._encode(params, batch["src_embeds"])
        x = self._embed_inputs(params, batch)
        x, aux = self._backbone(params, x, enc)
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        if cfg.arch_type == ArchType.VLM:
            # drop the patch positions: loss/logits apply to text only
            x = x[:, batch["patch_embeds"].shape[1] :, :]
        return x, aux

    def _head_matrix(self, params: PyTree) -> jnp.ndarray:
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    def forward_logits(self, params: PyTree, batch: dict[str, jnp.ndarray]) -> jnp.ndarray:
        x, _ = self.hidden(params, batch)
        return (x @ self._head_matrix(params)).astype(jnp.float32)

    # ------------------------------------------------------------------ loss
    def _chunked_ce(self, h: jnp.ndarray, head: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
        """Memory-bounded CE: scan over sequence chunks, remat the logits."""
        b, s, d = h.shape
        chunk = min(self.loss_chunk, s)
        nc = -(-s // chunk)
        pad = nc * chunk - s
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        hc = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
        yc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

        @jax.checkpoint
        def body(carry, inp):
            total, count = carry
            h_k, y_k = inp
            logits = (h_k @ head).astype(jnp.float32)
            logits = constrain(logits, DATA, None, MODEL)
            logp = jax.nn.log_softmax(logits, axis=-1)
            valid = y_k >= 0
            ll = jnp.take_along_axis(logp, jnp.maximum(y_k, 0)[..., None], axis=-1)[..., 0]
            total = total + jnp.sum(jnp.where(valid, -ll, 0.0))
            count = count + jnp.sum(valid)
            return (total, count), None

        (total, count), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (hc, yc)
        )
        return total / jnp.maximum(count, 1).astype(jnp.float32)

    def loss(self, params: PyTree, batch: dict[str, jnp.ndarray]) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
        cfg = self.cfg
        h, aux = self.hidden(params, batch)
        head = self._head_matrix(params)
        ce = self._chunked_ce(h, head, batch["labels"])
        total = ce
        metrics = {"ce": ce, "router_aux": aux}
        if cfg.moe is not None:
            total = total + cfg.moe.router_aux_weight * aux
        if cfg.mtp and "mtp" in params:
            # DeepSeek-style MTP: predict t+2 from (h_t, emb(tok_{t+1}))
            emb_next = params["embed"][batch["tokens"]][:, 1:, :]
            mtp_in = jnp.concatenate(
                [rmsnorm(params["mtp"]["ln"], h[:, :-1, :], cfg.norm_eps), emb_next], axis=-1
            )
            mtp_h = mtp_in @ params["mtp"]["proj"]
            mtp_h, _ = dense_block_apply(params["mtp"]["block"], cfg, mtp_h, use_moe=False)
            mtp_ce = self._chunked_ce(mtp_h, head, batch["labels"][:, 1:])
            total = total + 0.3 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        metrics["loss"] = total
        return total, metrics

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int) -> PyTree:
        cfg = self.cfg
        dtype = _dtype(cfg)
        at = cfg.arch_type

        def stack_cache(make, n):
            assert n > 0
            one = make()
            return jax.tree.map(lambda l: jnp.broadcast_to(l[None], (n, *l.shape)).copy(), one)

        attn_cache = lambda: _self_attn_cache_init(cfg, batch, max_len, dtype)
        mamba_cache = lambda: mamba2_cache_init(cfg, batch, dtype)

        if at in (ArchType.DENSE, ArchType.VLM):
            return {"blocks": stack_cache(attn_cache, cfg.num_layers)}
        if at == ArchType.MOE:
            first, n_moe, n_inter = moe_layout(cfg)
            cache: dict[str, Any] = {}
            if first:
                cache["first_blocks"] = stack_cache(attn_cache, first)
            if cfg.moe.moe_every == 1:
                cache["moe_blocks"] = stack_cache(attn_cache, n_moe)
            else:
                cache["pair_blocks"] = {
                    "dense": stack_cache(attn_cache, n_moe),
                    "moe": stack_cache(attn_cache, n_moe),
                }
                tail = n_inter - n_moe
                if tail > 0:
                    cache["tail_blocks"] = stack_cache(attn_cache, tail)
            return cache
        if at == ArchType.SSM:
            return {"blocks": stack_cache(mamba_cache, cfg.num_layers)}
        if at == ArchType.HYBRID:
            groups, per_group, tail = hybrid_layout(cfg)
            cache = {
                "group_mamba": jax.tree.map(
                    lambda l: jnp.broadcast_to(l[None, None], (groups, per_group, *l.shape)).copy(),
                    mamba_cache(),
                ),
                "shared_attn": stack_cache(attn_cache, groups),
            }
            if tail:
                cache["tail_blocks"] = stack_cache(mamba_cache, tail)
            return cache
        if at == ArchType.ENCDEC:
            hd = cfg.resolved_head_dim
            # cross K/V get filled by encode_for_decode(); sized to the
            # encoder frame count — stored per layer.
            return {
                "blocks": {
                    "self": stack_cache(attn_cache, cfg.num_layers),
                    "cross_k": jnp.zeros(
                        (cfg.num_layers, batch, self.encoder_frames(max_len), cfg.num_kv_heads, hd), dtype=dtype
                    ),
                    "cross_v": jnp.zeros(
                        (cfg.num_layers, batch, self.encoder_frames(max_len), cfg.num_kv_heads, hd), dtype=dtype
                    ),
                }
            }
        raise ValueError(at)

    @staticmethod
    def encoder_frames(seq_len: int) -> int:
        """Audio frontend stub: 4x temporal downsampling of the frame track."""
        return max(seq_len // 4, 8)

    # ---------------------------------------------------------------- decode
    def decode_step(
        self,
        params: PyTree,
        tokens: jnp.ndarray,
        cache: PyTree,
        pos: jnp.ndarray,
        *,
        token_embeds: jnp.ndarray | None = None,
    ) -> tuple[jnp.ndarray, PyTree]:
        """One new token for every sequence in the batch.

        tokens: (B, 1) int32; pos: scalar int32 absolute position.
        ``token_embeds`` (B, 1, D) bypasses the embedding table — used to
        prefill VLM patch embeddings through the decode path.
        Returns (logits (B, vocab) fp32, new cache).
        """
        cfg = self.cfg
        at = cfg.arch_type
        if token_embeds is not None:
            x = token_embeds.astype(params["embed"].dtype)
            if cfg.frontend == "vision":
                x = x @ params["frontend_proj"]
        else:
            x = params["embed"][tokens]
        x = constrain(x, DATA, None, None)

        def dense_dec(use_moe):
            def body(p, h, c):
                return dense_block_decode(p, cfg, h, c, pos, use_moe=use_moe)
            return body

        new_cache: dict[str, Any] = {}
        if at in (ArchType.DENSE, ArchType.VLM):
            x, new_cache["blocks"] = run_stack_decode(params["blocks"], cache["blocks"], x, dense_dec(False))
        elif at == ArchType.MOE:
            if "first_blocks" in params:
                x, new_cache["first_blocks"] = run_stack_decode(
                    params["first_blocks"], cache["first_blocks"], x, dense_dec(False)
                )
            if "moe_blocks" in params:
                x, new_cache["moe_blocks"] = run_stack_decode(
                    params["moe_blocks"], cache["moe_blocks"], x, dense_dec(True)
                )
            if "pair_blocks" in params:
                def pair_body(p, h, c):
                    h, cd = dense_block_decode(p["dense"], cfg, h, c["dense"], pos, use_moe=False)
                    h, cm = dense_block_decode(p["moe"], cfg, h, c["moe"], pos, use_moe=True)
                    return h, {"dense": cd, "moe": cm}
                x, new_cache["pair_blocks"] = run_stack_decode(
                    params["pair_blocks"], cache["pair_blocks"], x, pair_body
                )
            if "tail_blocks" in params:
                x, new_cache["tail_blocks"] = run_stack_decode(
                    params["tail_blocks"], cache["tail_blocks"], x, dense_dec(False)
                )
        elif at == ArchType.SSM:
            def body(p, h, c):
                return mamba_block_decode(p, cfg, h, c, pos)
            x, new_cache["blocks"] = run_stack_decode(params["blocks"], cache["blocks"], x, body)
        elif at == ArchType.HYBRID:
            shared = params["shared_attn"]

            def group_body(h, inputs):
                p_group, c_group, c_attn = inputs

                def inner(hh, inp):
                    pp, cc = inp
                    hh, cc_new = mamba_block_decode(pp, cfg, hh, cc, pos)
                    return hh, cc_new

                h, c_group_new = jax.lax.scan(inner, h, (p_group, c_group))
                h, c_attn_new = dense_block_decode(shared, cfg, h, c_attn, pos, use_moe=False)
                return h, (c_group_new, c_attn_new)

            x, (cg, ca) = jax.lax.scan(
                group_body, x, (params["group_mamba"], cache["group_mamba"], cache["shared_attn"])
            )
            new_cache["group_mamba"] = cg
            new_cache["shared_attn"] = ca
            if "tail_blocks" in params:
                def body(p, h, c):
                    return mamba_block_decode(p, cfg, h, c, pos)
                x, new_cache["tail_blocks"] = run_stack_decode(
                    params["tail_blocks"], cache["tail_blocks"], x, body
                )
        elif at == ArchType.ENCDEC:
            def body(p, h, c):
                return dec_block_decode(p, cfg, h, c, pos)
            x, new_cache["blocks"] = run_stack_decode(params["blocks"], cache["blocks"], x, body)
        else:
            raise ValueError(at)

        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = (x[:, 0, :] @ self._head_matrix(params)).astype(jnp.float32)
        return logits, new_cache

    def encode_for_decode(self, params: PyTree, src_embeds: jnp.ndarray, cache: PyTree) -> PyTree:
        """Precompute encoder output and per-layer cross K/V into the cache."""
        cfg = self.cfg
        enc = self._encode(params, src_embeds)
        hd = cfg.resolved_head_dim
        b, t, _ = enc.shape

        def kv(p):
            k = (enc @ p["cross"]["w_k"]).reshape(b, t, cfg.num_kv_heads, hd)
            v = (enc @ p["cross"]["w_v"]).reshape(b, t, cfg.num_kv_heads, hd)
            return k, v

        ks, vs = jax.vmap(kv)(params["blocks"])
        cache = dict(cache)
        blocks = dict(cache["blocks"])
        blocks["cross_k"] = ks.astype(cache["blocks"]["cross_k"].dtype)
        blocks["cross_v"] = vs.astype(cache["blocks"]["cross_v"].dtype)
        cache["blocks"] = blocks
        return cache


# ==========================================================================
# analytic parameter counting (roofline MODEL_FLOPS = 6 N D)
# ==========================================================================

def _attn_params(cfg: ArchConfig) -> int:
    if cfg.mla is not None:
        m = cfg.mla
        h = cfg.num_heads
        return (
            cfg.d_model * m.q_lora_rank
            + m.q_lora_rank * h * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            + cfg.d_model * m.kv_lora_rank
            + cfg.d_model * m.qk_rope_head_dim
            + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
            + h * m.v_head_dim * cfg.d_model
            + m.q_lora_rank + m.kv_lora_rank
        )
    hd = cfg.resolved_head_dim
    base = cfg.d_model * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) + cfg.num_heads * hd * cfg.d_model
    if cfg.qk_norm:
        base += 2 * hd
    return base


def _dense_block_params(cfg: ArchConfig) -> int:
    return _attn_params(cfg) + mlp_param_count(cfg.d_model, cfg.d_ff, cfg.activation) + 2 * cfg.d_model


def _moe_block_params(cfg: ArchConfig, active_only: bool) -> int:
    return _attn_params(cfg) + moe_param_count(cfg, active_only) + 2 * cfg.d_model


def count_params_config(cfg: ArchConfig, active_only: bool = False) -> int:
    at = cfg.arch_type
    total = cfg.vocab_size * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        total += cfg.d_model * cfg.vocab_size
    total += cfg.d_model  # ln_f

    if at in (ArchType.DENSE, ArchType.VLM):
        total += cfg.num_layers * _dense_block_params(cfg)
    elif at == ArchType.MOE:
        first, n_moe, n_inter = moe_layout(cfg)
        total += first * _dense_block_params(cfg)
        total += n_moe * _moe_block_params(cfg, active_only)
        if cfg.moe.moe_every != 1:
            total += n_inter * _dense_block_params(cfg)
    elif at == ArchType.SSM:
        total += cfg.num_layers * (mamba2_param_count(cfg) + cfg.d_model)
    elif at == ArchType.HYBRID:
        groups, per_group, tail = hybrid_layout(cfg)
        total += (groups * per_group + tail) * (mamba2_param_count(cfg) + cfg.d_model)
        total += _dense_block_params(cfg)  # the shared attention block, once
    elif at == ArchType.ENCDEC:
        total += cfg.encoder_layers * _dense_block_params(cfg) + cfg.d_model
        # decoder blocks: self-attn + cross-attn + mlp
        total += cfg.num_layers * (
            2 * _attn_params(cfg)
            + mlp_param_count(cfg.d_model, cfg.d_ff, cfg.activation)
            + 3 * cfg.d_model
        )
        total += cfg.d_model * cfg.d_model  # frontend proj
    if cfg.frontend == "vision":
        total += cfg.d_model * cfg.d_model
    if cfg.mtp:
        total += 2 * cfg.d_model * cfg.d_model + _dense_block_params(cfg) + cfg.d_model
    return int(total)
