"""The paper's model: stacked GRU + single ReLU-headed FCN for LoS regression.

Paper Table 1: L=2 layers, N=32 hidden, dropout r=0.05, batch 128,
AdamW(lr=5e-3, wd=5e-3), loss = MSLE.  Eq. (1)-(2) define the cell and the
strictly-positive output head (a patient cannot have negative LoS).

Implemented as explicit pytrees + ``jax.lax.scan`` over time.  When
``use_pallas`` is set, the recurrence runs through the fused Pallas TPU
kernel in ``repro.kernels.gru_scan`` (interpret mode on CPU).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GRUConfig:
    input_dim: int = 38
    hidden_dim: int = 32
    num_layers: int = 2
    dropout: float = 0.05
    use_pallas: bool = False


def init_gru(key: jax.Array, cfg: GRUConfig) -> PyTree:
    """Glorot-ish init matching torch.nn.GRU defaults (U(-1/sqrt(N), 1/sqrt(N)))."""
    params: dict[str, Any] = {"layers": []}
    scale = 1.0 / jnp.sqrt(cfg.hidden_dim)
    for layer in range(cfg.num_layers):
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        in_dim = cfg.input_dim if layer == 0 else cfg.hidden_dim
        params["layers"].append(
            {
                "w_ih": jax.random.uniform(k1, (in_dim, 3 * cfg.hidden_dim), minval=-scale, maxval=scale),
                "w_hh": jax.random.uniform(k2, (cfg.hidden_dim, 3 * cfg.hidden_dim), minval=-scale, maxval=scale),
                "b_ih": jax.random.uniform(k3, (3 * cfg.hidden_dim,), minval=-scale, maxval=scale),
                "b_hh": jax.random.uniform(k4, (3 * cfg.hidden_dim,), minval=-scale, maxval=scale),
            }
        )
    key, k_head = jax.random.split(key)
    params["head"] = {
        "w": jax.random.uniform(k_head, (cfg.hidden_dim, 1), minval=-scale, maxval=scale),
        "b": jnp.zeros((1,)),
    }
    return params


def gru_cell(layer: PyTree, x_t: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Paper eq. (1).  x_t: (B, F), h: (B, N) -> new h."""
    gates_x = x_t @ layer["w_ih"] + layer["b_ih"]          # (B, 3N)
    gates_h = h @ layer["w_hh"] + layer["b_hh"]            # (B, 3N)
    xr, xz, xn = jnp.split(gates_x, 3, axis=-1)
    hr, hz, hn = jnp.split(gates_h, 3, axis=-1)
    r = jax.nn.sigmoid(xr + hr)
    z = jax.nn.sigmoid(xz + hz)
    n = jnp.tanh(xn + r * hn)
    return (1.0 - z) * n + z * h


def _layer_scan(layer: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    """Run one GRU layer over time.  x: (B, T, F) -> hidden seq (B, T, N)."""
    batch = x.shape[0]
    hidden = layer["w_hh"].shape[0]
    h0 = jnp.zeros((batch, hidden), dtype=x.dtype)

    def step(h, x_t):
        h = gru_cell(layer, x_t, h)
        return h, h

    _, h_seq = jax.lax.scan(step, h0, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(h_seq, 0, 1)


def _layer_scan_pallas(layer: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels.gru_scan import ops as gru_ops

    return gru_ops.gru_sequence(
        x, layer["w_ih"], layer["w_hh"], layer["b_ih"], layer["b_hh"]
    )


def gru_apply(
    params: PyTree,
    cfg: GRUConfig,
    x: jnp.ndarray,
    *,
    train: bool = False,
    rng: jax.Array | None = None,
) -> jnp.ndarray:
    """x: (B, T, F) -> predicted LoS (B,), strictly non-negative (eq. 2)."""
    h = x
    for i, layer in enumerate(params["layers"]):
        run = _layer_scan_pallas if cfg.use_pallas else _layer_scan
        h = run(layer, h)
        if train and cfg.dropout > 0.0 and i < len(params["layers"]) - 1:
            assert rng is not None, "dropout requires an rng in train mode"
            rng, sub = jax.random.split(rng)
            keep = jax.random.bernoulli(sub, 1.0 - cfg.dropout, h.shape)
            h = jnp.where(keep, h / (1.0 - cfg.dropout), 0.0)
    h_final = h[:, -1, :]  # prediction from the final hidden state (24th hour)
    y_hat = jax.nn.relu(h_final @ params["head"]["w"] + params["head"]["b"])
    return y_hat[:, 0]


def msle_loss(y: jnp.ndarray, y_hat: jnp.ndarray, mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Paper eq. (6): mean squared logarithmic error."""
    err = (jnp.log1p(y) - jnp.log1p(y_hat)) ** 2
    if mask is None:
        return jnp.mean(err)
    return jnp.sum(err * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(cfg: GRUConfig):
    """loss(params, batch=(x, y, mask), rng) for training loops."""

    def loss_fn(params, batch, rng=None):
        x, y, mask = batch
        y_hat = gru_apply(params, cfg, x, train=rng is not None, rng=rng)
        return msle_loss(y, y_hat, mask)

    return loss_fn


def count_params(params: PyTree) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
