"""Mixture-of-Experts feed-forward with sort-based capacity dispatch.

TPU-native formulation (no per-expert Python loops, no (T, E, C) one-hot):

  1. top-k routing over router logits (fp32);
  2. flatten (token, slot) pairs and ``argsort`` by expert id;
  3. position-within-expert via ``searchsorted`` on the sorted ids;
  4. scatter into a dense (E, C, D) expert buffer (capacity drop);
  5. batched expert matmuls ``(E,C,D) @ (E,D,F)`` — MXU-shaped einsums;
  6. gather back and weighted segment-sum per token.

Expert parallelism: the (E, C, D) buffer and expert weights are sharded over
the ``model`` axis on E ('ep' mode — XLA inserts the all-to-all style
resharding between token-sharded and expert-sharded layouts), or over F
('tp' mode — no all-to-all, experts replicated).  The mode is the subject of
one of the §Perf hillclimbs.

Aux load-balance loss follows Switch/DeepSeek: E * sum_e f_e * p_e.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import Activation, ArchConfig, MoEConfig
from repro.distribution.sharding import DATA, MODEL, constrain
from repro.models.layers import dense_init, mlp_apply, mlp_init

PyTree = Any


def moe_init(key: jax.Array, cfg: ArchConfig, dtype) -> PyTree:
    m: MoEConfig = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    keys = jax.random.split(key, 6)
    scale = d ** -0.5
    params: dict[str, Any] = {
        "router": dense_init(keys[0], d, e, jnp.float32),  # router kept fp32
        "w_gate": (jax.random.truncated_normal(keys[1], -2, 2, (e, d, f)) * scale).astype(dtype),
        "w_up": (jax.random.truncated_normal(keys[2], -2, 2, (e, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.truncated_normal(keys[3], -2, 2, (e, f, d)) * (f ** -0.5)).astype(dtype),
    }
    if m.num_shared_experts > 0:
        params["shared"] = mlp_init(
            keys[4], d, f * m.num_shared_experts, Activation.SWIGLU, dtype
        )
    return params


def _capacity(num_tokens: int, m: MoEConfig) -> int:
    cap = int(num_tokens * m.top_k / m.num_experts * m.capacity_factor)
    return max(cap, m.top_k)


def moe_apply(params: PyTree, cfg: ArchConfig, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.moe.expert_sharding == "ep_local":
        return moe_apply_local(params, cfg, x)
    return moe_apply_global(params, cfg, x)


def moe_apply_global(params: PyTree, cfg: ArchConfig, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (output (B,S,D), aux load-balance loss scalar)."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    flat = x.reshape(t, d)

    # --- routing ----------------------------------------------------------
    logits = flat.astype(jnp.float32) @ params["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)                          # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e f_e * p_e
    f_e = jnp.mean(
        (jax.nn.one_hot(ids, e, dtype=jnp.float32)).sum(axis=1), axis=0
    )                                                               # frac routed
    p_e = probs.mean(axis=0)
    aux = e * jnp.sum(f_e * p_e)

    # --- sort-based dispatch ------------------------------------------------
    cap = _capacity(t, m)
    flat_e = ids.reshape(t * k)                                     # expert per pair
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    pair_token = order // k                                         # token per sorted pair
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)      # drop slot at end

    buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype)
    buf = buf.at[dest].set(flat[pair_token])                        # dropped pairs land in slot e*cap
    buf = buf[: e * cap].reshape(e, cap, d)
    buf = constrain(buf, MODEL, None, None)                         # expert-parallel layout

    # --- expert computation (swiglu) ----------------------------------------
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", gate * up, params["w_down"])
    out_buf = constrain(out_buf, MODEL, None, None)

    # --- combine ------------------------------------------------------------
    out_buf = jnp.concatenate([out_buf.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)])
    gathered = out_buf[dest]                                        # (T*k, D), dropped→0
    w_sorted = weights.reshape(t * k)[order].astype(x.dtype)
    contrib = gathered * w_sorted[:, None]
    token_out = jnp.zeros((t, d), dtype=x.dtype).at[pair_token].add(contrib)
    token_out = constrain(token_out.reshape(b, s, d), DATA, None, None)

    # --- shared experts ------------------------------------------------------
    if "shared" in params:
        token_out = token_out + mlp_apply(params["shared"], x, Activation.SWIGLU)
    return token_out, aux


def moe_apply_local(
    params: PyTree, cfg: ArchConfig, x: jnp.ndarray, num_shards: int = 16
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Shard-local MoE dispatch (§Perf optimization, beyond-paper).

    The global formulation lets GSPMD implement the token->expert scatter as
    a full-size materialize + all-reduce: at deepseek-v3 train_4k scale that
    is a 240 GB all-reduce *per MoE layer*.  Here the dispatch is batched
    over ``num_shards`` groups aligned with the ``data`` mesh axis: argsort,
    position-within-expert, scatter, and combine all carry a leading group
    dim sharded over ``data``, so every data shard dispatches only its own
    tokens into a *local* (E, C_loc, D) buffer — GSPMD then needs only the
    genuine expert all-to-all/all-gather on (E, C_loc, D), two orders of
    magnitude smaller.

    Identical math to ``moe_apply_global`` (same capacity per token count;
    drops happen per shard instead of globally — at realistic capacity
    factors the difference is noise).
    """
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    g = num_shards if t % num_shards == 0 and t >= num_shards else 1
    t_loc = t // g
    flat = x.reshape(g, t_loc, d)
    flat = constrain(flat, DATA, None, None)

    logits = flat.astype(jnp.float32) @ params["router"]            # (G, T_loc, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)                          # (G, T_loc, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    f_e = jnp.mean(jax.nn.one_hot(ids, e, dtype=jnp.float32).sum(axis=2), axis=(0, 1))
    p_e = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)

    cap = _capacity(t_loc, m)

    def dispatch_one(flat_g, ids_g, w_g):
        """One shard's dispatch: all shapes local."""
        flat_e = ids_g.reshape(t_loc * k)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        pair_token = order // k
        starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
        pos_in_e = jnp.arange(t_loc * k) - starts[sorted_e]
        keep = pos_in_e < cap
        dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
        buf = jnp.zeros((e * cap + 1, d), dtype=flat_g.dtype).at[dest].set(flat_g[pair_token])
        w_sorted = w_g.reshape(t_loc * k)[order].astype(flat_g.dtype)
        return buf[: e * cap].reshape(e, cap, d), dest, pair_token, w_sorted

    buf, dest, pair_token, w_sorted = jax.vmap(dispatch_one)(flat, ids, weights)
    # (G, E, C_loc, D): groups over data, experts over model.  NOTE (§Perf,
    # refuted hypothesis): reshaping to (E@(data,model), G*C) to force the
    # "canonical" expert all-to-all lowered to gathers and was 8x WORSE in
    # collective bytes than this formulation — GSPMD handles the (data,
    # model)-aligned einsum below with cheaper resharding.
    buf = constrain(buf, DATA, MODEL, None, None)

    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]))
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    out_buf = jnp.einsum("gecf,efd->gecd", gate * up, params["w_down"])
    out_buf = constrain(out_buf, DATA, MODEL, None, None)

    def combine_one(out_buf_g, dest_g, pair_token_g, w_sorted_g):
        padded = jnp.concatenate(
            [out_buf_g.reshape(e * cap, d), jnp.zeros((1, d), out_buf_g.dtype)]
        )
        contrib = padded[dest_g] * w_sorted_g[:, None]
        return jnp.zeros((t_loc, d), dtype=out_buf_g.dtype).at[pair_token_g].add(contrib)

    token_out = jax.vmap(combine_one)(out_buf, dest, pair_token, w_sorted)
    token_out = constrain(token_out, DATA, None, None).reshape(b, s, d)

    if "shared" in params:
        token_out = token_out + mlp_apply(params["shared"], x, Activation.SWIGLU)
    return token_out, aux


def moe_param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    """Per-layer MoE parameter count (router + experts + shared)."""
    m: MoEConfig = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    per_expert = 3 * d * f
    num = m.top_k if active_only else m.num_experts
    total = cfg.d_model * m.num_experts + num * per_expert
    if m.num_shared_experts > 0:
        total += 3 * d * f * m.num_shared_experts
    return total
