"""Shared neural-net building blocks (pure JAX, explicit pytrees).

Initializers return dict pytrees; apply functions are free functions so the
whole zoo stays functional and scan/pjit friendly.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import Activation

PyTree = Any


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key: jax.Array, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    """Truncated-normal fan-in init (matches common LLM practice)."""
    scale = in_dim ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype) -> PyTree:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params: PyTree, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) with D even; positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs         # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# feed-forward variants
# --------------------------------------------------------------------------

def mlp_init(key: jax.Array, d_model: int, d_ff: int, activation: Activation, dtype) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    if activation == Activation.SWIGLU:
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype),
        }
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
    }


def mlp_apply(params: PyTree, x: jnp.ndarray, activation: Activation) -> jnp.ndarray:
    if activation == Activation.SWIGLU:
        gate = jax.nn.silu(x @ params["w_gate"])
        return (gate * (x @ params["w_up"])) @ params["w_down"]
    h = x @ params["w_up"]
    if activation == Activation.RELU2:
        h = jnp.square(jax.nn.relu(h))     # Nemotron-4 squared ReLU
    elif activation == Activation.GELU:
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return h @ params["w_down"]


def mlp_param_count(d_model: int, d_ff: int, activation: Activation) -> int:
    return d_model * d_ff * (3 if activation == Activation.SWIGLU else 2)
