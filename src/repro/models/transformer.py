"""Transformer blocks and scan-over-layers stacks for every assigned family.

All per-layer parameters are *stacked* on a leading layer dimension and run
through ``jax.lax.scan`` — this keeps the HLO size O(1) in depth (critical
for compiling 61-layer/671B configs on the CPU dry-run) and gives XLA a
single layer body to schedule.  Training bodies are wrapped in
``jax.checkpoint`` (full remat per layer) so activation memory is O(layers)
in checkpoints, not intermediates.

Block kinds:
  * ``dense``  — [MLA | GQA] attention + [swiglu | relu2 | gelu] MLP
  * ``moe``    — attention + sort-dispatch MoE (+ shared experts)
  * ``mamba``  — Mamba2 SSD block
  * ``enc``    — bidirectional attention + MLP (audio encoder)
  * ``dec``    — causal self-attention + cross-attention + MLP
Hybrid (Zamba2) runs groups of mamba blocks with one weight-*shared*
attention block applied between groups.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ArchType
from repro.models.attention import (
    blockwise_attention,
    gqa_apply,
    gqa_cache_init,
    gqa_decode,
    gqa_init,
    mla_apply,
    mla_cache_init,
    mla_decode,
    mla_init,
)
from repro.models.layers import (
    apply_rope,
    dense_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.mamba2 import (
    mamba2_apply,
    mamba2_cache_init,
    mamba2_decode,
    mamba2_init,
)
from repro.models.moe import moe_apply, moe_init

PyTree = Any


def stack_init(init_fn: Callable[..., PyTree], key: jax.Array, n: int) -> PyTree:
    """Initialize ``n`` copies of a block with stacked (leading-dim) leaves."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ==========================================================================
# block init / apply
# ==========================================================================

def _self_attn_init(key: jax.Array, cfg: ArchConfig, dtype) -> PyTree:
    if cfg.mla is not None:
        return mla_init(key, cfg, dtype)
    return gqa_init(key, cfg, dtype)


def _self_attn_apply(params: PyTree, cfg: ArchConfig, x: jnp.ndarray, *, causal=True) -> jnp.ndarray:
    if cfg.mla is not None:
        return mla_apply(params, cfg, x)
    return gqa_apply(params, cfg, x, causal=causal)


def _self_attn_decode(params, cfg, x, cache, pos):
    if cfg.mla is not None:
        return mla_decode(params, cfg, x, cache, pos)
    return gqa_decode(params, cfg, x, cache, pos)


def _self_attn_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype) -> PyTree:
    if cfg.mla is not None:
        return mla_cache_init(cfg, batch, max_len, dtype)
    return gqa_cache_init(cfg, batch, max_len, dtype)


def dense_block_init(key: jax.Array, cfg: ArchConfig, dtype, *, use_moe: bool) -> PyTree:
    k1, k2 = jax.random.split(key)
    params = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": _self_attn_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if use_moe:
        params["moe"] = moe_init(k2, cfg, dtype)
    else:
        params["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return params


def dense_block_apply(
    params: PyTree, cfg: ArchConfig, x: jnp.ndarray, *, use_moe: bool, causal: bool = True
) -> tuple[jnp.ndarray, jnp.ndarray]:
    h = x + _self_attn_apply(params["attn"], cfg, rmsnorm(params["ln1"], x, cfg.norm_eps), causal=causal)
    ff_in = rmsnorm(params["ln2"], h, cfg.norm_eps)
    if use_moe:
        ff, aux = moe_apply(params["moe"], cfg, ff_in)
    else:
        ff, aux = mlp_apply(params["mlp"], ff_in, cfg.activation), jnp.zeros((), jnp.float32)
    return h + ff, aux


def dense_block_decode(
    params: PyTree, cfg: ArchConfig, x: jnp.ndarray, cache: PyTree, pos, *, use_moe: bool
) -> tuple[jnp.ndarray, PyTree]:
    attn_out, new_cache = _self_attn_decode(params["attn"], cfg, rmsnorm(params["ln1"], x, cfg.norm_eps), cache, pos)
    h = x + attn_out
    ff_in = rmsnorm(params["ln2"], h, cfg.norm_eps)
    if use_moe:
        ff, _ = moe_apply(params["moe"], cfg, ff_in)
    else:
        ff = mlp_apply(params["mlp"], ff_in, cfg.activation)
    return h + ff, new_cache


def mamba_block_init(key: jax.Array, cfg: ArchConfig, dtype) -> PyTree:
    return {"ln": rmsnorm_init(cfg.d_model, dtype), "mamba": mamba2_init(key, cfg, dtype)}


def mamba_block_apply(params, cfg, x, *, use_pallas=False):
    return x + mamba2_apply(params["mamba"], cfg, rmsnorm(params["ln"], x, cfg.norm_eps), use_pallas=use_pallas)


def mamba_block_decode(params, cfg, x, cache, _pos):
    out, new_cache = mamba2_decode(params["mamba"], cfg, rmsnorm(params["ln"], x, cfg.norm_eps), cache)
    return x + out, new_cache


# --- cross attention (encoder-decoder) ------------------------------------

def cross_attn_init(key: jax.Array, cfg: ArchConfig, dtype) -> PyTree:
    return gqa_init(key, cfg, dtype)


def cross_attn_apply(params: PyTree, cfg: ArchConfig, x: jnp.ndarray, enc: jnp.ndarray) -> jnp.ndarray:
    b, s, _ = x.shape
    t = enc.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ params["w_q"]).reshape(b, s, cfg.num_heads, hd)
    k = (enc @ params["w_k"]).reshape(b, t, cfg.num_kv_heads, hd)
    v = (enc @ params["w_v"]).reshape(b, t, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    out = blockwise_attention(q, k, v, causal=False)
    return out.reshape(b, s, -1) @ params["w_o"]


def cross_attn_decode(
    params: PyTree, cfg: ArchConfig, x: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray
) -> jnp.ndarray:
    """Decode-time cross attention over precomputed encoder K/V."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    group = cfg.num_heads // cfg.num_kv_heads
    q = (x @ params["w_q"]).reshape(b, cfg.num_kv_heads, group, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
    qf = q.astype(jnp.float32) * hd**-0.5
    scores = jnp.einsum("bhgd,bthd->bhgt", qf, k_cache.astype(jnp.float32))
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", attn, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, cfg.num_heads * hd).astype(x.dtype) @ params["w_o"]


def dec_block_init(key: jax.Array, cfg: ArchConfig, dtype) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": _self_attn_init(k1, cfg, dtype),
        "ln_x": rmsnorm_init(cfg.d_model, dtype),
        "cross": cross_attn_init(k2, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def dec_block_apply(params, cfg, x, enc):
    h = x + _self_attn_apply(params["attn"], cfg, rmsnorm(params["ln1"], x, cfg.norm_eps), causal=True)
    h = h + cross_attn_apply(params["cross"], cfg, rmsnorm(params["ln_x"], h, cfg.norm_eps), enc)
    return h + mlp_apply(params["mlp"], rmsnorm(params["ln2"], h, cfg.norm_eps), cfg.activation)


def dec_block_decode(params, cfg, x, cache, pos):
    attn_out, self_cache = _self_attn_decode(
        params["attn"], cfg, rmsnorm(params["ln1"], x, cfg.norm_eps), cache["self"], pos
    )
    h = x + attn_out
    h = h + cross_attn_decode(
        params["cross"], cfg, rmsnorm(params["ln_x"], h, cfg.norm_eps), cache["cross_k"], cache["cross_v"]
    )
    h = h + mlp_apply(params["mlp"], rmsnorm(params["ln2"], h, cfg.norm_eps), cfg.activation)
    return h, {"self": self_cache, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}


# ==========================================================================
# stacks (scan over layers)
# ==========================================================================

def run_stack(
    stack_params: PyTree,
    x: jnp.ndarray,
    body: Callable[[PyTree, jnp.ndarray], tuple[jnp.ndarray, jnp.ndarray]],
    *,
    remat: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Scan ``body(layer_params, x) -> (x, aux)`` over stacked layers."""
    fn = jax.checkpoint(body) if remat else body

    def scan_body(carry, layer_params):
        x, aux = carry
        x, a = fn(layer_params, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)), stack_params)
    return x, aux


def run_stack_decode(
    stack_params: PyTree,
    caches: PyTree,
    x: jnp.ndarray,
    body: Callable[[PyTree, jnp.ndarray, PyTree], tuple[jnp.ndarray, PyTree]],
) -> tuple[jnp.ndarray, PyTree]:
    def scan_body(x, inputs):
        layer_params, cache = inputs
        x, new_cache = body(layer_params, x, cache)
        return x, new_cache

    x, new_caches = jax.lax.scan(scan_body, x, (stack_params, caches))
    return x, new_caches


# ==========================================================================
# layer layout per architecture
# ==========================================================================

def moe_layout(cfg: ArchConfig) -> tuple[int, int, int]:
    """(num leading dense layers, num moe layers, num trailing dense layers
    interleaved) — as (first_dense, n_moe, n_inter_dense)."""
    m = cfg.moe
    rest = cfg.num_layers - m.first_dense
    if m.moe_every == 1:
        return m.first_dense, rest, 0
    n_pairs = rest // m.moe_every
    n_moe = n_pairs
    n_inter = rest - n_pairs
    return m.first_dense, n_moe, n_inter


def hybrid_layout(cfg: ArchConfig) -> tuple[int, int, int]:
    """(num groups, mamba per group, trailing mamba layers)."""
    period = cfg.hybrid.attn_every
    groups = cfg.num_layers // period
    return groups, period - 1, cfg.num_layers - groups * period
