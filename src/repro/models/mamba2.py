"""Mamba2 (state-space duality / SSD) block — arXiv:2405.21060.

The SSD layer computes, per head h with per-step decay ``a_t = exp(dt_t A)``::

    S_t = a_t * S_{t-1} + dt_t * B_t x_t^T          (state:  (head_dim, N))
    y_t = C_t . S_t + D * x_t

Training/prefill uses the *chunked* dual form: within a chunk of length L the
quadratic "attention" form (C B^T ⊙ decay) is used; across chunks the state
recurrence is carried by a ``lax.scan``.  Scanning chunk-by-chunk keeps the
(L x L) score tensor bounded to one chunk at a time — at 4k train with 256
global batch a fully vectorized form would materialize TBs.

Decode is the O(1) recurrence on a cached state.  A depthwise causal conv
(width 4) precedes the SSM as in the reference implementation; its decode
cache holds the last (d_conv - 1) inputs.

``use_pallas`` routes the chunk computation through the Pallas SSD kernel.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

PyTree = Any


def mamba2_init(key: jax.Array, cfg: ArchConfig, dtype) -> PyTree:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    nheads = s.num_heads(d)
    conv_dim = d_in + 2 * s.d_state  # x, B, C all go through the conv
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # in_proj emits [z, x, B, C, dt]
    proj_out = 2 * d_in + 2 * s.d_state + nheads
    return {
        "in_proj": dense_init(k1, d, proj_out, dtype),
        "conv_w": (jax.random.normal(k2, (s.d_conv, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((nheads,), dtype=jnp.float32),
        "norm": rmsnorm_init(d_in, dtype),
        "out_proj": dense_init(k3, d_in, d, dtype),
    }


def _split_proj(proj: jnp.ndarray, cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nheads = s.num_heads(cfg.d_model)
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * s.d_state], axis=-1)
    assert dt.shape[-1] == nheads
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time.  xbc: (B, S, C), w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def _ssd_chunk_scan(
    x: jnp.ndarray,    # (B, S, H, P)  fp32
    dt: jnp.ndarray,   # (B, S, H)     fp32, post-softplus
    A: jnp.ndarray,    # (H,)          fp32, negative
    B_mat: jnp.ndarray,  # (B, S, N)
    C_mat: jnp.ndarray,  # (B, S, N)
    chunk: int,
    use_pallas: bool = False,
) -> jnp.ndarray:
    b, s, h, p = x.shape
    n = B_mat.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B_mat.reshape(b, nc, chunk, n)
    Cc = C_mat.reshape(b, nc, chunk, n)

    dA = dtc * A[None, None, None, :]              # (b, nc, L, h), <= 0
    cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumulative decay

    if use_pallas:
        from repro.kernels.ssd import ops as ssd_ops

        y = ssd_ops.ssd_chunk_scan(xc, dtc, cum, Bc, Cc)   # (b, nc, L, h, p)
        return y.reshape(b, nc * chunk, h, p)[:, :s]

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]          # (L, L)

    def body(state, inputs):
        # state: (b, h, p, n)
        x_k, dt_k, cum_k, b_k, c_k = inputs
        # intra-chunk quadratic form.  Mask INSIDE the exponent: the i<j
        # entries of (cum_i - cum_j) are large positive and would overflow
        # exp, poisoning the backward pass with inf * 0 = NaN.
        cb = jnp.einsum("bln,bmn->blm", c_k, b_k)                     # (b, L, L)
        diff = cum_k[:, :, None, :] - cum_k[:, None, :, :]            # (b, L, L, h)
        decay = jnp.exp(jnp.where(causal[None, :, :, None], diff, -1e30))
        w = cb[:, :, :, None] * decay * dt_k[:, None, :, :]           # (b, L, L, h)
        y_intra = jnp.einsum("blmh,bmhp->blhp", w, x_k)
        # contribution of the carried state
        state_decay = jnp.exp(cum_k)                                  # (b, L, h)
        y_inter = jnp.einsum("bln,bhpn,blh->blhp", c_k, state, state_decay)
        # update the carried state
        chunk_decay = jnp.exp(cum_k[:, -1, :])                        # (b, h)
        in_decay = jnp.exp(cum_k[:, -1:, :] - cum_k) * dt_k           # (b, L, h)
        new_state = state * chunk_decay[:, :, None, None] + jnp.einsum(
            "bln,blh,blhp->bhpn", b_k, in_decay, x_k
        )
        return new_state, y_intra + y_inter

    state0 = jnp.zeros((b, h, p, n), dtype=jnp.float32)
    _, ys = jax.lax.scan(
        body,
        state0,
        (
            jnp.moveaxis(xc, 1, 0),
            jnp.moveaxis(dtc, 1, 0),
            jnp.moveaxis(cum, 1, 0),
            jnp.moveaxis(Bc, 1, 0),
            jnp.moveaxis(Cc, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, h, p)
    return y[:, :s]


def mamba2_apply(
    params: PyTree, cfg: ArchConfig, u: jnp.ndarray, *, use_pallas: bool = False
) -> jnp.ndarray:
    """Full-sequence SSD block.  u: (B, S, D) -> (B, S, D)."""
    s_cfg: SSMConfig = cfg.ssm
    b, s, d = u.shape
    d_in = s_cfg.d_inner(d)
    nheads = s_cfg.num_heads(d)

    proj = u @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    x_in, B_mat, C_mat = jnp.split(xbc, [d_in, d_in + s_cfg.d_state], axis=-1)

    x_heads = x_in.reshape(b, s, nheads, s_cfg.head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y = _ssd_chunk_scan(
        x_heads, dt, A, B_mat.astype(jnp.float32), C_mat.astype(jnp.float32),
        s_cfg.chunk_size, use_pallas=use_pallas,
    )
    y = y + x_heads * params["D"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return y @ params["out_proj"]


# --------------------------------------------------------------------------
# decode (O(1) state update)
# --------------------------------------------------------------------------

def mamba2_cache_init(cfg: ArchConfig, batch: int, dtype) -> PyTree:
    s: SSMConfig = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nheads = s.num_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.d_state
    return {
        "ssm_state": jnp.zeros((batch, nheads, s.head_dim, s.d_state), dtype=jnp.float32),
        "conv_state": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype=dtype),
    }


def mamba2_decode(
    params: PyTree, cfg: ArchConfig, u: jnp.ndarray, cache: PyTree
) -> tuple[jnp.ndarray, PyTree]:
    """One-token SSD step.  u: (B, 1, D)."""
    s_cfg: SSMConfig = cfg.ssm
    b, _, d = u.shape
    d_in = s_cfg.d_inner(d)
    nheads = s_cfg.num_heads(d)

    proj = u[:, 0, :] @ params["in_proj"]
    z, xbc_new, dt_raw = _split_proj(proj, cfg)

    # causal conv over [cached inputs, new input]
    conv_in = jnp.concatenate(
        [cache["conv_state"], xbc_new[:, None, :].astype(cache["conv_state"].dtype)], axis=1
    )  # (B, d_conv, C)
    conv_out = jnp.einsum("bkc,kc->bc", conv_in, params["conv_w"]) + params["conv_b"]
    xbc = jax.nn.silu(conv_out)
    new_conv_state = conv_in[:, 1:, :]

    x_in, B_mat, C_mat = jnp.split(xbc, [d_in, d_in + s_cfg.d_state], axis=-1)
    x_h = x_in.reshape(b, nheads, s_cfg.head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :])                                      # (B, H)

    state = cache["ssm_state"]
    state = state * decay[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhpn", B_mat.astype(jnp.float32), dt, x_h
    )
    y = jnp.einsum("bn,bhpn->bhp", C_mat.astype(jnp.float32), state)
    y = y + x_h * params["D"][None, :, None]
    y = y.reshape(b, d_in).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y[:, None, :], cfg.norm_eps)[:, 0]
    out = y @ params["out_proj"]
    return out[:, None, :], {"ssm_state": state, "conv_state": new_conv_state}


def mamba2_param_count(cfg: ArchConfig) -> int:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    nheads = s.num_heads(d)
    conv_dim = d_in + 2 * s.d_state
    proj_out = 2 * d_in + 2 * s.d_state + nheads
    return (
        d * proj_out
        + s.d_conv * conv_dim + conv_dim
        + 3 * nheads
        + d_in            # norm
        + d_in * d        # out_proj
    )
