"""Adversarial-client scenarios: attacks a robust aggregator must survive.

``ScenarioConfig`` turns a seeded fraction of a federation's clients into
attackers and :func:`apply_scenario` wires the attack into an existing
``Federation`` without touching the engine:

* ``"label-flip"`` — data poisoning: attackers train on mirrored LoS
  targets (``y -> max + min - y`` over their local range), so their honest
  training procedure pushes the model the wrong way.  Works on every
  engine and aggregation mode, because only the client datasets change.
* ``"scaled-update"`` — model poisoning: attackers send
  ``params + scale * delta`` instead of ``params + delta``, the classic
  norm-amplification attack that a single client can use to dominate
  plain FedAvg.
* ``"sign-flip"`` — model poisoning: attackers send ``params - delta``,
  exactly undoing their local progress and dragging the average backward.

Model-poisoning attacks intercept updates in a trainer proxy, which
requires per-client updates to materialize: reduced-mode aggregators are
transparently re-wrapped to stacked delivery (numerically identical
FedAvg), and grouped-mode aggregators are rejected.

The robust side of the ledger: the registry's ``"trimmed-mean"`` and the
``"krum[:f]"`` aggregator added here (Blanchard et al. 2017) — Krum picks
the update whose nearest-neighbor distance mass is smallest, discarding
up to ``f`` Byzantine clients entirely, and ``"krum:f,m"`` (multi-Krum)
averages the ``m`` best-scored updates.
"""

from __future__ import annotations

import dataclasses
import difflib

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.pipeline import ArrayDataset, ClientDataset
from repro.federated.api import Aggregator, register_aggregator
from repro.federated.fedavg import aggregate_stacked

ATTACKS = ("label-flip", "scaled-update", "sign-flip")
_MODEL_POISON = ("scaled-update", "sign-flip")


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """A seeded adversarial scenario over any federation.

    ``fraction`` of the clients (chosen by ``seed``, independent of the
    run seed) execute ``attack``; ``scale`` parameterizes
    ``"scaled-update"``.  ``fraction = 0`` is the clean run.
    """

    attack: str = "label-flip"
    fraction: float = 0.2
    scale: float = 10.0
    seed: int = 0

    def __post_init__(self) -> None:
        if self.attack not in ATTACKS:
            hint = difflib.get_close_matches(str(self.attack), ATTACKS, n=1)
            suggest = f" — did you mean {hint[0]!r}?" if hint else ""
            raise ValueError(
                f"unknown attack {self.attack!r} (choose from {list(ATTACKS)})"
                f"{suggest}"
            )
        if not (0.0 <= float(self.fraction) <= 1.0):
            raise ValueError(
                f"attacker fraction must be in [0, 1], got {self.fraction}"
            )
        if not np.isfinite(self.scale):
            raise ValueError(f"attack scale must be finite, got {self.scale}")


def attacker_ids(client_ids, scenario: ScenarioConfig) -> np.ndarray:
    """The sorted attacker subset — seeded, independent of the run's rng."""
    ids = np.sort(np.asarray(list(client_ids), dtype=np.int64))
    if scenario.fraction == 0.0 or ids.size == 0:
        return np.array([], dtype=np.int64)
    count = max(1, int(round(scenario.fraction * ids.size)))
    count = min(count, ids.size)
    rng = np.random.default_rng([scenario.seed, 0xAD5])
    return np.sort(rng.choice(ids, size=count, replace=False))


def flip_labels(dataset: ArrayDataset) -> ArrayDataset:
    """Mirror the regression targets across their local range."""
    y = np.asarray(dataset.y)
    flipped = (y.max() + y.min() - y).astype(y.dtype)
    return ArrayDataset(x=dataset.x, y=flipped)


def poison_clients(clients, attackers) -> list[ClientDataset]:
    """Label-flipped copies of the attacker clients (others untouched)."""
    bad = set(int(a) for a in np.asarray(attackers).tolist())
    out = []
    for c in clients:
        if int(c.client_id) in bad:
            out.append(
                ClientDataset(
                    client_id=c.client_id, train=flip_labels(c.train), val=c.val
                )
            )
        else:
            out.append(c)
    return out


class _AttackedTrainer:
    """Trainer proxy: honest local training, then a poisoned update."""

    def __init__(self, inner, attackers, attack: str, scale: float) -> None:
        self._inner = inner
        self._attackers = set(int(a) for a in np.asarray(attackers).tolist())
        self._attack = attack
        self._scale = float(scale)

    def train_client(self, params, client, rng, jax_rng):
        new_params, loss, n_c = self._inner.train_client(
            params, client, rng, jax_rng
        )
        if int(client.client_id) in self._attackers:
            if self._attack == "scaled-update":
                s = self._scale
                new_params = jax.tree.map(
                    lambda p, q: (p + s * (q - p)).astype(q.dtype),
                    params,
                    new_params,
                )
            elif self._attack == "sign-flip":
                new_params = jax.tree.map(
                    lambda p, q: (p - (q - p)).astype(q.dtype),
                    params,
                    new_params,
                )
        return new_params, loss, n_c

    def __getattr__(self, name):
        return getattr(self._inner, name)


class _StackedFedAvg(Aggregator):
    """FedAvg delivered stacked, so a trainer proxy sees every update."""

    mode = "stacked"

    def aggregate(self, stacked, weights):
        return aggregate_stacked(stacked, weights)


def apply_scenario(federation, scenario: ScenarioConfig):
    """Install the scenario on a built ``Federation`` (mutates in place).

    Call before ``run()``.  Returns the federation; the chosen attacker
    ids land on ``federation.scenario_attackers`` for inspection.
    """
    attackers = attacker_ids(federation.all_clients.keys(), scenario)
    federation.scenario_attackers = attackers
    if attackers.size == 0:
        return federation
    if scenario.attack == "label-flip":
        poisoned = poison_clients(federation.all_clients.values(), attackers)
        federation.all_clients = {c.client_id: c for c in poisoned}
        return federation
    # Model poisoning needs every client's update to pass through the
    # trainer proxy, which only stacked delivery materializes.
    if federation.aggregator.mode == "grouped":
        raise ValueError(
            f"attack {scenario.attack!r} poisons per-client updates; grouped "
            "aggregators reduce regions before updates materialize — use a "
            "reduced or stacked aggregator"
        )
    if federation.aggregator.mode == "reduced":
        federation.aggregator = _StackedFedAvg()
    federation.trainer = _AttackedTrainer(
        federation.trainer, attackers, scenario.attack, scenario.scale
    )
    return federation


@register_aggregator("krum")
class KrumAggregator(Aggregator):
    """Krum / multi-Krum (Blanchard et al. 2017) — Byzantine-robust.

    Spec forms: ``"krum"`` (f=1), ``"krum:f"``, ``"krum:f,m"`` (multi-Krum
    averages the ``m`` best-scored updates).  Each client's score is the
    sum of its ``C - f - 2`` smallest squared distances to other updates;
    the lowest-scoring update(s) win.  Requires ``C >= 2f + 3`` clients
    per round — fewer and the guarantee is vacuous, so we fail fast.
    """

    mode = "stacked"

    def __init__(self, f: int = 1, m: int = 1) -> None:
        if int(f) < 0:
            raise ValueError(f"krum needs f >= 0 Byzantine clients, got {f}")
        if int(m) < 1:
            raise ValueError(f"multi-krum needs m >= 1 selections, got {m}")
        self.f = int(f)
        self.m = int(m)

    def aggregate(self, stacked, weights):
        leaves = jax.tree.leaves(stacked)
        c = leaves[0].shape[0]
        if c < 2 * self.f + 3:
            raise ValueError(
                f"krum:{self.f} needs at least 2f+3 = {2 * self.f + 3} "
                f"clients per round, got {c} — lower f or select more clients"
            )
        flat = np.concatenate(
            [np.asarray(leaf, dtype=np.float64).reshape(c, -1) for leaf in leaves],
            axis=1,
        )
        sq_norms = np.sum(flat * flat, axis=1)
        d2 = sq_norms[:, None] + sq_norms[None, :] - 2.0 * (flat @ flat.T)
        np.fill_diagonal(d2, np.inf)
        d2 = np.maximum(d2, 0.0)
        neighbor_count = c - self.f - 2
        scores = np.sort(d2, axis=1)[:, :neighbor_count].sum(axis=1)
        chosen = np.argsort(scores, kind="stable")[: min(self.m, c)]
        sel = jnp.asarray(np.sort(chosen))
        return jax.tree.map(
            lambda leaf: jnp.mean(
                jnp.take(leaf, sel, axis=0), axis=0
            ).astype(leaf.dtype),
            stacked,
        )
