"""Privacy & robustness tier: DP-SGD, masked-sum secagg, adversary scenarios.

Three coordinated pieces over the federated engine (ROADMAP item 4):

* :mod:`repro.privacy.dp` — in-jit DP-SGD (per-example clipping +
  Gaussian noise inside the engines' jitted steps), configured with
  :class:`DPConfig` threaded through ``FederationConfig.privacy``;
* :mod:`repro.privacy.accountant` — a Rényi/moments accountant turning
  per-round sampling rates into the cumulative ``(epsilon, delta)``
  reported on every ``RoundRecord``;
* :mod:`repro.privacy.secagg` — the ``"secagg-fedavg"`` aggregator whose
  server-side sum only ever touches pairwise-masked fixed-point tensors;
* :mod:`repro.privacy.adversary` — label-flip / scaled-update / sign-flip
  attacker scenarios plus the ``"krum[:f]"`` robust aggregator.

Only the leaf modules (``dp``, ``accountant``) load eagerly: the cohort
engine imports ``repro.privacy.dp`` from inside ``repro.federated``, so
this package must not import ``repro.federated`` back at init time.  The
registry-facing names (secagg / adversary) resolve lazily on first
attribute access; importing ``repro.federated.api`` registers their
aggregator specs as a side effect either way.
"""

import importlib

from repro.privacy.accountant import (
    RdpAccountant,
    epsilon_after,
    rdp_subsampled_gaussian,
)
from repro.privacy.dp import (
    DPConfig,
    add_gaussian_noise,
    dp_value_and_grad,
    per_example_clip_factors,
    resolve_dp,
)

_LAZY = {
    "SecAggFedAvg": "secagg",
    "dequantize_total": "secagg",
    "masked_client_tensors": "secagg",
    "masked_sum": "secagg",
    "pair_masks": "secagg",
    "quantize_leaf": "secagg",
    "ring_offsets": "secagg",
    "ATTACKS": "adversary",
    "KrumAggregator": "adversary",
    "ScenarioConfig": "adversary",
    "apply_scenario": "adversary",
    "attacker_ids": "adversary",
    "flip_labels": "adversary",
    "poison_clients": "adversary",
}

__all__ = [
    "DPConfig",
    "RdpAccountant",
    "add_gaussian_noise",
    "dp_value_and_grad",
    "epsilon_after",
    "per_example_clip_factors",
    "rdp_subsampled_gaussian",
    "resolve_dp",
    *sorted(_LAZY),
]


def __getattr__(name: str):
    module_name = _LAZY.get(name)
    if module_name is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    module = importlib.import_module(f"repro.privacy.{module_name}")
    value = getattr(module, name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
