"""In-jit DP-SGD: per-example clipping + calibrated Gaussian noise.

The privacy unit is one *local step*: every example's gradient is clipped
to ``clip_norm`` in L2, the clipped gradients are summed, Gaussian noise
with standard deviation ``noise_multiplier * clip_norm`` is added to the
sum, and the noised sum is normalized by the batch's real example count —
the classic DP-SGD estimator (Abadi et al. 2016).  All of it happens
*inside* the engines' jitted step functions:

* the vectorized engine's ``client_step`` (``repro.federated.cohort``)
  computes per-example gradients with a ``jax.vmap`` over the batch axis
  of the already-vmapped per-client step, so DP rides the same single
  jitted vmap+scan round as the unprotected path — no per-client (or
  per-example) Python loop ever appears;
* the sequential engine's ``LocalTrainer._step`` uses the identical
  :func:`dp_value_and_grad`, so the two engines stay parity oracles for
  each other under DP exactly as they are without it.

Key discipline: a DP step consumes a 3-way split of the per-client chain
key (next-chain, dropout, noise) where the unprotected step consumes a
2-way split.  Noise is therefore a pure function of the run seed — seeded
DP runs replay bit-identically — and a ``dp=None`` trainer builds the
*original* 2-way-split step closure untouched, keeping the unprotected
hot path bitwise identical to the pre-privacy engine.

Per-example gradients reuse the training ``loss_fn`` unchanged: the
masked-mean loss evaluated on a singleton batch is exactly the example's
masked (unnormalized) loss contribution, so summing per-example gradients
and dividing by the batch's mask count reproduces the batch gradient —
which is why ``DPConfig(clip_norm=None, noise_multiplier=0)`` matches the
unprotected path to float-association tolerance.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any
LossFn = Callable[..., Any]  # loss(params, batch, rng) -> scalar

_DP_KEYS = ("clip_norm", "noise_multiplier", "delta")


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Per-step DP-SGD parameters, threaded as ``FederationConfig.privacy``.

    ``clip_norm`` is the per-example L2 clipping bound (``None`` = no
    clipping); ``noise_multiplier`` scales the Gaussian noise relative to
    the clip (sigma = ``noise_multiplier * clip_norm`` on the summed
    clipped gradients); ``delta`` is the accountant's target failure
    probability.  Values are validated strictly — JSON job specs must
    carry real numbers, never strings or booleans (truthy coercion of
    ``"0.1"`` would silently change the privacy guarantee).
    """

    clip_norm: float | None = 1.0
    noise_multiplier: float = 1.0
    delta: float = 1e-5

    def __post_init__(self) -> None:
        _require_number("clip_norm", self.clip_norm, allow_none=True)
        _require_number("noise_multiplier", self.noise_multiplier)
        _require_number("delta", self.delta)
        if self.clip_norm is not None and not (float(self.clip_norm) > 0):
            raise ValueError(
                f"privacy.clip_norm must be > 0 (or null for no clipping), "
                f"got {self.clip_norm}"
            )
        if float(self.noise_multiplier) < 0:
            raise ValueError(
                f"privacy.noise_multiplier must be >= 0, got {self.noise_multiplier}"
            )
        if self.noise_multiplier > 0 and (
            self.clip_norm is None or math.isinf(float(self.clip_norm))
        ):
            raise ValueError(
                "privacy.noise_multiplier > 0 needs a finite clip_norm: the "
                "noise is calibrated to noise_multiplier * clip_norm"
            )
        if not (0.0 < float(self.delta) < 1.0):
            raise ValueError(f"privacy.delta must be in (0, 1), got {self.delta}")

    @property
    def effective_clip(self) -> float:
        """The clipping bound as a float (``inf`` when clipping is off)."""
        return math.inf if self.clip_norm is None else float(self.clip_norm)

    @property
    def noise_sigma(self) -> float:
        """Noise std on the *summed* clipped gradients (0 when noiseless)."""
        if float(self.noise_multiplier) == 0.0:
            return 0.0
        return float(self.noise_multiplier) * float(self.clip_norm)

    def to_state(self) -> dict:
        """JSON form — the job spec's ``privacy`` section."""
        return {
            "clip_norm": None if self.clip_norm is None else float(self.clip_norm),
            "noise_multiplier": float(self.noise_multiplier),
            "delta": float(self.delta),
        }


def _require_number(name: str, value, allow_none: bool = False) -> None:
    if value is None and allow_none:
        return
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        raise TypeError(
            f"privacy.{name} must be a number, got {value!r} "
            f"({type(value).__name__}) — JSON strings are rejected, never coerced"
        )


def resolve_dp(spec) -> DPConfig | None:
    """``None`` / :class:`DPConfig` / job-spec dict -> validated config.

    The dict form is the JSON job spec's ``privacy`` section; unknown keys
    fail fast with the allowed set, matching the control plane's
    validation convention.
    """
    if spec is None:
        return None
    if isinstance(spec, DPConfig):
        return spec
    if isinstance(spec, dict):
        unknown = sorted(set(spec) - set(_DP_KEYS))
        if unknown:
            raise ValueError(
                f"unknown privacy key(s) {unknown} (allowed: {sorted(_DP_KEYS)})"
            )
        return DPConfig(**spec)
    raise TypeError(
        f"privacy must be None, a DPConfig, or a dict, got {type(spec).__name__}"
    )


def per_example_clip_factors(grads: PyTree, clip_norm: float) -> jax.Array:
    """(B,) scale factors bounding each example's gradient L2 norm.

    ``grads`` carries a leading example axis on every leaf.  With
    ``clip_norm = inf`` every factor is exactly 1 — the clipped sum is the
    plain per-example sum.
    """
    leaves = jax.tree.leaves(grads)
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32).reshape(g.shape[0], -1)), axis=1)
        for g in leaves
    )
    norms = jnp.sqrt(sq)
    return jnp.minimum(1.0, clip_norm / (norms + 1e-12))


def add_gaussian_noise(tree: PyTree, key: jax.Array, sigma: float) -> PyTree:
    """Add independent N(0, sigma^2) noise to every leaf (one key per leaf).

    ``sigma`` is a Python float decided at trace time, so ``sigma == 0``
    compiles to the identity — the noiseless DP path carries no RNG ops.
    """
    if sigma == 0.0:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noised = [
        leaf
        + sigma
        * jax.random.normal(
            k, leaf.shape, jnp.promote_types(leaf.dtype, jnp.float32)
        ).astype(leaf.dtype)
        for leaf, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noised)


def dp_value_and_grad(loss_fn: LossFn, dp: DPConfig):
    """DP-SGD drop-in for ``jax.value_and_grad(loss_fn)`` on masked batches.

    Returns ``f(params, batch, rng, noise_key) -> (loss, grads)`` where
    ``batch = (x, y, mask)``: per-example gradients (a vmap over the batch
    axis — safe to nest under the cohort engine's per-client vmap and
    ``lax.scan``), each clipped to ``dp.clip_norm``, summed, noised with
    sigma ``dp.noise_sigma``, and normalized by the batch's real example
    count.  The reported loss is the exact masked-mean batch loss.
    """
    clip = dp.effective_clip
    sigma = dp.noise_sigma

    def per_example(params, x_i, y_i, m_i, rng):
        # The masked-mean loss on a singleton batch is m_i * loss_i (the
        # mask is 0/1), i.e. the example's unnormalized contribution.
        return loss_fn(params, (x_i[None], y_i[None], m_i[None]), rng)

    def value_and_grad(params, batch, rng, noise_key):
        x, y, m = batch
        losses, grads = jax.vmap(
            jax.value_and_grad(per_example), in_axes=(None, 0, 0, 0, None)
        )(params, x, y, m, rng)
        denom = jnp.maximum(jnp.sum(m), 1.0)
        factors = per_example_clip_factors(grads, clip)
        clipped_sum = jax.tree.map(
            lambda g: jnp.tensordot(
                factors.astype(jnp.promote_types(g.dtype, jnp.float32)),
                g.astype(jnp.promote_types(g.dtype, jnp.float32)),
                axes=((0,), (0,)),
            ),
            grads,
        )
        noised = add_gaussian_noise(clipped_sum, noise_key, sigma)
        grads_out = jax.tree.map(
            lambda g, ref: (g / denom).astype(ref.dtype), noised, params
        )
        return jnp.sum(losses) / denom, grads_out

    return value_and_grad
