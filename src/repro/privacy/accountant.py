"""Rényi-DP (moments) accountant for subsampled Gaussian DP-SGD.

Tracks cumulative privacy loss across federation rounds.  Each round the
federation samples a fraction ``q`` of its clients (the recruitment/
selection stages), every sampled client runs noised local steps, and the
accountant composes the round's Rényi divergence bounds; ``epsilon()``
converts the running totals to an ``(epsilon, delta)`` guarantee.

The per-order bound is Mironov et al.'s integer-order formula for the
Poisson-subsampled Gaussian mechanism::

    RDP(alpha) = 1/(alpha-1) * log( sum_{k=0..alpha}
        C(alpha, k) * (1-q)^(alpha-k) * q^k * exp((k^2 - k) / (2 sigma^2)) )

composed linearly over rounds, then converted with the classic bound
``epsilon = min_alpha [ RDP_total(alpha) + log(1/delta) / (alpha - 1) ]``.
Binomial coefficients come from ``math.lgamma`` — no SciPy dependency —
and the log-sum-exp is stabilized, so small ``sigma`` / large ``alpha``
never overflow.

Accounting granularity is one federation *round* per client sample: the
round's local steps all touch the same sampled cohort, so we compose one
subsampled-Gaussian event per local step at the round's sampling rate
(``steps`` parameter).  ``sigma = 0`` (no noise) yields ``epsilon = inf``
— an honest report, never a silent 0.
"""

from __future__ import annotations

import math

import numpy as np

DEFAULT_ORDERS: tuple[int, ...] = tuple(range(2, 65))


def _log_binom(n: int, k: int) -> float:
    return math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)


def _logsumexp(terms) -> float:
    arr = np.asarray(terms, dtype=np.float64)
    m = float(np.max(arr))
    if math.isinf(m):
        return m
    return m + math.log(float(np.sum(np.exp(arr - m))))


def rdp_subsampled_gaussian(q: float, sigma: float, alpha: int) -> float:
    """RDP of order ``alpha`` for one subsampled Gaussian release."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"sampling rate must be in [0, 1], got {q}")
    if alpha < 2 or int(alpha) != alpha:
        raise ValueError(f"integer order >= 2 required, got {alpha}")
    if q == 0.0:
        return 0.0
    if sigma == 0.0:
        return math.inf
    if q == 1.0:
        return alpha / (2.0 * sigma * sigma)
    alpha = int(alpha)
    log_q, log_1q = math.log(q), math.log1p(-q)
    terms = [
        _log_binom(alpha, k)
        + (alpha - k) * log_1q
        + k * log_q
        + (k * k - k) / (2.0 * sigma * sigma)
        for k in range(alpha + 1)
    ]
    return _logsumexp(terms) / (alpha - 1)


class RdpAccountant:
    """Cumulative (epsilon, delta) over federation rounds.

    One accountant per run; ``step(q)`` after each round with that round's
    client sampling rate, ``epsilon()`` whenever a ``RoundRecord`` is cut.
    Epsilon is non-decreasing in the number of steps, so every record in a
    run carries a monotonically increasing cumulative budget.
    """

    def __init__(
        self,
        noise_multiplier: float,
        delta: float = 1e-5,
        orders: tuple[int, ...] = DEFAULT_ORDERS,
    ) -> None:
        if noise_multiplier < 0:
            raise ValueError(f"noise_multiplier must be >= 0, got {noise_multiplier}")
        if not (0.0 < delta < 1.0):
            raise ValueError(f"delta must be in (0, 1), got {delta}")
        if not orders:
            raise ValueError("at least one RDP order is required")
        self.noise_multiplier = float(noise_multiplier)
        self.delta = float(delta)
        self.orders = tuple(int(a) for a in orders)
        self._rdp = np.zeros(len(self.orders), dtype=np.float64)
        self._steps = 0

    def step(self, sampling_rate: float, steps: int = 1) -> None:
        """Compose ``steps`` subsampled-Gaussian events at ``sampling_rate``."""
        if steps < 0:
            raise ValueError(f"steps must be >= 0, got {steps}")
        if steps == 0:
            return
        per_order = np.array(
            [
                rdp_subsampled_gaussian(sampling_rate, self.noise_multiplier, a)
                for a in self.orders
            ],
            dtype=np.float64,
        )
        self._rdp += steps * per_order
        self._steps += steps

    @property
    def steps(self) -> int:
        return self._steps

    def epsilon(self) -> float:
        """Current epsilon at the accountant's delta (0.0 before any step)."""
        if self._steps == 0:
            return 0.0
        log_inv_delta = math.log(1.0 / self.delta)
        candidates = [
            rdp + log_inv_delta / (alpha - 1)
            for rdp, alpha in zip(self._rdp, self.orders)
        ]
        return float(min(candidates))


def epsilon_after(
    rounds: int,
    sampling_rate: float,
    noise_multiplier: float,
    delta: float = 1e-5,
    steps_per_round: int = 1,
) -> float:
    """One-shot budget estimate — e.g. for sizing a run before launch."""
    acct = RdpAccountant(noise_multiplier, delta=delta)
    for _ in range(rounds):
        acct.step(sampling_rate, steps=steps_per_round)
    return acct.epsilon()
