"""Secure-aggregation-style masked sum: pairwise masks that cancel exactly.

``"secagg-fedavg"`` is a registry aggregator (``mode = "stacked"``) whose
server-side reduction never touches a plaintext client update.  Each
client quantizes its weighted parameters to fixed-point int64, then adds
pairwise *antisymmetric* PRG masks shared with its ring neighbors — for
the pair (i, j) client i adds ``+m_ij`` where j adds ``-m_ij`` — so the
masks cancel identically in the sum (Bonawitz et al. 2017; the k-regular
ring pair graph follows Bell et al. 2020).  The masked integer tensors
are the *only* per-client data the aggregation path consumes:
:meth:`SecAggFedAvg.aggregate` sums masked tensors and pair-mask
regenerations, never an unmasked update.

Exactness is the whole design: masking happens in the wrapping uint64
ring, where addition is associative and commutative with no rounding, so
the masked sum is **bitwise equal** to the sum of the quantized inputs
(floating-point masks could never cancel bitwise — per-client rounding
would contaminate the total before cancellation).  The only deviation
from plain ``fedavg`` is the fixed-point quantization itself, bounded by
``clients / 2^(fraction_bits + 1)`` per coordinate of the weighted mean.

Dropout: a dropout model from the PR 5 runtime registry
(``"secagg-fedavg:bernoulli:0.1"`` or a bare probability) decides, per
round and per client slot, whose masked update never arrives.  Survivors'
masks toward dropped clients no longer cancel, so the server runs the
mask-recovery path: regenerate exactly the orphaned pair masks (in a real
deployment the survivors reveal those pair seeds) and subtract them,
recovering the survivors-only sum bit-exactly.  All mask generation,
masking, and recovery is vectorized over the stacked client axis — one
``(clients, leaf_size)`` PRG draw per ring offset, no per-pair Python
loop.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.federated.api import Aggregator, register_aggregator
from repro.federated.fedavg import aggregate_stacked
from repro.federated.runtime.latency import NeverDropout, resolve_dropout

DEFAULT_FRACTION_BITS = 24
DEFAULT_NEIGHBORS = 8


def quantize_leaf(values: np.ndarray, fraction_bits: int) -> np.ndarray:
    """Float -> fixed-point int64 viewed as uint64 (two's complement)."""
    scale = float(1 << fraction_bits)
    q = np.round(np.asarray(values, dtype=np.float64) * scale)
    return q.astype(np.int64).view(np.uint64)


def dequantize_total(total: np.ndarray, fraction_bits: int) -> np.ndarray:
    """uint64 modular total -> float64 (exact for sums within int64 range)."""
    return total.view(np.int64).astype(np.float64) / float(1 << fraction_bits)


def pair_masks(
    seed: int, round_index: int, offset: int, num_clients: int, size: int
) -> np.ndarray:
    """The ring-offset-``offset`` pair masks for one round, shape (C, size).

    Row ``i`` is the mask shared by the pair ``(i, (i + offset) % C)`` —
    client ``i`` adds it, its partner subtracts it.  Deterministic in
    ``(seed, round, offset)`` so the recovery path can regenerate any
    orphaned mask without having stored it.
    """
    rng = np.random.default_rng([seed, round_index, offset])
    return rng.integers(0, 1 << 64, size=(num_clients, size), dtype=np.uint64)


def ring_offsets(num_clients: int, neighbors: int) -> list[int]:
    """Ring pair-graph offsets: each client pairs with its next ``k`` peers."""
    return [d for d in range(1, min(neighbors, num_clients - 1) + 1)]


def masked_client_tensors(
    quantized: np.ndarray, seed: int, round_index: int, offsets: list[int]
) -> np.ndarray:
    """Apply every client's pairwise masks: the tensors a server would see.

    ``quantized`` is (C, size) uint64.  Client ``i`` adds ``+M_d[i]`` for
    each of its forward pairs and ``-M_d[(i - d) % C]`` for each backward
    pair; everything is one vectorized roll per offset.
    """
    c, size = quantized.shape
    masked = quantized.copy()
    for d in offsets:
        m = pair_masks(seed, round_index, d, c, size)
        masked += m
        masked -= np.roll(m, d, axis=0)
    return masked


def masked_sum(
    masked: np.ndarray,
    survivors: np.ndarray,
    seed: int,
    round_index: int,
    offsets: list[int],
) -> np.ndarray:
    """Sum survivors' masked tensors, recovering orphaned pair masks.

    With every client surviving, the pair masks cancel algebraically and
    no mask is ever regenerated.  When client ``i`` dropped, each pair
    straddling the survivor boundary leaves one orphaned ``±mask`` in the
    total; those — and only those — are regenerated and removed.  Returns
    the uint64 modular total, bitwise equal to
    ``quantized[survivors].sum(axis=0)`` (mod 2^64).
    """
    c, size = masked.shape
    surv = np.asarray(survivors, dtype=bool)
    if surv.shape != (c,):
        raise ValueError(f"survivors must have shape ({c},), got {surv.shape}")
    if not surv.any():
        raise RuntimeError(
            "secagg: every masked client dropped this round — the masked sum "
            "is unrecoverable (no survivor can reveal pair seeds)"
        )
    total = masked[surv].sum(axis=0, dtype=np.uint64)
    if surv.all():
        return total
    for d in offsets:
        # surv_fwd[r] == survivor status of r's forward partner (r + d) % C.
        surv_fwd = np.roll(surv, -d)
        plus_rows = surv & ~surv_fwd  # survivor added +M_d[r], partner gone
        minus_rows = ~surv & surv_fwd  # partner added -M_d[r], owner gone
        if not (plus_rows.any() or minus_rows.any()):
            continue
        m = pair_masks(seed, round_index, d, c, size)
        if plus_rows.any():
            total -= m[plus_rows].sum(axis=0, dtype=np.uint64)
        if minus_rows.any():
            total += m[minus_rows].sum(axis=0, dtype=np.uint64)
    return total


@register_aggregator("secagg-fedavg")
class SecAggFedAvg(Aggregator):
    """FedAvg computed from pairwise-masked fixed-point client tensors.

    Spec forms: ``"secagg-fedavg"``, ``"secagg-fedavg:0.1"`` (Bernoulli
    dropout probability), ``"secagg-fedavg:bernoulli:0.1"`` (any runtime
    dropout-model spec).  ``mode = "stacked"`` — per-client updates must
    materialize on the client side of the masking boundary, so the
    synchronous engine runs sequentially; the *server* reduction is the
    masked integer sum.

    The aggregator keeps an internal round counter for mask derivation;
    reusing one instance across federations (or resuming mid-run) reseeds
    the counter via ``reset_round``.
    """

    mode = "stacked"

    def __init__(
        self,
        dropout="never",
        neighbors: int = DEFAULT_NEIGHBORS,
        fraction_bits: int = DEFAULT_FRACTION_BITS,
        seed: int = 0,
    ) -> None:
        self.dropout_model = resolve_dropout(dropout)
        if int(neighbors) < 1:
            raise ValueError(f"secagg needs >= 1 ring neighbor, got {neighbors}")
        if not (1 <= int(fraction_bits) <= 52):
            raise ValueError(
                f"fraction_bits must be in [1, 52], got {fraction_bits}"
            )
        self.neighbors = int(neighbors)
        self.fraction_bits = int(fraction_bits)
        self.seed = int(seed)
        self._round = 0
        self.last_survivors: np.ndarray | None = None

    def reset_round(self, round_index: int = 0) -> None:
        """Reset the mask-derivation round counter (e.g. on resume)."""
        self._round = int(round_index)

    def _survivors(self, num_clients: int, round_index: int) -> np.ndarray:
        if isinstance(self.dropout_model, NeverDropout):
            return np.ones(num_clients, dtype=bool)
        rng = np.random.default_rng([self.seed, round_index, 0x5EC])
        return np.array(
            [not self.dropout_model.drops(i, rng) for i in range(num_clients)],
            dtype=bool,
        )

    def aggregate(self, stacked, weights):
        w = np.asarray(weights, dtype=np.float64)
        c = w.shape[0]
        if np.any(w < 0) or w.sum() <= 0:
            raise ValueError(f"invalid aggregation weights: {weights}")
        round_index = self._round
        self._round += 1
        survivors = self._survivors(c, round_index)
        self.last_survivors = survivors
        offsets = ring_offsets(c, self.neighbors)
        w_surv = float(w[survivors].sum())
        if w_surv <= 0:
            raise RuntimeError(
                "secagg: all surviving clients carry zero weight — nothing "
                "to average"
            )

        leaves, treedef = jax.tree.flatten(stacked)
        out = []
        for leaf in leaves:
            arr = np.asarray(leaf, dtype=np.float64)
            flat = (arr.reshape(c, -1) * w[:, None]).reshape(c, -1)
            quantized = quantize_leaf(flat, self.fraction_bits)
            masked = masked_client_tensors(
                quantized, self.seed, round_index, offsets
            )
            total = masked_sum(masked, survivors, self.seed, round_index, offsets)
            mean = dequantize_total(total, self.fraction_bits) / w_surv
            out.append(
                jnp.asarray(mean.reshape(arr.shape[1:]), dtype=leaf.dtype)
            )
        return jax.tree.unflatten(treedef, out)

    def reference_aggregate(self, stacked, weights):
        """The plain (unmasked) FedAvg of the same inputs — test oracle."""
        return aggregate_stacked(stacked, weights)
