"""Version-portable wrappers over jax mesh / sharding APIs.

The production meshes are written against the jax >= 0.5 explicit-sharding
surface (``jax.sharding.AxisType``, ``set_mesh``, ``get_abstract_mesh``);
the pinned environment ships jax 0.4.37, where the active mesh lives in
``thread_resources`` and is entered with the classic ``with mesh:`` block.
Everything that touches those APIs goes through this module so the rest of
the codebase is version-agnostic.
"""

from __future__ import annotations

import contextlib
import enum
from typing import Any

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    _HAS_AXIS_TYPE = True
except ImportError:  # jax 0.4.x: meshes have no axis types; provide a stand-in

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _HAS_AXIS_TYPE = False


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    if _HAS_AXIS_TYPE:
        try:
            return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


@contextlib.contextmanager
def set_mesh(mesh):
    """Activate ``mesh`` for the enclosed block on any supported jax.

    jax >= 0.5 exposes ``jax.sharding.set_mesh``; on 0.4.x the classic
    ``with mesh:`` context sets ``thread_resources`` which is what
    ``with_sharding_constraint`` consults there.
    """
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def get_active_mesh() -> Any | None:
    """The mesh currently in scope, or None.

    Returns whatever mesh object the running jax tracks (abstract on >= 0.5,
    the physical ``Mesh`` from ``thread_resources`` on 0.4.x); callers only
    rely on ``.axis_names`` / ``.empty`` / ``.shape``, present on both.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        mesh = getter()
        if mesh is None or mesh.empty:
            return None
        return mesh
    from jax._src import mesh as mesh_lib  # jax 0.4.x fallback

    mesh = mesh_lib.thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return None
    return mesh
