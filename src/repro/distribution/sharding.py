"""Mesh-aware sharding annotations that degrade gracefully.

Model code calls ``constrain(x, "data", None, "model")`` at layout-critical
points.  Under an active mesh (``jax.sharding.set_mesh``) this lowers to a
real ``with_sharding_constraint``; in single-device tests it is a no-op.
Axis names not present on the current mesh are dropped, so the same model
code runs on ``("data","model")`` and ``("pod","data","model")`` meshes.

Axis conventions:
  * ``data``  — batch / tokens (and ZeRO-sharded optimizer state)
  * ``model`` — heads / ffn / experts / vocab (tensor & expert parallelism)
  * ``pod``   — pods; in the federated mapping, one pod = one hospital silo
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
from jax.sharding import PartitionSpec as P

from repro.distribution.compat import get_active_mesh

AxisLike = Any  # None | str | tuple[str, ...]

DATA = "data"
MODEL = "model"
POD = "pod"


def active_mesh():
    return get_active_mesh()


def clean_spec(spec: Sequence[AxisLike] | P) -> P | None:
    """Drop axis names that do not exist on the active mesh."""
    mesh = active_mesh()
    if mesh is None:
        return None
    names = set(mesh.axis_names)

    def _clean(axis: AxisLike) -> AxisLike:
        if axis is None:
            return None
        if isinstance(axis, (tuple, list)):
            kept = tuple(a for a in axis if a in names)
            return kept if kept else None
        return axis if axis in names else None

    return P(*(_clean(a) for a in spec))


def constrain(x: jax.Array, *spec: AxisLike) -> jax.Array:
    """``with_sharding_constraint`` against the active mesh (no-op without one)."""
    cleaned = clean_spec(spec)
    if cleaned is None:
        return x
    return jax.lax.with_sharding_constraint(x, cleaned)


def named_sharding(mesh, *spec: AxisLike):
    from jax.sharding import NamedSharding

    names = set(mesh.axis_names)

    def _clean(axis):
        if axis is None:
            return None
        if isinstance(axis, (tuple, list)):
            kept = tuple(a for a in axis if a in names)
            return kept if kept else None
        return axis if axis in names else None

    return NamedSharding(mesh, P(*(_clean(a) for a in spec)))
