from repro.optim.adamw import AdamW, AdamWState, apply_updates, cosine_schedule, global_norm

__all__ = ["AdamW", "AdamWState", "apply_updates", "cosine_schedule", "global_norm"]
