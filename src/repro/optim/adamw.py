"""Pure-JAX AdamW (Loshchilov & Hutter) — the paper's optimizer.

No optax in this environment; this is a minimal, well-tested decoupled
weight-decay Adam with optional global-norm gradient clipping, exposed
through the same ``init`` / ``update`` functional interface optax uses so
the training loops stay framework-shaped.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    mu: PyTree         # first moment
    nu: PyTree         # second moment


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float = 5e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 5e-3
    clip_norm: float | None = None
    # Optional schedule: callable step -> lr multiplier (traced inside jit).
    schedule: Any = None

    def init(self, params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p)
        return AdamWState(
            step=jnp.zeros((), dtype=jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(
        self, grads: PyTree, state: AdamWState, params: PyTree
    ) -> tuple[PyTree, AdamWState]:
        """Returns (updates, new_state); apply with ``params + updates``."""
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * (g * g), state.nu, grads)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)

        lr = jnp.asarray(self.learning_rate, dtype=jnp.float32)
        if self.schedule is not None:
            lr = lr * self.schedule(step)

        def _update(m, v, p):
            m_hat = m / b1c
            v_hat = v / b2c
            adam = m_hat / (jnp.sqrt(v_hat) + self.eps)
            return (-lr * (adam + self.weight_decay * p)).astype(p.dtype)

        updates = jax.tree.map(_update, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u, params, updates)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def cosine_schedule(warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    """lr multiplier: linear warmup then cosine decay to ``min_ratio``."""

    def schedule(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(1.0, float(warmup_steps))
        progress = (step - warmup_steps) / jnp.maximum(1.0, float(total_steps - warmup_steps))
        progress = jnp.clip(progress, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule
