"""smollm-135m — llama-arch small dense, GQA kv=3  [hf:HuggingFaceTB/SmolLM-135M]."""

from repro.configs.base import Activation, ArchConfig, ArchType

CONFIG = ArchConfig(
    name="smollm-135m",
    arch_type=ArchType.DENSE,
    source="hf:HuggingFaceTB/SmolLM-135M",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49_152,
    activation=Activation.SWIGLU,
    tie_embeddings=True,
)
