"""internvl2-26b — VLM: InternViT (stub) + InternLM2 backbone  [arXiv:2404.16821].

The InternViT-6B vision tower + MLP projector is a STUB per the harness
carve-out: ``input_specs()`` provides 256 precomputed patch embeddings at
d_model which are prepended to the text sequence (early fusion).
"""

from repro.configs.base import Activation, ArchConfig, ArchType

CONFIG = ArchConfig(
    name="internvl2-26b",
    arch_type=ArchType.VLM,
    source="arXiv:2404.16821 (InternVL2, InternLM2-20B LM)",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92_553,
    activation=Activation.SWIGLU,
    frontend="vision",
    num_frontend_tokens=256,
)
