"""gru-eicu — the paper's own model: 2-layer GRU(32) + ReLU head (Table 1)."""

from repro.models.gru import GRUConfig

CONFIG = GRUConfig(
    input_dim=38,     # 20 temporal + 18 static (fused), paper Table 2
    hidden_dim=32,
    num_layers=2,
    dropout=0.05,
)

# Paper Table 1 training hyperparameters.
LEARNING_RATE = 5e-3
BATCH_SIZE = 128
WEIGHT_DECAY = 5e-3
