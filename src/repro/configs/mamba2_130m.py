"""mamba2-130m — attention-free SSM with SSD  [arXiv:2405.21060]."""

from repro.configs.base import ArchConfig, ArchType, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    arch_type=ArchType.SSM,
    source="arXiv:2405.21060 (Mamba-2)",
    num_layers=24,
    d_model=768,
    num_heads=1,        # attention-free; SSD heads come from ssm config
    num_kv_heads=1,
    d_ff=0,             # no MLP blocks in Mamba2
    vocab_size=50_280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
)
