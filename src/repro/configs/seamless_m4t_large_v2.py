"""seamless-m4t-large-v2 — audio enc-dec backbone  [arXiv:2308.11596].

The mel-spectrogram + conformer feature frontend is a STUB per the harness
carve-out: ``input_specs()`` feeds precomputed frame embeddings (d_model
wide, 4x temporal downsampling) straight into the transformer encoder.
"""

from repro.configs.base import Activation, ArchConfig, ArchType

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    arch_type=ArchType.ENCDEC,
    source="arXiv:2308.11596 (SeamlessM4T v2)",
    num_layers=24,          # decoder depth
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    activation=Activation.SWIGLU,
    frontend="audio",
)
