"""nemotron-4-15b — dense, GQA kv=8, squared-ReLU MLP  [arXiv:2402.16819]."""

from repro.configs.base import Activation, ArchConfig, ArchType

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    arch_type=ArchType.DENSE,
    source="arXiv:2402.16819 (Nemotron-4 15B)",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256_000,
    activation=Activation.RELU2,
)
