"""qwen3-1.7b — dense, GQA kv=8, qk-norm  [hf:Qwen/Qwen3-8B family]."""

from repro.configs.base import Activation, ArchConfig, ArchType

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    arch_type=ArchType.DENSE,
    source="hf:Qwen/Qwen3-8B (1.7B sibling card)",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151_936,
    activation=Activation.SWIGLU,
    qk_norm=True,
    rope_theta=1_000_000.0,
    # long_500k decode runs through the sliding-window variant applied by
    # repro.launch.specs.long_context_variant (window=8192); the base config
    # stays full-attention.
)
