"""Architecture config system.

One ``ArchConfig`` dataclass describes every selectable architecture
(``--arch <id>``).  Families: dense decoder, MoE decoder, SSM (Mamba2),
hybrid (Mamba2 + shared attention), encoder-decoder (audio backbone), and
VLM (vision-stub + decoder).  Reduced variants for CPU smoke tests come from
``.reduced()``.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class ArchType(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    ENCDEC = "encdec"   # audio backbone (stub frontend feeds the encoder)
    VLM = "vlm"         # vision-stub embeddings prepended to the decoder


class Activation(str, enum.Enum):
    SWIGLU = "swiglu"
    RELU2 = "relu2"     # squared ReLU (Nemotron-4)
    GELU = "gelu"
    RELU = "relu"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    # layers [0, first_dense) are dense; among the rest, every
    # ``moe_every``-th layer is MoE (1 = all MoE, 2 = alternating).
    first_dense: int = 0
    moe_every: int = 1
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # 'ep' shards the expert dim over the model axis (all-to-all dispatch);
    # 'tp' shards each expert's ffn dim (no all-to-all).  Baseline: 'ep'.
    expert_sharding: str = "ep"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style layout: runs of Mamba2 blocks with a weight-shared
    attention block applied every ``attn_every`` layers."""

    attn_every: int = 6


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: ArchType
    source: str                      # citation (paper / model card)
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    activation: Activation = Activation.SWIGLU
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None

    # encoder-decoder (audio): encoder depth/width may differ from decoder
    encoder_layers: int = 0
    # modality frontend stub: number of prepended embedding positions the
    # ``input_specs`` provide (vision patches / audio frames)
    frontend: Optional[str] = None   # None | 'audio' | 'vision'
    num_frontend_tokens: int = 0

    # sliding-window variant for sub-quadratic long-context decode; None
    # means full attention (long_500k then runs only if ssm/hybrid)
    sliding_window: Optional[int] = None
    # multi-token prediction extra block (DeepSeek-V3)
    mtp: bool = False

    def __post_init__(self) -> None:
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: num_heads must divide num_kv_heads")
        if self.arch_type in (ArchType.MOE,) and self.moe is None:
            raise ValueError(f"{self.name}: MoE arch needs moe config")
        if self.arch_type in (ArchType.SSM, ArchType.HYBRID) and self.ssm is None:
            raise ValueError(f"{self.name}: SSM/hybrid arch needs ssm config")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this config decode at 500k context?"""
        return self.arch_type in (ArchType.SSM, ArchType.HYBRID) or self.sliding_window is not None

    def reduced(self) -> "ArchConfig":
        """CPU-smoke-test variant of the same family: 2 layers, d_model<=512,
        <=4 experts — per the harness contract."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        # keep the GQA ratio family: kv divides heads
        while num_heads % num_kv:
            num_kv -= 1
        changes: dict = dict(
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=d_model // num_heads,
            d_ff=min(self.d_ff, 512) or 0,
            vocab_size=min(self.vocab_size, 512),
            encoder_layers=min(self.encoder_layers, 2),
            num_frontend_tokens=min(self.num_frontend_tokens, 8),
            dtype="float32",
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 256),
                first_dense=min(self.moe.first_dense, 1),
            )
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                qk_rope_head_dim=16, v_head_dim=32,
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=min(self.ssm.d_state, 16), head_dim=32, chunk_size=16
            )
        if self.hybrid is not None:
            changes["hybrid"] = HybridConfig(attn_every=2)
        if self.sliding_window is not None:
            changes["sliding_window"] = min(self.sliding_window, 64)
        return dataclasses.replace(self, **changes)

    # --- parameter counting (for MODEL_FLOPS = 6 N D roofline term) -------
    def param_count(self) -> int:
        from repro.models.zoo import count_params_config  # lazy, avoids cycle

        return count_params_config(self)

    def active_param_count(self) -> int:
        from repro.models.zoo import count_params_config

        return count_params_config(self, active_only=True)
