"""yi-9b — llama-arch dense, GQA kv=4  [arXiv:2403.04652]."""

from repro.configs.base import Activation, ArchConfig, ArchType

CONFIG = ArchConfig(
    name="yi-9b",
    arch_type=ArchType.DENSE,
    source="arXiv:2403.04652 (Yi)",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64_000,
    activation=Activation.SWIGLU,
)
