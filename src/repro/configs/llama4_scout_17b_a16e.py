"""llama4-scout-17b-a16e — MoE 16e top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E]."""

from repro.configs.base import Activation, ArchConfig, ArchType, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    arch_type=ArchType.MOE,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,              # dense-path FFN (unused: every layer is MoE)
    vocab_size=202_048,
    activation=Activation.SWIGLU,
    moe=MoEConfig(
        num_experts=16,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        first_dense=0,
        moe_every=1,
        capacity_factor=1.5,  # top-1 routing needs headroom against drops
        expert_sharding="ep",
    ),
)
