"""zamba2-7b — hybrid: Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242]."""

from repro.configs.base import Activation, ArchConfig, ArchType, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    arch_type=ArchType.HYBRID,
    source="arXiv:2411.15242 (Zamba2)",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,             # shared attention block's MLP width
    vocab_size=32_000,
    activation=Activation.SWIGLU,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    hybrid=HybridConfig(attn_every=6),
)
