"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""

from __future__ import annotations

from repro.configs.base import (
    Activation,
    ArchConfig,
    ArchType,
    HybridConfig,
    MLAConfig,
    MoEConfig,
    SSMConfig,
)


def _registry() -> dict[str, ArchConfig]:
    from repro.configs import (
        deepseek_v3_671b,
        internvl2_26b,
        llama4_scout_17b_a16e,
        mamba2_130m,
        nemotron_4_15b,
        qwen3_1_7b,
        seamless_m4t_large_v2,
        smollm_135m,
        yi_9b,
        zamba2_7b,
    )

    configs = [
        qwen3_1_7b.CONFIG,
        mamba2_130m.CONFIG,
        seamless_m4t_large_v2.CONFIG,
        deepseek_v3_671b.CONFIG,
        smollm_135m.CONFIG,
        yi_9b.CONFIG,
        internvl2_26b.CONFIG,
        nemotron_4_15b.CONFIG,
        llama4_scout_17b_a16e.CONFIG,
        zamba2_7b.CONFIG,
    ]
    return {c.name: c for c in configs}


ARCH_IDS: tuple[str, ...] = (
    "qwen3-1.7b",
    "mamba2-130m",
    "seamless-m4t-large-v2",
    "deepseek-v3-671b",
    "smollm-135m",
    "yi-9b",
    "internvl2-26b",
    "nemotron-4-15b",
    "llama4-scout-17b-a16e",
    "zamba2-7b",
)


def get_config(name: str) -> ArchConfig:
    reg = _registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(reg)}")
    return reg[name]


def all_configs() -> dict[str, ArchConfig]:
    return _registry()


__all__ = [
    "Activation",
    "ArchConfig",
    "ArchType",
    "HybridConfig",
    "MLAConfig",
    "MoEConfig",
    "SSMConfig",
    "ARCH_IDS",
    "get_config",
    "all_configs",
]
