"""deepseek-v3-671b — MoE 256e top-8 + MLA + MTP  [arXiv:2412.19437]."""

from repro.configs.base import ArchConfig, ArchType, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    arch_type=ArchType.MOE,
    source="arXiv:2412.19437 (DeepSeek-V3)",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,       # MLA supersedes GQA; kept for bookkeeping
    d_ff=18432,             # dense-layer FFN width (first 3 layers)
    vocab_size=129_280,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        first_dense=3,
        moe_every=1,
        capacity_factor=1.25,
        expert_sharding="ep",
    ),
    mtp=True,
)
