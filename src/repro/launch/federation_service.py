"""Federation control plane: declarative jobs, streamed records, resume.

This module is the *job service* in front of the PR 4/5 facades: a
federated training run is described by one JSON **job spec** (policy spec
strings, engine/staging knobs, data/model/optimizer sections), validated
against the policy registries up front (unknown names fail with
did-you-mean suggestions before any cohort is built), and executed through
:class:`~repro.federated.api.Federation` (``mode="sync"``) or
:class:`~repro.federated.runtime.AsyncFederation` (``mode="async"``).

Not to be confused with :mod:`repro.launch.serve`, the *decode driver*
(batched GRU inference micro-benchmark).  "Serve" there means serving
predictions; the control plane here serves *training jobs*.  See the
README glossary.

Each job owns a **run directory**:

    run_dir/
      job.json         # normalized spec + its sha256 spec_hash
      records.jsonl    # the RoundRecord stream, one JSON line per round
      checkpoint/      # latest federation snapshot (atomic, overwritten)
      final/           # final parameter pytree (repro.checkpoint layout)
      result.json      # terminal status + run summary

Records stream *live*: every round/flush appends one JSONL line and fans
out to in-process subscribers before the next round starts, so a watcher
tails progress without waiting for the run.  The snapshot written after
every round (``checkpoint_every`` thins it) carries the job's spec hash;
``resume`` re-validates the spec, rejects a hash mismatch (a resumed job
must be *the same experiment*), truncates the record stream to the
snapshot's prefix, and continues bit-identically — the kill-and-resume
parity contract of the tier-1 suite.

CLI::

    python -m repro.launch.federation_service submit --spec job.json --run-dir d
    python -m repro.launch.federation_service status --run-dir d
    python -m repro.launch.federation_service resume --run-dir d
    python -m repro.launch.federation_service diff d1 d2
    python -m repro.launch.federation_service registries [--check docs/API_SPEC.md]

``submit``/``resume`` exit 75 (EX_TEMPFAIL) when preempted — the shell
convention for "retry me" — and ``--preempt-after N`` injects a
deterministic preemption after the round-``N`` snapshot for drills.
"""

from __future__ import annotations

import argparse
import dataclasses
import difflib
import hashlib
import json
import os
import sys
from typing import Any, Callable, Iterable, Sequence

import numpy as np

EX_TEMPFAIL = 75

JOB_FILE = "job.json"
RECORDS_FILE = "records.jsonl"
METRICS_FILE = "metrics.jsonl"
TRACE_FILE = "trace.json"
CHECKPOINT_DIR = "checkpoint"
FINAL_DIR = "final"
RESULT_FILE = "result.json"

REGISTRY_BEGIN = "<!-- registry-table:begin -->"
REGISTRY_END = "<!-- registry-table:end -->"


class JobPreempted(Exception):
    """The run was cut at a snapshot boundary; resume from the run dir."""

    def __init__(self, run_dir: str, round_index: int) -> None:
        super().__init__(
            f"job preempted at round {round_index}; resume with "
            f"`federation_service resume --run-dir {run_dir}`"
        )
        self.run_dir = run_dir
        self.round_index = round_index


# ---------------------------------------------------------------------------
# job-spec schema + validation
# ---------------------------------------------------------------------------

MODES = ("sync", "async")

# Top-level defaults shared by both modes.  Values mirror the facade
# configs so a minimal spec ({"mode": "sync"}) is a runnable job.
_COMMON_DEFAULTS: dict[str, Any] = {
    "name": "job",
    "mode": "sync",
    "rounds": 15,
    "local_epochs": 4,
    "batch_size": 128,
    "seed": 0,
    "recruitment": "all",
    "aggregator": None,  # mode-dependent: "fedavg" sync, "fedbuff" async
    "engine": "vectorized",
    "cohort_chunk": None,
    "mesh": None,  # null or "auto" (device meshes are runtime objects)
    "staging": "resident",
    "prefetch": True,
    "donate_buffers": True,
    "resident_budget_bytes": None,  # null = bake the full cohort resident
    "checkpoint_every": 1,
    "data": None,
    "model": None,
    "optimizer": None,
    "privacy": None,  # null = unprotected; object = in-jit DP-SGD section
    "observability": None,  # null = uninstrumented; object = tracing/profiling
}
_SYNC_DEFAULTS: dict[str, Any] = {"selection": "uniform"}
_ASYNC_DEFAULTS: dict[str, Any] = {
    "latency": "constant",
    "dropout": "never",
    "concurrency": None,
    "target_loss": None,
    "max_virtual_time": None,
}
_DATA_DEFAULTS: dict[str, Any] = {
    "scale": 1.0,          # CohortConfig.scaled factor (1.0 = full cohort)
    "seed": 0,             # cohort generation seed (independent of job seed)
    "split_mode": "global",
    "num_hospitals": None,  # None = the paper's 189
}
_MODEL_DEFAULTS: dict[str, Any] = {
    "hidden_dim": 32,
    "num_layers": 2,
    "dropout": 0.05,
    "use_pallas": False,
}
_PRIVACY_DEFAULTS: dict[str, Any] = {
    "clip_norm": 1.0,          # per-example L2 clip (null = no clipping)
    "noise_multiplier": 1.0,   # sigma / clip_norm (0 = clip-only, no noise)
    "delta": 1e-5,             # accountant's target delta
}
_OPT_DEFAULTS: dict[str, Any] = {
    "learning_rate": 5e-3,
    "weight_decay": 5e-3,
    "b1": 0.9,
    "b2": 0.999,
    "eps": 1e-8,
    "clip_norm": None,
}


def _check_keys(given: Iterable[str], allowed: Iterable[str], where: str) -> None:
    allowed = sorted(allowed)
    for key in given:
        if key in allowed:
            continue
        close = difflib.get_close_matches(key, allowed, n=1)
        hint = f"; did you mean {close[0]!r}?" if close else ""
        raise ValueError(
            f"unknown key {key!r} in {where}{hint} (allowed: {allowed})"
        )


def _merge_section(spec: dict, key: str, defaults: dict[str, Any]) -> dict:
    section = spec.get(key) or {}
    if not isinstance(section, dict):
        raise ValueError(f"job spec section {key!r} must be an object")
    _check_keys(section, defaults, f"job spec section {key!r}")
    return {**defaults, **section}


def validate_job_spec(spec: dict) -> dict:
    """Validate a raw job spec and return its normalized (complete) form.

    Normalization fills every default so two specs that mean the same job
    hash identically.  Validation is front-loaded: unknown keys and policy
    spec strings fail here with did-you-mean suggestions; numeric
    constraints are enforced by building the actual facade config.
    """
    # Imported lazily so `federation_service --help` stays jax-free.
    from repro.federated.api import (
        resolve_aggregator,
        resolve_recruitment,
        resolve_selection,
    )
    from repro.federated.runtime import (
        AsyncAggregator,
        resolve_dropout,
        resolve_latency,
    )

    if not isinstance(spec, dict):
        raise ValueError(f"job spec must be a JSON object, got {type(spec).__name__}")
    mode = spec.get("mode", _COMMON_DEFAULTS["mode"])
    if mode not in MODES:
        close = difflib.get_close_matches(str(mode), MODES, n=1)
        hint = f"; did you mean {close[0]!r}?" if close else ""
        raise ValueError(f"unknown mode {mode!r}{hint} (choose from {MODES})")
    defaults = dict(_COMMON_DEFAULTS)
    defaults.update(_SYNC_DEFAULTS if mode == "sync" else _ASYNC_DEFAULTS)
    for key in spec:
        if mode == "sync" and key in _ASYNC_DEFAULTS:
            raise ValueError(
                f"job spec key {key!r} is only valid for mode 'async' "
                f"(this job has mode 'sync')"
            )
        if mode == "async" and key in _SYNC_DEFAULTS:
            raise ValueError(
                f"job spec key {key!r} is only valid for mode 'sync' "
                f"(async dispatch replaces per-round selection)"
            )
    _check_keys(spec, defaults, "job spec")

    out = {**defaults, **spec}
    out["mode"] = mode
    if out["aggregator"] is None:
        out["aggregator"] = "fedavg" if mode == "sync" else "fedbuff"
    out["data"] = _merge_section(out, "data", _DATA_DEFAULTS)
    out["model"] = _merge_section(out, "model", _MODEL_DEFAULTS)
    out["optimizer"] = _merge_section(out, "optimizer", _OPT_DEFAULTS)
    # privacy is tri-state: null stays null (unprotected — and hashes
    # differently from any DP job), an object merges over the defaults.
    if out["privacy"] is not None:
        out["privacy"] = _merge_section(out, "privacy", _PRIVACY_DEFAULTS)
        # Strict number validation (rejects JSON strings and booleans,
        # negative clip norms, negative noise) lives with the DP config.
        from repro.privacy.dp import resolve_dp

        resolve_dp(out["privacy"])
    # observability is tri-state like privacy: null means the run is
    # uninstrumented (the hash of an unobserved job stays stable), an
    # object merges over the defaults and is strictly type-checked.
    if out["observability"] is not None:
        from repro.obs.profile import OBSERVABILITY_DEFAULTS, resolve_observability

        out["observability"] = _merge_section(
            out, "observability", OBSERVABILITY_DEFAULTS
        )
        resolve_observability(out["observability"])

    # Policy spec strings: resolve them now so typos die with suggestions.
    resolve_recruitment(out["recruitment"])
    aggregator = resolve_aggregator(out["aggregator"])
    if mode == "sync":
        resolve_selection(out["selection"])
        if isinstance(aggregator, AsyncAggregator):
            raise ValueError(
                f"aggregator {out['aggregator']!r} is buffered/asynchronous; "
                "set mode='async' to run it on the virtual-clock runtime"
            )
    else:
        resolve_latency(out["latency"])
        resolve_dropout(out["dropout"])
        if not isinstance(aggregator, AsyncAggregator):
            raise ValueError(
                f"aggregator {out['aggregator']!r} is synchronous; mode='async' "
                "needs a buffered aggregator ('fedbuff:K', "
                "'hierarchical-async:R') — or set mode='sync'"
            )
    if int(out["checkpoint_every"]) < 1:
        raise ValueError(
            f"checkpoint_every must be >= 1, got {out['checkpoint_every']}"
        )
    if not isinstance(out["model"]["use_pallas"], bool):
        # bool() would truthy-coerce "false" to True — reject anything but
        # a JSON boolean before it reaches the kernel-path switch.
        raise ValueError(
            "model.use_pallas must be a JSON boolean (true/false), "
            f"got {out['model']['use_pallas']!r}"
        )
    if not (float(out["data"]["scale"]) > 0):
        raise ValueError(f"data.scale must be > 0, got {out['data']['scale']}")
    if out["mesh"] not in (None, "auto"):
        raise ValueError(
            f"mesh must be null or 'auto' in a job spec, got {out['mesh']!r} "
            "(device meshes are runtime objects; pass one via the Python API)"
        )
    # Everything numeric flows through the frozen facade configs, whose
    # __post_init__ owns the constraints — build one to fail fast.
    federation_config_from_spec(out)
    return out


def job_spec_hash(spec: dict) -> str:
    """sha256 of the canonical JSON form of a *normalized* spec."""
    canon = json.dumps(spec, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(canon.encode("utf-8")).hexdigest()


def federation_config_from_spec(spec: dict):
    """Normalized spec -> FederationConfig / AsyncFederationConfig."""
    from repro.federated.api import FederationConfig
    from repro.federated.runtime import AsyncFederationConfig

    common = dict(
        rounds=int(spec["rounds"]),
        local_epochs=int(spec["local_epochs"]),
        batch_size=int(spec["batch_size"]),
        recruitment=spec["recruitment"],
        aggregator=spec["aggregator"],
        seed=int(spec["seed"]),
        engine=spec["engine"],
        cohort_chunk=spec["cohort_chunk"],
        mesh=spec["mesh"],
        donate_buffers=bool(spec["donate_buffers"]),
        staging=spec["staging"],
        prefetch=bool(spec["prefetch"]),
        resident_budget_bytes=(
            None
            if spec["resident_budget_bytes"] is None
            else int(spec["resident_budget_bytes"])
        ),
        # .get(): snapshots written before the privacy tier existed carry
        # specs without the key — they resume as unprotected jobs.
        privacy=spec.get("privacy"),
    )
    if spec["mode"] == "sync":
        return FederationConfig(selection=spec["selection"], **common)
    return AsyncFederationConfig(
        latency=spec["latency"],
        dropout=spec["dropout"],
        concurrency=spec["concurrency"],
        target_loss=spec["target_loss"],
        max_virtual_time=spec["max_virtual_time"],
        **common,
    )


# ---------------------------------------------------------------------------
# workload construction
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Workload:
    """Everything a facade needs beyond its config, built from one spec."""

    clients: list
    loss_fn: Callable[..., Any]
    optimizer: Any
    init_params: Any
    model_cfg: Any


def build_workload(spec: dict) -> Workload:
    """Materialize the spec's data/model/optimizer sections.

    The cohort is the synthetic eICU generator (``data.seed`` keeps it
    independent of the training seed so the same federation can be trained
    under many seeds), the model is the paper's GRU with ``input_dim``
    derived from the cohort's feature layout, and params are initialized
    from the *job* seed — all deterministic, so resume rebuilds the exact
    same workload from job.json alone.
    """
    import jax

    from repro.data.pipeline import build_client_datasets
    from repro.data.synth_eicu import CohortConfig, generate_cohort
    from repro.models.gru import GRUConfig, init_gru, make_loss_fn
    from repro.optim.adamw import AdamW

    data = spec["data"]
    cohort_cfg = CohortConfig(split_mode=data["split_mode"])
    if data["num_hospitals"] is not None:
        cohort_cfg = dataclasses.replace(
            cohort_cfg, num_hospitals=int(data["num_hospitals"])
        )
    if float(data["scale"]) != 1.0:
        cohort_cfg = cohort_cfg.scaled(float(data["scale"]))
    cohort = generate_cohort(cohort_cfg, seed=int(data["seed"]))
    clients = build_client_datasets(cohort)

    model = spec["model"]
    model_cfg = GRUConfig(
        input_dim=cohort_cfg.num_temporal + cohort_cfg.num_static,
        hidden_dim=int(model["hidden_dim"]),
        num_layers=int(model["num_layers"]),
        dropout=float(model["dropout"]),
        use_pallas=bool(model["use_pallas"]),
    )
    opt = spec["optimizer"]
    optimizer = AdamW(
        learning_rate=float(opt["learning_rate"]),
        weight_decay=float(opt["weight_decay"]),
        b1=float(opt["b1"]),
        b2=float(opt["b2"]),
        eps=float(opt["eps"]),
        clip_norm=None if opt["clip_norm"] is None else float(opt["clip_norm"]),
    )
    init_params = init_gru(jax.random.key(int(spec["seed"])), model_cfg)
    return Workload(
        clients=clients,
        loss_fn=make_loss_fn(model_cfg),
        optimizer=optimizer,
        init_params=init_params,
        model_cfg=model_cfg,
    )


# ---------------------------------------------------------------------------
# record streaming
# ---------------------------------------------------------------------------


class RecordStream:
    """Fans each RoundRecord out to a JSONL sink and live subscribers.

    The JSONL line is written and flushed *before* subscribers run, so an
    external tail sees every round the in-process watchers saw even if a
    subscriber (or the run) dies mid-round.
    """

    def __init__(
        self,
        path: str | None,
        subscribers: Sequence[Callable[[Any], None]] = (),
        append: bool = False,
    ) -> None:
        self.path = path
        self.subscribers = list(subscribers)
        if path is not None and not append:
            with open(path, "w", encoding="utf-8"):
                pass  # truncate: a fresh run owns the whole stream
        self.count = 0

    def emit(self, record) -> None:
        if self.path is not None:
            with open(self.path, "a", encoding="utf-8") as fh:
                fh.write(json.dumps(record.to_state(), sort_keys=True) + "\n")
                fh.flush()
        self.count += 1
        for fn in self.subscribers:
            fn(record)


def read_records(path: str) -> list:
    """Parse a records.jsonl stream back into RoundRecords."""
    from repro.federated.api import RoundRecord

    records = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                records.append(RoundRecord.from_state(json.loads(line)))
    return records


def _rewrite_records(path: str, history: list) -> None:
    """Truncate the stream to a snapshot's record prefix (atomic)."""
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        for record in history:
            fh.write(json.dumps(record.to_state(), sort_keys=True) + "\n")
    os.replace(tmp, path)


def _truncate_jsonl_prefix(path: str, count: int) -> None:
    """Keep only the first ``count`` lines of a JSONL stream (atomic).

    The metrics stream emits exactly one line per record, so truncating it
    to the snapshot's record count keeps the two files in lockstep when a
    preempted run rolls back past rounds the cut already streamed.
    """
    if not os.path.exists(path):
        return
    with open(path, encoding="utf-8") as fh:
        lines = [line for line in fh if line.strip()]
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        fh.writelines(lines[:count])
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# job execution
# ---------------------------------------------------------------------------


def _write_json(path: str, obj: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(obj, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)


def _read_json(path: str) -> dict:
    with open(path, encoding="utf-8") as fh:
        return json.load(fh)


def _run_job(
    job: dict,
    run_dir: str,
    *,
    resume_snapshot=None,
    subscribers: Sequence[Callable[[Any], None]] = (),
    preempt_after: int | None = None,
) -> dict:
    """Shared submit/resume engine: build, run, snapshot, finalize."""
    from repro.checkpoint.store import (
        federation_snapshot_state,
        has_federation_snapshot,
        save_pytree,
    )
    from repro.federated.api import Federation
    from repro.federated.runtime import AsyncFederation
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.profile import RoundProfiler, resolve_observability
    from repro.obs.trace import Tracer

    spec = job["spec"]
    spec_hash = job["spec_hash"]
    cfg = federation_config_from_spec(spec)
    workload = build_workload(spec)
    ckpt_dir = os.path.join(run_dir, CHECKPOINT_DIR)

    # Observability: the metrics registry always exists (metrics.jsonl is
    # part of the run-dir contract); the tracer and profiler only when the
    # spec's observability section asks for them.  .get(): job.json files
    # written before the observability tier existed resume uninstrumented.
    obs = resolve_observability(spec.get("observability"))
    metrics = MetricsRegistry()
    if resume_snapshot is not None and has_federation_snapshot(ckpt_dir):
        # Continue the series: counters resume from the snapshot instead of
        # restarting at zero (the metrics.jsonl prefix was truncated to the
        # same snapshot by resume_job).
        metrics.load_snapshot(federation_snapshot_state(ckpt_dir).get("metrics"))
    tracer = Tracer(capacity=obs.trace_capacity) if obs is not None and obs.trace else None
    profiler = (
        RoundProfiler(obs.jax_profile_rounds, os.path.join(run_dir, "jax_profile"))
        if obs is not None and obs.jax_profile_rounds > 0
        else None
    )

    metrics_path = os.path.join(run_dir, METRICS_FILE)
    if resume_snapshot is None:
        with open(metrics_path, "w", encoding="utf-8"):
            pass  # truncate: a fresh run owns the whole series

    def stream_metrics(record) -> None:
        # Runs after the facade absorbed the round into the registry, so
        # the line is the cumulative state *through* this record.
        line = {"round_index": int(record.round_index), **metrics.snapshot()}
        with open(metrics_path, "a", encoding="utf-8") as fh:
            fh.write(json.dumps(line, sort_keys=True) + "\n")
            fh.flush()

    stream = RecordStream(
        os.path.join(run_dir, RECORDS_FILE),
        [stream_metrics, *subscribers],
        append=resume_snapshot is not None,
    )
    every = int(spec["checkpoint_every"])

    def snapshot_hook(snap) -> None:
        index = int(snap.round_index)
        if index % every == 0 or (preempt_after is not None and index >= preempt_after):
            snap.save(
                ckpt_dir,
                extra_state={"spec_hash": spec_hash, "metrics": metrics.snapshot()},
            )
        if preempt_after is not None and index >= preempt_after:
            _write_json(
                os.path.join(run_dir, RESULT_FILE),
                {"status": "preempted", "round_index": index, "spec_hash": spec_hash},
            )
            raise JobPreempted(run_dir, index)

    facade_cls = Federation if spec["mode"] == "sync" else AsyncFederation
    federation = facade_cls(
        cfg,
        workload.clients,
        workload.loss_fn,
        workload.optimizer,
        tracer=tracer,
        metrics=metrics,
        profiler=profiler,
    )
    try:
        result = federation.run(
            workload.init_params,
            progress=stream.emit,
            snapshot_hook=snapshot_hook,
            resume=resume_snapshot,
        )
    finally:
        # Preempted runs keep their partial trace too — the ring holds
        # whatever happened up to the cut.
        if tracer is not None:
            tracer.export_chrome(os.path.join(run_dir, TRACE_FILE))
        if profiler is not None:
            profiler.stop()

    save_pytree(
        os.path.join(run_dir, FINAL_DIR),
        result.params,
        metadata={"spec_hash": spec_hash, "rounds": len(result.history)},
    )
    summary = result.summary()
    out = {
        "status": "completed",
        "spec_hash": spec_hash,
        "name": spec["name"],
        "mode": spec["mode"],
        "summary": summary,
        "resumed_from": None
        if resume_snapshot is None
        else int(resume_snapshot.round_index),
    }
    _write_json(os.path.join(run_dir, RESULT_FILE), out)
    return out


def submit_job(
    spec: dict,
    run_dir: str,
    *,
    subscribers: Sequence[Callable[[Any], None]] = (),
    preempt_after: int | None = None,
) -> dict:
    """Validate a spec, persist it, and run the job in ``run_dir``.

    Returns the result dict (also written to ``result.json``).  Raises
    :class:`JobPreempted` if ``preempt_after`` cuts the run — the run dir
    then holds everything :func:`resume_job` needs.
    """
    normalized = validate_job_spec(spec)
    job = {"spec": normalized, "spec_hash": job_spec_hash(normalized)}
    os.makedirs(run_dir, exist_ok=True)
    _write_json(os.path.join(run_dir, JOB_FILE), job)
    return _run_job(
        job,
        run_dir,
        subscribers=subscribers,
        preempt_after=preempt_after,
    )


def resume_job(
    run_dir: str,
    *,
    spec: dict | None = None,
    subscribers: Sequence[Callable[[Any], None]] = (),
    preempt_after: int | None = None,
) -> dict:
    """Continue a preempted job from its latest snapshot.

    The snapshot's embedded spec hash must match the job's (and the
    optional caller-supplied ``spec``): resuming under a different spec
    would silently produce a run that is neither experiment.  The record
    stream is truncated to the snapshot's prefix, so the resumed
    ``records.jsonl`` is byte-for-byte the uninterrupted one.
    """
    from repro.checkpoint.store import (
        federation_snapshot_state,
        has_federation_snapshot,
    )
    from repro.federated.api import FederationSnapshot
    from repro.federated.runtime import AsyncFederationSnapshot

    job = _read_json(os.path.join(run_dir, JOB_FILE))
    stored_hash = job["spec_hash"]
    if job_spec_hash(job["spec"]) != stored_hash:
        raise ValueError(f"job.json in {run_dir} is corrupt: spec_hash mismatch")
    if spec is not None:
        supplied = job_spec_hash(validate_job_spec(spec))
        if supplied != stored_hash:
            raise ValueError(
                f"supplied spec (hash {supplied[:12]}…) does not match the "
                f"submitted job (hash {stored_hash[:12]}…); a resumed job "
                "must run the exact spec it was submitted with"
            )
    ckpt_dir = os.path.join(run_dir, CHECKPOINT_DIR)
    if not has_federation_snapshot(ckpt_dir):
        raise FileNotFoundError(
            f"no federation snapshot in {ckpt_dir}; nothing to resume"
        )
    snap_hash = federation_snapshot_state(ckpt_dir).get("spec_hash")
    if snap_hash != stored_hash:
        raise ValueError(
            f"snapshot spec_hash {str(snap_hash)[:12]}… does not match job "
            f"spec_hash {stored_hash[:12]}…; refusing to resume a different "
            "experiment's checkpoint"
        )
    workload = build_workload(job["spec"])
    snapshot_cls = (
        FederationSnapshot if job["spec"]["mode"] == "sync" else AsyncFederationSnapshot
    )
    snapshot = snapshot_cls.load(ckpt_dir, workload.init_params)
    _rewrite_records(os.path.join(run_dir, RECORDS_FILE), snapshot.history)
    _truncate_jsonl_prefix(os.path.join(run_dir, METRICS_FILE), len(snapshot.history))
    return _run_job(
        job,
        run_dir,
        resume_snapshot=snapshot,
        subscribers=subscribers,
        preempt_after=preempt_after,
    )


def status_job(run_dir: str) -> dict:
    """Inspect a run dir from its JSON manifests (no array payloads read)."""
    from repro.checkpoint.store import (
        federation_snapshot_state,
        has_federation_snapshot,
    )

    out: dict[str, Any] = {"run_dir": run_dir, "status": "unknown"}
    job_path = os.path.join(run_dir, JOB_FILE)
    if not os.path.exists(job_path):
        out["status"] = "missing"
        return out
    job = _read_json(job_path)
    out["name"] = job["spec"]["name"]
    out["mode"] = job["spec"]["mode"]
    out["spec_hash"] = job["spec_hash"]
    out["rounds_budget"] = job["spec"]["rounds"]
    records_path = os.path.join(run_dir, RECORDS_FILE)
    out["rounds_recorded"] = 0
    if os.path.exists(records_path):
        with open(records_path, encoding="utf-8") as fh:
            out["rounds_recorded"] = sum(1 for line in fh if line.strip())
    ckpt_dir = os.path.join(run_dir, CHECKPOINT_DIR)
    if has_federation_snapshot(ckpt_dir):
        state = federation_snapshot_state(ckpt_dir)
        out["checkpoint_round"] = state.get("round_index", state.get("version"))
    result_path = os.path.join(run_dir, RESULT_FILE)
    if os.path.exists(result_path):
        result = _read_json(result_path)
        out["status"] = result["status"]
        if "summary" in result:
            out["summary"] = result["summary"]
        if "round_index" in result:
            out["preempted_at"] = result["round_index"]
    else:
        out["status"] = "submitted"
    return out


def diff_runs(run_a: str, run_b: str, atol: float = 1e-5) -> list[str]:
    """Compare two finished runs; returns human-readable mismatches.

    Used by the CI kill-and-resume drill: a resumed run dir must match the
    uninterrupted one — records pairwise (virtual clock and participants
    exact, losses to ``atol``) and final params to ``atol``.
    """
    problems: list[str] = []
    recs_a = read_records(os.path.join(run_a, RECORDS_FILE))
    recs_b = read_records(os.path.join(run_b, RECORDS_FILE))
    if len(recs_a) != len(recs_b):
        problems.append(f"record count: {len(recs_a)} vs {len(recs_b)}")
    for ra, rb in zip(recs_a, recs_b):
        tag = f"round {ra.round_index}"
        if ra.round_index != rb.round_index:
            problems.append(f"{tag}: index mismatch ({rb.round_index})")
        if ra.participant_ids != rb.participant_ids:
            problems.append(f"{tag}: participant_ids differ")
        if ra.virtual_time != rb.virtual_time:
            problems.append(
                f"{tag}: virtual_time {ra.virtual_time} vs {rb.virtual_time}"
            )
        la, lb = ra.mean_local_loss, rb.mean_local_loss
        if np.isnan(la) != np.isnan(lb) or (
            not np.isnan(la) and abs(la - lb) > atol
        ):
            problems.append(f"{tag}: mean_local_loss {la} vs {lb}")
    for name in ("arrays.npz",):
        pa = os.path.join(run_a, FINAL_DIR, name)
        pb = os.path.join(run_b, FINAL_DIR, name)
        if not (os.path.exists(pa) and os.path.exists(pb)):
            problems.append(f"final params missing ({name})")
            continue
        with np.load(pa) as za, np.load(pb) as zb:
            if sorted(za.files) != sorted(zb.files):
                problems.append("final params: tensor sets differ")
                continue
            for key in za.files:
                if not np.allclose(za[key], zb[key], atol=atol, rtol=0):
                    worst = float(np.max(np.abs(za[key] - zb[key])))
                    problems.append(f"final params: {key} differs (max {worst:.3e})")
    return problems


# ---------------------------------------------------------------------------
# registry table (docs drift check)
# ---------------------------------------------------------------------------


def registry_table() -> str:
    """The generated markdown table of every registered spec name.

    docs/API_SPEC.md embeds this between the ``registry-table`` markers;
    `federation_service registries --check` fails CI when a registry gains
    or loses a name without the committed table following.
    """
    from repro.federated.api import available_policies
    from repro.federated.runtime import available_runtime_models

    rows = {**available_policies(), **available_runtime_models()}
    lines = ["| Stage | Registered specs |", "| --- | --- |"]
    for stage in sorted(rows):
        specs = ", ".join(f"`{name}`" for name in rows[stage])
        lines.append(f"| {stage} | {specs} |")
    return "\n".join(lines)


def check_registry_table(path: str) -> list[str]:
    """Compare the committed table in ``path`` against the generated one."""
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    if REGISTRY_BEGIN not in text or REGISTRY_END not in text:
        return [f"{path} has no {REGISTRY_BEGIN} … {REGISTRY_END} block"]
    committed = text.split(REGISTRY_BEGIN, 1)[1].split(REGISTRY_END, 1)[0].strip()
    generated = registry_table().strip()
    if committed != generated:
        return [
            f"{path} registry table is stale; regenerate with "
            "`python -m repro.launch.federation_service registries "
            f"--write {path}`"
        ]
    return []


def write_registry_table(path: str) -> None:
    """Rewrite the marked block in ``path`` with the generated table."""
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    if REGISTRY_BEGIN not in text or REGISTRY_END not in text:
        raise ValueError(f"{path} has no {REGISTRY_BEGIN} … {REGISTRY_END} block")
    head, rest = text.split(REGISTRY_BEGIN, 1)
    _, tail = rest.split(REGISTRY_END, 1)
    new = f"{head}{REGISTRY_BEGIN}\n{registry_table()}\n{REGISTRY_END}{tail}"
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        fh.write(new)
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _progress_printer(record) -> None:
    vt = "" if record.virtual_time is None else f"  vt={record.virtual_time:.2f}"
    print(
        f"round {record.round_index:3d}  loss={record.mean_local_loss:.4f}  "
        f"clients={len(record.participant_ids)}{vt}",
        flush=True,
    )


def main(argv: Sequence[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="federation_service",
        description="Declarative federated-training job service "
        "(submit / status / resume / diff / registries).",
    )
    sub = parser.add_subparsers(dest="command", required=True)

    p_submit = sub.add_parser("submit", help="validate a job spec and run it")
    p_submit.add_argument("--spec", required=True, help="path to the job-spec JSON")
    p_submit.add_argument("--run-dir", required=True)
    p_submit.add_argument("--preempt-after", type=int, default=None, metavar="N",
                          help="deterministically preempt after the round-N snapshot")
    p_submit.add_argument("--quiet", action="store_true")

    p_status = sub.add_parser("status", help="summarize a run directory")
    p_status.add_argument("--run-dir", required=True)

    p_resume = sub.add_parser("resume", help="continue from the latest snapshot")
    p_resume.add_argument("--run-dir", required=True)
    p_resume.add_argument("--spec", default=None,
                          help="optional spec to re-verify against the job's hash")
    p_resume.add_argument("--preempt-after", type=int, default=None, metavar="N")
    p_resume.add_argument("--quiet", action="store_true")

    p_diff = sub.add_parser("diff", help="compare two finished run dirs")
    p_diff.add_argument("run_a")
    p_diff.add_argument("run_b")
    p_diff.add_argument("--atol", type=float, default=1e-5)

    p_reg = sub.add_parser("registries", help="print or check the registry table")
    p_reg.add_argument("--check", default=None, metavar="FILE",
                       help="fail if FILE's registry-table block is stale")
    p_reg.add_argument("--write", default=None, metavar="FILE",
                       help="rewrite FILE's registry-table block in place")

    args = parser.parse_args(argv)

    if args.command == "submit":
        with open(args.spec, encoding="utf-8") as fh:
            spec = json.load(fh)
        subscribers = () if args.quiet else (_progress_printer,)
        try:
            result = submit_job(
                spec, args.run_dir,
                subscribers=subscribers, preempt_after=args.preempt_after,
            )
        except JobPreempted as exc:
            print(exc, file=sys.stderr)
            return EX_TEMPFAIL
        print(json.dumps(result["summary"], indent=2, sort_keys=True))
        return 0

    if args.command == "status":
        print(json.dumps(status_job(args.run_dir), indent=2, sort_keys=True))
        return 0

    if args.command == "resume":
        spec = None
        if args.spec is not None:
            with open(args.spec, encoding="utf-8") as fh:
                spec = json.load(fh)
        subscribers = () if args.quiet else (_progress_printer,)
        try:
            result = resume_job(
                args.run_dir, spec=spec,
                subscribers=subscribers, preempt_after=args.preempt_after,
            )
        except JobPreempted as exc:
            print(exc, file=sys.stderr)
            return EX_TEMPFAIL
        print(json.dumps(result["summary"], indent=2, sort_keys=True))
        return 0

    if args.command == "diff":
        problems = diff_runs(args.run_a, args.run_b, atol=args.atol)
        if problems:
            for p in problems:
                print(f"DIFF: {p}", file=sys.stderr)
            return 1
        print(f"runs match: {args.run_a} == {args.run_b}")
        return 0

    if args.command == "registries":
        if args.write is not None:
            write_registry_table(args.write)
            print(f"updated registry table in {args.write}")
            return 0
        if args.check is not None:
            problems = check_registry_table(args.check)
            if problems:
                for p in problems:
                    print(f"DRIFT: {p}", file=sys.stderr)
                return 1
            print(f"registry table in {args.check} is current")
            return 0
        print(registry_table())
        return 0

    raise AssertionError(f"unhandled command {args.command!r}")  # pragma: no cover


if __name__ == "__main__":
    sys.exit(main())
