import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST stay first — jax locks the device count on first
backend initialization, and the production meshes need 512 host-platform
stand-in devices.

For each combination this script jits the right step function with explicit
in/out shardings, ``.lower().compile()``s it, and records:

  * ``memory_analysis()``  — proves the layout fits (bytes per device),
  * ``cost_analysis()``    — HLO FLOPs / bytes for the roofline terms,
  * collective bytes parsed from the compiled HLO (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute),
  * derived roofline terms (seconds) against TPU v5e constants.

Results are persisted incrementally to ``benchmarks/results/dryrun/`` so the
run is resumable; ``--all`` sweeps the full matrix.

Usage::

    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_analysis import (
    COLLECTIVE_KINDS,
    RooflineTerms,
    analyze_hlo,
    cost_summary,
    memory_summary,
    model_flops_estimate,
)
from repro.distribution.compat import set_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    INPUT_SHAPES,
    batch_shardings,
    batch_specs,
    cache_shardings,
    cache_specs,
    config_for_shape,
    decode_token_specs,
    params_shardings,
    params_specs,
)
from repro.launch.steps import (
    make_fed_round_step,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models.zoo import Model
from repro.optim.adamw import AdamW, AdamWState

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# --- §Perf hillclimb variants ------------------------------------------------
# Each entry tweaks one knob relative to the baseline lowering.  Variants are
# lowered with ``--variant <name>`` and recorded as separate result files so
# before/after roofline terms are directly comparable.
VARIANTS: dict[str, dict] = {
    "baseline": {},
    "moe_tp": {"moe_sharding": "tp"},          # expert-TP instead of expert-parallel
    "moe_local": {"moe_sharding": "ep_local"},  # shard-local dispatch (see moe.py)
    "noremat": {"remat": False},               # trade HBM for recompute FLOPs
    "losschunk128": {"loss_chunk": 128},
    "losschunk4096": {"loss_chunk": 4096},
    "kvchunk4096": {"kv_chunk": 4096},
    "fed_k1": {"fed_local_steps": 1},          # FedAvg round, 1 local step
    "fed_k4": {"fed_local_steps": 4},
    "fed_k16": {"fed_local_steps": 16},
    "capacity1": {"capacity_factor": 1.0},
    "capacity2": {"capacity_factor": 2.0},
    "cache_batch": {"cache_mode": "batch"},    # decode cache: batch-only sharding
}


def _opt_state_specs(optimizer: AdamW, p_specs):
    return jax.eval_shape(optimizer.init, p_specs)


def _opt_state_shardings(p_shardings, mesh):
    return AdamWState(
        step=NamedSharding(mesh, P()),
        mu=p_shardings,
        nu=p_shardings,
    )


def _apply_variant_cfg(cfg, spec: dict):
    import dataclasses as _dc

    if cfg.moe is not None:
        moe = cfg.moe
        if "moe_sharding" in spec:
            moe = _dc.replace(moe, expert_sharding=spec["moe_sharding"])
        if "capacity_factor" in spec:
            moe = _dc.replace(moe, capacity_factor=spec["capacity_factor"])
        if moe is not cfg.moe:
            cfg = _dc.replace(cfg, moe=moe)
    return cfg


def lower_combo(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    variant: str = "baseline",
    extra_tags: dict | None = None,
):
    """Lower + compile one combination; returns the result record."""
    spec_v = VARIANTS[variant]
    shape = INPUT_SHAPES[shape_name]
    cfg = _apply_variant_cfg(config_for_shape(get_config(arch), shape), spec_v)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    model = Model(
        cfg,
        remat=spec_v.get("remat", True),
        loss_chunk=spec_v.get("loss_chunk", 512),
    )
    optimizer = AdamW(learning_rate=1e-4, weight_decay=0.01)

    if "fed_local_steps" in spec_v:
        return _lower_fed_round(
            arch, shape_name, mesh_kind, cfg, mesh, model, optimizer,
            local_steps=spec_v["fed_local_steps"], extra_tags=extra_tags,
        )

    p_specs = params_specs(model)
    with set_mesh(mesh):
        p_shardings = params_shardings(p_specs, cfg, mesh)

        t0 = time.perf_counter()
        if shape.kind == "train":
            step = make_train_step(model, optimizer)
            o_specs = _opt_state_specs(optimizer, p_specs)
            o_shardings = _opt_state_shardings(p_shardings, mesh)
            b_specs = batch_specs(cfg, shape)
            b_shardings = batch_shardings(b_specs, mesh)
            metrics_sharding = NamedSharding(mesh, P())
            jitted = jax.jit(
                step,
                in_shardings=(p_shardings, o_shardings, b_shardings),
                out_shardings=(p_shardings, o_shardings, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(p_specs, o_specs, b_specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            b_specs = batch_specs(cfg, shape)
            b_shardings = batch_shardings(b_specs, mesh)
            jitted = jax.jit(step, in_shardings=(p_shardings, b_shardings))
            lowered = jitted.lower(p_specs, b_specs)
        else:  # decode
            step = make_serve_step(model)
            c_specs = cache_specs(model, shape)
            c_shardings = cache_shardings(
                c_specs, cfg, mesh, mode=spec_v.get("cache_mode", "heads")
            )
            tok = decode_token_specs(cfg, shape)
            tok_sharding = batch_shardings({"tokens": tok["tokens"]}, mesh)["tokens"]
            jitted = jax.jit(
                step,
                in_shardings=(p_shardings, tok_sharding, c_shardings, NamedSharding(mesh, P())),
                out_shardings=(None, c_shardings),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(p_specs, tok["tokens"], c_specs, tok["pos"])

        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    return _finalize_record(
        compiled, arch, shape_name, mesh_kind, cfg, shape, mesh,
        t_lower, t_compile, extra_tags,
    )


def _finalize_record(
    compiled, arch, shape_name, mesh_kind, cfg, shape, mesh, t_lower, t_compile, extra_tags
):
    cost_raw = cost_summary(compiled)          # per-device, scan-body-once
    mem = memory_summary(compiled)
    analysis = analyze_hlo(compiled.as_text())  # trip-count-aware, per-device
    chips = mesh.devices.size
    coll_per_dev = sum(analysis.get(k, 0.0) for k in COLLECTIVE_KINDS)
    terms = RooflineTerms(
        hlo_flops=analysis["flops"] * chips,
        hlo_bytes=analysis["bytes"] * chips,
        coll_bytes=coll_per_dev * chips,
        chips=chips,
        model_flops=model_flops_estimate(cfg, shape, shape.kind),
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": chips,
        "kind": shape.kind,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "cost_raw": cost_raw,
        "memory": mem,
        "hlo_analysis": analysis,
        "roofline": terms.as_dict(),
        "tags": extra_tags or {},
    }
    return record


def _lower_fed_round(
    arch, shape_name, mesh_kind, cfg, mesh, model, optimizer, *, local_steps, extra_tags
):
    """Lower the FedAvg round step: client-replica axis over (pod, data)."""
    import jax.numpy as jnp

    shape = INPUT_SHAPES[shape_name]
    assert shape.kind == "train", "fed variants apply to train shapes"
    from repro.launch.mesh import data_axes

    daxes = data_axes(mesh)
    n_clients = 1
    for a in daxes:
        n_clients *= mesh.shape[a]
    local_batch = max(shape.global_batch // n_clients, 1)
    client_spec = daxes if len(daxes) > 1 else daxes[0]

    p_specs = params_specs(model)
    with set_mesh(mesh):
        base_shardings = params_shardings(p_specs, cfg, mesh)

        def stack_spec(l):
            return jax.ShapeDtypeStruct((n_clients, *l.shape), l.dtype)

        def stack_shard(s):
            return NamedSharding(mesh, P(client_spec, *s.spec))

        pc_specs = jax.tree.map(stack_spec, p_specs)
        pc_shardings = jax.tree.map(
            stack_shard, base_shardings, is_leaf=lambda x: isinstance(x, NamedSharding)
        )
        oc_specs = jax.tree.map(stack_spec, _opt_state_specs(optimizer, p_specs))
        oc_shardings = AdamWState(
            step=NamedSharding(mesh, P(client_spec)),
            mu=pc_shardings,
            nu=pc_shardings,
        )

        b_one = batch_specs(cfg, shape)
        b_specs = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                (n_clients, local_steps, local_batch, *l.shape[1:]), l.dtype
            ),
            b_one,
        )
        b_shardings = jax.tree.map(
            lambda l: NamedSharding(mesh, P(client_spec, *([None] * (len(l.shape) - 1)))),
            b_specs,
        )
        w_specs = jax.ShapeDtypeStruct((n_clients,), jnp.float32)
        w_sharding = NamedSharding(mesh, P(client_spec))

        step = make_fed_round_step(model, optimizer)
        t0 = time.perf_counter()
        jitted = jax.jit(
            step,
            in_shardings=(pc_shardings, oc_shardings, b_shardings, w_sharding),
            out_shardings=(pc_shardings, oc_shardings, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(pc_specs, oc_specs, b_specs, w_specs)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    tags = dict(extra_tags or {})
    tags.update({"fed_local_steps": local_steps, "clients": n_clients, "local_batch": local_batch})
    record = _finalize_record(
        compiled, arch, shape_name, mesh_kind, cfg, shape, mesh, t_lower, t_compile, tags
    )
    # normalize: model_flops for ONE local step x clients x local_steps
    record["roofline"]["model_flops"] = (
        record["roofline"]["model_flops"] / shape.global_batch * local_batch * n_clients * local_steps
    )
    return record


def result_path(arch: str, shape: str, mesh_kind: str, variant: str = "baseline") -> Path:
    return RESULTS_DIR / f"{arch}__{shape}__{mesh_kind}__{variant}.json"


def run_combo(arch: str, shape: str, mesh_kind: str, force: bool = False, variant: str = "baseline"):
    out = result_path(arch, shape, mesh_kind, variant)
    if out.exists() and not force:
        print(f"[skip] {arch} x {shape} x {mesh_kind} (cached)")
        return json.loads(out.read_text())
    print(f"[run ] {arch} x {shape} x {mesh_kind} ({variant}) ...", flush=True)
    t0 = time.perf_counter()
    try:
        record = lower_combo(arch, shape, mesh_kind, variant=variant)
        record["variant"] = variant
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(record, indent=1))
        r = record["roofline"]
        print(
            f"[ ok ] {arch} x {shape} x {mesh_kind}: "
            f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
            f"collective={r['collective_s']:.3e}s dominant={r['dominant']} "
            f"(lower+compile {time.perf_counter()-t0:.1f}s)",
            flush=True,
        )
        return record
    except Exception as exc:  # record failures — they are bugs to fix
        err = {
            "arch": arch, "shape": shape, "mesh": mesh_kind, "variant": variant,
            "error": f"{type(exc).__name__}: {exc}",
            "traceback": traceback.format_exc()[-4000:],
        }
        out.parent.mkdir(parents=True, exist_ok=True)
        out.with_suffix(".error.json").write_text(json.dumps(err, indent=1))
        print(f"[FAIL] {arch} x {shape} x {mesh_kind}: {exc}", flush=True)
        return err


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--variant", choices=list(VARIANTS), default="baseline")
    ap.add_argument("--all", action="store_true", help="sweep all archs x shapes")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCH_IDS) if args.all or args.arch is None else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or args.shape is None else [args.shape]

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_combo(arch, shape, mesh_kind, force=args.force, variant=args.variant)
                if "error" in rec:
                    failures += 1
    if failures:
        raise SystemExit(f"{failures} combination(s) failed")
    print("all requested combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
