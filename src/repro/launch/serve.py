"""Decode driver: batched autoregressive *inference* with a KV/state cache.

Naming note: "serve" here means serving *predictions* from a trained
model — batched greedy decode, tokens/step timings.  The service that
accepts and runs federated *training jobs* is
:mod:`repro.launch.federation_service` (the control plane); the two are
unrelated beyond living in ``repro.launch``.  See the README glossary.

Runs a *reduced* config on CPU end-to-end (prefill via the decode path,
then batched greedy decode), printing tokens/step timings.  The full-size
serve paths are exercised through dryrun.py (decode_32k / long_500k specs).

    python -m repro.launch.serve --arch smollm-135m --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ArchType
from repro.launch.steps import make_serve_step
from repro.models.zoo import Model


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    max_len = args.prompt_len + args.gen + cfg.num_frontend_tokens
    cache = model.init_cache(args.batch, max_len)
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(2,))

    if cfg.arch_type == ArchType.ENCDEC:
        src = jnp.asarray(rng.normal(size=(args.batch, max(args.prompt_len, 8), cfg.d_model)), jnp.float32)
        cache = model.encode_for_decode(params, src, cache)

    pos = 0
    if cfg.arch_type == ArchType.VLM:
        patches = jnp.asarray(
            rng.normal(size=(args.batch, cfg.num_frontend_tokens, cfg.d_model)), jnp.float32
        )
        for i in range(cfg.num_frontend_tokens):
            _, cache = model.decode_step(params, None, cache, jnp.int32(pos), token_embeds=patches[:, i : i + 1])
            pos += 1

    prompt = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    logits = None
    t0 = time.perf_counter()
    for t in range(args.prompt_len):
        logits, cache = serve_step(params, jnp.asarray(prompt[:, t : t + 1]), cache, jnp.int32(pos))
        pos += 1
    prefill_s = time.perf_counter() - t0

    generated = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for _ in range(args.gen):
        generated.append(np.asarray(tok)[:, 0])
        logits, cache = serve_step(params, tok, cache, jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        pos += 1
    decode_s = time.perf_counter() - t0

    gen = np.stack(generated, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} steps in {prefill_s:.2f}s")
    print(
        f"decode : {args.gen} steps in {decode_s:.2f}s "
        f"({args.gen * args.batch / max(decode_s, 1e-9):.1f} tok/s batched)"
    )
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
