"""Step functions the launcher jits: train, prefill, serve (decode).

These are the functions every (architecture x input-shape x mesh) dry-run
lowers and compiles, and the same functions the real drivers run.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.zoo import Model
from repro.optim.adamw import AdamW, apply_updates

PyTree = Any


def make_train_step(model: Model, optimizer: AdamW) -> Callable:
    def train_step(params: PyTree, opt_state, batch: dict[str, jnp.ndarray]):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return train_step


def make_fed_round_step(model: Model, optimizer: AdamW) -> Callable:
    """FedAvg round as a single SPMD program (the paper's technique at
    production scale).

    Parameters and optimizer state carry an explicit leading CLIENT axis
    (size C = number of client slots), sharded over the mesh's (pod, data)
    axes — each slot holds one hospital silo's *diverged* replica, itself
    tensor-sharded over ``model``.  A round is:

      1. ``vmap`` over clients of ``local_steps`` optimizer steps — zero
         cross-client communication (per-replica grads stay local);
      2. one weighted parameter average over the client axis — FedAvg's
         server aggregation as a single reduce+broadcast collective.

    ``weights`` carry ``n_c * recruited_c``: recruitment zeroes a client's
    contribution *before* the federation runs, which is exactly the paper's
    pre-federation exclusion expressed in the collective.

    Versus synchronous data-parallel (grad all-reduce every step), a K-local-
    step round moves the cross-silo traffic from K x grads to 2 x params —
    the collective-term saving quantified in EXPERIMENTS.md §Perf.
    """

    def local_loss(params, batch):
        return model.loss(params, batch)[0]

    def local_run(params, opt_state, client_batches):
        """K purely-local steps for ONE client (vmapped over the client axis)."""

        def one_step(carry, batch):
            p, o = carry
            loss, grads = jax.value_and_grad(local_loss)(p, batch)
            updates, o = optimizer.update(grads, o, p)
            return (apply_updates(p, updates), o), loss

        (params, opt_state), losses = jax.lax.scan(one_step, (params, opt_state), client_batches)
        return params, opt_state, jnp.mean(losses)

    def fed_round_step(params_c, opt_state_c, batches, weights):
        # params_c leaves: (C, ...); batches leaves: (C, K, local_batch, ...);
        # weights: (C,) float — n_c * recruited mask.
        params_c, opt_state_c, loss_c = jax.vmap(local_run)(params_c, opt_state_c, batches)

        w = (weights / jnp.maximum(weights.sum(), 1e-9)).astype(jnp.float32)

        def weighted_avg(x):
            avg = jnp.tensordot(w.astype(x.dtype), x, axes=1)     # reduce over C
            return jnp.broadcast_to(avg[None], x.shape)            # redistribute

        params_c = jax.tree.map(weighted_avg, params_c)
        return params_c, opt_state_c, jnp.sum(loss_c * w)

    return fed_round_step


def make_prefill_step(model: Model) -> Callable:
    """Serving prefill: hidden states for the whole prompt, logits for the
    LAST position only (materializing (B, 32k, V) fp32 logits is never what
    a serving system does)."""

    def prefill_step(params: PyTree, batch: dict[str, jnp.ndarray]) -> jnp.ndarray:
        h, _ = model.hidden(params, batch)
        last = h[:, -1, :]
        return (last @ model._head_matrix(params)).astype(jnp.float32)

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """One decode step: new token for every sequence against a full cache."""

    def serve_step(params: PyTree, tokens: jnp.ndarray, cache: PyTree, pos: jnp.ndarray):
        return model.decode_step(params, tokens, cache, pos)

    return serve_step
