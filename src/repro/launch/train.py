"""Training driver.

Two modes:

  * ``paper`` (default) — the paper's experiments on the synthetic eICU
    cohort: central / federated with and without client recruitment.
  * ``lm`` — single-process smoke training of any assigned architecture's
    *reduced* variant on synthetic tokens (sanity path for the zoo; the
    full configs only ever lower through dryrun.py on this CPU container).

Examples::

    python -m repro.launch.train --setting federated-src --scale 0.2 --seeds 0 1 2
    python -m repro.launch.train --mode lm --arch smollm-135m --steps 10
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.experiments.paper import MODEL_SETTINGS, ExperimentConfig, run_seeds

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def run_paper(args) -> None:
    exp = ExperimentConfig(
        cohort_scale=args.scale,
        rounds=args.rounds,
        gamma_th=args.gamma_th,
        use_pallas=args.pallas,
    )
    agg = run_seeds(args.setting, exp, seeds=args.seeds)
    print(json.dumps({k: v for k, v in agg.items() if k != "runs"}, indent=2))
    out = RESULTS_DIR / "paper" / f"{args.setting}_scale{args.scale}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(agg, indent=1))
    print(f"saved -> {out}")


def run_lm(args) -> None:
    import jax.numpy as jnp

    from repro.data.pipeline import lm_token_batch
    from repro.launch.steps import make_train_step
    from repro.models.zoo import Model
    from repro.optim.adamw import AdamW

    cfg = get_config(args.arch).reduced()
    model = Model(cfg, remat=False)
    optimizer = AdamW(learning_rate=1e-3)
    params = model.init(jax.random.key(args.seed))
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(model, optimizer))
    rng = np.random.default_rng(args.seed)

    from repro.configs.base import ArchType

    for i in range(args.steps):
        batch = lm_token_batch(rng, args.batch, args.seq, cfg.vocab_size)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.arch_type == ArchType.VLM:
            batch["patch_embeds"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.num_frontend_tokens, cfg.d_model)), jnp.float32
            )
        if cfg.arch_type == ArchType.ENCDEC:
            batch["src_embeds"] = jnp.asarray(
                rng.normal(size=(args.batch, max(args.seq // 4, 8), cfg.d_model)), jnp.float32
            )
        params, opt_state, metrics = step(params, opt_state, batch)
        print(f"step {i}: loss={float(metrics['loss']):.4f}")
    print("lm smoke training done")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=["paper", "lm"], default="paper")
    # paper mode
    ap.add_argument("--setting", choices=list(MODEL_SETTINGS), default="federated-src")
    ap.add_argument("--scale", type=float, default=1.0, help="cohort scale (1.0 = full)")
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--gamma-th", type=float, default=0.1)
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1, 2])
    ap.add_argument("--pallas", action="store_true")
    # lm mode
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-135m")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.mode == "paper":
        run_paper(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
