"""Input shapes, ShapeDtypeStruct stand-ins, and sharding rules.

``input_specs(cfg, shape, mesh)`` builds weak-type-correct, shardable
ShapeDtypeStructs for every model input — nothing is allocated; the dry-run
lowers against these.

Sharding rules are path-pattern based over the params pytree (built once
from ``jax.eval_shape`` of ``model.init``) — the same rules serve the 2-axis
and 3-axis production meshes because unknown axis names are dropped.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ArchType
from repro.launch.mesh import axis_size, data_axes
from repro.models.zoo import Model

PyTree = Any


# --------------------------------------------------------------------------
# input shapes (assigned)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

LONG_CONTEXT_WINDOW = 8_192


def long_context_variant(cfg: ArchConfig) -> ArchConfig:
    """Sub-quadratic variant for long_500k: SSM/hybrid run natively; every
    full-attention family gets the sliding-window decode cache."""
    if cfg.arch_type in (ArchType.SSM, ArchType.HYBRID):
        return cfg
    return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)


def config_for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    if shape.name == "long_500k":
        return long_context_variant(cfg)
    return cfg


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: InputShape) -> dict[str, jax.ShapeDtypeStruct]:
    """Training / prefill batch ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    specs: dict[str, Any] = {}
    if cfg.arch_type == ArchType.VLM:
        text = s - cfg.num_frontend_tokens
        specs["tokens"] = _sds((b, text), jnp.int32)
        specs["labels"] = _sds((b, text), jnp.int32)
        specs["patch_embeds"] = _sds((b, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    elif cfg.arch_type == ArchType.ENCDEC:
        specs["tokens"] = _sds((b, s), jnp.int32)
        specs["labels"] = _sds((b, s), jnp.int32)
        specs["src_embeds"] = _sds((b, Model.encoder_frames(s), cfg.d_model), jnp.bfloat16)
    else:
        specs["tokens"] = _sds((b, s), jnp.int32)
        specs["labels"] = _sds((b, s), jnp.int32)
    return specs


def decode_token_specs(cfg: ArchConfig, shape: InputShape) -> dict[str, Any]:
    b = shape.global_batch
    return {"tokens": _sds((b, 1), jnp.int32), "pos": _sds((), jnp.int32)}


def cache_specs(model: Model, shape: InputShape) -> PyTree:
    """ShapeDtypeStructs of the decode cache via eval_shape (no allocation)."""
    return jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len))


def params_specs(model: Model) -> PyTree:
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


# --------------------------------------------------------------------------
# sharding rules
# --------------------------------------------------------------------------

_LAST_DIM_MODEL = {"w_q", "w_k", "w_v", "w_gate", "w_up", "in_proj", "w_uq", "w_dq"}
_ROW_DIM_MODEL = {"w_o", "w_down", "out_proj"}
_REPLICATED = {
    "scale", "b_ih", "b_hh", "conv_w", "conv_b", "A_log", "D", "dt_bias",
    "router", "w_dkv", "w_kr", "b",
}


def _path_names(path) -> list[str]:
    names = []
    for p in path:
        key = getattr(p, "key", None)
        if key is not None:
            names.append(str(key))
    return names


def param_spec(path, leaf, cfg: ArchConfig, mesh) -> P:
    """PartitionSpec for one parameter leaf."""
    names = _path_names(path)
    name = names[-1] if names else ""
    ndim = len(leaf.shape)
    model_size = axis_size(mesh, "model")
    dsize = axis_size(mesh, "data") * axis_size(mesh, "pod")

    def spec_with(axis_idx: int, axis_val) -> P:
        spec = [None] * ndim
        spec[axis_idx] = axis_val
        return P(*spec)

    # --- MoE expert tensors: (..., E, D, F) / (..., E, F, D) --------------
    if cfg.moe is not None and ndim >= 3 and name in ("w_gate", "w_up", "w_down"):
        e_axis = ndim - 3
        if leaf.shape[e_axis] == cfg.moe.num_experts:
            if cfg.moe.expert_sharding == "tp":
                # shard each expert's ffn dim
                f_axis = ndim - 2 if name == "w_down" else ndim - 1
                if leaf.shape[f_axis] % model_size == 0:
                    return spec_with(f_axis, "model")
                return P()
            # 'ep': shard experts — over (data, model) when divisible, else model
            if leaf.shape[e_axis] % (dsize * model_size) == 0:
                return spec_with(e_axis, ("data", "model"))
            if leaf.shape[e_axis] % model_size == 0:
                return spec_with(e_axis, "model")
            return P()

    if name == "embed":
        return P("model", None) if leaf.shape[0] % model_size == 0 else P()
    if name == "head":
        return P(None, "model") if leaf.shape[1] % model_size == 0 else P()
    if name in ("w_uk", "w_uv"):  # (..., R, H, dh): shard heads
        h_axis = ndim - 2
        if leaf.shape[h_axis] % model_size == 0:
            return spec_with(h_axis, "model")
        return P()
    if name in _LAST_DIM_MODEL:
        if leaf.shape[-1] % model_size == 0:
            return spec_with(ndim - 1, "model")
        return P()
    if name in _ROW_DIM_MODEL:
        row_axis = ndim - 2
        if leaf.shape[row_axis] % model_size == 0:
            return spec_with(row_axis, "model")
        return P()
    if name in _REPLICATED or name == "proj":
        return P()
    return P()


def params_shardings(param_tree: PyTree, cfg: ArchConfig, mesh) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_tree)
    specs = [NamedSharding(mesh, param_spec(p, l, cfg, mesh)) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_shardings(batch_tree: PyTree, mesh) -> PyTree:
    daxes = data_axes(mesh)
    spec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    dsize = 1
    for a in daxes:
        dsize *= axis_size(mesh, a)

    def _shard(leaf):
        ndim = len(leaf.shape)
        if ndim == 0 or leaf.shape[0] % dsize != 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(spec, *([None] * (ndim - 1))))

    return jax.tree.map(_shard, batch_tree)


def cache_shardings(cache_tree: PyTree, cfg: ArchConfig, mesh, mode: str = "heads") -> PyTree:
    """Decode caches: batch dim over data axes; heads/latent over model
    when divisible.  Leaf layouts (with optional leading layer-stack dims):

      GQA k/v      (..., B, S, Hkv, hd)
      MLA c_kv     (..., B, S, R) / k_rope (..., B, S, dr)
      SSM state    (..., B, H, P, N) / conv (..., B, K, C)
      cross k/v    (..., B, T, Hkv, hd)
      slot_pos     (..., S)
    """
    daxes = data_axes(mesh)
    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    model_size = axis_size(mesh, "model")
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)

    def _spec(path, leaf) -> P:
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = leaf.shape
        ndim = len(shape)
        if name == "slot_pos":
            return P()
        # base ranks without layer stacking
        base_rank = {
            "k": 4, "v": 4, "cross_k": 4, "cross_v": 4,
            "c_kv": 3, "k_rope": 3,
            "ssm_state": 4, "conv_state": 3,
        }.get(name)
        if base_rank is None:
            return P()
        lead = ndim - base_rank               # layer-stack dims
        spec = [None] * ndim
        spec[lead] = dspec                    # batch dim
        if mode == "batch":
            # §Perf variant: shard ONLY the batch dim — avoids the
            # head/hd-axis reshard pathology in GQA decode at the cost of
            # replicated weights traffic
            if shape[lead] == 1:
                spec[lead] = None
            return P(*spec)
        if name in ("k", "v", "cross_k", "cross_v"):
            hkv_dim, hd_dim = lead + 2, lead + 3
            if shape[hkv_dim] % model_size == 0:
                spec[hkv_dim] = "model"
            elif shape[hd_dim] % model_size == 0:
                spec[hd_dim] = "model"
        elif name == "c_kv":
            if shape[lead + 2] % model_size == 0:
                spec[lead + 2] = "model"
        elif name == "ssm_state":
            if shape[lead + 1] % model_size == 0:
                spec[lead + 1] = "model"      # SSD heads
        elif name == "conv_state":
            if shape[lead + 2] % model_size == 0:
                spec[lead + 2] = "model"      # conv channels
        # batch=1 long-context: no data sharding possible on batch
        if shape[lead] == 1:
            spec[lead] = None
        return P(*spec)

    specs = [NamedSharding(mesh, _spec(p, l)) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)
