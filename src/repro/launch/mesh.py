"""Production mesh definitions (TPU v5e pods; host-platform stand-ins here).

Defined as FUNCTIONS so importing this module never touches jax device
state — ``dryrun.py`` must set ``XLA_FLAGS`` before anything initializes
the backend.

Axis semantics (see repro.distribution.sharding):
  single-pod : (16, 16)      -> ("data", "model")        = 256 chips
  multi-pod  : (2, 16, 16)   -> ("pod", "data", "model") = 512 chips

In the federated mapping, the ``pod`` axis is the hospital-silo axis:
FedAvg aggregation across silos is an all-reduce over ``pod``.
"""

from __future__ import annotations

from repro.distribution.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke tests of the sharded paths."""
    return make_mesh((1, 1), ("data", "model"))


def make_data_mesh(num_devices: int | None = None):
    """1-D ``("data",)`` mesh over the local devices — the federated client
    axis.  On CPU, force multiple devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    initializes (CI's multi-device matrix leg does exactly this)."""
    import jax

    return make_mesh((num_devices or jax.device_count(),), ("data",))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry the batch: ('pod','data') on multi-pod, ('data',) else."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
