"""Roofline-term derivation from compiled dry-run artifacts.

Methodology (calibrated on this container's XLA):

  * ``compiled.cost_analysis()`` reports **per-device** numbers and counts
    every ``while`` (scan) body **once**, not x trip-count — verified with a
    controlled probe.  Raw cost_analysis therefore underestimates looped
    programs (all our stacks scan over layers) by orders of magnitude.
  * We instead parse the post-SPMD compiled HLO text with a
    **trip-count-aware analyzer**: while-loop trip counts come from the
    ``constant(N)`` in each loop's condition computation; per-instruction
    FLOPs come from ``dot`` shapes (2 x numel(out) x contracted size);
    HBM traffic from operand+output bytes of every top-level instruction
    (post-fusion, this approximates actual HBM round-trips); collective
    bytes from the five collective op kinds.  Everything is multiplied up
    through nested loops, then scaled by the device count to global terms.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

# --- TPU v5e constants -----------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# "%name = <shape-or-tuple> <op>(" — result name, shape spec, op name, args.
# Tuple result specs may contain '/*index=N*/' comments (with '=') but never
# parentheses, so "[^()]*" is the safe tuple matcher.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+([a-z0-9\-]+)\((.*)$"
)
# computation headers sit at column 0 and end with '{'; params may nest parens
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_ATTR_RE = re.compile(r"(?:to_apply|calls|called_computations?)=\{?%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes_numel(spec: str) -> tuple[int, int]:
    """(bytes, numel-of-first-shape) over all shapes in a spec string."""
    total = 0
    first_numel = 0
    for i, (dtype, dims) in enumerate(_SHAPE_RE.findall(spec)):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
        if i == 0:
            first_numel = n
    return total, first_numel


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result_spec: str
    operands: list
    attrs_text: str
    raw: str = ""


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: list
    shapes: dict  # result name -> shape spec
    is_entry: bool = False


def _split_args(rest: str) -> tuple[str, str]:
    """Split 'a, %b, ...), attr=..., ...' into (operand region, attrs)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1 :]
    return rest, ""


def _parse_computations(hlo_text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    current: _Computation | None = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            header = _COMP_HEADER_RE.match(line)
            if header and "=" not in line.split("(")[0]:
                current = _Computation(
                    name=header.group(1), instrs=[], shapes={},
                    is_entry=line.lstrip().startswith("ENTRY"),
                )
                comps[current.name] = current
                continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, spec, op, rest = m.groups()
            operand_region, attrs = _split_args(rest)
            operands = _NAME_RE.findall(operand_region)
            instr = _Instr(
                name=name, op=op, result_spec=spec, operands=operands,
                attrs_text=attrs, raw=line,
            )
            current.instrs.append(instr)
            current.shapes[name] = spec
    return comps


# ops whose traffic we do not attribute (control flow / zero-cost views)
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call",
}


class HloAnalyzer:
    """Trip-count-aware FLOPs / bytes / collective-bytes over a compiled
    HLO module (per-device numbers; multiply by chips for global)."""

    def __init__(self, hlo_text: str) -> None:
        self.comps = _parse_computations(hlo_text)
        self._memo: dict[str, dict[str, float]] = {}
        self.entry = next((c.name for c in self.comps.values() if c.is_entry), None)

    def _trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for instr in comp.instrs:
            for c in _CONST_RE.findall(instr.raw):
                best = max(best, int(c))
        return best

    def _dot_flops(self, comp: _Computation, instr: _Instr) -> float:
        _, out_numel = _shape_bytes_numel(instr.result_spec)
        contract = _CONTRACT_RE.search(instr.attrs_text)
        if not instr.operands or contract is None:
            return 0.0
        lhs_spec = comp.shapes.get(instr.operands[0], "")
        lhs_shapes = _SHAPE_RE.findall(lhs_spec)
        if not lhs_shapes:
            return 0.0
        lhs_dims = lhs_shapes[0][1].split(",") if lhs_shapes[0][1] else []
        csize = 1
        if contract.group(1):
            for idx in contract.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    csize *= int(lhs_dims[i])
        return 2.0 * out_numel * csize

    _VIEW_OPS = frozenset({"bitcast", "reshape", "copy", "transpose", "convert"})

    def _sliced_param_bytes(self, called: str) -> dict[int, float]:
        """For a fused computation: parameters consumed ONLY through
        view-op chains ending in dynamic-slice -> the sliced bytes actually
        touched.  XLA scan bodies carry full stacked (layers, ...) buffers
        into fusions that internally slice one layer out; charging the full
        buffer per iteration overcounts HBM traffic by the layer count."""
        comp = self.comps.get(called)
        if comp is None:
            return {}
        param_index: dict[str, int] = {}
        for instr in comp.instrs:
            if instr.op == "parameter":
                m = re.match(r"\s*(\d+)", instr.raw.split("parameter(")[-1])
                if m:
                    param_index[instr.name] = int(m.group(1))
        if not param_index:
            return {}
        consumers: dict[str, list] = {}
        for instr in comp.instrs:
            for op_name in instr.operands:
                consumers.setdefault(op_name, []).append(instr)

        def trace(name: str, depth: int = 0) -> float | None:
            """Bytes actually read from ``name``; None = full read."""
            if depth > 8:
                return None
            total = 0.0
            for instr in consumers.get(name, []):
                if instr.op == "dynamic-slice" and instr.operands and instr.operands[0] == name:
                    b, _ = _shape_bytes_numel(instr.result_spec)
                    total += b
                elif instr.op == "dynamic-update-slice" and instr.operands and instr.operands[0] == name:
                    # in-place update of the buffer: reads only the slice RMW,
                    # charged at the DUS itself
                    continue
                elif instr.op in self._VIEW_OPS:
                    sub = trace(instr.name, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        out: dict[int, float] = {}
        for pname, idx in param_index.items():
            b = trace(pname)
            if b is not None and consumers.get(pname):
                out[idx] = b
        return out

    def _dus_root_update_bytes(self, called: str) -> float | None:
        """If the fused computation's ROOT is a dynamic-update-slice, return
        the update operand's bytes (the actual write size)."""
        comp = self.comps.get(called)
        if comp is None or not comp.instrs:
            return None
        root = comp.instrs[-1]
        # peel view ops (bitcast/reshape/...) between the root and the DUS
        seen = 0
        while root.op in self._VIEW_OPS and root.operands and seen < 8:
            nxt = next((i for i in comp.instrs if i.name == root.operands[0]), None)
            if nxt is None:
                break
            root = nxt
            seen += 1
        if root.op != "dynamic-update-slice" or len(root.operands) < 2:
            return None
        spec = comp.shapes.get(root.operands[1])
        if spec is None:
            return None
        b, _ = _shape_bytes_numel(spec)
        return float(b)

    def _instr_bytes(self, comp: _Computation, instr: _Instr) -> float:
        result_bytes, _ = _shape_bytes_numel(instr.result_spec)
        if instr.op == "dynamic-update-slice":
            # writes only the update slice (read-modify-write of the slice)
            if len(instr.operands) >= 2:
                spec = comp.shapes.get(instr.operands[1])
                if spec is not None:
                    b, _ = _shape_bytes_numel(spec)
                    return 2.0 * b
            return float(result_bytes)
        if instr.op == "dynamic-slice":
            return 2.0 * result_bytes   # read slice + write result
        sliced: dict[int, float] = {}
        if instr.op == "fusion":
            m = _CALL_ATTR_RE.search(instr.attrs_text)
            if m:
                called = m.group(1)
                sliced = self._sliced_param_bytes(called)
                dus_update = self._dus_root_update_bytes(called)
                if dus_update is not None:
                    # fusion root is a dynamic-update-slice into a stacked
                    # buffer: the write is the update slice, not the buffer
                    result_bytes = 2.0 * dus_update
        total = float(result_bytes)
        for i, op_name in enumerate(instr.operands):
            if i in sliced:
                total += sliced[i]
                continue
            spec = comp.shapes.get(op_name)
            if spec is not None:
                b, _ = _shape_bytes_numel(spec)
                total += b
        return total

    def _fusion_flops(self, name: str, depth: int = 0) -> float:
        """Dot FLOPs inside a fused computation (recursing into nested calls)."""
        comp = self.comps.get(name)
        if comp is None or depth > 50:
            return 0.0
        flops = 0.0
        for instr in comp.instrs:
            if instr.op == "dot":
                flops += self._dot_flops(comp, instr)
            elif instr.op in ("fusion", "call", "conditional"):
                m = _CALL_ATTR_RE.search(instr.attrs_text)
                if m:
                    flops += self._fusion_flops(m.group(1), depth + 1)
        return flops

    def _analyze(self, name: str, depth: int = 0) -> dict[str, float]:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        totals: dict[str, float] = {"flops": 0.0, "bytes": 0.0}
        for k in COLLECTIVE_KINDS:
            totals[k] = 0.0
            totals[f"{k}-count"] = 0.0
        if comp is None or depth > 50:
            return totals
        self._memo[name] = totals  # pre-insert to break cycles
        for instr in comp.instrs:
            if instr.op == "while":
                attrs = _WHILE_ATTR_RE.search(instr.attrs_text)
                if attrs:
                    cond, body = attrs.group(1), attrs.group(2)
                    trip = self._trip_count(cond)
                    sub = self._analyze(body, depth + 1)
                    for k, v in sub.items():
                        totals[k] += trip * v
                continue
            if instr.op in ("conditional", "call"):
                m = _CALL_ATTR_RE.search(instr.attrs_text)
                if m:
                    sub = self._analyze(m.group(1), depth + 1)
                    for k, v in sub.items():
                        totals[k] += v
                continue
            if instr.op == "dot":
                totals["flops"] += self._dot_flops(comp, instr)
            if instr.op == "fusion":
                # XLA (output-)fusions wrap dots inside called computations;
                # count their FLOPs (HBM bytes stay at the fusion boundary).
                m = _CALL_ATTR_RE.search(instr.attrs_text)
                if m:
                    totals["flops"] += self._fusion_flops(m.group(1), depth + 1)
            kind = next((k for k in COLLECTIVE_KINDS if instr.op.startswith(k)), None)
            if kind is not None:
                b, _ = _shape_bytes_numel(instr.result_spec)
                totals[kind] += b
                totals[f"{kind}-count"] += 1
            if instr.op not in _SKIP_BYTES_OPS:
                totals["bytes"] += self._instr_bytes(comp, instr)
        return totals

    def analyze(self) -> dict[str, float]:
        if self.entry is None:
            return {"flops": 0.0, "bytes": 0.0}
        return dict(self._analyze(self.entry))


def analyze_hlo(hlo_text: str) -> dict[str, float]:
    return HloAnalyzer(hlo_text).analyze()


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Trip-count-aware collective bytes per kind (per-device)."""
    out = analyze_hlo(hlo_text)
    return {k: out.get(k, 0.0) for k in COLLECTIVE_KINDS} | {
        f"{k}-count": out.get(f"{k}-count", 0.0) for k in COLLECTIVE_KINDS
    }


@dataclasses.dataclass
class RooflineTerms:
    """Per-step roofline terms in seconds, for a given chip count.

    ``hlo_flops`` / ``hlo_bytes`` / ``coll_bytes`` are GLOBAL (the analyzer's
    per-device numbers x chips).
    """

    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    chips: int
    model_flops: float | None = None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s, "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float | None:
        if self.model_flops is None or self.hlo_flops == 0:
            return None
        return self.model_flops / self.hlo_flops

    def as_dict(self) -> dict[str, Any]:
        return {
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def cost_summary(compiled) -> dict[str, float]:
    """Raw compiled.cost_analysis() numbers (per-device, scan-body-once —
    kept for reference alongside the trip-aware analyzer)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes": byts}


def model_flops_estimate(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference) with N = active params.

    D = processed tokens for the step: batch*seq for train/prefill,
    batch*1 for decode.
    """
    from repro.models.zoo import count_params_config

    n_active = count_params_config(cfg, active_only=True)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens


def live_buffer_stats() -> dict[str, int]:
    """Count and bytes of every live (undeleted) jax array in the process.

    The donated cohort round is validated against this: donation must make
    the round's peak live footprint strictly smaller than the plain path
    (``repro.federated.cohort.CohortTrainer.last_round_stats``), which is
    what lets the 189-client paper federation fit the CI container.
    """
    import jax

    count = 0
    total = 0
    for a in jax.live_arrays():
        count += 1
        total += int(a.size) * a.dtype.itemsize
    return {"count": count, "bytes": total}


def memory_summary(compiled) -> dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        if hasattr(ma, attr):
            out[attr] = float(getattr(ma, attr))
    return out
