from repro.checkpoint.store import (
    federation_snapshot_state,
    has_federation_snapshot,
    load_federation_snapshot,
    load_pytree,
    restore_server_state,
    save_federation_snapshot,
    save_pytree,
    save_server_state,
)

__all__ = [
    "load_pytree",
    "save_pytree",
    "save_server_state",
    "restore_server_state",
    "save_federation_snapshot",
    "load_federation_snapshot",
    "federation_snapshot_state",
    "has_federation_snapshot",
]
