from repro.checkpoint.store import load_pytree, restore_server_state, save_pytree, save_server_state

__all__ = ["load_pytree", "save_pytree", "save_server_state", "restore_server_state"]
