"""Pytree checkpointing: npz payload + JSON manifest of the treedef.

No orbax offline; this covers what the framework needs — atomic save/restore
of parameter/optimizer pytrees and full federation-state snapshots — with
structure validation on load.

Two storage formats live here:

* **Pytree checkpoints** (``save_pytree`` / ``load_pytree``): one pytree of
  arrays plus a small JSON metadata dict.  Used for model params and the
  legacy server round state.
* **Federation snapshots** (``save_federation_snapshot`` /
  ``load_federation_snapshot``): the resumable state of a live federation
  run at a round/flush boundary — *several* named pytrees (the global
  params plus every in-flight update's params/anchor), named standalone
  arrays (PRNG key data, buffered losses), and a JSON ``state`` dict
  carrying everything scalar: round index, numpy bit-generator states, the
  async runtime's virtual-clock state and pending-event list, and the
  round-record history.  The snapshot dataclasses that produce/consume
  these live with their runtimes (``repro.federated.api.FederationSnapshot``
  and ``repro.federated.runtime.async_federation.AsyncFederationSnapshot``);
  this module only knows how to persist them.

Both formats write atomically (payload and manifest land via ``os.replace``)
so a writer killed mid-save can never leave a half-snapshot that loads —
the property the control plane's kill-and-resume contract rests on.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"
_PAYLOAD = "arrays.npz"
_SNAP_MANIFEST = "snapshot.json"
_SNAP_PAYLOAD = "snapshot.npz"


def _flatten_with_paths(tree: PyTree) -> list[tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def _atomic_write_npz(directory: str, filename: str, payload: dict) -> None:
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz.tmp")
    with os.fdopen(fd, "wb") as f:  # file handle: savez must not mangle the name
        np.savez(f, **payload)
    os.replace(tmp, os.path.join(directory, filename))


def _atomic_write_json(directory: str, filename: str, obj: dict) -> None:
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".json.tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, os.path.join(directory, filename))


def save_pytree(directory: str, tree: PyTree, metadata: dict | None = None) -> None:
    """Atomic directory save: write to tmp, then rename files into place."""
    os.makedirs(directory, exist_ok=True)
    entries = _flatten_with_paths(tree)
    payload = {f"a{i}": arr for i, (_, arr) in enumerate(entries)}
    manifest = {
        "keys": [k for k, _ in entries],
        "dtypes": [str(a.dtype) for _, a in entries],
        "shapes": [list(a.shape) for _, a in entries],
        "metadata": metadata or {},
    }
    _atomic_write_npz(directory, _PAYLOAD, payload)
    _atomic_write_json(directory, _MANIFEST, manifest)


def load_pytree(directory: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (validates key alignment)."""
    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, _PAYLOAD))
    arrays = [data[f"a{i}"] for i in range(len(manifest["keys"]))]

    entries = _flatten_with_paths(like)
    saved_keys = manifest["keys"]
    like_keys = [k for k, _ in entries]
    if saved_keys != like_keys:
        missing = set(like_keys) - set(saved_keys)
        extra = set(saved_keys) - set(like_keys)
        raise ValueError(f"checkpoint structure mismatch; missing={missing} extra={extra}")
    for (key, ref), arr in zip(entries, arrays):
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {ref.shape}")
    leaves = [a.astype(r.dtype) for a, (_, r) in zip(arrays, entries)]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def checkpoint_metadata(directory: str) -> dict:
    with open(os.path.join(directory, _MANIFEST)) as f:
        return json.load(f)["metadata"]


def save_server_state(directory: str, params: PyTree, round_index: int, history: list) -> None:
    save_pytree(
        directory,
        params,
        metadata={
            "round_index": round_index,
            "history": [
                {"round": r.round_index, "loss": r.mean_local_loss, "participants": r.participant_ids}
                for r in history
            ],
        },
    )


def restore_server_state(directory: str, like_params: PyTree) -> tuple[PyTree, dict]:
    params = load_pytree(directory, like_params)
    return params, checkpoint_metadata(directory)


# ---------------------------------------------------------------------------
# federation-state snapshots
# ---------------------------------------------------------------------------


def save_federation_snapshot(
    directory: str,
    *,
    trees: dict[str, PyTree],
    arrays: dict[str, np.ndarray] | None = None,
    state: dict | None = None,
) -> None:
    """Atomically persist one federation-state snapshot.

    ``trees`` maps names to pytrees that all share the structure of the
    run's parameter pytree — ``"params"`` plus, for async runs, each
    pending/buffered update's ``params``/``anchor``.  ``arrays`` maps names
    to standalone numpy arrays (jax PRNG key data, per-update losses and
    client ids).  ``state`` must be JSON-serializable; it carries the
    scalar run state (round index, numpy bit-generator state dicts, the
    virtual clock, the record history) and is returned verbatim by
    :func:`federation_snapshot_state` without touching the array payload.

    Each call overwrites the previous snapshot in ``directory``; payload
    first, manifest second, both via rename, so readers only ever see a
    manifest whose payload is complete.
    """
    os.makedirs(directory, exist_ok=True)
    arrays = arrays or {}
    entries: list[tuple[str, np.ndarray]] = []
    tree_manifest: dict[str, list[str]] = {}
    for name in sorted(trees):
        flat = _flatten_with_paths(trees[name])
        tree_manifest[name] = [k for k, _ in flat]
        entries.extend((f"tree:{name}:{k}", arr) for k, arr in flat)
    for name in sorted(arrays):
        entries.append((f"array:{name}", np.asarray(arrays[name])))
    payload = {f"a{i}": arr for i, (_, arr) in enumerate(entries)}
    manifest = {
        "keys": [k for k, _ in entries],
        "dtypes": [str(a.dtype) for _, a in entries],
        "shapes": [list(a.shape) for _, a in entries],
        "trees": tree_manifest,
        "arrays": sorted(arrays),
        "state": state or {},
    }
    _atomic_write_npz(directory, _SNAP_PAYLOAD, payload)
    _atomic_write_json(directory, _SNAP_MANIFEST, manifest)


def has_federation_snapshot(directory: str) -> bool:
    return os.path.exists(os.path.join(directory, _SNAP_MANIFEST)) and os.path.exists(
        os.path.join(directory, _SNAP_PAYLOAD)
    )


def federation_snapshot_state(directory: str) -> dict:
    """The snapshot's scalar ``state`` dict, without loading any arrays."""
    with open(os.path.join(directory, _SNAP_MANIFEST)) as f:
        return json.load(f)["state"]


def load_federation_snapshot(
    directory: str, like_params: PyTree
) -> tuple[dict[str, PyTree], dict[str, np.ndarray], dict]:
    """Restore ``(trees, arrays, state)`` as saved by the snapshot writer.

    Every named tree is validated against and unflattened into the
    structure of ``like_params`` (the model built from the job spec), so a
    spec/model mismatch fails loudly here rather than as silent shape
    garbage mid-run.  Arrays come back with their stored dtypes.
    """
    with open(os.path.join(directory, _SNAP_MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, _SNAP_PAYLOAD))
    by_key = {k: data[f"a{i}"] for i, k in enumerate(manifest["keys"])}

    like_entries = _flatten_with_paths(like_params)
    like_keys = [k for k, _ in like_entries]
    treedef = jax.tree_util.tree_structure(like_params)
    trees: dict[str, PyTree] = {}
    for name, keys in manifest["trees"].items():
        if keys != like_keys:
            missing = set(like_keys) - set(keys)
            extra = set(keys) - set(like_keys)
            raise ValueError(
                f"snapshot tree {name!r} does not match the model structure; "
                f"missing={missing} extra={extra}"
            )
        leaves = []
        for key, ref in like_entries:
            arr = by_key[f"tree:{name}:{key}"]
            if list(arr.shape) != list(ref.shape):
                raise ValueError(
                    f"snapshot tree {name!r} shape mismatch at {key}: "
                    f"{arr.shape} vs {ref.shape}"
                )
            leaves.append(arr.astype(ref.dtype))
        trees[name] = jax.tree_util.tree_unflatten(treedef, leaves)
    arrays = {name: by_key[f"array:{name}"] for name in manifest["arrays"]}
    return trees, arrays, manifest["state"]
