"""Pytree checkpointing: npz payload + msgpack manifest of the treedef.

No orbax offline; this covers what the framework needs — atomic save/restore
of parameter/optimizer pytrees and the federated server's round state — with
structure validation on load.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"
_PAYLOAD = "arrays.npz"


def _flatten_with_paths(tree: PyTree) -> list[tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save_pytree(directory: str, tree: PyTree, metadata: dict | None = None) -> None:
    """Atomic directory save: write to tmp, then rename files into place."""
    os.makedirs(directory, exist_ok=True)
    entries = _flatten_with_paths(tree)
    payload = {f"a{i}": arr for i, (_, arr) in enumerate(entries)}
    manifest = {
        "keys": [k for k, _ in entries],
        "dtypes": [str(a.dtype) for _, a in entries],
        "shapes": [list(a.shape) for _, a in entries],
        "metadata": metadata or {},
    }
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz.tmp")
    with os.fdopen(fd, "wb") as f:  # file handle: savez must not mangle the name
        np.savez(f, **payload)
    os.replace(tmp, os.path.join(directory, _PAYLOAD))
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".json.tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(directory, _MANIFEST))


def load_pytree(directory: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (validates key alignment)."""
    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, _PAYLOAD))
    arrays = [data[f"a{i}"] for i in range(len(manifest["keys"]))]

    entries = _flatten_with_paths(like)
    saved_keys = manifest["keys"]
    like_keys = [k for k, _ in entries]
    if saved_keys != like_keys:
        missing = set(like_keys) - set(saved_keys)
        extra = set(saved_keys) - set(like_keys)
        raise ValueError(f"checkpoint structure mismatch; missing={missing} extra={extra}")
    for (key, ref), arr in zip(entries, arrays):
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {ref.shape}")
    leaves = [a.astype(r.dtype) for a, (_, r) in zip(arrays, entries)]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def checkpoint_metadata(directory: str) -> dict:
    with open(os.path.join(directory, _MANIFEST)) as f:
        return json.load(f)["metadata"]


def save_server_state(directory: str, params: PyTree, round_index: int, history: list) -> None:
    save_pytree(
        directory,
        params,
        metadata={
            "round_index": round_index,
            "history": [
                {"round": r.round_index, "loss": r.mean_local_loss, "participants": r.participant_ids}
                for r in history
            ],
        },
    )


def restore_server_state(directory: str, like_params: PyTree) -> tuple[PyTree, dict]:
    params = load_pytree(directory, like_params)
    return params, checkpoint_metadata(directory)
