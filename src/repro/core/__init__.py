"""Core contribution of the paper: pre-federation client recruitment."""

from repro.core.histogram import (
    LOS_BIN_EDGES,
    NUM_LOS_BINS,
    l1_divergence,
    normalize,
    target_histogram,
    token_histogram,
)
from repro.core.recruitment import (
    BALANCED,
    DATA_GREEDY,
    QUALITY_GREEDY,
    RECRUITMENT_PRESETS,
    ClientStats,
    RecruitmentConfig,
    RecruitmentResult,
    preset_recruitment,
    recruit,
    recruitment_curve,
    representativeness,
)

__all__ = [
    "LOS_BIN_EDGES",
    "NUM_LOS_BINS",
    "l1_divergence",
    "normalize",
    "target_histogram",
    "token_histogram",
    "BALANCED",
    "DATA_GREEDY",
    "QUALITY_GREEDY",
    "RECRUITMENT_PRESETS",
    "ClientStats",
    "RecruitmentConfig",
    "RecruitmentResult",
    "preset_recruitment",
    "recruit",
    "recruitment_curve",
    "representativeness",
]
