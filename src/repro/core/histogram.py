"""Target-distribution histograms used by client recruitment.

The paper bins the continuous LoS target (fractional days) into ten buckets::

    [0,1), [1,2), ..., [7,8), [8,14), [14, +inf)

which converts the regression target into "class counts" over which the
distribution divergence in eq. (4) is computed.  For language-model targets
(the assigned LM architectures) we bin token ids into a fixed number of
equal-width vocabulary buckets — the recruitment math is identical.
"""

from __future__ import annotations

import numpy as np

# Paper's LoS bin edges (days).  Ten bins.
LOS_BIN_EDGES: tuple[float, ...] = (0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 14.0, np.inf)

NUM_LOS_BINS = len(LOS_BIN_EDGES) - 1


def target_histogram(y: np.ndarray, edges: tuple[float, ...] = LOS_BIN_EDGES) -> np.ndarray:
    """Counts of target values per bin.  ``y`` is 1-D, continuous, >= 0."""
    y = np.asarray(y, dtype=np.float64).ravel()
    counts, _ = np.histogram(y, bins=np.asarray(edges))
    return counts.astype(np.int64)


def token_histogram(tokens: np.ndarray, vocab_size: int, num_bins: int = 10) -> np.ndarray:
    """Equal-width vocabulary-bucket histogram for LM targets."""
    tokens = np.asarray(tokens).ravel()
    edges = np.linspace(0, vocab_size, num_bins + 1)
    counts, _ = np.histogram(tokens, bins=edges)
    return counts.astype(np.int64)


def normalize(counts: np.ndarray) -> np.ndarray:
    """Counts -> probability vector.  All-zero counts normalize to zeros."""
    counts = np.asarray(counts, dtype=np.float64)
    total = counts.sum()
    if total <= 0:
        return np.zeros_like(counts)
    return counts / total


def l1_divergence(p_counts: np.ndarray, q_counts: np.ndarray) -> float:
    """Sum of absolute differences between two *normalized* histograms.

    This is the paper's ``| P_go/n_g - P_co/n_c |`` term (twice the total
    variation distance).
    """
    return float(np.abs(normalize(p_counts) - normalize(q_counts)).sum())
