"""Client recruitment (the paper's core contribution).

Prior to forming a federation, every candidate client ``c`` reports only the
tuple ``(P_co, n_c)`` — its local *target histogram* and sample size.  The
server computes per-client representativeness (paper eq. 4)::

    nu_c = gamma_dv * sum_bins | P_go/n_g - P_co/n_c |  +  gamma_sa * n_c^-0.5

(lower = more representative) and recruits greedily in ascending-nu order
until the cumulative representativeness crosses ``iota = gamma_th * nu_g``
with ``nu_g = sum_c nu_c`` (paper eq. 5).

Nothing here touches model parameters or raw features — recruitment is
model-agnostic by construction, which is why it composes with every
architecture in the zoo.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.histogram import normalize


@dataclasses.dataclass(frozen=True)
class ClientStats:
    """What a candidate client discloses to the recruitment server."""

    client_id: int
    counts: np.ndarray  # per-bin target counts, shape (num_bins,)
    n: int              # local sample size

    def __post_init__(self) -> None:
        if self.n <= 0:
            raise ValueError(f"client {self.client_id}: sample size must be positive, got {self.n}")
        if np.any(np.asarray(self.counts) < 0):
            raise ValueError(f"client {self.client_id}: negative histogram counts")


@dataclasses.dataclass(frozen=True)
class RecruitmentConfig:
    gamma_dv: float = 0.5   # weight of target-distribution divergence
    gamma_sa: float = 0.5   # weight of the n_c^-0.5 sample-size term
    gamma_th: float = 0.1   # fraction of global representativeness to cover

    def __post_init__(self) -> None:
        if not (0.0 < self.gamma_th <= 1.0):
            raise ValueError(f"gamma_th must be in (0, 1], got {self.gamma_th}")
        if self.gamma_dv < 0 or self.gamma_sa < 0:
            raise ValueError("gamma weights must be non-negative")


# Paper section 6.2 presets.
BALANCED = RecruitmentConfig(gamma_dv=0.5, gamma_sa=0.5, gamma_th=0.1)
QUALITY_GREEDY = RecruitmentConfig(gamma_dv=1.0, gamma_sa=0.01, gamma_th=0.1)
DATA_GREEDY = RecruitmentConfig(gamma_dv=0.01, gamma_sa=1.0, gamma_th=0.1)

# Named presets, addressable from policy spec strings ("nu-greedy:balanced");
# the registry the Federation facade's recruitment stage resolves against.
RECRUITMENT_PRESETS: dict[str, RecruitmentConfig] = {
    "balanced": BALANCED,
    "quality-greedy": QUALITY_GREEDY,
    "data-greedy": DATA_GREEDY,
}


def preset_recruitment(name: str) -> RecruitmentConfig:
    """Look up a section-6.2 preset by name (``"balanced"`` etc.)."""
    if name not in RECRUITMENT_PRESETS:
        known = ", ".join(sorted(RECRUITMENT_PRESETS))
        raise ValueError(f"unknown recruitment preset {name!r}; choose from: {known}")
    return RECRUITMENT_PRESETS[name]


@dataclasses.dataclass(frozen=True)
class RecruitmentResult:
    recruited_ids: np.ndarray      # client ids, ascending-nu order
    nu: np.ndarray                 # per-client nu, aligned with ``client_ids``
    client_ids: np.ndarray         # all candidate ids (input order)
    nu_g: float                    # global representativeness (sum of nu)
    iota: float                    # recruitment threshold gamma_th * nu_g

    @property
    def num_recruited(self) -> int:
        return int(self.recruited_ids.size)

    def is_recruited(self, client_id: int) -> bool:
        return bool(np.isin(client_id, self.recruited_ids))


def representativeness(
    stats: Sequence[ClientStats],
    config: RecruitmentConfig,
) -> np.ndarray:
    """Per-client nu_c (paper eq. 4), aligned with ``stats`` order."""
    if not stats:
        raise ValueError("no candidate clients")
    counts = np.stack([np.asarray(s.counts, dtype=np.float64) for s in stats])
    n = np.array([s.n for s in stats], dtype=np.float64)
    # P_go = sum_c P_co (counts); P_go/n_g is the normalized global histogram.
    global_counts = counts.sum(axis=0)
    p_global = normalize(global_counts)
    p_local = counts / np.maximum(n[:, None], 1.0)
    divergence = np.abs(p_global[None, :] - p_local).sum(axis=1)
    return config.gamma_dv * divergence + config.gamma_sa * n ** -0.5


def recruit(
    stats: Sequence[ClientStats],
    config: RecruitmentConfig = BALANCED,
) -> RecruitmentResult:
    """Greedy threshold recruitment (paper section 4.2).

    Sort nu ascending (most representative first), accumulate, and recruit
    every client up to and including the one at which the running sum crosses
    ``iota = gamma_th * nu_g``.  ``gamma_th = 1`` recruits everyone.
    """
    nu = representativeness(stats, config)
    client_ids = np.array([s.client_id for s in stats], dtype=np.int64)
    order = np.argsort(nu, kind="stable")
    nu_sorted = nu[order]
    nu_g = float(nu.sum())
    iota = config.gamma_th * nu_g
    cumulative = np.cumsum(nu_sorted)
    # First index where the running sum reaches the threshold; recruit through it.
    crossed = np.searchsorted(cumulative, iota, side="left")
    cutoff = min(int(crossed) + 1, len(stats))
    recruited = client_ids[order][:cutoff]
    return RecruitmentResult(
        recruited_ids=recruited,
        nu=nu,
        client_ids=client_ids,
        nu_g=nu_g,
        iota=iota,
    )


def recruitment_curve(
    stats: Sequence[ClientStats],
    config: RecruitmentConfig,
    gamma_ths: Sequence[float],
) -> list[tuple[float, int]]:
    """(gamma_th, num_recruited) pairs for the paper's Fig. 2 sweep."""
    out = []
    for g in gamma_ths:
        cfg = dataclasses.replace(config, gamma_th=g)
        out.append((float(g), recruit(stats, cfg).num_recruited))
    return out
