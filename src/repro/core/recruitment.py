"""Client recruitment (the paper's core contribution).

Prior to forming a federation, every candidate client ``c`` reports only the
tuple ``(P_co, n_c)`` — its local *target histogram* and sample size.  The
server computes per-client representativeness (paper eq. 4)::

    nu_c = gamma_dv * sum_bins | P_go/n_g - P_co/n_c |  +  gamma_sa * n_c^-0.5

(lower = more representative) and recruits greedily in ascending-nu order
until the cumulative representativeness crosses ``iota = gamma_th * nu_g``
with ``nu_g = sum_c nu_c`` (paper eq. 5).

Nothing here touches model parameters or raw features — recruitment is
model-agnostic by construction, which is why it composes with every
architecture in the zoo.

Two evaluation paths share the same scoring math:

- ``recruit`` materializes every disclosure and argsorts the population —
  the exact oracle, fine through ~10^3 clients (the paper's 189).
- ``recruit_streaming`` / ``StreamingRecruiter`` consume the disclosure
  stream in one bounded-memory pass for cross-device populations
  (10^4–10^6): a running global histogram, a bounded candidate pool of the
  lowest-nu clients, and a weighted nu-quantile sketch for the threshold.
  Populations that fit the exact buffer delegate to ``recruit`` verbatim,
  so the two paths agree exactly at paper scale.
"""

from __future__ import annotations

import dataclasses
import heapq
import warnings
from functools import cached_property
from typing import Iterable, Iterator, Sequence

import numpy as np

from repro.core.histogram import normalize


@dataclasses.dataclass(frozen=True)
class ClientStats:
    """What a candidate client discloses to the recruitment server."""

    client_id: int
    counts: np.ndarray  # per-bin target counts, shape (num_bins,)
    n: int              # local sample size

    def __post_init__(self) -> None:
        if self.n <= 0:
            raise ValueError(f"client {self.client_id}: sample size must be positive, got {self.n}")
        counts = np.asarray(self.counts)
        if np.any(counts < 0):
            raise ValueError(f"client {self.client_id}: negative histogram counts")
        mass = float(counts.sum())
        # A stay can lack an LoS label (mass < n) but the histogram can never
        # count more stays than the client reports having.
        if mass > self.n + 1e-9:
            raise ValueError(
                f"client {self.client_id}: histogram mass {mass} exceeds reported n={self.n}"
            )


@dataclasses.dataclass(frozen=True)
class RecruitmentConfig:
    gamma_dv: float = 0.5   # weight of target-distribution divergence
    gamma_sa: float = 0.5   # weight of the n_c^-0.5 sample-size term
    gamma_th: float = 0.1   # fraction of global representativeness to cover

    def __post_init__(self) -> None:
        if not (0.0 < self.gamma_th <= 1.0):
            raise ValueError(f"gamma_th must be in (0, 1], got {self.gamma_th}")
        if self.gamma_dv < 0 or self.gamma_sa < 0:
            raise ValueError("gamma weights must be non-negative")


# Paper section 6.2 presets.
BALANCED = RecruitmentConfig(gamma_dv=0.5, gamma_sa=0.5, gamma_th=0.1)
QUALITY_GREEDY = RecruitmentConfig(gamma_dv=1.0, gamma_sa=0.01, gamma_th=0.1)
DATA_GREEDY = RecruitmentConfig(gamma_dv=0.01, gamma_sa=1.0, gamma_th=0.1)

# Named presets, addressable from policy spec strings ("nu-greedy:balanced");
# the registry the Federation facade's recruitment stage resolves against.
RECRUITMENT_PRESETS: dict[str, RecruitmentConfig] = {
    "balanced": BALANCED,
    "quality-greedy": QUALITY_GREEDY,
    "data-greedy": DATA_GREEDY,
}


def preset_recruitment(name: str) -> RecruitmentConfig:
    """Look up a section-6.2 preset by name (``"balanced"`` etc.)."""
    if name not in RECRUITMENT_PRESETS:
        known = ", ".join(sorted(RECRUITMENT_PRESETS))
        raise ValueError(f"unknown recruitment preset {name!r}; choose from: {known}")
    return RECRUITMENT_PRESETS[name]


@dataclasses.dataclass(frozen=True)
class RecruitmentResult:
    recruited_ids: np.ndarray      # client ids, ascending-nu order
    nu: np.ndarray                 # per-client nu, aligned with ``client_ids``
    client_ids: np.ndarray         # all candidate ids (input order)
    nu_g: float                    # global representativeness (sum of nu)
    iota: float                    # recruitment threshold gamma_th * nu_g

    @property
    def num_recruited(self) -> int:
        return int(self.recruited_ids.size)

    @cached_property
    def _recruited_set(self) -> frozenset:
        # cached_property assigns through __dict__, so it works on the frozen
        # dataclass; built once, then membership is O(1) amortized.
        return frozenset(int(c) for c in self.recruited_ids)

    def is_recruited(self, client_id: int) -> bool:
        return int(client_id) in self._recruited_set


def _nu_against(
    counts: np.ndarray,
    n: np.ndarray,
    p_global: np.ndarray,
    config: RecruitmentConfig,
) -> np.ndarray:
    """nu_c for a (C, bins) batch of disclosures against a fixed p_global.

    The local histogram is normalized by its own mass, not the reported
    ``n``: a client whose stays are missing LoS labels (mass < n) still
    discloses a valid distribution, and the divergence term must compare
    distributions, not under-scaled ones.
    """
    mass = counts.sum(axis=1)
    p_local = counts / np.maximum(mass, 1.0)[:, None]
    divergence = np.abs(p_global[None, :] - p_local).sum(axis=1)
    return config.gamma_dv * divergence + config.gamma_sa * n ** -0.5


def representativeness(
    stats: Sequence[ClientStats],
    config: RecruitmentConfig,
) -> np.ndarray:
    """Per-client nu_c (paper eq. 4), aligned with ``stats`` order."""
    if not stats:
        raise ValueError("no candidate clients")
    counts = np.stack([np.asarray(s.counts, dtype=np.float64) for s in stats])
    n = np.array([s.n for s in stats], dtype=np.float64)
    # P_go = sum_c P_co (counts); P_go/n_g is the normalized global histogram.
    global_counts = counts.sum(axis=0)
    p_global = normalize(global_counts)
    return _nu_against(counts, n, p_global, config)


def _crossing_cutoff(cumulative: np.ndarray, iota: float, gamma_th: float) -> int:
    """Eq.-5 crossing: recruit up to and *including* the crossing client.

    ``side="left"`` finds the first prefix sum >= iota; that client is the
    crossing client, so the cutoff is its index + 1 — never one past it.  A
    relative tolerance keeps an exact mathematical tie (prefix == iota) from
    flipping to "one more client" when float rounding lands iota a ulp above
    the prefix, and ``gamma_th = 1`` short-circuits to the whole population
    so full-threshold recruitment cannot be lost to summation error.
    """
    num = int(cumulative.size)
    if num == 0:
        return 0
    if gamma_th >= 1.0:
        return num
    tol = 1e-12 * max(float(cumulative[-1]), 1.0)
    crossed = int(np.searchsorted(cumulative, iota - tol, side="left"))
    return min(crossed + 1, num)


def recruit(
    stats: Sequence[ClientStats],
    config: RecruitmentConfig = BALANCED,
) -> RecruitmentResult:
    """Greedy threshold recruitment (paper section 4.2).

    Sort nu ascending (most representative first), accumulate, and recruit
    every client up to and including the one at which the running sum crosses
    ``iota = gamma_th * nu_g``.  ``gamma_th = 1`` recruits everyone.
    """
    nu = representativeness(stats, config)
    client_ids = np.array([s.client_id for s in stats], dtype=np.int64)
    order = np.argsort(nu, kind="stable")
    nu_sorted = nu[order]
    cumulative = np.cumsum(nu_sorted)
    # nu_g accumulated in the *same* (sorted) order as the prefix sums, so
    # iota and cumulative[-1] share a rounding history and gamma_th = 1.0 is
    # exact by construction rather than hostage to summation order.
    nu_g = float(cumulative[-1])
    iota = config.gamma_th * nu_g
    cutoff = _crossing_cutoff(cumulative, iota, config.gamma_th)
    recruited = client_ids[order][:cutoff]
    return RecruitmentResult(
        recruited_ids=recruited,
        nu=nu,
        client_ids=client_ids,
        nu_g=nu_g,
        iota=iota,
    )


def recruitment_curve(
    stats: Sequence[ClientStats],
    config: RecruitmentConfig,
    gamma_ths: Sequence[float],
) -> list[tuple[float, int]]:
    """(gamma_th, num_recruited) pairs for the paper's Fig. 2 sweep."""
    out = []
    for g in gamma_ths:
        cfg = dataclasses.replace(config, gamma_th=g)
        out.append((float(g), recruit(stats, cfg).num_recruited))
    return out


# ---------------------------------------------------------------------------
# Streaming recruitment (population scale)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamingRecruitmentConfig:
    """Memory knobs for ``recruit_streaming``.

    ``exact_buffer``: populations up to this size are buffered whole and
    delegated to the exact ``recruit`` oracle — streaming and exact results
    are then identical, which covers the paper's 189-hospital scale with
    room to spare.

    ``pool_size``: above the buffer, only the ``pool_size`` lowest-nu
    candidates keep their full disclosure; everything else is folded into
    the global histogram and the nu-quantile sketch.  Size it at or above
    the number of recruits you expect — the result sets ``pool_exhausted``
    when the budget was too small to hold the crossing.

    ``sketch_bins``: resolution of the weighted nu histogram used to
    estimate where the iota threshold falls in the full population.
    """

    exact_buffer: int = 1024
    pool_size: int = 8192
    sketch_bins: int = 512

    def __post_init__(self) -> None:
        if self.exact_buffer < 0:
            raise ValueError(f"exact_buffer must be >= 0, got {self.exact_buffer}")
        if self.pool_size < 1:
            raise ValueError(f"pool_size must be >= 1, got {self.pool_size}")
        if self.sketch_bins < 2:
            raise ValueError(f"sketch_bins must be >= 2, got {self.sketch_bins}")


@dataclasses.dataclass(frozen=True)
class StreamingRecruitmentResult:
    """What a one-pass recruitment run decides and how sure it is.

    ``mode`` is ``"exact"`` when the population fit the exact buffer (the
    participant set then matches ``recruit`` verbatim) and ``"sketch"``
    otherwise, where ``num_recruited`` carries the documented tolerance:
    candidates inside the pool are re-scored exactly against the final
    global histogram, so only the iota estimate (and therefore the cutoff
    position, not the ranking) inherits sketch error.
    """

    recruited_ids: np.ndarray   # ascending-nu order (arrival order at gamma_th=1)
    recruited_nu: np.ndarray    # nu of each recruited client, same order
    nu_g: float                 # global representativeness (estimate in sketch mode)
    iota: float                 # threshold gamma_th * nu_g
    clients_seen: int
    mode: str                   # "exact" | "sketch"
    pool_exhausted: bool        # True when pool_size was too small for the cutoff
    estimated_num_recruited: int  # independent estimate from the nu-quantile sketch

    @property
    def num_recruited(self) -> int:
        return int(self.recruited_ids.size)

    @cached_property
    def _recruited_set(self) -> frozenset:
        return frozenset(int(c) for c in self.recruited_ids)

    def is_recruited(self, client_id: int) -> bool:
        return int(client_id) in self._recruited_set


class _NuSketch:
    """Fixed-grid weighted histogram of nu over (0, hi].

    Tracks per-bin client counts and nu mass; ``count_until_mass`` walks the
    bins in ascending-nu order until the accumulated mass crosses a target,
    which is exactly the eq.-5 crossing evaluated on the sketch instead of
    the sorted population.
    """

    def __init__(self, hi: float, bins: int) -> None:
        self.hi = max(float(hi), 1e-9)
        self.bins = int(bins)
        self.counts = np.zeros(self.bins, dtype=np.int64)
        self.mass = np.zeros(self.bins, dtype=np.float64)

    def add(self, nu: float) -> None:
        idx = min(int(nu / self.hi * self.bins), self.bins - 1)
        self.counts[max(idx, 0)] += 1
        self.mass[max(idx, 0)] += nu

    def count_until_mass(self, target: float) -> int:
        """Clients recruited if the cumulative-nu threshold is ``target``."""
        cum = np.cumsum(self.mass)
        if cum.size == 0 or target <= 0.0:
            return 0
        j = int(np.searchsorted(cum, target, side="left"))
        if j >= self.bins:
            return int(self.counts.sum())
        before = int(self.counts[:j].sum())
        prior = float(cum[j - 1]) if j > 0 else 0.0
        bin_mass = float(self.mass[j])
        # Linear interpolation inside the crossing bin (+1: include the
        # crossing client, mirroring _crossing_cutoff).
        frac = (target - prior) / bin_mass if bin_mass > 0 else 0.0
        return min(before + int(frac * int(self.counts[j])) + 1, int(self.counts.sum()))


class StreamingRecruiter:
    """One-pass, bounded-memory nu-greedy recruitment.

    Feed disclosures with ``observe``/``extend``; ``finalize`` returns the
    decision.  State is O(exact_buffer + pool_size + sketch_bins) regardless
    of population size — nothing is materialized or argsorted at population
    scale.  (At ``gamma_th = 1`` everyone is recruited, so the id list —
    which *is* the output — is the only per-client state kept.)

    While streaming, each client is scored provisionally against the global
    histogram of the prefix seen so far; the prefix converges to the final
    histogram at O(1/P), so late provisional scores are nearly exact and the
    pool of lowest-nu candidates is re-scored exactly at finalize time.
    """

    def __init__(
        self,
        config: RecruitmentConfig = BALANCED,
        *,
        stream: StreamingRecruitmentConfig | None = None,
    ) -> None:
        self.config = config
        self.stream = stream if stream is not None else StreamingRecruitmentConfig()
        self._buffer: list[ClientStats] | None = []
        self._clients_seen = 0
        self._seq = 0
        self._global_counts: np.ndarray | None = None
        self._nu_prov_sum = 0.0
        # Max-heap (negated nu) of the pool_size lowest provisional-nu
        # candidates: (-nu_prov, seq, client_id, counts, n).
        self._pool: list[tuple[float, int, int, np.ndarray, float]] = []
        self._pool_dropped = 0
        self._sketch: _NuSketch | None = None
        self._ids: list[int] | None = [] if config.gamma_th >= 1.0 else None
        self._result: StreamingRecruitmentResult | None = None

    # -- ingest -------------------------------------------------------------

    def observe(self, s: ClientStats) -> None:
        if self._result is not None:
            raise RuntimeError("recruiter already finalized")
        self._clients_seen += 1
        if self._buffer is not None:
            self._buffer.append(s)
            if len(self._buffer) > self.stream.exact_buffer:
                self._spill()
            return
        self._ingest(np.asarray(s.counts, dtype=np.float64), s.client_id, float(s.n))

    def extend(self, stats_iter: Iterable[ClientStats]) -> None:
        for s in stats_iter:
            self.observe(s)

    def _spill(self) -> None:
        """Buffer overflow: switch from exact mode to sketch mode."""
        buf, self._buffer = self._buffer, None
        counts = np.stack([np.asarray(b.counts, dtype=np.float64) for b in buf])
        n = np.array([b.n for b in buf], dtype=np.float64)
        self._global_counts = counts.sum(axis=0)
        nu_hi = 2.0 * self.config.gamma_dv + self.config.gamma_sa
        self._sketch = _NuSketch(nu_hi, self.stream.sketch_bins)
        # Score the whole buffer against the buffer-prefix histogram.
        nu = _nu_against(counts, n, normalize(self._global_counts), self.config)
        for b, nu_c in zip(buf, nu):
            self._record(float(nu_c), b.client_id, np.asarray(b.counts, dtype=np.float64), float(b.n))

    def _ingest(self, counts: np.ndarray, client_id: int, n: float) -> None:
        self._global_counts += counts
        p_global = normalize(self._global_counts)
        mass = max(float(counts.sum()), 1.0)
        divergence = float(np.abs(p_global - counts / mass).sum())
        nu = self.config.gamma_dv * divergence + self.config.gamma_sa * n ** -0.5
        self._record(nu, client_id, counts, n)

    def _record(self, nu: float, client_id: int, counts: np.ndarray, n: float) -> None:
        self._nu_prov_sum += nu
        self._sketch.add(nu)
        if self._ids is not None:
            self._ids.append(int(client_id))
        entry = (-nu, self._seq, int(client_id), counts, n)
        self._seq += 1
        if len(self._pool) < self.stream.pool_size:
            heapq.heappush(self._pool, entry)
        elif entry > self._pool[0]:  # lower nu than the pool's current worst
            heapq.heapreplace(self._pool, entry)
            self._pool_dropped += 1
        else:
            self._pool_dropped += 1

    # -- decide -------------------------------------------------------------

    def finalize(self) -> StreamingRecruitmentResult:
        if self._result is not None:
            return self._result
        if self._clients_seen == 0:
            raise ValueError("no candidate clients")
        if self._buffer is not None:
            res = recruit(self._buffer, self.config)
            order = np.argsort(res.nu, kind="stable")
            self._result = StreamingRecruitmentResult(
                recruited_ids=res.recruited_ids,
                recruited_nu=res.nu[order][: res.num_recruited],
                nu_g=res.nu_g,
                iota=res.iota,
                clients_seen=self._clients_seen,
                mode="exact",
                pool_exhausted=False,
                estimated_num_recruited=res.num_recruited,
            )
            return self._result
        self._result = self._finalize_sketch()
        return self._result

    def _finalize_sketch(self) -> StreamingRecruitmentResult:
        p_global = normalize(self._global_counts)
        pool = sorted(self._pool, key=lambda t: t[1])  # arrival order: stable ties
        counts = np.stack([t[3] for t in pool])
        n = np.array([t[4] for t in pool], dtype=np.float64)
        ids = np.array([t[2] for t in pool], dtype=np.int64)
        nu_final = _nu_against(counts, n, p_global, self.config)
        # Global-sum estimate: pooled candidates contribute their exact final
        # nu; only the (high-nu, never-recruited) tail keeps its provisional
        # score, whose error vanishes as the prefix histogram converges.
        prov_in_pool = sum(-t[0] for t in pool)
        nu_g = self._nu_prov_sum - prov_in_pool + float(nu_final.sum())
        iota = self.config.gamma_th * nu_g

        if self.config.gamma_th >= 1.0:
            recruited = np.array(self._ids, dtype=np.int64)
            return StreamingRecruitmentResult(
                recruited_ids=recruited,
                recruited_nu=np.full(recruited.size, np.nan),
                nu_g=nu_g,
                iota=iota,
                clients_seen=self._clients_seen,
                mode="sketch",
                pool_exhausted=False,
                estimated_num_recruited=self._clients_seen,
            )

        order = np.argsort(nu_final, kind="stable")
        cumulative = np.cumsum(nu_final[order])
        cutoff = _crossing_cutoff(cumulative, iota, self.config.gamma_th)
        tol = 1e-12 * max(float(cumulative[-1]), 1.0)
        exhausted = bool(
            self._pool_dropped > 0 and float(cumulative[-1]) < iota - tol
        )
        if exhausted:
            warnings.warn(
                f"streaming recruitment pool ({self.stream.pool_size} candidates) "
                f"filled before the iota crossing; num_recruited is truncated — "
                f"raise StreamingRecruitmentConfig.pool_size",
                stacklevel=3,
            )
        return StreamingRecruitmentResult(
            recruited_ids=ids[order][:cutoff],
            recruited_nu=nu_final[order][:cutoff],
            nu_g=nu_g,
            iota=iota,
            clients_seen=self._clients_seen,
            mode="sketch",
            pool_exhausted=exhausted,
            estimated_num_recruited=self._sketch.count_until_mass(iota),
        )


def recruit_streaming(
    stats_iter: Iterable[ClientStats] | Iterator[ClientStats],
    config: RecruitmentConfig = BALANCED,
    *,
    stream: StreamingRecruitmentConfig | None = None,
) -> StreamingRecruitmentResult:
    """One-pass bounded-memory recruitment over a disclosure stream.

    Exact-``recruit`` parity whenever the population fits
    ``stream.exact_buffer`` (default 1024 ≥ the paper's 189); above that, a
    sketch-mode decision with a tolerance contract on ``num_recruited`` —
    see ``StreamingRecruitmentResult``.
    """
    recruiter = StreamingRecruiter(config, stream=stream)
    recruiter.extend(stats_iter)
    return recruiter.finalize()
